// Calibration grid search: re-derives the Ultrascale+ timing constants in
// rust/src/fpga/device.rs from the paper anchor numbers (EXPERIMENTS.md
// §Calibration). Run after changing the cost model structure.
use loms::fpga::device::{Family, FpgaDevice, TimingParams};
use loms::fpga::{CostModel, Methodology};
use loms::sortnet::{batcher, loms as lm, s2ms};

fn main() {
    let mut best = (f64::MAX, TimingParams { t_lut: 0., t_net: 0., t_muxf: 0., t_carry8: 0., t_io: 0. });
    for t_lut in [0.06, 0.08, 0.10, 0.12] {
        for t_net in [0.20, 0.24, 0.28, 0.32, 0.36, 0.40, 0.44] {
            for t_carry8 in [0.10, 0.12, 0.14, 0.16, 0.18, 0.20, 0.22] {
                for t_muxf in [0.04, 0.06, 0.08] {
                    for t_io in [0.10, 0.20, 0.30, 0.40] {
                        let t = TimingParams { t_lut, t_net, t_muxf, t_carry8, t_io };
                        let fpga = FpgaDevice { name: "x", family: Family::UltrascalePlus, luts_available: 216_960, routable_fraction: 0.75, t };
                        let m = CostModel::new(fpga, Methodology::TwoInsLut, 32);
                        let b = m.delay_ns(&batcher::odd_even_merge(32));
                        let l = m.delay_ns(&lm::loms_2way(32, 32, 2));
                        let s = m.delay_ns(&s2ms::s2ms(32, 32));
                        let l3 = m.delay_ns(&lm::loms_kway(&[7, 7, 7]));
                        // anchors: batcher 5.89, loms 2.24 (ratio 2.63 weighted heavily), s2ms ~1.45, loms3 3.4
                        let e = ((b - 5.89) / 5.89).powi(2)
                            + 4.0 * ((l - 2.24) / 2.24).powi(2)
                            + 4.0 * ((b / l - 2.63) / 2.63).powi(2)
                            + 0.5 * ((s - 1.45) / 1.45).powi(2)
                            + ((l3 - 3.4) / 3.4).powi(2);
                        if e < best.0 {
                            best = (e, t);
                        }
                    }
                }
            }
        }
    }
    let t = best.1;
    println!("best err {:.4}: {:?}", best.0, t);
    let fpga = FpgaDevice { name: "x", family: Family::UltrascalePlus, luts_available: 216_960, routable_fraction: 0.75, t };
    let m = CostModel::new(fpga, Methodology::TwoInsLut, 32);
    println!(
        "batcher64={:.2} loms64={:.2} (speedup {:.2}) s2ms64={:.2} loms3c7r={:.2}",
        m.delay_ns(&batcher::odd_even_merge(32)),
        m.delay_ns(&lm::loms_2way(32, 32, 2)),
        m.delay_ns(&batcher::odd_even_merge(32)) / m.delay_ns(&lm::loms_2way(32, 32, 2)),
        m.delay_ns(&s2ms::s2ms(32, 32)),
        m.delay_ns(&lm::loms_kway(&[7, 7, 7]))
    );
}
