//! Bounded-memory file sort through the streaming merge engine — the
//! same library path the `loms sort --input FILE` subcommand drives:
//! write a file of random little-endian u32 keys, sort it with
//! `stream::extsort_file` (runs spilled next to the output, multi-pass
//! merge through the LOMS tile kernels), then verify the result
//! exactly against std sort.
//!
//!     cargo run --release --example sort_file [n_keys]

use loms::stream::{extsort_file, ExtSortConfig};
use loms::util::Rng;
use std::io::Write as _;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1_000_000);
    let dir = std::env::temp_dir().join(format!("loms_sort_file_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let input = dir.join("input.u32");
    let output = dir.join("sorted.u32");

    // Full u32 domain on purpose: the streaming path tracks fill by
    // count, so u32::MAX keys are legal (unlike the serving path).
    let mut rng = Rng::new(0xF17E);
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&input)?);
        for &k in &data {
            w.write_all(&k.to_le_bytes())?;
        }
        w.flush()?;
    }
    println!("wrote {} ({} keys, {} MiB)", input.display(), n, (n * 4) >> 20);

    // Small fan-in + short runs force multi-pass spilling even at
    // modest sizes, so the whole bounded-memory machinery runs.
    let cfg = ExtSortConfig {
        run_len: 1 << 15,
        max_fanin: 8,
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let t0 = Instant::now();
    let stats = extsort_file(&input, &output, &cfg)?;
    let dt = t0.elapsed();
    println!(
        "sorted in {dt:.2?} ({:.2} Mkeys/s): {} runs, {} merge passes, {:.1} MiB spilled",
        n as f64 / dt.as_secs_f64() / 1e6,
        stats.runs,
        stats.merge_passes,
        stats.spill_bytes as f64 / (1 << 20) as f64
    );

    // Verify byte-exactly.
    let got: Vec<u32> = std::fs::read(&output)?
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut want = data;
    want.sort_unstable();
    anyhow::ensure!(got == want, "output mismatch");
    println!("verified: output is the exact sorted multiset");
    let _ = std::fs::remove_dir_all(dir);
    Ok(())
}
