//! Quickstart: build the paper's UP-8/DN-8 List Offset Merge Sorter,
//! merge the Fig.-1 example lists in software, inspect the device, and
//! price it on both FPGAs with the cost model.
//!
//!     cargo run --release --example quickstart

use loms::fpga::{CostModel, Methodology, ULTRASCALE_PLUS, VERSAL_PRIME};
use loms::sortnet::exec::{merge, ExecMode};
use loms::sortnet::loms::loms_2way;
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::sortnet::validate::validate_merge_01;

fn main() -> anyhow::Result<()> {
    // The Fig.-1 device: two sorted 8-value lists, 2-column setup array.
    let device = loms_2way(8, 8, 2);
    println!("device: {} ({} stages)", device.name, device.depth());
    for (i, st) in device.stages.iter().enumerate() {
        println!("  stage {}: {} × {}", i + 1, st.blocks.len(), st.label);
    }

    // Fig. 1's example values (ascending here; the paper prints descending).
    let a = vec![1u32, 5, 6, 9, 10, 13, 14, 15];
    let b = vec![2u32, 3, 4, 7, 8, 11, 12, 16];
    let out = merge(&device, &[a.clone(), b.clone()], ExecMode::Strict)?;
    println!("merged: {out:?}");
    assert_eq!(out, (1..=16).collect::<Vec<u32>>());

    // The serving hot path lowers the device once into a flat IR and
    // reuses the plan for every row (see `loms::sortnet::plan`).
    let plan = CompiledPlan::compile(&device).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "compiled plan: {} ops over {} stages, index arena {} u32, {} values/row",
        plan.op_count(),
        plan.depth(),
        plan.arena_len(),
        plan.n()
    );
    let mut scratch = PlanScratch::new();
    let planned = plan.merge_row(&[a, b], ExecMode::Strict, &mut scratch)?;
    assert_eq!(planned, out, "plan and interpreter agree bit-for-bit");

    // Prove it correct for ALL inputs (sorted-0-1 principle, 81 patterns).
    validate_merge_01(&device).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("validated: correct for all inputs (exhaustive sorted-0-1)");

    // What would it cost on the paper's FPGAs?
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        for meth in [Methodology::TwoInsLut, Methodology::FourInsLut] {
            let m = CostModel::new(fpga, meth, 32);
            let r = m.report(&device);
            println!(
                "{:>9} {:>8}: {:.2} ns, {} LUTs, fits={}",
                fpga.name,
                meth.label(),
                r.delay_ns,
                r.luts,
                r.fits
            );
        }
    }
    Ok(())
}
