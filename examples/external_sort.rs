//! External sort through the compiled LOMS merge ladder: sort 1M
//! synthetic keys by chunking into 32-value runs and merging level by
//! level through the batched merge service (32+32 → 64 → … → 512), then
//! the final streaming k-way merge (`stream::merge_runs`). Reports
//! throughput and plan statistics, and verifies the output exactly.
//!
//!     make artifacts && cargo run --release --example external_sort [n_keys]

use loms::coordinator::{planner, MergeService, PjrtBackend, ServiceConfig, SoftwareBackend};
use loms::util::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1_000_000);
    let dir = std::path::PathBuf::from("artifacts");
    let (svc, backend) = if dir.join("manifest.json").exists() {
        (MergeService::start(move || PjrtBackend::load(dir), ServiceConfig::default())?, "pjrt")
    } else {
        eprintln!("artifacts missing — software backend (run `make artifacts`)");
        (
            MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())?,
            "software",
        )
    };

    let mut rng = Rng::new(0x5027);
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 1).collect();
    println!("backend={backend}; sorting {n} u32 keys (chunk=32, ladder to 512)...");
    let t0 = Instant::now();
    let (sorted, stats) = planner::external_sort(&svc, &data, 32, 512)?;
    let dt = t0.elapsed();

    // Verify exactly.
    let mut want = data;
    want.sort_unstable();
    assert_eq!(sorted, want, "external sort output mismatch");

    println!("sorted+verified in {dt:.2?} ({:.2} Mkeys/s)", n as f64 / dt.as_secs_f64() / 1e6);
    println!(
        "plan: {} chunks, {} network levels, {} network merges, final {}-way streaming merge",
        stats.chunks, stats.network_levels, stats.network_merges, stats.final_kway_runs
    );
    let snap = svc.metrics().snapshot();
    println!(
        "service: {} batches, padding {:.1}%, p50={:.0}µs p99={:.0}µs",
        snap.batches,
        100.0 * snap.rows_padded as f64 / (snap.rows_real + snap.rows_padded).max(1) as f64,
        snap.p50_latency_us,
        snap.p99_latency_us
    );
    svc.shutdown();
    Ok(())
}
