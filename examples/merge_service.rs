//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): start the full three-layer
//! stack — Rust coordinator → PJRT CPU client → AOT-compiled JAX/Pallas
//! merge kernels — and serve a mixed batched merge workload, reporting
//! throughput and latency percentiles. Every response is checked
//! bit-exactly against a software merge.
//!
//!     make artifacts && cargo run --release --example merge_service
//!
//! Falls back to the software backend when artifacts are missing.

use loms::coordinator::{MergeService, PjrtBackend, ServiceConfig, SoftwareBackend};
use loms::util::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000);
    let dir = std::path::PathBuf::from("artifacts");
    let (svc, backend) = if dir.join("manifest.json").exists() {
        (MergeService::start(move || PjrtBackend::load(dir), ServiceConfig::default())?, "pjrt")
    } else {
        eprintln!("artifacts missing — software backend (run `make artifacts`)");
        (
            MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())?,
            "software",
        )
    };

    let mut rng = Rng::new(0xE2E);
    println!("backend={backend}; firing {n_requests} mixed merge requests...");
    let t0 = Instant::now();
    let mut in_flight = Vec::new();
    let mut checked = 0usize;
    for i in 0..n_requests {
        // Workload mix: 60% 32+32, 20% ragged (padded routes), 20% 3-way.
        let lists = match i % 5 {
            0 | 1 | 2 => vec![rng.sorted_list(32, 1 << 22), rng.sorted_list(32, 1 << 22)],
            3 => {
                let la = rng.range(1, 33);
                let lb = rng.range(1, 33);
                vec![rng.sorted_list(la, 1 << 22), rng.sorted_list(lb, 1 << 22)]
            }
            _ => vec![
                rng.sorted_list(7, 1 << 22),
                rng.sorted_list(7, 1 << 22),
                rng.sorted_list(7, 1 << 22),
            ],
        };
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        in_flight.push((svc.submit(lists), want));
        // Bound the in-flight window like a real client.
        if in_flight.len() >= 4096 {
            for (rx, want) in in_flight.drain(..2048) {
                let resp = rx.recv()?;
                assert_eq!(resp.merged, want, "response mismatch");
                checked += 1;
            }
        }
    }
    for (rx, want) in in_flight {
        let resp = rx.recv()?;
        assert_eq!(resp.merged, want, "response mismatch");
        checked += 1;
    }
    let dt = t0.elapsed();
    let snap = svc.metrics().snapshot();
    println!("served+verified {checked} merges in {dt:.2?}");
    println!("throughput: {:.0} merges/s", checked as f64 / dt.as_secs_f64());
    println!(
        "latency: mean={:.0}µs p50={:.0}µs p99={:.0}µs",
        snap.mean_latency_us, snap.p50_latency_us, snap.p99_latency_us
    );
    println!(
        "batches={} padding={:.1}% software-served={}",
        snap.batches,
        100.0 * snap.rows_padded as f64 / (snap.rows_real + snap.rows_padded).max(1) as f64,
        snap.software_served
    );
    svc.shutdown();
    Ok(())
}
