//! NETWORKED ROUND TRIP (DESIGN.md §Network serving): start a
//! `MergeService` behind a framed-TCP `NetServer` on an ephemeral
//! port, then talk to it like an external client — ping, a one-shot
//! merge, and a pipelined burst, every response checked bit-exactly
//! against a scalar oracle.
//!
//!     cargo run --release --example net_client
//!
//! This is the whole two-process deployment (`loms serve --listen` +
//! `loms bench-net`) collapsed into one binary for a self-checking
//! demo; the wire bytes are identical.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::{NetClient, NetServer, NetServerConfig};
use loms::util::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())?;
    let server = NetServer::start("127.0.0.1:0", svc, NetServerConfig::default())?;
    let addr = server.addr();
    println!("serving on {addr}");

    let mut client = NetClient::connect(addr)?;
    client.ping()?;
    println!("ping ok");

    let resp = client.merge(&[vec![1, 3, 9], vec![2, 4]])?;
    assert_eq!(resp.merged, vec![1, 2, 3, 4, 9]);
    println!("one-shot merge served by {:?}", resp.served_by);

    // A pipelined burst: submit ahead, receive in order.
    let mut rng = Rng::new(0x7C9);
    let n = 2000usize;
    let window = 32usize;
    let mut wants: std::collections::VecDeque<Vec<u32>> = std::collections::VecDeque::new();
    let t0 = Instant::now();
    let mut checked = 0usize;
    for _ in 0..n {
        let la = rng.range(1, 33);
        let lb = rng.range(1, 33);
        let lists = vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)];
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        client.submit(&lists)?;
        wants.push_back(want);
        if wants.len() >= window {
            let resp = client.recv()?;
            assert_eq!(resp.merged, wants.pop_front().unwrap(), "response mismatch");
            checked += 1;
        }
    }
    while let Some(want) = wants.pop_front() {
        assert_eq!(client.recv()?.merged, want, "response mismatch");
        checked += 1;
    }
    let dt = t0.elapsed();
    println!(
        "pipelined {checked} merges in {dt:.2?} ({:.0} req/s over one connection)",
        checked as f64 / dt.as_secs_f64()
    );

    drop(client);
    let snap = server.service().metrics().snapshot();
    println!(
        "server: conns={} frames_in={} responses={} errors={}",
        snap.net_connections, snap.net_frames_in, snap.net_responses, snap.net_errors
    );
    server.shutdown();
    println!("drained and stopped");
    Ok(())
}
