//! Full paper reproduction report: regenerate every table and figure of
//! §VII from the frozen FPGA cost model, printing the same rows/series
//! the paper plots and saving CSVs under bench_out/.
//!
//!     cargo run --release --example fpga_report

use loms::bench::figures;

fn main() -> anyhow::Result<()> {
    for f in figures::all_figures() {
        println!("{}", f.to_table());
        let p = f.save_csv("bench_out")?;
        println!("   csv → {}\n", p.display());
    }
    println!("{}", figures::mwms_note());
    Ok(())
}
