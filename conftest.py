# Allow `pytest python/tests/` from the repo root: the test modules
# import the `compile` package that lives under python/.
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
