//! Differential tests of the streaming merge engine (hand-rolled
//! property style over `util::Rng`, like `proptest_suite.rs`): every
//! output must be byte-identical to `sort_unstable` over the
//! concatenated inputs AND to the scalar heap merge — across ragged
//! stream lengths, duplicates, empty streams, k ∈ {2, 3, 4, 8, 17},
//! block sizes, spill configurations and the full `u32` key domain.

use loms::coordinator::planner;
use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::stream::{
    boxed, extsort, extsort_with, merge_k, merge_runs, ExtSortConfig, FileRunStream, IterStream,
    MergeTree, RunFormer, SliceStream, SortedStream,
};
use loms::util::Rng;
use std::io::Write as _;

fn sorted_concat(runs: &[Vec<u32>]) -> Vec<u32> {
    let mut all: Vec<u32> = runs.concat();
    all.sort_unstable();
    all
}

/// Property: `merge_k` equals std sort AND the heap merge for every
/// (k, r) mix of ragged, duplicate-heavy, sometimes-empty streams.
#[test]
fn prop_merge_k_matches_sort_and_heap() {
    let mut rng = Rng::new(0x2024_0731);
    for &k in &[2usize, 3, 4, 8, 17] {
        for &r in &[2usize, 8, 32] {
            for case in 0..6 {
                let max = if case % 2 == 0 { 1 << 24 } else { 64 }; // dup-heavy half
                let runs: Vec<Vec<u32>> = (0..k)
                    .map(|i| {
                        // Force some empty and length-1 streams into
                        // every mix.
                        let len = match (case + i) % 5 {
                            0 => 0,
                            1 => 1,
                            _ => rng.range(2, 400),
                        };
                        rng.sorted_list(len, max)
                    })
                    .collect();
                let got = merge_runs(&runs, r).unwrap();
                assert_eq!(got, sorted_concat(&runs), "k={k} r={r} case={case}");
                // Last use consumes the runs: byte-identical to the heap.
                let heap = planner::kway_merge(runs);
                assert_eq!(got, heap, "heap differential k={k} r={r} case={case}");
            }
        }
    }
}

/// Regression (PAD-sentinel safety): the service rejects `u32::MAX`,
/// but the streaming path pads by tracked fill count, so adjacent
/// `u32::MAX - 1` / `u32::MAX` keys — including cross-stream ties —
/// must merge exactly.
#[test]
fn sentinel_adjacent_keys_merge_exactly() {
    let runs = vec![
        vec![1, u32::MAX - 1, u32::MAX - 1, u32::MAX],
        vec![u32::MAX - 1, u32::MAX, u32::MAX],
        vec![0, 2, u32::MAX],
        vec![],
        vec![u32::MAX - 1],
    ];
    for &r in &[2usize, 8, 32] {
        let got = merge_runs(&runs, r).unwrap();
        assert_eq!(got, sorted_concat(&runs), "r={r}");
        assert_eq!(got, planner::kway_merge(runs.clone()), "r={r}");
    }
    // And through the external sorter end to end.
    let mut data: Vec<u32> = runs.concat();
    data.push(u32::MAX);
    let cfg = ExtSortConfig { run_len: 3, r: 4, ..Default::default() };
    let (sorted, _) = extsort(&data, &cfg).unwrap();
    data.sort_unstable();
    assert_eq!(sorted, data);
}

/// Property: `extsort` equals std sort across run lengths, fan-in caps
/// and spill modes — including multi-pass merges.
#[test]
fn prop_extsort_matches_sort() {
    let mut rng = Rng::new(0xE5077);
    let spill_root =
        std::env::temp_dir().join(format!("loms_stream_diff_{}", std::process::id()));
    for case in 0..8 {
        let n = [0usize, 1, 7, 1000, 5003, 20_000][case % 6];
        let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
        let cfg = ExtSortConfig {
            run_len: [64usize, 333, 1024][case % 3],
            r: [4usize, 8, 32][case % 3],
            max_fanin: [2usize, 3, 64][case % 3],
            spill_dir: if case % 2 == 0 { Some(spill_root.clone()) } else { None },
            sort_threads: [1usize, 2, 0][case % 3],
            ..Default::default()
        };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want, "case {case} n={n} cfg={cfg:?}");
        assert_eq!(stats.keys, n);
        if n > 0 {
            assert_eq!(stats.runs, n.div_ceil(cfg.run_len));
        }
    }
    let _ = std::fs::remove_dir_all(spill_root);
}

/// The merge phase works in O(k·R) without materializing its input:
/// merge unbounded generators, drain a fixed prefix, watch the
/// resident working set.
#[test]
fn merge_phase_is_bounded_memory() {
    let r = 32;
    let k = 8;
    let streams: Vec<Box<dyn SortedStream>> = (0..k as u32)
        .map(|i| boxed(IterStream::new((0u32..).map(move |x| x * k as u32 + i))))
        .collect();
    let mut tree = MergeTree::new(streams, r).unwrap();
    let mut out = Vec::new();
    let mut peak = 0usize;
    while out.len() < 200_000 {
        assert!(tree.next_chunk(1024, &mut out).unwrap() > 0);
        peak = peak.max(tree.resident_keys());
    }
    // Every key 0..200k in order (the k generators partition 0..).
    assert!(out.iter().enumerate().all(|(i, &x)| x == i as u32));
    assert!(peak <= 16 * k * r, "peak working set {peak} not O(k·R)");
}

/// File-of-runs adapter: sorted windows of one spill-format file merge
/// byte-identically to the in-memory merge of the same runs.
#[test]
fn file_runs_merge_like_memory_runs() {
    let mut rng = Rng::new(0xF11E);
    let runs: Vec<Vec<u32>> =
        (0..5).map(|_| rng.sorted_list_ragged(0, 500, 1 << 30)).collect();
    let path = std::env::temp_dir()
        .join(format!("loms_stream_diff_runs_{}.u32", std::process::id()));
    let mut f = std::fs::File::create(&path).unwrap();
    for run in &runs {
        for &k in run {
            f.write_all(&k.to_le_bytes()).unwrap();
        }
    }
    drop(f);
    let mut start = 0u64;
    let mut streams: Vec<Box<dyn SortedStream>> = Vec::new();
    for run in &runs {
        streams.push(boxed(FileRunStream::open(&path, start, run.len() as u64).unwrap()));
        start += run.len() as u64;
    }
    let got = merge_k(streams, 8).unwrap();
    assert_eq!(got, merge_runs(&runs, 8).unwrap());
    assert_eq!(got, sorted_concat(&runs));
    let _ = std::fs::remove_file(path);
}

/// Run formation through the live merge service (the planner's batch
/// sorters) composed with the streaming final merge — the full
/// "batch sorters form runs, tile kernels stream the k-way" pipeline.
#[test]
fn extsort_with_ladder_run_formation() {
    let svc =
        MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
            .unwrap();
    let mut rng = Rng::new(0x1ADD);
    // Service keys must stay below the PAD sentinel.
    let data: Vec<u32> = (0..6000).map(|_| rng.next_u32() >> 1).collect();
    let cfg = ExtSortConfig { run_len: 2048, r: 32, ..Default::default() };
    let former = RunFormer::Ladder { service: &svc, chunk: 32, max_network: 512 };
    let (got, stats) = extsort_with(&data, &cfg, &former).unwrap();
    let mut want = data;
    want.sort_unstable();
    assert_eq!(got, want);
    assert_eq!(stats.runs, 3);
    assert!(svc.metrics().snapshot().responses > 0, "runs went through the service");
    svc.shutdown();
}

/// The planner's phase 3 (now the stream engine) stays byte-identical
/// to the retired heap path on service-produced runs, and the windowed
/// ladder never loses or reorders a merge.
#[test]
fn planner_reroute_is_byte_identical() {
    let svc =
        MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
            .unwrap();
    let mut rng = Rng::new(0x9E9E);
    let data: Vec<u32> = (0..30_000).map(|_| rng.next_u32() >> 2).collect();
    let (runs, _) = planner::ladder_runs(&svc, &data, 32, 256).unwrap();
    assert!(runs.len() > 1, "several surviving runs");
    assert_eq!(merge_runs(&runs, 32).unwrap(), planner::kway_merge(runs.clone()));
    let (sorted, stats) = planner::external_sort(&svc, &data, 32, 256).unwrap();
    let mut want = data;
    want.sort_unstable();
    assert_eq!(sorted, want);
    assert_eq!(stats.final_kway_runs, runs.len());
    svc.shutdown();
}

/// Composability: slice streams, an inner tree and an iterator stream
/// merged together behave like one flat sorted multiset.
#[test]
fn mixed_adapters_compose() {
    let a: Vec<u32> = (0..400).map(|x| x * 3).collect();
    let b: Vec<u32> = (0..300).map(|x| x * 5).collect();
    let c: Vec<u32> = (0..200).map(|x| x * 7).collect();
    let inner_streams: Vec<Box<dyn SortedStream + '_>> =
        vec![boxed(SliceStream::new(&a)), boxed(SliceStream::new(&b))];
    let inner = MergeTree::new(inner_streams, 8).unwrap();
    let outer: Vec<Box<dyn SortedStream + '_>> = vec![
        boxed(inner),
        boxed(SliceStream::new(&c)),
        boxed(IterStream::new((0u32..50).map(|x| x * 11))),
    ];
    let got = merge_k(outer, 8).unwrap();
    let mut want = [a, b, c].concat();
    want.extend((0u32..50).map(|x| x * 11));
    want.sort_unstable();
    assert_eq!(got, want);
}
