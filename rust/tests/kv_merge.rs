//! Key-value oracle suite: duplicate keys must carry the *right*
//! payloads through every layer of the rank-then-permute lowering —
//! backend tile execution, the merge service (tile route and software
//! fallback), the streaming engines, and the v1.1 wire. The serving
//! layers additionally promise stability — equal keys emit in
//! list-major arrival order, so the payload column equals a stable
//! `sort_by_key` of the zipped list-major concatenation — while the
//! streaming tree promises pair integrity (see [`check_pairs`]).
//!
//! Payload tags are globally unique per test, so a single swapped pair
//! anywhere in the permutation is a hard mismatch, not a coin flip.

use loms::coordinator::{Backend, MergeService, ServiceConfig, SoftwareBackend};
use loms::net::{NetClient, NetServer, NetServerConfig};
use loms::stream::{self, ExtSortConfig};
use loms::util::Rng;

/// Stable oracle: zip the list-major concatenation with its payload
/// column and stable-sort by key.
fn stable_oracle(lists: &[Vec<u32>], pays: &[u64]) -> (Vec<u32>, Vec<u64>) {
    let concat: Vec<u32> = lists.concat();
    assert_eq!(concat.len(), pays.len(), "test bug: payload column width");
    let mut pairs: Vec<(u32, u64)> = concat.into_iter().zip(pays.iter().copied()).collect();
    pairs.sort_by_key(|&(k, _)| k);
    pairs.into_iter().unzip()
}

/// Duplicate-heavy ragged lists (tiny key domain) plus a globally
/// unique payload per key: `(salt << 32) | ordinal`.
fn dup_workload(rng: &mut Rng, k: usize, max_len: usize, salt: u64) -> (Vec<Vec<u32>>, Vec<u64>) {
    let lists: Vec<Vec<u32>> =
        (0..k).map(|_| rng.sorted_list_ragged(0, max_len + 1, 7)).collect();
    let total: usize = lists.iter().map(Vec::len).sum();
    let pays: Vec<u64> = (0..total as u64).map(|t| (salt << 32) | t).collect();
    (lists, pays)
}

#[test]
fn backend_tile_kv_is_stable_for_duplicate_keys() {
    let mut backend = SoftwareBackend::default_set();
    let mut rng = Rng::new(0xCB0);
    // A full tail-heavy batch of ragged 32+32 rows on the default
    // serving artifact.
    let reqs: Vec<(Vec<Vec<u32>>, Vec<u64>)> =
        (0..37).map(|i| dup_workload(&mut rng, 2, 32, i as u64)).collect();
    let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|(l, _)| l.as_slice()).collect();
    let pay_cols: Vec<&[u64]> = reqs.iter().map(|(_, p)| p.as_slice()).collect();
    let widths: Vec<usize> = pay_cols.iter().map(|p| p.len()).collect();
    let mut out_keys: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
    let mut out_pays: Vec<Vec<u64>> = widths.iter().map(|&w| vec![0u64; w]).collect();
    {
        let mut ko: Vec<&mut [u32]> = out_keys.iter_mut().map(|v| v.as_mut_slice()).collect();
        let mut po: Vec<&mut [u64]> = out_pays.iter_mut().map(|v| v.as_mut_slice()).collect();
        backend
            .execute_direct_kv("loms2_up32_dn32_b256", &rows, &pay_cols, &mut ko, &mut po)
            .expect("kv batch");
    }
    for (r, (lists, pays)) in reqs.iter().enumerate() {
        let (want_k, want_p) = stable_oracle(lists, pays);
        assert_eq!(out_keys[r], want_k, "row {r} keys");
        assert_eq!(out_pays[r], want_p, "row {r} payloads not the stable permutation");
    }
}

#[test]
fn service_kv_is_stable_on_tile_route_and_software_fallback() {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    let mut rng = Rng::new(0x5EC);
    // Shapes chosen to hit: the 32+32 artifact route, the 3-way
    // artifact, an oversized 2-way (beyond every artifact cap → software
    // fallback), and a ragged k=8 (planner route).
    let shapes: [(usize, usize); 4] = [(2, 32), (3, 7), (2, 300), (8, 20)];
    for (i, &(k, max_len)) in shapes.iter().enumerate() {
        let (lists, pays) = dup_workload(&mut rng, k, max_len, 0x100 + i as u64);
        let (want_k, want_p) = stable_oracle(&lists, &pays);
        let resp = svc.merge_blocking_kv(lists, pays).expect("kv merge");
        assert_eq!(resp.merged, want_k, "shape {i} keys (served_by={})", resp.served_by);
        assert_eq!(
            resp.payloads.as_deref(),
            Some(want_p.as_slice()),
            "shape {i} payloads (served_by={})",
            resp.served_by
        );
    }
    // Key-only requests on the same service still answer without a
    // payload column.
    let resp = svc.merge_blocking(vec![vec![1, 5, 9], vec![2, 5, 8]]).expect("key-only merge");
    assert_eq!(resp.merged, vec![1, 2, 5, 5, 8, 9]);
    assert!(resp.payloads.is_none(), "key-only response grew a payload column");
    svc.shutdown();
}

/// Pair-integrity oracle for the streaming engines: the merge tree's
/// emit bound may release right-side ties before a left sibling's equal
/// keys (only the serving path promises global tie order), so the
/// contract here is merged keys == sorted concat AND the (key, payload)
/// pair multiset is preserved — with globally unique payloads that
/// still pins every duplicate key to exactly the payload it arrived
/// with.
fn check_pairs(got_k: &[u32], got_p: &[u64], lists: &[Vec<u32>], pays: &[u64]) {
    let mut want_k: Vec<u32> = lists.concat();
    want_k.sort_unstable();
    assert_eq!(got_k, want_k.as_slice(), "merged keys");
    assert_eq!(got_k.len(), got_p.len(), "column widths");
    let mut got_pairs: Vec<(u32, u64)> =
        got_k.iter().copied().zip(got_p.iter().copied()).collect();
    let mut want_pairs: Vec<(u32, u64)> =
        lists.concat().into_iter().zip(pays.iter().copied()).collect();
    got_pairs.sort_unstable();
    want_pairs.sort_unstable();
    assert_eq!(got_pairs, want_pairs, "(key, payload) pair multiset");
}

#[test]
fn stream_kv_engines_keep_every_duplicate_key_paired() {
    let mut rng = Rng::new(0x57AB);
    for k in [2usize, 5, 9] {
        let runs: Vec<(Vec<u32>, Vec<u64>)> = (0..k)
            .map(|i| {
                let keys = rng.sorted_list_ragged(0, 200, 11);
                let pays =
                    (0..keys.len() as u64).map(|t| ((i as u64) << 32) | t).collect();
                (keys, pays)
            })
            .collect();
        let lists: Vec<Vec<u32>> = runs.iter().map(|(k, _)| k.clone()).collect();
        let pays: Vec<u64> = runs.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        let (got_k, got_p) = stream::merge_runs_kv(&runs, 8).expect("merge_runs_kv");
        check_pairs(&got_k, &got_p, &lists, &pays);
    }
    // extsort_kv on unsorted duplicate-heavy input, forced multi-pass.
    let keys: Vec<u32> = (0..10_000).map(|_| rng.next_u32() % 64).collect();
    let pays: Vec<u64> = (0..keys.len() as u64).collect();
    let cfg = ExtSortConfig { run_len: 512, max_fanin: 4, ..Default::default() };
    let (got_k, got_p, stats) = stream::extsort_kv(&keys, &pays, &cfg).expect("extsort_kv");
    check_pairs(&got_k, &got_p, &[keys], &pays);
    assert!(stats.merge_passes >= 1, "fanin 4 over ~20 runs must multi-pass");
}

/// One server, both protocols: a v1 client flow (plain `submit`) must
/// behave exactly as before against a v1.1 server, and the KV flow must
/// round-trip payload columns over real sockets — including both frame
/// kinds interleaved on one connection.
#[test]
fn v1_and_kv_clients_round_trip_against_one_server() {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    let server = NetServer::start(
        "127.0.0.1:0",
        svc,
        NetServerConfig { workers: 4, ..NetServerConfig::default() },
    )
    .expect("server");
    let mut client = NetClient::connect(server.addr()).expect("connect");
    let mut rng = Rng::new(0xE7);

    // v1 client unchanged: key-only request, key-only response.
    let (lists, _) = dup_workload(&mut rng, 2, 32, 1);
    let mut want: Vec<u32> = lists.concat();
    want.sort_unstable();
    let resp = client.merge(&lists).expect("v1 merge");
    assert_eq!(resp.merged, want);
    assert!(resp.payloads.is_none(), "v1 response must not carry payloads");

    // KV round trip, duplicate keys, stable payload oracle.
    let (lists, pays) = dup_workload(&mut rng, 2, 32, 2);
    let (want_k, want_p) = stable_oracle(&lists, &pays);
    let resp = client.merge_kv(&lists, &pays).expect("kv merge");
    assert_eq!(resp.merged, want_k);
    assert_eq!(resp.payloads, Some(want_p), "wire payloads not the stable permutation");

    // Interleaved pipelining on one connection: v1, kv, v1, kv — FIFO
    // responses with the right shape each.
    let mut expected: Vec<(Vec<u32>, Option<Vec<u64>>)> = Vec::new();
    for i in 0..8usize {
        let (lists, pays) = dup_workload(&mut rng, 2 + i % 3, 24, 0x40 + i as u64);
        if i % 2 == 0 {
            let mut want: Vec<u32> = lists.concat();
            want.sort_unstable();
            client.submit(&lists).expect("submit v1");
            expected.push((want, None));
        } else {
            let (want_k, want_p) = stable_oracle(&lists, &pays);
            client.submit_kv(&lists, &pays).expect("submit kv");
            expected.push((want_k, Some(want_p)));
        }
    }
    for (i, (want_k, want_p)) in expected.into_iter().enumerate() {
        let resp = client.recv().expect("pipelined recv");
        assert_eq!(resp.merged, want_k, "pipelined response {i} keys");
        assert_eq!(resp.payloads, want_p, "pipelined response {i} payloads");
    }
    server.shutdown();
}
