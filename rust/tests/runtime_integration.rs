//! Integration: the full AOT bridge. Loads every artifact produced by
//! `make artifacts`, executes it on the PJRT CPU client, and checks the
//! numerics against the bit-exact software execution of the same device.
//!
//! Skips (with a message) when artifacts have not been built — CI runs
//! `make artifacts` first.

use loms::runtime::Runtime;
use loms::util::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime_or_skip() -> Option<Runtime> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(artifacts_dir()).expect("runtime load"))
}

/// Batched sorted inputs for an artifact, flattened row-major.
fn gen_inputs(sizes: &[usize], batch: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    sizes
        .iter()
        .map(|&s| {
            let mut flat = Vec::with_capacity(batch * s);
            for _ in 0..batch {
                flat.extend(rng.sorted_list(s, 1_000_000));
            }
            flat
        })
        .collect()
}

#[test]
fn every_artifact_matches_software_merge() {
    let Some(mut rt) = runtime_or_skip() else { return };
    assert_eq!(rt.platform(), "cpu");
    let names = rt.names();
    assert!(!names.is_empty());
    let mut rng = Rng::new(0xA07);
    for name in names {
        let meta = rt.executable_mut(&name).unwrap().meta.clone();
        let inputs = gen_inputs(&meta.list_sizes, meta.batch, &mut rng);
        let out = rt.executable_mut(&name).unwrap().execute_batch(&inputs).unwrap();
        // Reference: per-row std merge.
        for row in 0..meta.batch {
            let mut want: Vec<u32> = Vec::with_capacity(meta.total);
            for (l, &s) in meta.list_sizes.iter().enumerate() {
                want.extend_from_slice(&inputs[l][row * s..(row + 1) * s]);
            }
            want.sort_unstable();
            let got = &out[row * meta.total..(row + 1) * meta.total];
            assert_eq!(got, &want[..], "{name} row {row}");
        }
    }
}

#[test]
fn stats_accumulate() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let name = "loms2_up32_dn32_b256";
    let meta = rt.executable_mut(name).unwrap().meta.clone();
    let mut rng = Rng::new(1);
    let inputs = gen_inputs(&meta.list_sizes, meta.batch, &mut rng);
    for _ in 0..3 {
        rt.executable_mut(name).unwrap().execute_batch(&inputs).unwrap();
    }
    let stats = rt.executable_mut(name).unwrap().stats();
    assert_eq!(stats.executions, 3);
    assert_eq!(stats.rows_merged, 3 * meta.batch as u64);
    assert!(stats.total_exec_ns > 0);
}

#[test]
fn wrong_shape_rejected() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let exe = rt.executable_mut("loms2_up32_dn32_b256").unwrap();
    let bad = vec![vec![1u32; 10], vec![2u32; 10]];
    assert!(exe.execute_batch(&bad).is_err());
}
