//! Concurrency differential for the networked serving path: N client
//! threads × M pipelined requests against a live [`NetServer`] on an
//! ephemeral port, every response byte-exact against a scalar
//! `sort_unstable` oracle, plus [`Snapshot`] accounting under load
//! (`net_frames_in == net_responses + net_errors`) and drain-on-
//! shutdown semantics.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::{run_load, NetClient, NetServer, NetServerConfig};
use loms::util::Rng;
use std::collections::VecDeque;
use std::io::Write;
use std::time::{Duration, Instant};

fn start_server(workers: usize) -> NetServer {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    NetServer::start(
        "127.0.0.1:0",
        svc,
        NetServerConfig { workers, ..NetServerConfig::default() },
    )
    .expect("server")
}

/// A mixed workload shape: artifact-routed 2-way/3-way, ragged sizes,
/// and software-fallback shapes (lengths beyond every artifact cap).
fn mixed_lists(rng: &mut Rng, i: usize) -> Vec<Vec<u32>> {
    match i % 5 {
        0 | 1 => {
            let la = rng.range(1, 33);
            let lb = rng.range(1, 33);
            vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)]
        }
        2 => vec![
            rng.sorted_list(7, 1 << 20),
            rng.sorted_list(7, 1 << 20),
            rng.sorted_list(7, 1 << 20),
        ],
        3 => vec![rng.sorted_list(300, 1 << 20), rng.sorted_list(300, 1 << 20)],
        _ => (0..8).map(|_| rng.sorted_list_ragged(0, 20, 1 << 20)).collect(),
    }
}

#[test]
fn concurrent_pipelined_clients_match_scalar_oracle() {
    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 64;
    const WINDOW: usize = 8;
    let server = start_server(CLIENTS);
    let addr = server.addr();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            s.spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                let mut rng = Rng::new(0x5E21 + c as u64);
                let mut pending: VecDeque<Vec<u32>> = VecDeque::new();
                for i in 0..PER_CLIENT {
                    let lists = mixed_lists(&mut rng, i);
                    let mut want: Vec<u32> = lists.concat();
                    want.sort_unstable();
                    client.submit(&lists).expect("submit");
                    pending.push_back(want);
                    if pending.len() >= WINDOW {
                        let resp = client.recv().expect("recv");
                        assert_eq!(resp.merged, pending.pop_front().unwrap(), "client {c}");
                    }
                }
                while let Some(want) = pending.pop_front() {
                    assert_eq!(client.recv().expect("drain").merged, want, "client {c}");
                }
            });
        }
    });
    // Every client received every response before its thread exited,
    // so the counters are settled: one reply per frame, no errors.
    let snap = server.service().metrics().snapshot();
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(snap.net_connections, CLIENTS as u64, "{snap:?}");
    assert_eq!(snap.net_frames_in, total, "{snap:?}");
    assert_eq!(snap.net_responses, total, "{snap:?}");
    assert_eq!(snap.net_errors, 0, "{snap:?}");
    assert_eq!(snap.net_decode_errors, 0, "{snap:?}");
    assert_eq!(snap.net_frames_in, snap.net_responses + snap.net_errors);
    // The service behind the wire actually served them all.
    assert_eq!(snap.responses, total, "{snap:?}");
    server.shutdown();
}

/// The starvation regression: connections must be bounded by memory,
/// not worker threads. 64 pipelined connections against a 4-worker
/// server all make progress (under the old thread-per-connection
/// design, connection 5+ would wait for a slot forever); every
/// response stays oracle-exact.
#[test]
fn sixty_four_connections_progress_on_four_workers() {
    let server = start_server(4);
    let addr = server.addr().to_string();
    // Watchdog: run the load on a side thread so a starved server
    // fails the test with a diagnostic instead of hanging CI.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(run_load(&addr, 64, 4, 1024, 0x64C0, false));
    });
    let report = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("64-connection load starved against 4 workers")
        .expect("load");
    assert_eq!(report.ok, 1024, "{report:?}");
    assert_eq!(report.errors, 0, "{report:?}");
    assert_eq!(report.failed_conns, 0, "{:?}", report.conn_errors);
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_connections, 64, "{snap:?}");
    snap.check().expect("accounting balances under fan-out");
    server.shutdown();
}

/// Protocol v2: one connection multiplexing many logical requests —
/// ids correlate replies, which may arrive in any completion order.
#[test]
fn v2_connection_multiplexes_replies_by_id() {
    const N: usize = 64;
    let server = start_server(4);
    let mut client = NetClient::connect_v2(server.addr()).expect("connect v2");
    let mut rng = Rng::new(0xB2B2);
    let mut wants = std::collections::HashMap::new();
    for i in 0..N {
        let lists = mixed_lists(&mut rng, i);
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        let id = client.submit(&lists).expect("submit v2");
        assert!(wants.insert(id, want).is_none(), "ids unique");
    }
    for _ in 0..N {
        let resp = client.recv().expect("recv v2");
        let want = wants.remove(&resp.id).expect("each id answered exactly once");
        assert_eq!(resp.merged, want, "id {}", resp.id);
    }
    assert!(wants.is_empty());
    // Control frames ride the same framing (Pong echoes the id).
    client.ping().expect("v2 ping");
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_frames_in, (N + 1) as u64, "{snap:?}");
    assert_eq!(snap.net_responses, (N + 1) as u64, "{snap:?}");
    assert_eq!(snap.net_errors, 0, "{snap:?}");
    server.shutdown();
}

/// The shutdown-hang regression: `shutdown()` on a *saturated* server
/// — pipelined connections far over the inflight quota (reads
/// paused), none reading replies, plus connections parked mid-frame —
/// must return promptly, not block behind a full channel or an
/// unfinished frame.
#[test]
fn shutdown_returns_promptly_on_a_saturated_server() {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    let server = NetServer::start(
        "127.0.0.1:0",
        svc,
        NetServerConfig {
            workers: 2,
            max_inflight_per_conn: 4,
            write_timeout: Duration::from_secs(1),
            ..NetServerConfig::default()
        },
    )
    .expect("server");
    let addr = server.addr();
    let mut clients = Vec::new();
    for c in 0..8u64 {
        let mut client = NetClient::connect(addr).expect("connect");
        let mut rng = Rng::new(0x5A7 + c);
        for _ in 0..64 {
            let lists = vec![rng.sorted_list(8, 1 << 20), rng.sorted_list(8, 1 << 20)];
            client.submit(&lists).expect("submit");
        }
        clients.push(client);
    }
    let mut partials = Vec::new();
    for _ in 0..4 {
        let mut s = std::net::TcpStream::connect(addr).expect("connect");
        // A 100-byte frame with only 3 bytes sent — never completed.
        s.write_all(&[100, 0, 0, 0, 1, 2, 3]).expect("partial frame");
        partials.push(s);
    }
    std::thread::sleep(Duration::from_millis(50)); // let the loop ingest the mess
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        let _ = done_tx.send(());
    });
    done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("shutdown hung on a saturated server");
    drop(clients);
    drop(partials);
}

/// Stats-overflow regression over the real wire: with enough distinct
/// artifacts to push the full document past `MAX_STATS_BYTES`, the
/// server elides per-artifact detail (honestly counted) instead of
/// truncating into invalid JSON.
#[test]
fn oversized_stats_elide_artifact_detail_on_the_wire() {
    let server = start_server(2);
    let metrics = server.service().metrics();
    const ARTS: i64 = 8000;
    for i in 0..ARTS {
        let name: std::sync::Arc<str> =
            format!("synthetic_artifact_with_a_long_name_{i:05}").into();
        metrics.on_artifact_batch(&name, 1, Duration::from_micros(10));
    }
    let mut client = NetClient::connect(server.addr()).expect("connect");
    let doc = client.stats().expect("stats must still fit after eliding");
    loms::obs::expo::check_stats_doc(&doc).expect("stats grammar");
    assert_eq!(
        doc.get("artifacts_elided").and_then(loms::util::Json::as_i64),
        Some(ARTS),
        "{doc:?}"
    );
    match doc.get("artifacts") {
        Some(loms::util::Json::Obj(m)) => assert!(m.is_empty(), "detail must be elided"),
        other => panic!("missing artifacts object: {other:?}"),
    }
    server.shutdown();
}

#[test]
fn rejected_and_served_mix_accounts_exactly() {
    let server = start_server(2);
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(0xACC7);
    let (mut good, mut bad) = (0u64, 0u64);
    for i in 0..60usize {
        if i % 3 == 2 {
            // Admission-rejected payloads: unsorted, or carrying the
            // u32::MAX sentinel. Wire-valid, so they count as frames
            // and come back as typed error replies.
            let lists = if i % 2 == 0 {
                vec![vec![5, 1], vec![2, 3]]
            } else {
                vec![vec![1, u32::MAX], vec![2]]
            };
            let err = client.merge(&lists).unwrap_err().to_string();
            assert!(err.contains("REJECTED"), "{err}");
            bad += 1;
        } else {
            let lists = mixed_lists(&mut rng, i);
            let mut want: Vec<u32> = lists.concat();
            want.sort_unstable();
            assert_eq!(client.merge(&lists).unwrap().merged, want);
            good += 1;
        }
    }
    // A ping rides the same accounting (Pong counts as a response).
    client.ping().unwrap();
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_frames_in, good + bad + 1, "{snap:?}");
    assert_eq!(snap.net_responses, good + 1, "{snap:?}");
    assert_eq!(snap.net_errors, bad, "{snap:?}");
    assert_eq!(snap.net_decode_errors, 0, "{snap:?}");
    assert_eq!(snap.rejected, bad, "service-level rejections match {snap:?}");
    server.shutdown();
}

#[test]
fn shutdown_drains_written_responses() {
    const N: usize = 16;
    let server = start_server(2);
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(0xD2A1);
    let mut wants = Vec::new();
    for _ in 0..N {
        let lists = vec![rng.sorted_list(16, 1 << 20), rng.sorted_list(16, 1 << 20)];
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        client.submit(&lists).unwrap();
        wants.push(want);
    }
    // Wait until the server has *written* every reply (the client has
    // read none yet — they sit in socket buffers), then shut down.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.service().metrics().snapshot().net_responses < N as u64 {
        assert!(Instant::now() < deadline, "server never wrote the replies");
        std::thread::sleep(Duration::from_millis(5));
    }
    server.shutdown();
    // Graceful shutdown means those responses survive the close: all N
    // arrive, in order, byte-exact.
    for want in wants {
        assert_eq!(client.recv().expect("drained response").merged, want);
    }
    // After the drain the connection really is closed (ping fails on
    // write or on the EOF reply — not on in-flight accounting).
    assert!(client.ping().is_err(), "connection should be closed after the drain");
}

#[test]
fn racy_shutdown_never_panics_or_deadlocks() {
    // Shut the server down while clients are mid-burst: responses may
    // be lost to the close, but nothing panics, every client either
    // gets a valid in-order response or a clean failure, and no thread
    // deadlocks (the test completing is the assertion).
    let server = start_server(4);
    let addr = server.addr();
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let stop = &stop;
            s.spawn(move || {
                let Ok(mut client) = NetClient::connect(addr) else { return };
                let mut rng = Rng::new(0x0DD + c);
                let mut pending: VecDeque<Vec<u32>> = VecDeque::new();
                for _ in 0..200 {
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let lists = vec![rng.sorted_list(8, 1 << 20), rng.sorted_list(8, 1 << 20)];
                    let mut want: Vec<u32> = lists.concat();
                    want.sort_unstable();
                    if client.submit(&lists).is_err() {
                        return; // server gone mid-write: fine
                    }
                    pending.push_back(want);
                    if pending.len() >= 4 {
                        match client.recv() {
                            Ok(resp) => {
                                assert_eq!(resp.merged, pending.pop_front().unwrap())
                            }
                            Err(_) => return, // clean close mid-drain: fine
                        }
                    }
                }
                while let Some(want) = pending.pop_front() {
                    match client.recv() {
                        Ok(resp) => assert_eq!(resp.merged, want),
                        Err(_) => return,
                    }
                }
            });
        }
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(30));
            server.shutdown();
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
    });
}
