//! Concurrency differential for the networked serving path: N client
//! threads × M pipelined requests against a live [`NetServer`] on an
//! ephemeral port, every response byte-exact against a scalar
//! `sort_unstable` oracle, plus [`Snapshot`] accounting under load
//! (`net_frames_in == net_responses + net_errors`) and drain-on-
//! shutdown semantics.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::{NetClient, NetServer, NetServerConfig};
use loms::util::Rng;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

fn start_server(workers: usize) -> NetServer {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    NetServer::start(
        "127.0.0.1:0",
        svc,
        NetServerConfig { workers, ..NetServerConfig::default() },
    )
    .expect("server")
}

/// A mixed workload shape: artifact-routed 2-way/3-way, ragged sizes,
/// and software-fallback shapes (lengths beyond every artifact cap).
fn mixed_lists(rng: &mut Rng, i: usize) -> Vec<Vec<u32>> {
    match i % 5 {
        0 | 1 => {
            let la = rng.range(1, 33);
            let lb = rng.range(1, 33);
            vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)]
        }
        2 => vec![
            rng.sorted_list(7, 1 << 20),
            rng.sorted_list(7, 1 << 20),
            rng.sorted_list(7, 1 << 20),
        ],
        3 => vec![rng.sorted_list(300, 1 << 20), rng.sorted_list(300, 1 << 20)],
        _ => (0..8).map(|_| rng.sorted_list_ragged(0, 20, 1 << 20)).collect(),
    }
}

#[test]
fn concurrent_pipelined_clients_match_scalar_oracle() {
    const CLIENTS: usize = 6;
    const PER_CLIENT: usize = 64;
    const WINDOW: usize = 8;
    let server = start_server(CLIENTS);
    let addr = server.addr();
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            s.spawn(move || {
                let mut client = NetClient::connect(addr).expect("connect");
                let mut rng = Rng::new(0x5E21 + c as u64);
                let mut pending: VecDeque<Vec<u32>> = VecDeque::new();
                for i in 0..PER_CLIENT {
                    let lists = mixed_lists(&mut rng, i);
                    let mut want: Vec<u32> = lists.concat();
                    want.sort_unstable();
                    client.submit(&lists).expect("submit");
                    pending.push_back(want);
                    if pending.len() >= WINDOW {
                        let resp = client.recv().expect("recv");
                        assert_eq!(resp.merged, pending.pop_front().unwrap(), "client {c}");
                    }
                }
                while let Some(want) = pending.pop_front() {
                    assert_eq!(client.recv().expect("drain").merged, want, "client {c}");
                }
            });
        }
    });
    // Every client received every response before its thread exited,
    // so the counters are settled: one reply per frame, no errors.
    let snap = server.service().metrics().snapshot();
    let total = (CLIENTS * PER_CLIENT) as u64;
    assert_eq!(snap.net_connections, CLIENTS as u64, "{snap:?}");
    assert_eq!(snap.net_frames_in, total, "{snap:?}");
    assert_eq!(snap.net_responses, total, "{snap:?}");
    assert_eq!(snap.net_errors, 0, "{snap:?}");
    assert_eq!(snap.net_decode_errors, 0, "{snap:?}");
    assert_eq!(snap.net_frames_in, snap.net_responses + snap.net_errors);
    // The service behind the wire actually served them all.
    assert_eq!(snap.responses, total, "{snap:?}");
    server.shutdown();
}

#[test]
fn rejected_and_served_mix_accounts_exactly() {
    let server = start_server(2);
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(0xACC7);
    let (mut good, mut bad) = (0u64, 0u64);
    for i in 0..60usize {
        if i % 3 == 2 {
            // Admission-rejected payloads: unsorted, or carrying the
            // u32::MAX sentinel. Wire-valid, so they count as frames
            // and come back as typed error replies.
            let lists = if i % 2 == 0 {
                vec![vec![5, 1], vec![2, 3]]
            } else {
                vec![vec![1, u32::MAX], vec![2]]
            };
            let err = client.merge(&lists).unwrap_err().to_string();
            assert!(err.contains("REJECTED"), "{err}");
            bad += 1;
        } else {
            let lists = mixed_lists(&mut rng, i);
            let mut want: Vec<u32> = lists.concat();
            want.sort_unstable();
            assert_eq!(client.merge(&lists).unwrap().merged, want);
            good += 1;
        }
    }
    // A ping rides the same accounting (Pong counts as a response).
    client.ping().unwrap();
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_frames_in, good + bad + 1, "{snap:?}");
    assert_eq!(snap.net_responses, good + 1, "{snap:?}");
    assert_eq!(snap.net_errors, bad, "{snap:?}");
    assert_eq!(snap.net_decode_errors, 0, "{snap:?}");
    assert_eq!(snap.rejected, bad, "service-level rejections match {snap:?}");
    server.shutdown();
}

#[test]
fn shutdown_drains_written_responses() {
    const N: usize = 16;
    let server = start_server(2);
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(0xD2A1);
    let mut wants = Vec::new();
    for _ in 0..N {
        let lists = vec![rng.sorted_list(16, 1 << 20), rng.sorted_list(16, 1 << 20)];
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        client.submit(&lists).unwrap();
        wants.push(want);
    }
    // Wait until the server has *written* every reply (the client has
    // read none yet — they sit in socket buffers), then shut down.
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.service().metrics().snapshot().net_responses < N as u64 {
        assert!(Instant::now() < deadline, "server never wrote the replies");
        std::thread::sleep(Duration::from_millis(5));
    }
    server.shutdown();
    // Graceful shutdown means those responses survive the close: all N
    // arrive, in order, byte-exact.
    for want in wants {
        assert_eq!(client.recv().expect("drained response").merged, want);
    }
    // After the drain the connection really is closed (ping fails on
    // write or on the EOF reply — not on in-flight accounting).
    assert!(client.ping().is_err(), "connection should be closed after the drain");
}

#[test]
fn racy_shutdown_never_panics_or_deadlocks() {
    // Shut the server down while clients are mid-burst: responses may
    // be lost to the close, but nothing panics, every client either
    // gets a valid in-order response or a clean failure, and no thread
    // deadlocks (the test completing is the assertion).
    let server = start_server(4);
    let addr = server.addr();
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|s| {
        for c in 0..4u64 {
            let stop = &stop;
            s.spawn(move || {
                let Ok(mut client) = NetClient::connect(addr) else { return };
                let mut rng = Rng::new(0x0DD + c);
                let mut pending: VecDeque<Vec<u32>> = VecDeque::new();
                for _ in 0..200 {
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                    let lists = vec![rng.sorted_list(8, 1 << 20), rng.sorted_list(8, 1 << 20)];
                    let mut want: Vec<u32> = lists.concat();
                    want.sort_unstable();
                    if client.submit(&lists).is_err() {
                        return; // server gone mid-write: fine
                    }
                    pending.push_back(want);
                    if pending.len() >= 4 {
                        match client.recv() {
                            Ok(resp) => {
                                assert_eq!(resp.merged, pending.pop_front().unwrap())
                            }
                            Err(_) => return, // clean close mid-drain: fine
                        }
                    }
                }
                while let Some(want) = pending.pop_front() {
                    match client.recv() {
                        Ok(resp) => assert_eq!(resp.merged, want),
                        Err(_) => return,
                    }
                }
            });
        }
        s.spawn(|| {
            std::thread::sleep(Duration::from_millis(30));
            server.shutdown();
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
    });
}
