//! End-to-end coordinator integration over the real PJRT backend:
//! requests → router → dynamic batcher → compiled HLO → responses.
//! Skips when artifacts are absent (`make artifacts`).

use loms::coordinator::{MergeService, PjrtBackend, ServiceConfig};
use loms::util::Rng;
use std::time::Duration;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn service_or_skip() -> Option<MergeService> {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    let dir = artifacts_dir();
    Some(
        MergeService::start(
            move || PjrtBackend::load(dir),
            ServiceConfig { max_wait: Duration::from_millis(2), ..ServiceConfig::default() },
        )
        .expect("service start"),
    )
}

#[test]
fn pjrt_service_end_to_end() {
    let Some(s) = service_or_skip() else { return };
    let mut rng = Rng::new(0xE2E);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..300u32 {
        // Mix of shapes: exact artifact shapes, padded shapes, 3-way.
        let lists: Vec<Vec<u32>> = match i % 4 {
            0 => vec![rng.sorted_list(32, 1 << 20), rng.sorted_list(32, 1 << 20)],
            1 => vec![rng.sorted_list(20, 1 << 20), rng.sorted_list(9, 1 << 20)],
            2 => vec![rng.sorted_list(64, 1 << 20), rng.sorted_list(64, 1 << 20)],
            _ => vec![
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
            ],
        };
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        wants.push(want);
        rxs.push(s.submit(lists));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.merged, want);
        assert_ne!(&*resp.served_by, "software", "these shapes all route to artifacts");
    }
    let snap = s.metrics().snapshot();
    assert_eq!(snap.responses, 300);
    assert!(snap.batches > 0 && snap.batches < 300, "dynamic batching engaged: {snap:?}");
    s.shutdown();
}

#[test]
fn pjrt_service_latency_accounting() {
    let Some(s) = service_or_skip() else { return };
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let resp = s
            .merge_blocking(vec![rng.sorted_list(32, 1000), rng.sorted_list(32, 1000)])
            .unwrap();
        assert!(resp.latency_ns > 0);
    }
    let snap = s.metrics().snapshot();
    assert!(snap.mean_latency_us > 0.0);
    assert!(snap.p99_latency_us >= snap.p50_latency_us);
}

#[test]
fn pjrt_external_sort_end_to_end() {
    let Some(s) = service_or_skip() else { return };
    let mut rng = Rng::new(42);
    let data: Vec<u32> = (0..20_000).map(|_| rng.next_u32() >> 3).collect();
    let (sorted, stats) = loms::coordinator::planner::external_sort(&s, &data, 32, 512).unwrap();
    let mut want = data;
    want.sort_unstable();
    assert_eq!(sorted, want);
    assert!(stats.network_levels >= 4, "{stats:?}");
}
