//! Exhaustive device validation sweeps — the headline correctness
//! guarantee: every characterized device in the paper's study is proven
//! correct for ALL inputs via the sorted-0-1 principle (strict hardware
//! semantics, preconditions checked).

use loms::sortnet::loms::{loms_2way, loms_3way_median, loms_kway, table1_stage_count};
use loms::sortnet::mwms::{mwms_3way, mwms_3way_median};
use loms::sortnet::validate::{validate_median_01, validate_merge_01, validate_merge_random};
use loms::sortnet::{batcher, s2ms};

/// Every cell of the paper's Fig.-10 matrix (S2MS device sizes used in
/// S2MS/LOMS sorters, 4..256 outputs).
#[test]
fn fig10_matrix_devices_all_validate() {
    // S2MS row: 4..=128 outputs (256-out S2MS exists structurally even
    // though it never fits an FPGA — validation is about function).
    for m in [2usize, 4, 8, 16, 32, 64] {
        validate_merge_01(&s2ms::s2ms(m, m)).unwrap();
    }
    // LOMS rows: (outputs, cols) per Fig. 10.
    for (outs, cols) in [
        (8usize, 2usize),
        (16, 2),
        (16, 4),
        (32, 2),
        (32, 4),
        (32, 8),
        (64, 2),
        (64, 4),
        (64, 8),
        (128, 2),
        (128, 4),
        (128, 8),
        (256, 2),
        (256, 4),
        (256, 8),
    ] {
        let d = loms_2way(outs / 2, outs / 2, cols);
        assert_eq!(d.depth(), 2, "{}", d.name);
        validate_merge_01(&d).unwrap_or_else(|e| panic!("{e}"));
    }
}

/// Batcher baselines across the full studied range.
#[test]
fn batcher_baselines_validate() {
    for m in [2usize, 4, 8, 16, 32, 64, 128] {
        validate_merge_01(&batcher::odd_even_merge(m)).unwrap();
        validate_merge_01(&batcher::bitonic_merge(m)).unwrap();
    }
}

/// Mixed/odd list sizes — the versatility claim (§VIII): any mixture,
/// no power-of-2 restriction.
#[test]
fn loms_versatility_sweep() {
    for m in 1..=12usize {
        for n in 1..=12usize {
            for cols in [2usize, 3, 4] {
                let d = loms_2way(m, n, cols);
                validate_merge_01(&d)
                    .unwrap_or_else(|e| panic!("UP-{m}/DN-{n} {cols}col: {e}"));
            }
        }
    }
}

/// 3-way devices: LOMS (3 stages + 2-stage median) and the MWMS
/// baseline reconstruction, across list sizes.
#[test]
fn three_way_devices_validate() {
    for r in [1usize, 3, 5, 7, 9] {
        let d = loms_kway(&[r, r, r]);
        validate_merge_01(&d).unwrap_or_else(|e| panic!("{e}"));
        if r >= 3 {
            validate_median_01(&loms_3way_median(r)).unwrap_or_else(|e| panic!("{e}"));
        }
    }
    for r in [3usize, 5, 7] {
        validate_merge_01(&mwms_3way(r)).unwrap();
        validate_median_01(&mwms_3way_median(r)).unwrap();
    }
}

/// k-way merges up to k=8 validate within the Table-1 stage budget.
#[test]
fn kway_table1_budget_holds() {
    for k in 3..=8usize {
        for r in [2usize, 3, 4] {
            let d = loms_kway(&vec![r; k]);
            validate_merge_01(&d).unwrap_or_else(|e| panic!("k={k} r={r}: {e}"));
            assert!(
                d.depth() <= table1_stage_count(k),
                "k={k} r={r}: depth {} > table1 {}",
                d.depth(),
                table1_stage_count(k)
            );
        }
    }
}

/// Random differential check on the largest devices (value routing, not
/// just 0-1 order).
#[test]
fn large_devices_random_differential() {
    validate_merge_random(&loms_2way(128, 128, 8), 20, 1).unwrap();
    validate_merge_random(&loms_2way(64, 64, 2), 20, 2).unwrap();
    validate_merge_random(&s2ms::s2ms(64, 64), 20, 3).unwrap();
    validate_merge_random(&loms_kway(&[9, 9, 9, 9, 9]), 20, 4).unwrap();
}
