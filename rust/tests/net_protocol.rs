//! Protocol robustness suite: frame round-trips over the real wire and
//! a deterministic-seed malformed-frame fuzzer against a live
//! [`NetServer`]. The server-side contract under test: **no byte
//! sequence a client can send panics the server** — every malformed
//! frame is answered with an Error frame on the same connection (or a
//! clean close when the stream cannot be resynced), and the server
//! keeps serving fresh connections afterwards.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::protocol::{
    self, code, encode_merge_request, Frame, FrameReader, ReadFrame, MAX_FRAME_BYTES, MAX_K,
    MAX_LIST_LEN, MODE_MERGE, PROTOCOL_VERSION,
};
use loms::net::{NetClient, NetServer, NetServerConfig};
use loms::util::Rng;
use std::io::{Cursor, Write};
use std::net::TcpStream;
use std::time::Duration;

fn start_server() -> NetServer {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    NetServer::start("127.0.0.1:0", svc, NetServerConfig::default()).expect("server")
}

/// Pure codec round-trip (no socket): encode → FrameReader → equal.
/// (`read_frame` does one read per call, yielding `Pending` while a
/// multi-chunk frame is still arriving — loop like a real consumer.)
fn codec_roundtrip(f: &Frame) {
    let mut bytes = Vec::new();
    protocol::encode_frame(f, &mut bytes);
    let mut rd = FrameReader::new();
    let mut cur = Cursor::new(bytes);
    loop {
        match rd.read_frame(&mut cur).unwrap() {
            ReadFrame::Pending => continue,
            ReadFrame::Frame(g) => {
                assert_eq!(&g, f);
                return;
            }
            other => panic!("{f:?} decoded to {other:?}"),
        }
    }
}

#[test]
fn codec_round_trips_extreme_shapes() {
    // Ragged k, empty lists, a max-length list, keys including
    // u32::MAX (legal on the wire — the *service* rejects the
    // sentinel, the protocol does not).
    let ragged: Vec<Vec<u32>> = (0..7).map(|l| (0..l * 3).map(|x| x as u32).collect()).collect();
    codec_roundtrip(&Frame::MergeRequest { mode: MODE_MERGE, trace: 0, lists: ragged });
    codec_roundtrip(&Frame::MergeRequest {
        mode: MODE_MERGE,
        trace: 0,
        lists: vec![vec![], vec![0, 1, u32::MAX - 1, u32::MAX], vec![]],
    });
    codec_roundtrip(&Frame::MergeRequest {
        mode: MODE_MERGE,
        trace: u64::MAX,
        lists: vec![(0..MAX_LIST_LEN as u32).collect()],
    });
    codec_roundtrip(&Frame::MergeResponse {
        served_by: "loms2_up32_dn32_b256".into(),
        merged: vec![0, u32::MAX],
    });
    codec_roundtrip(&Frame::Error { code: code::MALFORMED, message: "truncated payload".into() });
    codec_roundtrip(&Frame::Ping);
    codec_roundtrip(&Frame::Pong);
}

#[test]
fn wire_round_trips_ragged_and_empty_and_max() {
    let server = start_server();
    let mut client = NetClient::connect(server.addr()).unwrap();
    client.ping().unwrap();
    // Ragged k ∈ {1, 2, 3}, including empty lists.
    for lists in [
        vec![vec![5u32, 9, 9]],
        vec![vec![], vec![1, 2, 3]],
        vec![vec![1, 4, 7], vec![2, 5], vec![3]],
        vec![vec![], vec![], vec![]],
    ] {
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        let resp = client.merge(&lists).unwrap();
        assert_eq!(resp.merged, want, "{lists:?}");
    }
    // A max-length list (k = 1 routes to the software fallback).
    let big: Vec<u32> = (0..MAX_LIST_LEN as u32).collect();
    let resp = client.merge(std::slice::from_ref(&big)).unwrap();
    assert_eq!(resp.merged, big);
    assert_eq!(resp.served_by, "software");
    // u32::MAX keys: protocol-legal, service-rejected — the reply is a
    // typed REJECTED error, not a disconnect, and the connection still
    // serves afterwards.
    let err = client.merge(&[vec![1, u32::MAX], vec![2]]).unwrap_err().to_string();
    assert!(err.contains("REJECTED"), "{err}");
    let resp = client.merge(&[vec![1, u32::MAX - 1], vec![2]]).unwrap();
    assert_eq!(resp.merged, vec![1, 2, u32::MAX - 1]);
    server.shutdown();
}

#[test]
fn oversized_request_shapes_rejected_client_side() {
    let server = start_server();
    let mut client = NetClient::connect(server.addr()).unwrap();
    assert!(client.submit(&[]).is_err());
    assert!(client.submit(&vec![vec![1u32]; MAX_K + 1]).is_err());
    assert!(client.submit(&[vec![0u32; MAX_LIST_LEN + 1]]).is_err());
    // Per-list-legal but over the total payload cap (8 × 4 MiB keys).
    assert!(client.submit(&vec![vec![0u32; MAX_LIST_LEN]; 8]).is_err());
    // The connection is untouched by local validation failures.
    client.ping().unwrap();
    server.shutdown();
}

/// Read the first reply frame, if any arrives within the deadline. A
/// server frame that fails to decode is itself a bug (the server
/// never sends garbage) and panics; timeout, EOF and resets return
/// `None`.
fn read_first_reply(stream: &mut TcpStream) -> Option<Frame> {
    stream.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_millis(450);
    let mut rd = FrameReader::new();
    loop {
        match rd.read_frame(stream) {
            Ok(ReadFrame::Frame(f)) => return Some(f),
            Ok(ReadFrame::Pending) => {
                if std::time::Instant::now() >= deadline {
                    return None; // trickle with no complete frame
                }
            }
            Ok(ReadFrame::Eof) => return None,
            Ok(other) => panic!("server sent undecodable bytes: {other:?}"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Keep waiting until the deadline: a loaded CI runner
                // may take more than one read-timeout tick to schedule
                // the server's reply.
                if std::time::Instant::now() >= deadline {
                    return None;
                }
            }
            Err(_) => return None, // reset mid-frame: treated as close
        }
    }
}

/// A valid request frame as raw bytes.
fn valid_request_bytes(rng: &mut Rng) -> Vec<u8> {
    let k = rng.range(1, 4);
    let lists: Vec<Vec<u32>> = (0..k).map(|_| rng.sorted_list_ragged(0, 40, 1 << 20)).collect();
    let mut out = Vec::new();
    encode_merge_request(MODE_MERGE, 0, &lists, &mut out);
    out
}

#[test]
fn malformed_frame_fuzzer_never_panics_the_server() {
    let server = start_server();
    let addr = server.addr();
    let mut rng = Rng::new(0xF422); // deterministic: failures reproduce
    for case in 0..120 {
        let mut bytes = valid_request_bytes(&mut rng);
        // Mutation categories from the issue list: truncated frames,
        // oversized length prefixes, wrong version, unknown type,
        // shape-limit violations, mid-frame disconnects, random flips.
        let expect_error_reply = match case % 8 {
            0 => {
                // Truncate mid-frame and disconnect.
                let cut = rng.range(1, bytes.len());
                bytes.truncate(cut);
                false
            }
            1 => {
                // Oversized length prefix: unrecoverable corruption.
                let len = (MAX_FRAME_BYTES as u32) + 1 + rng.below(1 << 20) as u32;
                bytes[..4].copy_from_slice(&len.to_le_bytes());
                true
            }
            2 => {
                bytes[4] = PROTOCOL_VERSION.wrapping_add(1 + rng.below(200) as u8);
                true
            }
            3 => {
                bytes[5] = 100 + rng.below(100) as u8; // unknown frame type
                true
            }
            4 => {
                // k = 0 or k > MAX_K.
                let k: u16 = if rng.below(2) == 0 { 0 } else { (MAX_K + 1) as u16 };
                bytes[7..9].copy_from_slice(&k.to_le_bytes());
                true
            }
            5 => {
                // First list length beyond MAX_LIST_LEN.
                let n = (MAX_LIST_LEN as u32) + 1 + rng.below(1000) as u32;
                bytes[9..13].copy_from_slice(&n.to_le_bytes());
                true
            }
            6 => {
                // Shrink the length prefix under the real body: the
                // remainder desyncs into garbage "frames".
                let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
                let len = len.saturating_sub(1 + rng.below(8) as u32).max(2);
                bytes[..4].copy_from_slice(&len.to_le_bytes());
                false // replies depend on how the tail re-parses
            }
            _ => {
                // Random single-byte flip anywhere (may stay valid).
                let i = rng.range(0, bytes.len());
                bytes[i] ^= 1 << rng.below(8);
                false
            }
        };
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(&bytes).expect("write mutated frame");
        let reply = read_first_reply(&mut stream);
        // Whatever came back decodes, and is only ever a response or
        // an error — the server never relays garbage.
        if let Some(f) = &reply {
            assert!(
                matches!(f, Frame::MergeResponse { .. } | Frame::Error { .. }),
                "case {case}: unexpected reply {f:?}"
            );
        }
        if expect_error_reply {
            assert!(
                matches!(reply, Some(Frame::Error { .. })),
                "case {case}: expected an Error reply, got {reply:?}"
            );
        }
        drop(stream);
        // The server must still be alive and correct: a fresh, valid
        // round trip after every mutation.
        if case % 10 == 9 {
            let mut probe = NetClient::connect(addr).expect("server died");
            let resp = probe.merge(&[vec![1, 3], vec![2, 4]]).expect("server unhealthy");
            assert_eq!(resp.merged, vec![1, 2, 3, 4]);
        }
    }
    // Final health check + the decode-error counter actually moved.
    let mut probe = NetClient::connect(addr).unwrap();
    probe.ping().unwrap();
    assert_eq!(probe.merge(&[vec![9], vec![1]]).unwrap().merged, vec![1, 9]);
    let snap = server.service().metrics().snapshot();
    assert!(snap.net_decode_errors > 0, "fuzzer produced no decode errors? {snap:?}");
    server.shutdown();
}

#[test]
fn mid_frame_disconnect_storm_leaves_server_healthy() {
    let server = start_server();
    let addr = server.addr();
    let mut rng = Rng::new(0xD15C);
    for _ in 0..20 {
        let bytes = valid_request_bytes(&mut rng);
        let cut = rng.range(1, bytes.len());
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&bytes[..cut]).unwrap();
        drop(stream); // vanish mid-frame
    }
    let mut probe = NetClient::connect(addr).unwrap();
    assert_eq!(probe.merge(&[vec![2, 4], vec![1, 3]]).unwrap().merged, vec![1, 2, 3, 4]);
    // Partial frames never count as received, so the account still
    // balances: every counted frame got exactly one reply.
    drop(probe);
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_frames_in, snap.net_responses + snap.net_errors, "{snap:?}");
    server.shutdown();
}
