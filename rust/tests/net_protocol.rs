//! Protocol robustness suite: frame round-trips over the real wire and
//! a deterministic-seed malformed-frame fuzzer against a live
//! [`NetServer`]. The server-side contract under test: **no byte
//! sequence a client can send panics the server** — every malformed
//! frame is answered with an Error frame on the same connection (or a
//! clean close when the stream cannot be resynced), and the server
//! keeps serving fresh connections afterwards.

use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
use loms::net::protocol::{
    self, code, encode_merge_request, encode_merge_request_v2, encode_merge_response_v2, Frame,
    FrameReader, ReadFrame, MAX_FRAME_BYTES, MAX_K, MAX_LIST_LEN, MODE_MERGE, PROTOCOL_VERSION,
};
use loms::net::{NetClient, NetServer, NetServerConfig};
use loms::util::Rng;
use std::io::{Cursor, Write};
use std::net::TcpStream;
use std::time::Duration;

fn start_server() -> NetServer {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    NetServer::start("127.0.0.1:0", svc, NetServerConfig::default()).expect("server")
}

/// Pure codec round-trip (no socket): encode → FrameReader → equal.
/// (`read_frame` does one read per call, yielding `Pending` while a
/// multi-chunk frame is still arriving — loop like a real consumer.)
fn codec_roundtrip(f: &Frame) {
    let mut bytes = Vec::new();
    protocol::encode_frame(f, &mut bytes);
    let mut rd = FrameReader::new();
    let mut cur = Cursor::new(bytes);
    loop {
        match rd.read_frame(&mut cur).unwrap() {
            ReadFrame::Pending => continue,
            ReadFrame::Frame(g) => {
                assert_eq!(&g, f);
                return;
            }
            other => panic!("{f:?} decoded to {other:?}"),
        }
    }
}

#[test]
fn codec_round_trips_extreme_shapes() {
    // Ragged k, empty lists, a max-length list, keys including
    // u32::MAX (legal on the wire — the *service* rejects the
    // sentinel, the protocol does not).
    let ragged: Vec<Vec<u32>> = (0..7).map(|l| (0..l * 3).map(|x| x as u32).collect()).collect();
    codec_roundtrip(&Frame::MergeRequest { mode: MODE_MERGE, trace: 0, lists: ragged });
    codec_roundtrip(&Frame::MergeRequest {
        mode: MODE_MERGE,
        trace: 0,
        lists: vec![vec![], vec![0, 1, u32::MAX - 1, u32::MAX], vec![]],
    });
    codec_roundtrip(&Frame::MergeRequest {
        mode: MODE_MERGE,
        trace: u64::MAX,
        lists: vec![(0..MAX_LIST_LEN as u32).collect()],
    });
    codec_roundtrip(&Frame::MergeResponse {
        served_by: "loms2_up32_dn32_b256".into(),
        merged: vec![0, u32::MAX],
    });
    codec_roundtrip(&Frame::Error { code: code::MALFORMED, message: "truncated payload".into() });
    codec_roundtrip(&Frame::Ping);
    codec_roundtrip(&Frame::Pong);
}

#[test]
fn wire_round_trips_ragged_and_empty_and_max() {
    let server = start_server();
    let mut client = NetClient::connect(server.addr()).unwrap();
    client.ping().unwrap();
    // Ragged k ∈ {1, 2, 3}, including empty lists.
    for lists in [
        vec![vec![5u32, 9, 9]],
        vec![vec![], vec![1, 2, 3]],
        vec![vec![1, 4, 7], vec![2, 5], vec![3]],
        vec![vec![], vec![], vec![]],
    ] {
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        let resp = client.merge(&lists).unwrap();
        assert_eq!(resp.merged, want, "{lists:?}");
    }
    // A max-length list (k = 1 routes to the software fallback).
    let big: Vec<u32> = (0..MAX_LIST_LEN as u32).collect();
    let resp = client.merge(std::slice::from_ref(&big)).unwrap();
    assert_eq!(resp.merged, big);
    assert_eq!(resp.served_by, "software");
    // u32::MAX keys: protocol-legal, service-rejected — the reply is a
    // typed REJECTED error, not a disconnect, and the connection still
    // serves afterwards.
    let err = client.merge(&[vec![1, u32::MAX], vec![2]]).unwrap_err().to_string();
    assert!(err.contains("REJECTED"), "{err}");
    let resp = client.merge(&[vec![1, u32::MAX - 1], vec![2]]).unwrap();
    assert_eq!(resp.merged, vec![1, 2, u32::MAX - 1]);
    server.shutdown();
}

#[test]
fn oversized_request_shapes_rejected_client_side() {
    let server = start_server();
    let mut client = NetClient::connect(server.addr()).unwrap();
    assert!(client.submit(&[]).is_err());
    assert!(client.submit(&vec![vec![1u32]; MAX_K + 1]).is_err());
    assert!(client.submit(&[vec![0u32; MAX_LIST_LEN + 1]]).is_err());
    // Per-list-legal but over the total payload cap (8 × 4 MiB keys).
    assert!(client.submit(&vec![vec![0u32; MAX_LIST_LEN]; 8]).is_err());
    // The connection is untouched by local validation failures.
    client.ping().unwrap();
    server.shutdown();
}

/// Read the first reply frame, if any arrives within the deadline. A
/// server frame that fails to decode is itself a bug (the server
/// never sends garbage) and panics; timeout, EOF and resets return
/// `None`.
fn read_first_reply(stream: &mut TcpStream) -> Option<Frame> {
    stream.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_millis(450);
    let mut rd = FrameReader::new();
    loop {
        match rd.read_frame(stream) {
            Ok(ReadFrame::Frame(f)) => return Some(f),
            Ok(ReadFrame::Pending) => {
                if std::time::Instant::now() >= deadline {
                    return None; // trickle with no complete frame
                }
            }
            Ok(ReadFrame::Eof) => return None,
            Ok(other) => panic!("server sent undecodable bytes: {other:?}"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Keep waiting until the deadline: a loaded CI runner
                // may take more than one read-timeout tick to schedule
                // the server's reply.
                if std::time::Instant::now() >= deadline {
                    return None;
                }
            }
            Err(_) => return None, // reset mid-frame: treated as close
        }
    }
}

/// A valid request frame as raw bytes.
fn valid_request_bytes(rng: &mut Rng) -> Vec<u8> {
    let k = rng.range(1, 4);
    let lists: Vec<Vec<u32>> = (0..k).map(|_| rng.sorted_list_ragged(0, 40, 1 << 20)).collect();
    let mut out = Vec::new();
    encode_merge_request(MODE_MERGE, 0, &lists, &mut out);
    out
}

#[test]
fn malformed_frame_fuzzer_never_panics_the_server() {
    let server = start_server();
    let addr = server.addr();
    let mut rng = Rng::new(0xF422); // deterministic: failures reproduce
    for case in 0..120 {
        let mut bytes = valid_request_bytes(&mut rng);
        // Mutation categories from the issue list: truncated frames,
        // oversized length prefixes, wrong version, unknown type,
        // shape-limit violations, mid-frame disconnects, random flips.
        let expect_error_reply = match case % 8 {
            0 => {
                // Truncate mid-frame and disconnect.
                let cut = rng.range(1, bytes.len());
                bytes.truncate(cut);
                false
            }
            1 => {
                // Oversized length prefix: unrecoverable corruption.
                let len = (MAX_FRAME_BYTES as u32) + 1 + rng.below(1 << 20) as u32;
                bytes[..4].copy_from_slice(&len.to_le_bytes());
                true
            }
            2 => {
                // Unknown version: skip past PROTOCOL_V2 (= v1 + 1),
                // which is a *valid* framing, to 3..=201.
                bytes[4] = PROTOCOL_VERSION.wrapping_add(2 + rng.below(199) as u8);
                true
            }
            3 => {
                bytes[5] = 100 + rng.below(100) as u8; // unknown frame type
                true
            }
            4 => {
                // k = 0 or k > MAX_K.
                let k: u16 = if rng.below(2) == 0 { 0 } else { (MAX_K + 1) as u16 };
                bytes[7..9].copy_from_slice(&k.to_le_bytes());
                true
            }
            5 => {
                // First list length beyond MAX_LIST_LEN.
                let n = (MAX_LIST_LEN as u32) + 1 + rng.below(1000) as u32;
                bytes[9..13].copy_from_slice(&n.to_le_bytes());
                true
            }
            6 => {
                // Shrink the length prefix under the real body: the
                // remainder desyncs into garbage "frames".
                let len = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
                let len = len.saturating_sub(1 + rng.below(8) as u32).max(2);
                bytes[..4].copy_from_slice(&len.to_le_bytes());
                false // replies depend on how the tail re-parses
            }
            _ => {
                // Random single-byte flip anywhere (may stay valid).
                let i = rng.range(0, bytes.len());
                bytes[i] ^= 1 << rng.below(8);
                false
            }
        };
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.write_all(&bytes).expect("write mutated frame");
        let reply = read_first_reply(&mut stream);
        // Whatever came back decodes, and is only ever a response or
        // an error — the server never relays garbage.
        if let Some(f) = &reply {
            assert!(
                matches!(f, Frame::MergeResponse { .. } | Frame::Error { .. }),
                "case {case}: unexpected reply {f:?}"
            );
        }
        if expect_error_reply {
            assert!(
                matches!(reply, Some(Frame::Error { .. })),
                "case {case}: expected an Error reply, got {reply:?}"
            );
        }
        drop(stream);
        // The server must still be alive and correct: a fresh, valid
        // round trip after every mutation.
        if case % 10 == 9 {
            let mut probe = NetClient::connect(addr).expect("server died");
            let resp = probe.merge(&[vec![1, 3], vec![2, 4]]).expect("server unhealthy");
            assert_eq!(resp.merged, vec![1, 2, 3, 4]);
        }
    }
    // Final health check + the decode-error counter actually moved.
    let mut probe = NetClient::connect(addr).unwrap();
    probe.ping().unwrap();
    assert_eq!(probe.merge(&[vec![9], vec![1]]).unwrap().merged, vec![1, 9]);
    let snap = server.service().metrics().snapshot();
    assert!(snap.net_decode_errors > 0, "fuzzer produced no decode errors? {snap:?}");
    server.shutdown();
}

/// Read the next frame (either framing) within a deadline, returning
/// the v2 request id when present. Panics on undecodable server bytes.
fn read_reply_any(stream: &mut TcpStream) -> Option<(Frame, Option<u64>)> {
    stream.set_read_timeout(Some(Duration::from_millis(150))).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut rd = FrameReader::new();
    loop {
        match rd.read_frame(stream) {
            Ok(ReadFrame::Frame(f)) => return Some((f, None)),
            Ok(ReadFrame::FrameV2(f, id)) => return Some((f, Some(id))),
            Ok(ReadFrame::Pending) => {}
            Ok(ReadFrame::Eof) => return None,
            Ok(other) => panic!("server sent undecodable bytes: {other:?}"),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            Err(_) => return None,
        }
        if std::time::Instant::now() >= deadline {
            return None;
        }
    }
}

/// Fuzzer leg for v2 ids: a duplicate in-flight id is answered with a
/// typed MALFORMED error *echoing the id*, the original request still
/// completes, the connection survives, and the id becomes reusable
/// once its reply has been released.
#[test]
fn duplicate_inflight_v2_id_is_a_typed_error_not_a_disconnect() {
    let server = start_server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    // Both same-id frames in ONE write so the server decodes them in
    // one read pump — the duplicate is guaranteed to still be in
    // flight when the second frame arrives.
    let mut bytes = Vec::new();
    encode_merge_request_v2(7, MODE_MERGE, 0, &[vec![1, 3], vec![2, 4]], &mut bytes);
    encode_merge_request_v2(7, MODE_MERGE, 0, &[vec![5], vec![6]], &mut bytes);
    stream.write_all(&bytes).unwrap();
    // Two replies, in either order (the error is synchronous on the
    // event loop; the merge completes on a worker): one MergeResponse
    // for the original, one MALFORMED error for the duplicate — both
    // echoing id 7.
    let (mut merged, mut errored) = (false, false);
    for _ in 0..2 {
        let (f, id) = read_reply_any(&mut stream).expect("reply");
        assert_eq!(id, Some(7), "{f:?}");
        match f {
            Frame::MergeResponse { merged: m, .. } => {
                assert_eq!(m, vec![1, 2, 3, 4]);
                merged = true;
            }
            Frame::Error { code: c, message } => {
                assert_eq!(c, code::MALFORMED, "{message}");
                assert!(message.contains('7'), "error must name the id: {message}");
                errored = true;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(merged && errored);
    // Id 7 was released by the original's reply: reusable now.
    let mut bytes = Vec::new();
    encode_merge_request_v2(7, MODE_MERGE, 0, &[vec![9], vec![8]], &mut bytes);
    stream.write_all(&bytes).unwrap();
    match read_reply_any(&mut stream) {
        Some((Frame::MergeResponse { merged, .. }, Some(7))) => {
            assert_eq!(merged, vec![8, 9]);
        }
        other => panic!("id 7 not reusable: {other:?}"),
    }
    server.shutdown();
}

/// The version latch: a v2 frame on a connection latched to v1 is a
/// typed MALFORMED error (framed v1, like every reply on that
/// connection) and the connection keeps serving v1.
#[test]
fn v2_frame_on_a_v1_latched_connection_is_malformed() {
    let server = start_server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut bytes = Vec::new();
    protocol::encode_frame(&Frame::Ping, &mut bytes); // latches v1
    stream.write_all(&bytes).unwrap();
    assert!(matches!(read_reply_any(&mut stream), Some((Frame::Pong, None))));

    let mut bytes = Vec::new();
    protocol::encode_frame_v2(&Frame::Ping, 5, &mut bytes);
    stream.write_all(&bytes).unwrap();
    match read_reply_any(&mut stream) {
        Some((Frame::Error { code: c, message }, None)) => {
            assert_eq!(c, code::MALFORMED, "{message}");
            assert!(message.contains("v2"), "{message}");
        }
        other => panic!("expected a v1-framed MALFORMED error, got {other:?}"),
    }
    // Still latched, still serving.
    let mut bytes = Vec::new();
    protocol::encode_frame(&Frame::Ping, &mut bytes);
    stream.write_all(&bytes).unwrap();
    assert!(matches!(read_reply_any(&mut stream), Some((Frame::Pong, None))));
    server.shutdown();
}

/// The mirror latch: a v1 frame on a v2 connection errors (framed v2,
/// id 0 — the offending frame carried no id to echo) and v2 service
/// continues.
#[test]
fn v1_frame_on_a_v2_latched_connection_is_malformed() {
    let server = start_server();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    let mut bytes = Vec::new();
    protocol::encode_frame_v2(&Frame::Ping, 1, &mut bytes); // latches v2
    stream.write_all(&bytes).unwrap();
    assert!(matches!(read_reply_any(&mut stream), Some((Frame::Pong, Some(1)))));

    let mut bytes = Vec::new();
    protocol::encode_frame(&Frame::Ping, &mut bytes);
    stream.write_all(&bytes).unwrap();
    match read_reply_any(&mut stream) {
        Some((Frame::Error { code: c, message }, Some(0))) => {
            assert_eq!(c, code::MALFORMED, "{message}");
            assert!(message.contains("v1"), "{message}");
        }
        other => panic!("expected a v2-framed MALFORMED error, got {other:?}"),
    }
    let mut bytes = Vec::new();
    protocol::encode_frame_v2(&Frame::Ping, 2, &mut bytes);
    stream.write_all(&bytes).unwrap();
    assert!(matches!(read_reply_any(&mut stream), Some((Frame::Pong, Some(2)))));
    server.shutdown();
}

/// Client-side id hygiene: a response naming an id the client never
/// sent (or already settled) is a peer protocol violation, surfaced as
/// an error — not silently matched to the wrong request.
#[test]
fn unknown_id_in_response_is_a_client_protocol_error() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut peer, _) = listener.accept().unwrap();
        // Consume the request frame (length prefix + body) so the
        // write isn't racing the reply, then answer with an id the
        // client never claimed.
        let mut rd = FrameReader::new();
        loop {
            match rd.read_frame(&mut peer) {
                Ok(ReadFrame::FrameV2(_, id)) => {
                    assert_eq!(id, 1, "client's first v2 id");
                    break;
                }
                Ok(ReadFrame::Pending) => continue,
                other => panic!("fake server expected a v2 request, got {other:?}"),
            }
        }
        let mut bytes = Vec::new();
        encode_merge_response_v2(999, "software", &[1, 2], &mut bytes);
        peer.write_all(&bytes).unwrap();
        // Hold the socket open until the client has judged the reply.
        std::thread::sleep(Duration::from_millis(300));
    });
    let mut client = loms::net::NetClient::connect_v2(addr).unwrap();
    client.submit(&[vec![1], vec![2]]).unwrap();
    let err = client.recv().unwrap_err().to_string();
    assert!(err.contains("unknown request id 999"), "{err}");
    fake.join().unwrap();
}

#[test]
fn mid_frame_disconnect_storm_leaves_server_healthy() {
    let server = start_server();
    let addr = server.addr();
    let mut rng = Rng::new(0xD15C);
    for _ in 0..20 {
        let bytes = valid_request_bytes(&mut rng);
        let cut = rng.range(1, bytes.len());
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&bytes[..cut]).unwrap();
        drop(stream); // vanish mid-frame
    }
    let mut probe = NetClient::connect(addr).unwrap();
    assert_eq!(probe.merge(&[vec![2, 4], vec![1, 3]]).unwrap().merged, vec![1, 2, 3, 4]);
    // Partial frames never count as received, so the account still
    // balances: every counted frame got exactly one reply.
    drop(probe);
    let snap = server.service().metrics().snapshot();
    assert_eq!(snap.net_frames_in, snap.net_responses + snap.net_errors, "{snap:?}");
    server.shutdown();
}
