//! Property-based tests (hand-rolled generators over `util::Rng`; the
//! offline build has no proptest crate). Each property runs hundreds of
//! randomized cases with deterministic seeds — failures print the seed.

use loms::coordinator::planner::kway_merge;
use loms::coordinator::{MergeService, Route, Router, ServiceConfig, SoftwareBackend};
use loms::sortnet::exec::{merge, ExecMode};
use loms::sortnet::{batcher, loms as lm, s2ms};
use loms::stream::{
    boxed, decode_block_meta, encode_block_meta, BlockKernel, BlockMerger2, MergeTree,
    SliceStream, SortedStream, SpillBlockMeta, SPILL_META_BYTES,
};
use loms::util::crc32::crc32;
use loms::util::Rng;

/// Property: every LOMS 2-way configuration merges arbitrary sorted
/// inputs exactly like std sort, for random (m, n, cols).
#[test]
fn prop_loms_2way_merges_like_sort() {
    let mut rng = Rng::new(2024);
    for case in 0..300 {
        let m = rng.range(1, 40);
        let n = rng.range(1, 40);
        let cols = [2, 3, 4, 8][rng.range(0, 4)];
        let d = lm::loms_2way(m, n, cols);
        let a = rng.sorted_list(m, 500);
        let b = rng.sorted_list(n, 500);
        let got = merge(&d, &[a.clone(), b.clone()], ExecMode::Strict)
            .unwrap_or_else(|e| panic!("case {case} (m={m},n={n},cols={cols}): {e}"));
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(got, want, "case {case} (m={m},n={n},cols={cols})");
    }
}

/// Property: k-way LOMS merges arbitrary sorted inputs for random k and
/// sizes (k in 3..=6; unequal sizes exercised at k=3).
#[test]
fn prop_loms_kway_merges_like_sort() {
    let mut rng = Rng::new(77);
    for case in 0..150 {
        // Equal sizes: the paper's k-way setting (Table 1). Unequal
        // mixtures are only claimed (and only hold) for 2-way/3-way
        // special cases — exercised separately below.
        let k = rng.range(3, 7);
        let sizes: Vec<usize> = vec![rng.range(1, 8); k];
        let d = lm::loms_kway(&sizes);
        let lists: Vec<Vec<u32>> = sizes.iter().map(|&s| rng.sorted_list(s, 300)).collect();
        let got = merge(&d, &lists, ExecMode::Strict)
            .unwrap_or_else(|e| panic!("case {case} sizes {sizes:?}: {e}"));
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        assert_eq!(got, want, "case {case} sizes {sizes:?}");
    }
}

/// Known-good unequal 3-way mixtures merge through the validated
/// constructor (schedule extended beyond Table 1 where needed).
#[test]
fn prop_loms_3way_unequal_known_good() {
    let mut rng = Rng::new(303);
    for sizes in [[7usize, 5, 3], [5, 3, 1], [3, 5, 7], [7, 7, 5], [9, 7, 5]] {
        let d = lm::loms_kway_validated(&sizes).unwrap_or_else(|e| panic!("{e}"));
        for _ in 0..20 {
            let lists: Vec<Vec<u32>> = sizes.iter().map(|&s| rng.sorted_list(s, 200)).collect();
            let got = merge(&d, &lists, ExecMode::Strict).unwrap();
            let mut want: Vec<u32> = lists.concat();
            want.sort_unstable();
            assert_eq!(got, want, "{sizes:?}");
        }
    }
    // Non-convergent mixtures are reported as errors, never mis-built.
    assert!(lm::loms_kway_validated(&[8, 1, 6]).is_err());
    assert!(lm::loms_kway_validated(&[5, 5, 3]).is_err());
}

/// Property: stability — S2MS and LOMS keep UP-list values ahead of
/// equal DN-list values (checked via (key, origin) pairs).
#[test]
fn prop_merge_stability() {
    let mut rng = Rng::new(5150);
    for _ in 0..100 {
        let m = rng.range(1, 20);
        let n = rng.range(1, 20);
        let a: Vec<(u32, u8)> = {
            let mut v: Vec<u32> = (0..m).map(|_| rng.below(8) as u32).collect();
            v.sort_unstable();
            v.into_iter().map(|x| (x, 0)).collect()
        };
        let b: Vec<(u32, u8)> = {
            let mut v: Vec<u32> = (0..n).map(|_| rng.below(8) as u32).collect();
            v.sort_unstable();
            v.into_iter().map(|x| (x, 1)).collect()
        };
        let d = s2ms::s2ms(m, n);
        let got = merge(&d, &[a, b], ExecMode::Strict).unwrap();
        // Among equal keys, all origin-0 entries must precede origin-1.
        for w in got.windows(2) {
            if w[0].0 == w[1].0 {
                assert!(w[0].1 <= w[1].1, "stability violated: {got:?}");
            }
        }
    }
}

/// Property: the Batcher baselines and LOMS agree on every input.
#[test]
fn prop_all_devices_agree() {
    let mut rng = Rng::new(31337);
    for _ in 0..100 {
        let m = [4usize, 8, 16, 32][rng.range(0, 4)];
        let a = rng.sorted_list(m, 1000);
        let b = rng.sorted_list(m, 1000);
        let oem = merge(&batcher::odd_even_merge(m), &[a.clone(), b.clone()], ExecMode::Fast).unwrap();
        let bim = merge(&batcher::bitonic_merge(m), &[a.clone(), b.clone()], ExecMode::Fast).unwrap();
        let lms = merge(&lm::loms_2way(m, m, 2), &[a.clone(), b.clone()], ExecMode::Strict).unwrap();
        let s2 = merge(&s2ms::s2ms(m, m), &[a, b], ExecMode::Strict).unwrap();
        assert_eq!(oem, bim);
        assert_eq!(oem, lms);
        assert_eq!(oem, s2);
    }
}

/// Property: the router always routes exact artifact shapes to that
/// artifact, never pads an exact match, and padding preserves order
/// dominance (every routed artifact dominates the request per-list).
#[test]
fn prop_router_invariants() {
    let backend = SoftwareBackend::default_set();
    use loms::coordinator::Backend;
    let router = Router::new(backend.artifacts());
    let mut rng = Rng::new(99);
    for _ in 0..500 {
        let k = if rng.below(4) == 0 { 3 } else { 2 };
        let sizes: Vec<usize> = (0..k).map(|_| rng.range(1, 300)).collect();
        match router.route(&sizes) {
            Route::Artifact { idx } => {
                let meta = &router.artifacts()[idx];
                assert_eq!(meta.list_sizes.len(), k);
                for (cap, want) in meta.list_sizes.iter().zip(&sizes) {
                    assert!(cap >= want, "{sizes:?} -> {}", meta.name);
                }
                // Tightest: no smaller dominating artifact exists.
                for other in router.artifacts() {
                    if other.list_sizes.len() == k
                        && other.total < meta.total
                        && other.list_sizes.iter().zip(&sizes).all(|(c, w)| c >= w)
                    {
                        panic!("{sizes:?} routed to {} but {} is tighter", meta.name, other.name);
                    }
                }
            }
            Route::Software => {
                // No artifact with matching k dominates.
                for a in router.artifacts() {
                    if a.list_sizes.len() == k {
                        assert!(
                            a.list_sizes.iter().zip(&sizes).any(|(c, w)| c < w),
                            "{sizes:?} should have routed to {}",
                            a.name
                        );
                    }
                }
            }
        }
    }
}

/// A sorted run in one of three value regimes: duplicate-heavy small
/// values, the wide domain, or keys crowded against `u32::MAX` (the
/// stream engine's count-tracked fill must keep genuine `u32::MAX`
/// keys exact — unlike the serving path, the full domain is legal).
fn stream_run(rng: &mut Rng, len: usize, regime: usize) -> Vec<u32> {
    let mut v: Vec<u32> = match regime % 3 {
        0 => (0..len).map(|_| rng.below(16) as u32).collect(),
        1 => (0..len).map(|_| rng.next_u32()).collect(),
        _ => (0..len).map(|_| u32::MAX - rng.below(5) as u32).collect(),
    };
    v.sort_unstable();
    v
}

/// Property: a [`MergeTree`] over k random streams, drained with
/// random chunk sizes, equals the scalar binary-heap merge — across
/// ragged lengths, duplicates, empty runs and `u32::MAX`-adjacent
/// keys, for every block size R. (The stream subsystem previously had
/// example-based tests only; this is its randomized differential.)
#[test]
fn prop_merge_tree_matches_heap_merge() {
    let mut rng = Rng::new(0x5742EA);
    for case in 0..120 {
        let k = rng.range(2, 10);
        let r = [2usize, 3, 8, 32][rng.range(0, 4)];
        let runs: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let len = rng.range(0, 250);
                stream_run(&mut rng, len, case + k)
            })
            .collect();
        let streams: Vec<Box<dyn SortedStream + '_>> =
            runs.iter().map(|run| boxed(SliceStream::new(run))).collect();
        let mut tree = MergeTree::new(streams, r).unwrap_or_else(|e| panic!("case {case}: {e}"));
        let mut got = Vec::new();
        // Random pull pattern: chunk sizes from 1 to well over R.
        loop {
            let chunk = rng.range(1, 4 * r + 7);
            if tree.next_chunk(chunk, &mut got).unwrap() == 0 {
                break;
            }
            assert!(
                tree.resident_keys() <= 8 * k * r,
                "case {case}: working set {} exceeds O(k·R)",
                tree.resident_keys()
            );
        }
        let want = kway_merge(runs.clone());
        assert_eq!(got, want, "case {case} k={k} r={r}");
    }
}

/// Property: the raw [`BlockMerger2`] refill loop (stage a block from
/// the min-head input, emit `min(m, h + cnt)`, retain the high cone)
/// driven through the real R+R kernel equals the heap merge, and the
/// retained tail never exceeds R. This pins the emit-safety arithmetic
/// itself, below the tree scheduler.
#[test]
fn prop_block_merger_refill_loop_matches_heap_merge() {
    let mut rng = Rng::new(0xB10C);
    for case in 0..60 {
        let r = [1usize, 2, 5, 8][rng.range(0, 4)];
        let mut kern = BlockKernel::new(r).unwrap();
        let la = rng.range(0, 160);
        let a = stream_run(&mut rng, la, case);
        let lb = rng.range(0, 160);
        let b = stream_run(&mut rng, lb, case + 1);
        let mut node = BlockMerger2::new();
        let (mut pa, mut pb) = (0usize, 0usize);
        let mut got = Vec::new();
        loop {
            let (ha, hb) = (a.get(pa).copied(), b.get(pb).copied());
            let (src, pos, other) = match (ha, hb) {
                (None, None) => break,
                (Some(x), Some(y)) if x <= y => (&a, &mut pa, hb),
                (Some(_), Some(_)) => (&b, &mut pb, ha),
                (Some(_), None) => (&a, &mut pa, None),
                (None, Some(_)) => (&b, &mut pb, None),
            };
            let m = r.min(src.len() - *pos);
            node.stage_buf().extend_from_slice(&src[*pos..*pos + m]);
            *pos += m;
            let emit = node.emit_count(other);
            let mut merged = vec![0u32; node.width()];
            let rows: Vec<&[Vec<u32>]> = vec![node.lists()];
            kern.merge_rows(&rows, &mut [&mut merged[..]]);
            node.apply(&merged, emit, &mut got);
            assert!(node.high().len() <= r, "case {case}: retained tail exceeds R={r}");
        }
        node.flush(&mut got);
        let want = kway_merge(vec![a.clone(), b.clone()]);
        assert_eq!(got, want, "case {case} r={r} la={} lb={}", a.len(), b.len());
    }
}

/// Property: the service returns the exact std-sort merge for random
/// mixed workloads (shapes, duplicates, empty-ish lists) and never loses
/// a request.
#[test]
fn prop_service_state_conservation() {
    let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .unwrap();
    let mut rng = Rng::new(60601);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    let total = 400;
    for _ in 0..total {
        let k = if rng.below(3) == 0 { 3 } else { 2 };
        let lists: Vec<Vec<u32>> = (0..k)
            .map(|_| {
                let len = rng.range(1, 80);
                rng.sorted_list(len, 100)
            })
            .collect();
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        wants.push(want);
        rxs.push(s.submit(lists));
    }
    let mut served = 0;
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv().expect("no request may be lost");
        assert_eq!(resp.merged, want);
        served += 1;
    }
    assert_eq!(served, total);
    let snap = s.metrics().snapshot();
    assert_eq!(snap.requests, total as u64);
    assert_eq!(snap.responses, total as u64);
    assert_eq!(snap.rejected, 0);
}

/// Property: the spill-block sidecar codec round-trips every meta, and
/// every single-bit flip of an encoded entry is caught — either decode
/// rejects the entry outright (magic/version/length damage) or the
/// decoded meta differs from the written one, which block verification
/// then catches against values derived from the data file (stride,
/// rec_count) or the recomputed payload CRC.
#[test]
fn prop_spill_block_meta_bit_flips_detected() {
    let mut rng = Rng::new(0xC3C);
    for case in 0..200 {
        let meta = SpillBlockMeta {
            stride: if rng.below(2) == 0 { 4 } else { 12 },
            rec_count: rng.below(1 << 16) as u16,
            crc: rng.next_u32(),
        };
        let mut enc = Vec::new();
        encode_block_meta(&meta, &mut enc);
        assert_eq!(enc.len(), SPILL_META_BYTES);
        assert_eq!(decode_block_meta(&enc), Ok(meta), "case {case}");
        for bit in 0..SPILL_META_BYTES * 8 {
            let mut flipped = enc.clone();
            flipped[bit / 8] ^= 1 << (bit % 8);
            match decode_block_meta(&flipped) {
                Err(_) => {}
                Ok(m) => assert_ne!(m, meta, "case {case}: bit {bit} flip went unnoticed"),
            }
        }
        // Truncated and oversized entries are rejected, not misread.
        assert!(decode_block_meta(&enc[..SPILL_META_BYTES - 1]).is_err());
        let mut long = enc.clone();
        long.push(0);
        assert!(decode_block_meta(&long).is_err());
    }
}

/// Property: any single-bit flip in a spill block's payload changes its
/// CRC-32 (guaranteed by CRC linearity; checked here over random block
/// lengths including the empty and one-byte edges).
#[test]
fn prop_spill_payload_bit_flips_change_crc() {
    let mut rng = Rng::new(0xF11);
    for _ in 0..60 {
        let len = [0usize, 1, 2, 63, 64, 65, 1021][rng.below(7) as usize];
        let mut block: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let clean = crc32(&block);
        if block.is_empty() {
            continue;
        }
        for _ in 0..40 {
            let bit = rng.below(len as u64 * 8) as usize;
            block[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32(&block), clean, "flip at bit {bit} kept the CRC");
            block[bit / 8] ^= 1 << (bit % 8);
        }
    }
}

/// Property: the batcher pads but never reorders — responses map 1:1 to
/// their requests (ids checked under heavy interleaving).
#[test]
fn prop_batcher_id_integrity() {
    let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .unwrap();
    let mut rng = Rng::new(8080);
    let mut pending = Vec::new();
    for round in 0..20 {
        for _ in 0..rng.range(1, 50) {
            let la = rng.range(1, 33);
            let a = rng.sorted_list(la, 1000);
            let lb = rng.range(1, 33);
            let b = rng.sorted_list(lb, 1000);
            let lo = *a.iter().chain(b.iter()).min().unwrap_or(&0);
            pending.push((s.submit(vec![a, b]), lo, round));
        }
        // Drain half each round to interleave submissions and flushes.
        let drain = pending.len() / 2;
        for (rx, lo, _) in pending.drain(..drain) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.merged.first().copied().unwrap_or(0), lo);
        }
    }
    for (rx, lo, _) in pending {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.merged.first().copied().unwrap_or(0), lo);
    }
}
