//! Tile-direct serving-path differential suite.
//!
//! The serving contract after the two-copy redesign: a batch is copied
//! exactly twice (request slices → transposed lane tile, output tile
//! slots → response buffers), with no list-major scratch or row-major
//! assembly in between — and the result must be **byte-exact** with the
//! old assemble-then-execute path (pad each request to the artifact
//! shape, pad the batch with sentinel rows, execute row-major, slice
//! each row's real prefix). This file enforces that equality across
//! every default artifact (all device families), ragged request sizes,
//! partial batches and Strict mode, then drives the full pipelined
//! service end to end over a mixed workload.

use loms::coordinator::router::PAD;
use loms::coordinator::{Backend, MergeService, ServiceConfig, SoftwareBackend};
use loms::runtime::ArtifactMeta;
use loms::sortnet::exec::ExecMode;
use loms::sortnet::plan::PlanScratch;
use loms::util::Rng;

/// Ragged random requests for an artifact: per-row lists each between 1
/// and the artifact slot size.
fn ragged_requests(rng: &mut Rng, meta: &ArtifactMeta, real: usize) -> Vec<Vec<Vec<u32>>> {
    (0..real)
        .map(|_| {
            meta.list_sizes
                .iter()
                .map(|&cap| {
                    let len = rng.range(1, cap + 1);
                    rng.sorted_list(len, 1 << 20)
                })
                .collect()
        })
        .collect()
}

/// The new path: ragged views in, per-row response buffers out.
fn tile_direct(
    backend: &mut SoftwareBackend,
    meta: &ArtifactMeta,
    reqs: &[Vec<Vec<u32>>],
) -> Vec<Vec<u32>> {
    let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
    let mut merged: Vec<Vec<u32>> =
        reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
    let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
    let run = backend.execute_direct(&meta.name, &rows, &mut outs).unwrap();
    assert_eq!(run.padded_rows, 0, "{}: tile-direct must pad no rows", meta.name);
    merged
}

#[test]
fn tile_direct_matches_assemble_then_execute_for_every_artifact() {
    // Every default artifact — every served device family (2-way LOMS
    // across column counts and sizes, 3-way k-way) — on ragged
    // requests, partial batches (scalar tail), tile-straddling and full
    // batches.
    let mut backend = SoftwareBackend::default_set();
    let mut rng = Rng::new(0x7D1F);
    for meta in backend.artifacts() {
        let reals: Vec<usize> = [1usize, 7, 16, 21, meta.batch / 2 + 1, meta.batch]
            .into_iter()
            .filter(|&r| r <= meta.batch)
            .collect();
        for real in reals {
            let reqs = ragged_requests(&mut rng, &meta, real);
            // The old assemble-then-execute path, via the shared
            // reference implementation on the backend.
            let want = backend.execute_padded_reference(&meta.name, &reqs).unwrap();
            let got = tile_direct(&mut backend, &meta, &reqs);
            assert_eq!(got, want, "{} real={real}", meta.name);
        }
    }
}

#[test]
fn strict_mode_view_path_matches_padded_batch() {
    // The scalar view path (used for the sub-tile tail, and the only
    // executor Strict mode may run on) must match the padded row-major
    // batch in Strict mode rank for rank.
    let mut backend = SoftwareBackend::default_set();
    backend.warm().unwrap();
    let mut rng = Rng::new(0x57C1);
    for name in ["loms2_up32_dn32_b256", "loms3_7r_b256"] {
        let meta = backend.artifacts().into_iter().find(|m| &*m.name == name).unwrap();
        let plan = backend.plan(name).expect("warmed");
        for real in [1usize, 5, 40] {
            let reqs = ragged_requests(&mut rng, &meta, real);
            // Padded row-major reference, Strict mode, batch == real.
            let lists: Vec<Vec<u32>> = (0..meta.list_sizes.len())
                .map(|l| {
                    let cap = meta.list_sizes[l];
                    let mut flat = Vec::new();
                    for r in &reqs {
                        flat.extend_from_slice(&r[l]);
                        flat.resize(flat.len() + (cap - r[l].len()), PAD);
                    }
                    flat
                })
                .collect();
            let mut reference = Vec::new();
            plan.run_batch(&lists, real, ExecMode::Strict, &mut PlanScratch::new(), &mut reference)
                .unwrap();
            let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            plan.run_view_batch_into(
                &rows,
                PAD,
                ExecMode::Strict,
                &mut PlanScratch::new(),
                &mut outs,
            )
            .unwrap();
            for (row, got) in merged.iter().enumerate() {
                assert_eq!(
                    &reference[row * meta.total..row * meta.total + got.len()],
                    &got[..],
                    "{name} real={real} row={row}"
                );
            }
        }
    }
}

#[test]
fn mixed_load_end_to_end_batches_and_is_correct() {
    // The full pipelined service (engine → depth-1 channel → executor,
    // fallback pool) over a mixed workload: exact shapes, ragged padded
    // shapes, 3-way, and unroutable software shapes. Every response
    // must equal the std-sort merge, dynamic batching must engage, and
    // the tile-direct path must report zero padding rows.
    let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .unwrap();
    let mut rng = Rng::new(0xE2E7);
    let total = 400usize;
    let mut software = 0u64;
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..total {
        let lists: Vec<Vec<u32>> = match i % 8 {
            0 | 1 | 2 => vec![rng.sorted_list(32, 1 << 20), rng.sorted_list(32, 1 << 20)],
            3 | 4 => {
                let la = rng.range(1, 33);
                let lb = rng.range(1, 33);
                vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)]
            }
            5 => vec![rng.sorted_list(64, 1 << 20), rng.sorted_list(64, 1 << 20)],
            6 => vec![
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
            ],
            _ => {
                // Unroutable (> largest artifact): software fallback.
                software += 1;
                vec![rng.sorted_list(400, 1 << 20), rng.sorted_list(400, 1 << 20)]
            }
        };
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        wants.push(want);
        rxs.push(s.submit(lists));
    }
    for (rx, want) in rxs.into_iter().zip(wants) {
        assert_eq!(rx.recv().expect("no request may be lost").merged, want);
    }
    let snap = s.metrics().snapshot();
    assert_eq!(snap.responses, total as u64);
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.software_served, software);
    // Dynamic batching engaged: far fewer batches than artifact-served
    // requests.
    let artifact_served = total as u64 - software;
    assert!(snap.batches >= 1);
    assert!(snap.batches < artifact_served / 2, "must batch: {snap:?}");
    // Tile-direct partial batches execute only their real rows.
    assert_eq!(snap.rows_padded, 0);
    assert_eq!(snap.rows_real, artifact_served);
    // Per-stage pipeline timings were recorded.
    assert!(snap.execute_us_mean > 0.0, "{snap:?}");
    s.shutdown();
}
