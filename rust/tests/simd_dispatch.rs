//! Dispatch-tier differential suite: the explicit SIMD kernels
//! (`std::arch` AVX2/NEON) and the portable fallback must be
//! byte-indistinguishable from the scalar compare-exchange reference on
//! every default artifact family, every ragged view shape, and every
//! `batch % LANES` tail — for both the key-only path and the
//! rank-then-permute key-value path.
//!
//! [`lanes::force_tier`] is a process-wide override, so every test that
//! forces a tier serializes on [`TIER_LOCK`] and restores the default
//! on drop (panic included) — a failing differential must not leak a
//! forced tier into a concurrently scheduled test.

use loms::sortnet::exec::ExecMode;
use loms::sortnet::lanes::{self, LanePlan, LaneScratch, SimdTier, LANES};
use loms::sortnet::loms as lm;
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::util::Rng;
use std::sync::Mutex;

static TIER_LOCK: Mutex<()> = Mutex::new(());

/// Holds the tier lock and clears any forced tier when dropped.
struct TierGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for TierGuard<'_> {
    fn drop(&mut self) {
        lanes::force_tier(None);
    }
}

fn lock_tiers() -> TierGuard<'static> {
    TierGuard(TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
}

/// The device families behind `SoftwareBackend::default_set()`'s
/// artifacts (2-col/4-col/8-col 2-way at each serving size, plus the
/// 3-way), compiled fresh so the differential is against the scalar
/// plan, not against another lane execution.
fn artifact_family_plans() -> Vec<(&'static str, CompiledPlan, LanePlan)> {
    let devices = vec![
        ("loms2_up32_dn32", lm::loms_2way(32, 32, 2)),
        ("loms2_up64_dn64", lm::loms_2way(64, 64, 2)),
        ("loms2_up128_dn128", lm::loms_2way(128, 128, 4)),
        ("loms2_up256_dn256", lm::loms_2way(256, 256, 8)),
        ("loms3_7r", lm::loms_kway(&[7, 7, 7])),
    ];
    devices
        .into_iter()
        .map(|(name, d)| {
            let plan = CompiledPlan::compile_auto(&d).expect("valid device");
            let lane = LanePlan::compile(&plan);
            (name, plan, lane)
        })
        .collect()
}

fn flat_batch(rng: &mut Rng, sizes: &[usize], batch: usize, max: u32) -> Vec<Vec<u32>> {
    sizes
        .iter()
        .map(|&s| {
            let mut flat = Vec::with_capacity(batch * s);
            for _ in 0..batch {
                flat.extend(rng.sorted_list(s, max));
            }
            flat
        })
        .collect()
}

/// Every available tier × every default artifact family × tail-heavy
/// batch sizes: lane output must be byte-equal to the scalar
/// `CompiledPlan` reference.
#[test]
fn every_tier_matches_scalar_plan_on_default_artifact_families() {
    let _guard = lock_tiers();
    let tiers = lanes::available_tiers();
    assert!(tiers.contains(&SimdTier::Scalar) && tiers.contains(&SimdTier::Portable));
    let mut rng = Rng::new(0xD15F);
    for (name, plan, lane) in artifact_family_plans() {
        for batch in [1usize, LANES - 1, LANES, LANES + 1, 3 * LANES + 5] {
            let lists = flat_batch(&mut rng, lane.list_sizes(), batch, 1 << 20);
            let mut want = Vec::new();
            plan.run_batch(&lists, batch, ExecMode::Fast, &mut PlanScratch::new(), &mut want)
                .expect("scalar reference");
            for &tier in &tiers {
                assert!(lanes::force_tier(Some(tier)), "{tier:?} listed as available");
                assert_eq!(lanes::active_tier(), tier);
                let mut got = Vec::new();
                lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got)
                    .expect("lane batch");
                assert_eq!(got, want, "{name} batch={batch} tier={tier:?} diverged");
            }
        }
    }
}

/// The ragged serving path (`run_view_batch_into`): per-row views of
/// uneven sizes, exact-width outputs, every tier against the sorted
/// concat oracle and against each other.
#[test]
fn ragged_views_are_tier_invariant() {
    let _guard = lock_tiers();
    let d = lm::loms_2way(32, 32, 2);
    let plan = CompiledPlan::compile_auto(&d).expect("valid device");
    let lane = LanePlan::compile(&plan);
    let mut rng = Rng::new(0x7A66);
    let reqs: Vec<Vec<Vec<u32>>> = (0..3 * LANES + 7)
        .map(|_| {
            vec![rng.sorted_list_ragged(0, 33, 1 << 20), rng.sorted_list_ragged(0, 33, 1 << 20)]
        })
        .collect();
    let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
    let widths: Vec<usize> = reqs.iter().map(|r| r.iter().map(Vec::len).sum()).collect();
    for &tier in &lanes::available_tiers() {
        assert!(lanes::force_tier(Some(tier)));
        let mut merged: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        lane.run_view_batch_into(&plan, &rows, u32::MAX, &mut LaneScratch::new(), &mut outs)
            .expect("ragged view batch");
        for (r, req) in reqs.iter().enumerate() {
            let mut want: Vec<u32> = req.concat();
            want.sort_unstable();
            assert_eq!(merged[r], want, "row {r} tier={tier:?} diverged from sorted oracle");
        }
    }
}

/// The rank-then-permute path is tier-invariant too: identical keys
/// AND identical permutations (the packed (key, origin) merge is fully
/// deterministic, so even equal-key orders must not differ by tier).
#[test]
fn kv_permutations_are_tier_invariant() {
    let _guard = lock_tiers();
    let d = lm::loms_2way(32, 32, 2);
    let plan = CompiledPlan::compile_auto(&d).expect("valid device");
    let lane = LanePlan::compile(&plan);
    let mut rng = Rng::new(0xBEAD);
    // Tiny key domain → dense duplicates, so tie handling is exercised.
    let reqs: Vec<Vec<Vec<u32>>> = (0..2 * LANES + 3)
        .map(|_| vec![rng.sorted_list_ragged(0, 33, 8), rng.sorted_list_ragged(0, 33, 8)])
        .collect();
    let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
    let widths: Vec<usize> = reqs.iter().map(|r| r.iter().map(Vec::len).sum()).collect();
    let mut reference: Option<(Vec<Vec<u32>>, Vec<Vec<u32>>)> = None;
    for &tier in &lanes::available_tiers() {
        assert!(lanes::force_tier(Some(tier)));
        let mut keys: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
        let mut perms: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
        {
            let mut key_outs: Vec<&mut [u32]> = keys.iter_mut().map(|v| v.as_mut_slice()).collect();
            let mut perm_outs: Vec<&mut [u32]> =
                perms.iter_mut().map(|v| v.as_mut_slice()).collect();
            lanes::run_view_batch_perm_auto(
                &lane,
                &plan,
                &rows,
                &mut LaneScratch::new(),
                &mut key_outs,
                &mut perm_outs,
            )
            .expect("perm view batch");
        }
        for (r, req) in reqs.iter().enumerate() {
            // The permutation must be the stable (key, origin) merge of
            // the list-major concatenation.
            let concat: Vec<u32> = req.concat();
            let mut want: Vec<(u32, u32)> =
                concat.iter().enumerate().map(|(o, &k)| (k, o as u32)).collect();
            want.sort_unstable();
            let got: Vec<(u32, u32)> =
                keys[r].iter().zip(&perms[r]).map(|(&k, &p)| (k, p)).collect();
            assert_eq!(got, want, "row {r} tier={tier:?} perm diverged");
        }
        match &reference {
            None => reference = Some((keys, perms)),
            Some((rk, rp)) => {
                assert_eq!((&keys, &perms), (rk, rp), "tier={tier:?} vs first tier");
            }
        }
    }
}

/// Forcing a tier the host cannot run must fail closed — the dispatch
/// invariant (`active_tier` is always available) is what makes the
/// `unsafe` kernel entries sound.
#[test]
fn unavailable_tiers_cannot_be_forced() {
    let _guard = lock_tiers();
    let before = lanes::active_tier();
    for tier in [SimdTier::Avx2, SimdTier::Neon] {
        if !tier.available() {
            assert!(!lanes::force_tier(Some(tier)), "{tier:?} forced despite unavailability");
            assert_eq!(lanes::active_tier(), before, "{tier:?} refusal must not change dispatch");
        }
    }
    assert!(lanes::active_tier().available());
}
