//! Observability integration suite: a [`NetClient`] request followed
//! end-to-end by trace id through the sampled span JSONL, the live
//! `Stats` wire frame against a running server, the single percentile
//! definition shared by the client and the service metrics, and the
//! concurrency/merge contracts of the log-linear histogram.

use loms::coordinator::{Metrics, MergeService, ServiceConfig, SoftwareBackend};
use loms::net::{client, NetClient, NetServer, NetServerConfig};
use loms::obs::{expo, percentile_us, write_spans_jsonl, Hist};
use loms::util::{Json, Rng};
use std::time::{Duration, Instant};

fn start_server() -> NetServer {
    let svc = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
        .expect("service");
    NetServer::start("127.0.0.1:0", svc, NetServerConfig::default()).expect("server")
}

/// Acceptance: a client-minted trace id is honored by the server and
/// every request-path span — admit, queue, assemble, execute, respond —
/// lands in the sampled span ring carrying that id, with the execute
/// span naming its artifact and SIMD tier in the JSONL export.
#[test]
fn a_traced_request_is_followable_end_to_end() {
    let server = start_server();
    server.service().metrics().tracer().set_sample(1);
    let mut client = NetClient::connect(server.addr()).unwrap();
    const TRACE: u64 = 0x0DD_BA11;
    client.submit_traced(&[vec![1, 3, 5], vec![2, 4, 6]], TRACE).unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp.merged, vec![1, 2, 3, 4, 5, 6]);

    // Batch spans are retained on the executor after the response fans
    // out, so the reply can race the recording — poll briefly.
    let tracer = server.service().metrics().tracer();
    let want = ["admit", "queue", "assemble", "execute", "respond"];
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut spans = Vec::new();
    loop {
        spans.extend(tracer.drain());
        let have: Vec<&str> =
            spans.iter().filter(|s| s.trace == TRACE).map(|s| s.name).collect();
        if want.iter().all(|w| have.contains(w)) {
            break;
        }
        assert!(Instant::now() < deadline, "spans never arrived; have {have:?}");
        std::thread::sleep(Duration::from_millis(10));
    }

    // The JSONL export carries the id and the execute attributes.
    let mut buf = Vec::new();
    write_spans_jsonl(&spans, &mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let mine: Vec<&Json> = lines
        .iter()
        .filter(|j| j.get("trace").and_then(Json::as_i64) == Some(TRACE as i64))
        .collect();
    for w in want {
        assert!(
            mine.iter().any(|j| j.get("span").and_then(Json::as_str) == Some(w)),
            "missing {w} span in:\n{text}"
        );
    }
    let exec = mine
        .iter()
        .find(|j| j.get("span").and_then(Json::as_str) == Some("execute"))
        .unwrap();
    assert!(exec.get("artifact").and_then(Json::as_str).is_some(), "{exec:?}");
    assert!(exec.get("tier").and_then(Json::as_str).is_some(), "{exec:?}");
    server.shutdown();
}

/// A request arriving without a trace id gets one minted at the net
/// edge whenever sampling is on, so server-side sampling needs no
/// client cooperation.
#[test]
fn untraced_requests_get_server_minted_ids_when_sampling() {
    let server = start_server();
    server.service().metrics().tracer().set_sample(1);
    let mut client = NetClient::connect(server.addr()).unwrap();
    assert_eq!(client.merge(&[vec![2], vec![1]]).unwrap().merged, vec![1, 2]);
    let tracer = server.service().metrics().tracer();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if tracer.drain().iter().any(|s| s.name == "respond" && s.trace != 0) {
            break;
        }
        assert!(Instant::now() < deadline, "no minted-trace spans arrived");
        std::thread::sleep(Duration::from_millis(10));
    }
    server.shutdown();
}

/// Acceptance: `loms stats` against a live server — the wire document
/// passes the grammar check, reports per-artifact execute histograms
/// consistent with the batch counts, and carries the fault/retry/shed
/// counters. Once the connection drains, the snapshot balance
/// invariants hold ([`loms::coordinator::Snapshot::check`]).
#[test]
fn live_stats_frame_reports_artifacts_and_counters() {
    let server = start_server();
    let mut client = NetClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(0x0B5);
    const N: i64 = 24;
    for i in 0..N {
        let lists = if i % 4 == 3 {
            vec![
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
                rng.sorted_list(7, 1 << 20),
            ]
        } else {
            vec![rng.sorted_list(32, 1 << 20), rng.sorted_list(32, 1 << 20)]
        };
        let mut want: Vec<u32> = lists.concat();
        want.sort_unstable();
        assert_eq!(client.merge(&lists).unwrap().merged, want);
    }

    let doc = client.stats().expect("stats round-trip");
    expo::check_stats_doc(&doc).expect("stats grammar");
    assert!(doc.get("requests").unwrap().as_i64().unwrap() >= N, "{doc:?}");
    assert_eq!(doc.get("responses").unwrap().as_i64(), doc.get("requests").unwrap().as_i64());
    let artifacts = match doc.get("artifacts") {
        Some(Json::Obj(m)) => m,
        other => panic!("artifacts section: {other:?}"),
    };
    assert!(!artifacts.is_empty(), "{doc:?}");
    let mut batches = 0;
    for (name, a) in artifacts {
        let b = a.get("batches").unwrap().as_i64().unwrap();
        // Every executed batch recorded exactly one execute sample, so
        // the per-artifact histogram count equals its batch count.
        assert_eq!(
            a.get("execute").unwrap().get("count").unwrap().as_i64(),
            Some(b),
            "artifact {name}: {a:?}"
        );
        batches += b;
    }
    assert!(batches >= N, "{doc:?}");
    // Fault-free run: the counters exist and read zero.
    let faults = doc.get("faults").unwrap();
    for key in ["faults_injected", "corrupt_detected", "sheds"] {
        assert_eq!(faults.get(key).unwrap().as_i64(), Some(0), "{key}");
    }

    // Satellite: the drained-state balance invariants hold once the
    // connection closes (poll — the server sees the close asynchronously).
    drop(client);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match server.service().metrics().snapshot().check() {
            Ok(()) => break,
            Err(e) => {
                assert!(Instant::now() < deadline, "snapshot never balanced: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    server.shutdown();
}

/// Satellite: one percentile definition everywhere — the client's
/// sample percentiles, the service snapshot's latency percentiles, and
/// the raw histogram agree exactly on the same data.
#[test]
fn one_percentile_definition_across_client_and_metrics() {
    let mut rng = Rng::new(0xDEF);
    let samples: Vec<f64> = (0..5_000).map(|_| rng.below(1_000_000) as f64).collect();
    let m = Metrics::new();
    for &s in &samples {
        m.on_request();
        m.on_response(Duration::from_micros(s as u64));
    }
    let snap = m.snapshot();
    assert_eq!(client::percentile_us(&samples, 0.50), snap.p50_latency_us);
    assert_eq!(client::percentile_us(&samples, 0.99), snap.p99_latency_us);
    assert_eq!(client::percentile_us(&samples, 0.99), percentile_us(&samples, 0.99));
    assert_eq!(snap.latency.count, samples.len() as u64);
}

/// Satellite: concurrent recording into one shared histogram, and
/// merging per-thread partials, both match a single-threaded oracle
/// replaying the same deterministic streams.
#[test]
fn concurrent_records_and_merges_match_single_thread_oracle() {
    const THREADS: u64 = 8;
    const PER: usize = 5_000;
    let shared = Hist::new();
    let partials: Vec<Hist> = (0..THREADS).map(|_| Hist::new()).collect();
    std::thread::scope(|s| {
        for (t, partial) in partials.iter().enumerate() {
            let shared = &shared;
            s.spawn(move || {
                let mut rng = Rng::new(t as u64 + 1);
                for _ in 0..PER {
                    let v = rng.below(1 << 22);
                    shared.record(v);
                    partial.record(v);
                }
            });
        }
    });
    let oracle = Hist::new();
    for t in 0..THREADS {
        let mut rng = Rng::new(t + 1);
        for _ in 0..PER {
            oracle.record(rng.below(1 << 22));
        }
    }
    assert_eq!(shared.snapshot(), oracle.snapshot());
    let merged = Hist::new();
    for partial in &partials {
        merged.merge_from(partial);
    }
    assert_eq!(merged.snapshot(), oracle.snapshot());
}

/// Satellite (hand-rolled property test): across random partitions of
/// random samples, the merged histogram's percentiles bound the exact
/// union percentiles — never under, and over by at most the 1/16
/// bucket width (+1 for the unit rounding).
#[test]
fn merged_histogram_percentiles_bound_the_union() {
    let mut rng = Rng::new(0x93E0);
    for case in 0..60 {
        let merged = Hist::new();
        let mut all = Vec::new();
        for _ in 0..1 + rng.below(5) {
            let h = Hist::new();
            for _ in 0..1 + rng.below(400) {
                // Shifted samples cover several orders of magnitude.
                let v = u64::from(rng.next_u32()) >> rng.below(32);
                h.record(v);
                all.push(v);
            }
            merged.merge_from(&h);
        }
        all.sort_unstable();
        for q in [0.5, 0.9, 0.99, 1.0] {
            let rank = ((q * all.len() as f64).ceil() as usize).clamp(1, all.len());
            let exact = all[rank - 1];
            let got = merged.percentile(q);
            assert!(got >= exact, "case {case} q={q}: {got} under-reports {exact}");
            assert!(
                got as f64 <= exact as f64 * (1.0 + 1.0 / 16.0) + 1.0,
                "case {case} q={q}: {got} over-reports {exact}"
            );
        }
    }
}
