//! Partitioned external sort vs the single-tree path, byte for byte.
//!
//! The range-partitioned final merge claims *byte-identical* output to
//! one big merge tree — same keys, same order, same payload permutation
//! — whatever the partition count, thread count or prefetch depth. This
//! suite checks that claim on the file-to-file paths across the inputs
//! most likely to break it: ragged partition sizes, duplicate-heavy
//! keys straddling pivot boundaries, keys adjacent to `u32::MAX`, and
//! inputs too small to partition at all. It also covers the spill-file
//! lifecycle (concurrent sorts in one spill dir; failed sorts must not
//! leak spill files) and the phase-timing stats surface.

use loms::stream::{
    self, encode_keys_into, encode_records_into, merge_runs_kv_parallel, merge_runs_parallel,
    ExtSortConfig, ExtSortStats,
};
use loms::util::Rng;
use std::fs;
use std::path::{Path, PathBuf};

/// Fresh scratch dir per test (process id + label keep parallel test
/// binaries and parallel tests apart).
fn scratch(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("loms_part_{}_{label}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_keys(path: &Path, keys: &[u32]) {
    let mut bytes = Vec::new();
    encode_keys_into(keys, &mut bytes);
    fs::write(path, bytes).unwrap();
}

fn write_records(path: &Path, keys: &[u32], pays: &[u64]) {
    let mut bytes = Vec::new();
    encode_records_into(keys, pays, &mut bytes);
    fs::write(path, bytes).unwrap();
}

/// Sort `input` twice — forced single tree vs the partitioned/threaded
/// config under test — and require bit-identical output files.
fn assert_partitioned_matches_single(
    dir: &Path,
    label: &str,
    keys: &[u32],
    cfg: &ExtSortConfig,
) -> ExtSortStats {
    let input = dir.join(format!("{label}.u32"));
    write_keys(&input, keys);
    let out_single = dir.join(format!("{label}.single.u32"));
    let out_part = dir.join(format!("{label}.part.u32"));
    let single = ExtSortConfig { partitions: 1, sort_threads: 1, prefetch_buf: 0, ..cfg.clone() };
    stream::extsort_file(&input, &out_single, &single).unwrap();
    let stats = stream::extsort_file(&input, &out_part, cfg).unwrap();
    assert_eq!(
        fs::read(&out_single).unwrap(),
        fs::read(&out_part).unwrap(),
        "{label}: partitioned output differs from single-tree"
    );
    // Against std as well, so both paths can't share one bug.
    let mut want = keys.to_vec();
    want.sort_unstable();
    let mut bytes = Vec::new();
    encode_keys_into(&want, &mut bytes);
    assert_eq!(fs::read(&out_part).unwrap(), bytes, "{label}: output != std sort");
    stats
}

#[test]
fn partitioned_file_sort_is_byte_identical() {
    let dir = scratch("keys");
    let mut rng = Rng::new(0xBA5E);
    let cfg = ExtSortConfig {
        run_len: 1 << 10,
        r: 8,
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        sort_threads: 3,
        partitions: 4,
        prefetch_buf: 256,
        ..Default::default()
    };
    // Random over the full domain (ragged partition sizes fall where
    // they may), including both domain edges.
    let mut full: Vec<u32> = (0..40_000).map(|_| rng.next_u32()).collect();
    full.extend([u32::MAX, u32::MAX - 1, 0, 1, u32::MAX]);
    let stats = assert_partitioned_matches_single(&dir, "full", &full, &cfg);
    assert!(stats.partitions >= 1 && stats.spilled_runs > 0, "{stats:?}");
    // Duplicate-heavy: every pivot lands inside a duplicate plateau, so
    // the cut rule (all duplicates of a pivot go right) is load-bearing.
    let dups: Vec<u32> = (0..30_000).map(|_| rng.next_u32() % 7).collect();
    assert_partitioned_matches_single(&dir, "dups", &dups, &cfg);
    // Skewed: 90% of the mass in one narrow band.
    let skew: Vec<u32> = (0..30_000)
        .map(|i| if i % 10 == 0 { rng.next_u32() } else { 1_000_000 + rng.next_u32() % 64 })
        .collect();
    assert_partitioned_matches_single(&dir, "skew", &skew, &cfg);
    // Tiny inputs fall back to one partition without fuss.
    for (label, n) in [("one", 1usize), ("few", 37)] {
        let tiny: Vec<u32> = (0..n as u32).map(|x| x.wrapping_mul(2_654_435_761)).collect();
        let stats = assert_partitioned_matches_single(&dir, label, &tiny, &cfg);
        assert_eq!(stats.keys, n);
    }
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn partitioned_kv_file_sort_keeps_pairs_and_stability() {
    let dir = scratch("kv");
    let mut rng = Rng::new(0x1D5);
    // Duplicate-heavy keys + unique payload tags: any broken pair or
    // unstable reorder within a duplicate plateau is a hard mismatch.
    let keys: Vec<u32> = (0..25_000).map(|_| rng.next_u32() % 100).collect();
    let pays: Vec<u64> = (0..keys.len() as u64).map(|t| t | (t << 32)).collect();
    let input = dir.join("kv.rec");
    write_records(&input, &keys, &pays);
    let base = ExtSortConfig {
        run_len: 1 << 10,
        r: 8,
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    let out_single = dir.join("kv.single.rec");
    let single =
        ExtSortConfig { partitions: 1, sort_threads: 1, prefetch_buf: 0, ..base.clone() };
    stream::extsort_kv_file(&input, &out_single, &single).unwrap();
    // Stable oracle: sort (key, tag) pairs by key only.
    let mut want: Vec<(u32, u64)> = keys.iter().copied().zip(pays.iter().copied()).collect();
    want.sort_by_key(|&(k, _)| k);
    let (wk, wp): (Vec<u32>, Vec<u64>) = want.into_iter().unzip();
    let mut want_bytes = Vec::new();
    encode_records_into(&wk, &wp, &mut want_bytes);
    assert_eq!(fs::read(&out_single).unwrap(), want_bytes, "single-tree KV != stable sort");
    for (sort_threads, partitions, prefetch_buf) in [(2, 3, 128), (4, 5, 0), (0, 0, 1 << 12)] {
        let cfg = ExtSortConfig { sort_threads, partitions, prefetch_buf, ..base.clone() };
        let out = dir.join(format!("kv.t{sort_threads}p{partitions}.rec"));
        let stats = stream::extsort_kv_file(&input, &out, &cfg).unwrap();
        assert_eq!(
            fs::read(&out).unwrap(),
            want_bytes,
            "t={sort_threads} p={partitions}: KV output differs"
        );
        assert_eq!(stats.keys, keys.len());
    }
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn concurrent_sorts_share_a_spill_dir() {
    // Two sorts spilling into the same directory at once must not
    // collide on spill names or delete each other's segments.
    let dir = scratch("concurrent");
    let mut rng = Rng::new(0xC0C0);
    let a: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
    let b: Vec<u32> = (0..20_000).map(|_| rng.next_u32() % 1000).collect();
    let ia = dir.join("a.u32");
    let ib = dir.join("b.u32");
    write_keys(&ia, &a);
    write_keys(&ib, &b);
    let cfg = ExtSortConfig {
        run_len: 1 << 9,
        r: 8,
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        sort_threads: 2,
        partitions: 2,
        prefetch_buf: 64,
        ..Default::default()
    };
    let (oa, ob) = (dir.join("a.sorted"), dir.join("b.sorted"));
    std::thread::scope(|s| {
        let ha = s.spawn(|| stream::extsort_file(&ia, &oa, &cfg).unwrap());
        let hb = s.spawn(|| stream::extsort_file(&ib, &ob, &cfg).unwrap());
        ha.join().unwrap();
        hb.join().unwrap();
    });
    for (input, output, data) in [(&ia, &oa, &a), (&ib, &ob, &b)] {
        let mut want = data.clone();
        want.sort_unstable();
        let mut bytes = Vec::new();
        encode_keys_into(&want, &mut bytes);
        assert_eq!(&fs::read(output).unwrap(), &bytes, "{}", input.display());
    }
    // Both sorts done: no spill segments may remain.
    assert_eq!(count_spill_files(&dir), 0, "spill files left behind");
    fs::remove_dir_all(&dir).unwrap();
}

fn count_spill_files(dir: &Path) -> usize {
    fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            let n = e.file_name();
            let n = n.to_string_lossy().into_owned();
            n.contains("spill") && (n.ends_with(".u32") || n.ends_with(".kv12"))
        })
        .count()
}

#[test]
fn failed_sort_leaves_the_spill_dir_empty() {
    let dir = scratch("failure");
    let mut rng = Rng::new(0xDEAD);
    let keys: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
    let input = dir.join("in.u32");
    write_keys(&input, &keys);
    // The output's parent is a regular file, so creating the output
    // fails *after* run formation has spilled segments. The drop guard
    // must unlink every spill file on the error path.
    let blocker = dir.join("blocker");
    fs::write(&blocker, b"not a directory").unwrap();
    let cfg = ExtSortConfig {
        run_len: 1 << 9,
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        sort_threads: 2,
        ..Default::default()
    };
    let err = stream::extsort_file(&input, &blocker.join("out.u32"), &cfg);
    assert!(err.is_err(), "sort into a file's child path must fail");
    assert_eq!(count_spill_files(&dir), 0, "failed sort leaked spill files");
    // KV twin of the same failure.
    let pays: Vec<u64> = (0..keys.len() as u64).collect();
    let kin = dir.join("in.rec");
    write_records(&kin, &keys, &pays);
    let err = stream::extsort_kv_file(&kin, &blocker.join("out.rec"), &cfg);
    assert!(err.is_err());
    assert_eq!(count_spill_files(&dir), 0, "failed KV sort leaked spill files");
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn file_sort_reports_phase_timings() {
    let dir = scratch("stats");
    let mut rng = Rng::new(0x717);
    let keys: Vec<u32> = (0..30_000).map(|_| rng.next_u32()).collect();
    let input = dir.join("in.u32");
    write_keys(&input, &keys);
    let cfg = ExtSortConfig {
        run_len: 1 << 10,
        max_fanin: 4,
        spill_dir: Some(dir.clone()),
        sort_threads: 2,
        partitions: 2,
        prefetch_buf: 512,
        ..Default::default()
    };
    let stats = stream::extsort_file(&input, &dir.join("out.u32"), &cfg).unwrap();
    assert_eq!(stats.keys, keys.len());
    assert!(stats.merge_passes >= 1, "{stats:?}");
    assert!(stats.run_form_secs > 0.0, "{stats:?}");
    assert!(stats.merge_secs > 0.0, "{stats:?}");
    assert!(stats.io_wait_secs >= 0.0, "{stats:?}");
    assert!(stats.partitions >= 1, "{stats:?}");
    assert!(stats.tree.kernel_rows as usize >= keys.len(), "{stats:?}");
    fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn in_memory_parallel_merge_matches_single_tree() {
    // The library-level partitioned merge (planner phase 3) against the
    // single tree, on ragged duplicate-heavy runs.
    let mut rng = Rng::new(0x9A9);
    let runs: Vec<Vec<u32>> =
        (0..11).map(|_| rng.sorted_list_ragged(0, 4000, 50)).collect();
    let want = stream::merge_runs(&runs, 8).unwrap();
    for parts in [0, 1, 2, 5, 16] {
        assert_eq!(merge_runs_parallel(&runs, 8, parts).unwrap(), want, "parts={parts}");
    }
    // KV: unique tags make stability violations visible.
    let mut tag = 0u64;
    let kv_runs: Vec<(Vec<u32>, Vec<u64>)> = (0..7)
        .map(|_| {
            let ks = rng.sorted_list_ragged(0, 3000, 40);
            let ps: Vec<u64> = ks
                .iter()
                .map(|_| {
                    tag += 1;
                    tag
                })
                .collect();
            (ks, ps)
        })
        .collect();
    let want = stream::merge_runs_kv(&kv_runs, 8).unwrap();
    for parts in [0, 2, 4, 9] {
        let got = merge_runs_kv_parallel(&kv_runs, 8, parts).unwrap();
        assert_eq!(got, want, "parts={parts}");
    }
}
