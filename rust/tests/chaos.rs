//! Chaos capstone: seeded fault storms through the spill and serving
//! paths. Every test installs a [`loms::util::fault::FaultPlan`] (the
//! install guard also serializes chaos tests and shields them from any
//! ambient `LOMS_FAULTS` the CI matrix sets on the whole binary), then
//! asserts the only observable outcomes are byte-identical output or a
//! typed error with the spill directory left clean — never a panic,
//! never silently wrong bytes.

use loms::stream::{
    encode_block_meta, encode_keys_into, extsort, extsort_file, extsort_kv, sidecar_path,
    ExtSortConfig, ExtSortError, IoWait, SortedStream, SpillBlockMeta, SpillRunStream,
    SPILL_BLOCK_RECS,
};
use loms::util::crc32::crc32;
use loms::util::fault::{self, FaultPlan, Site};
use loms::util::Rng;
use std::fs;
use std::path::{Path, PathBuf};

/// Fresh scratch dir per test (process id + label keep parallel test
/// binaries and parallel tests apart).
fn scratch(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("loms_chaos_{}_{label}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// The typed spill error somewhere in an anyhow context chain.
fn spill_error(e: &anyhow::Error) -> Option<&ExtSortError> {
    e.chain().find_map(|c| c.downcast_ref::<ExtSortError>())
}

fn entries(dir: &Path) -> Vec<PathBuf> {
    match fs::read_dir(dir) {
        Ok(rd) => rd.map(|e| e.unwrap().path()).collect(),
        Err(_) => Vec::new(),
    }
}

fn cfg(spill: &Path) -> ExtSortConfig {
    ExtSortConfig {
        run_len: 4096,
        max_fanin: 4,
        spill_dir: Some(spill.to_path_buf()),
        prefetch_buf: 1024,
        ..ExtSortConfig::default()
    }
}

/// Write a spill segment the way the sorter does: raw LE keys plus the
/// per-block CRC sidecar.
fn write_segment(path: &Path, keys: &[u32]) {
    let mut bytes = Vec::new();
    encode_keys_into(keys, &mut bytes);
    let mut side = Vec::new();
    for block in bytes.chunks(SPILL_BLOCK_RECS * 4) {
        let meta = SpillBlockMeta {
            stride: 4,
            rec_count: (block.len() / 4).min(SPILL_BLOCK_RECS) as u16,
            crc: crc32(block),
        };
        encode_block_meta(&meta, &mut side);
    }
    fs::write(path, &bytes).unwrap();
    fs::write(sidecar_path(path), &side).unwrap();
}

fn drain(path: &Path, start: u64, keys: u64, wait: &IoWait) -> anyhow::Result<Vec<u32>> {
    let mut s = SpillRunStream::open(path, start, keys, 0, wait.clone())?;
    let mut out = Vec::new();
    loop {
        if s.next_chunk(4096, &mut out)? == 0 {
            return Ok(out);
        }
    }
}

fn flip_byte(path: &Path, offset: usize) {
    let mut bytes = fs::read(path).unwrap();
    bytes[offset] ^= 0x10;
    fs::write(path, bytes).unwrap();
}

/// On-disk corruption that survives the bounded re-read must surface as
/// `ExtSortError::CorruptSpill` naming the bad block — in the data
/// file, in the sidecar, and on truncation.
#[test]
fn on_disk_corruption_is_a_typed_error() {
    let _g = fault::install(&FaultPlan::new(0)); // no injection: real disk damage only
    let dir = scratch("disk_corrupt");
    let seg = dir.join("seg.bin");
    let keys: Vec<u32> = (0..40_000u32).collect();
    write_segment(&seg, &keys);

    // Clean segment round-trips, full range and a block-straddling window.
    let wait = IoWait::new();
    assert_eq!(drain(&seg, 0, 40_000, &wait).unwrap(), keys);
    assert_eq!(drain(&seg, 10_000, 20_000, &wait).unwrap(), &keys[10_000..30_000]);
    assert_eq!(wait.corrupt_detected(), 0);

    // One flipped payload byte in block 1 (bytes 65536..131072).
    flip_byte(&seg, 70_000);
    let wait = IoWait::new();
    let err = drain(&seg, 0, 40_000, &wait).unwrap_err();
    match spill_error(&err) {
        Some(ExtSortError::CorruptSpill { run, offset }) => {
            assert_eq!(run, &seg);
            assert_eq!(*offset, 65_536, "{err:#}");
        }
        other => panic!("want CorruptSpill, got {other:?} ({err:#})"),
    }
    // Detected on attempt 0 and again after the one bounded re-read.
    assert_eq!(wait.read_retries(), 1);
    assert_eq!(wait.corrupt_detected(), 2);
    flip_byte(&seg, 70_000); // restore

    // A flipped CRC byte in block 2's sidecar entry fails that block.
    let side = sidecar_path(&seg);
    flip_byte(&side, 2 * 12 + 8);
    let err = drain(&seg, 0, 40_000, &IoWait::new()).unwrap_err();
    match spill_error(&err) {
        Some(ExtSortError::CorruptSpill { offset, .. }) => assert_eq!(*offset, 131_072),
        other => panic!("want CorruptSpill, got {other:?} ({err:#})"),
    }
    flip_byte(&side, 2 * 12 + 8); // restore

    // A smashed sidecar magic is rejected at open, before any data read.
    flip_byte(&side, 0);
    let err = drain(&seg, 0, 40_000, &IoWait::new()).unwrap_err();
    assert!(
        matches!(spill_error(&err), Some(ExtSortError::CorruptSpill { .. })),
        "{err:#}"
    );
    flip_byte(&side, 0); // restore

    // Truncation: the run index now points past end-of-file.
    let bytes = fs::read(&seg).unwrap();
    fs::write(&seg, &bytes[..bytes.len() - 4]).unwrap();
    let err = drain(&seg, 0, 40_000, &IoWait::new()).unwrap_err();
    assert!(
        matches!(spill_error(&err), Some(ExtSortError::CorruptSpill { .. })),
        "{err:#}"
    );
    fs::remove_dir_all(&dir).unwrap();
}

/// Transient read faults (in-memory bit flips, short reads) are
/// recovered by the bounded re-read: output stays byte-identical and
/// the stats record every detection and retry.
#[test]
fn transient_read_corruption_recovers_byte_identical() {
    let dir = scratch("transient");
    let mut rng = Rng::new(0x7A57);
    let n = 120_000;
    let data: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
    let mut want = data.clone();
    want.sort_unstable();

    let plan = FaultPlan::new(11)
        .with_max(Site::SpillCorruptByte, 1.0, 3)
        .with_max(Site::SpillReadShort, 1.0, 2);
    let _g = fault::install(&plan);
    let (out, stats) = extsort(&data, &cfg(&dir)).unwrap();
    assert_eq!(out, want, "recovered output must be byte-identical");
    // 5 capped faults land on 3..=5 distinct block reads (short and
    // corrupt can co-fire on one read); every failed read is retried
    // once, and at least one pure corruption is detected by checksum.
    assert_eq!(fault::injected(Site::SpillCorruptByte), 3);
    assert_eq!(fault::injected(Site::SpillReadShort), 2);
    assert!((3..=5).contains(&stats.read_retries), "{stats:?}");
    assert!((1..=3).contains(&stats.corrupt_detected), "{stats:?}");
    assert!(entries(&dir).is_empty(), "spill dir not cleaned: {:?}", entries(&dir));
    fs::remove_dir_all(&dir).unwrap();
}

/// The key-value engine shares the verified reader: same storm, same
/// recovery, payloads still riding their keys.
#[test]
fn transient_read_corruption_recovers_kv() {
    let dir = scratch("transient_kv");
    let n = 90_000u32;
    let mut keys: Vec<u32> = (0..n).collect();
    let mut rng = Rng::new(0x6B5E);
    rng.shuffle(&mut keys);
    let pays: Vec<u64> = keys.iter().map(|&k| u64::from(k) * 7 + 1).collect();

    let plan = FaultPlan::new(13)
        .with_max(Site::SpillCorruptByte, 1.0, 2)
        .with_max(Site::SpillReadShort, 1.0, 2);
    let _g = fault::install(&plan);
    let (ok, op, stats) = extsort_kv(&keys, &pays, &cfg(&dir)).unwrap();
    assert!(ok.windows(2).all(|w| w[0] <= w[1]));
    assert_eq!(ok, (0..n).collect::<Vec<_>>());
    assert!(op.iter().zip(&ok).all(|(&p, &k)| p == u64::from(k) * 7 + 1));
    assert!(stats.read_retries >= 2, "{stats:?}");
    assert!(entries(&dir).is_empty());
    fs::remove_dir_all(&dir).unwrap();
}

/// A guaranteed disk-full on spill write: the sort fails with the typed
/// ENOSPC error and the guard leaves no spill files behind.
#[test]
fn enospc_fails_typed_and_cleans_spill_dir() {
    let dir = scratch("enospc");
    let spill = dir.join("spill");
    let mut rng = Rng::new(0xE05C);
    let data: Vec<u32> = (0..50_000).map(|_| rng.next_u32()).collect();

    let plan = FaultPlan::new(3).with(Site::SpillWriteEnospc, 1.0);
    let _g = fault::install(&plan);

    // In-memory input, spilled runs.
    let err = extsort(&data, &cfg(&spill)).unwrap_err();
    match spill_error(&err) {
        Some(ExtSortError::Spill(io)) => assert_eq!(io.raw_os_error(), Some(28), "{err:#}"),
        other => panic!("want Spill(ENOSPC), got {other:?} ({err:#})"),
    }
    assert!(entries(&spill).is_empty(), "guard left spill files: {:?}", entries(&spill));

    // File-to-file path.
    let input = dir.join("in.u32");
    let output = dir.join("out.u32");
    let mut bytes = Vec::new();
    encode_keys_into(&data, &mut bytes);
    fs::write(&input, &bytes).unwrap();
    let err = extsort_file(&input, &output, &cfg(&spill)).unwrap_err();
    assert!(
        matches!(spill_error(&err), Some(ExtSortError::Spill(_))),
        "{err:#}"
    );
    assert!(entries(&spill).is_empty());

    // Key-value path.
    let pays: Vec<u64> = (0..data.len() as u64).collect();
    let err = extsort_kv(&data, &pays, &cfg(&spill)).unwrap_err();
    assert!(
        matches!(spill_error(&err), Some(ExtSortError::Spill(_))),
        "{err:#}"
    );
    assert!(entries(&spill).is_empty());
    fs::remove_dir_all(&dir).unwrap();
}

/// Seeded mixed storms: across seeds the only outcomes are a
/// byte-identical sort or a typed error, and the spill directory is
/// empty either way.
#[test]
fn seeded_fault_storms_never_corrupt_output() {
    let mut rng = Rng::new(0x5702);
    let data: Vec<u32> = (0..150_000).map(|_| rng.next_u32()).collect();
    let mut want = data.clone();
    want.sort_unstable();

    for seed in 0..6u64 {
        let dir = scratch(&format!("storm_{seed}"));
        let mut plan = FaultPlan::new(seed)
            .with(Site::SpillCorruptByte, 0.05)
            .with(Site::SpillReadShort, 0.05);
        if seed != 0 {
            // Seed 0 keeps one guaranteed-clean-write run in the matrix
            // so the Ok arm is always exercised.
            plan = plan.with(Site::SpillWriteEnospc, 0.02);
        }
        let _g = fault::install(&plan);
        match extsort(&data, &cfg(&dir)) {
            Ok((out, _)) => assert_eq!(out, want, "seed {seed}: silent corruption"),
            Err(e) => assert!(
                spill_error(&e).is_some(),
                "seed {seed}: untyped failure: {e:#}"
            ),
        }
        assert!(
            entries(&dir).is_empty(),
            "seed {seed}: spill dir not cleaned: {:?}",
            entries(&dir)
        );
        fs::remove_dir_all(&dir).unwrap();
    }
}

mod net {
    use super::*;
    use loms::coordinator::{MergeService, ServiceConfig, SoftwareBackend};
    use loms::net::{run_load, NetClient, NetServer, NetServerConfig};
    use loms::obs::expo;

    fn start_server(cfg: NetServerConfig) -> NetServer {
        let svc =
            MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default())
                .expect("service");
        NetServer::start("127.0.0.1:0", svc, cfg).expect("server")
    }

    /// Connection kills, write stalls and transient exec failures, all
    /// at once: the retrying load generator still gets every response
    /// oracle-correct, and the counters account for each injected
    /// fault exactly.
    #[test]
    fn killed_connections_recover_oracle_correct() {
        let plan = FaultPlan::new(21)
            .with_max(Site::NetConnReset, 1.0, 4)
            .with_max(Site::NetWriteStall, 1.0, 2)
            .with_max(Site::ExecTransient, 1.0, 5);
        let _g = fault::install(&plan);
        let server = start_server(NetServerConfig { workers: 3, ..NetServerConfig::default() });
        let addr = server.addr().to_string();
        let report = run_load(&addr, 3, 4, 120, 0xC405, false).expect("load");
        assert_eq!(report.ok, 120, "{report:?}");
        assert_eq!(report.errors, 0, "{report:?}");
        assert_eq!(report.failed_conns, 0, "{:?}", report.conn_errors);
        assert!(report.retries >= 1, "no reconnect recorded: {report:?}");

        let snap = server.service().metrics().snapshot();
        // Each site fires to its cap (probability 1.0, plenty of
        // evaluations) and every fire is mirrored into the metrics.
        assert_eq!(fault::injected(Site::NetConnReset), 4);
        assert_eq!(fault::injected(Site::NetWriteStall), 2);
        assert_eq!(fault::injected(Site::ExecTransient), 5);
        assert_eq!(snap.faults_injected, 11, "{snap:?}");
        assert_eq!(snap.retries, 5, "transient execs absorbed in place: {snap:?}");
        assert_eq!(snap.sheds, 0, "{snap:?}");
        server.shutdown();
    }

    /// A tiny admission watermark sheds aggressively with `OVERLOADED`;
    /// the load generator resubmits until everything completes, so
    /// shedding degrades latency, never correctness.
    #[test]
    fn overload_shedding_resubmits_to_completion() {
        let _g = fault::install(&FaultPlan::new(0)); // shed policy only, no injection
        let server = start_server(NetServerConfig {
            workers: 2,
            shed_pending: 2,
            ..NetServerConfig::default()
        });
        let addr = server.addr().to_string();
        let report = run_load(&addr, 2, 8, 80, 0x5EDD, true).expect("load");
        assert_eq!(report.ok, 80, "{report:?}");
        assert_eq!(report.errors, 0, "{report:?}");
        assert_eq!(report.failed_conns, 0, "{:?}", report.conn_errors);

        let snap = server.service().metrics().snapshot();
        assert!(snap.sheds > 0, "watermark 2 under 16 pipelined requests must shed: {snap:?}");
        assert!(report.retries >= snap.sheds, "every shed is resubmitted: {report:?} {snap:?}");
        // Shed requests never reached the service, so its pending gauge
        // settled back to zero and accounting balances.
        assert_eq!(server.service().pending(), 0);
        assert_eq!(snap.net_frames_in, snap.net_responses + snap.net_errors, "{snap:?}");
        server.shutdown();
    }

    /// Satellite: injected faults surface in the *stats wire frame* — a
    /// live `loms stats` round-trip reports the same fault/retry/shed
    /// counters the in-process snapshot holds, so chaos runs are
    /// diagnosable from outside the process.
    #[test]
    fn fault_counters_surface_in_the_stats_frame() {
        let plan = FaultPlan::new(29).with_max(Site::ExecTransient, 1.0, 4);
        let _g = fault::install(&plan);
        let server = start_server(NetServerConfig {
            workers: 2,
            shed_pending: 2,
            ..NetServerConfig::default()
        });
        let addr = server.addr().to_string();
        let report = run_load(&addr, 2, 8, 60, 0xFA17, false).expect("load");
        assert_eq!(report.ok, 60, "{report:?}");

        let mut client = NetClient::connect(&*addr).expect("stats connection");
        let doc = client.stats().expect("stats frame");
        expo::check_stats_doc(&doc).expect("stats grammar");
        let faults = doc.get("faults").expect("faults section");
        let get = |k: &str| faults.get(k).and_then(loms::util::Json::as_i64).unwrap();
        assert_eq!(get("faults_injected"), 4, "{doc:?}");
        assert_eq!(get("retries"), 4, "transient execs absorbed in place: {doc:?}");
        let snap = server.service().metrics().snapshot();
        assert_eq!(get("sheds"), snap.sheds as i64, "{doc:?}");
        assert!(
            get("sheds") > 0,
            "watermark 2 under 16 pipelined requests must shed: {doc:?}"
        );
        server.shutdown();
    }
}

/// Satellite: the CLI reports failures as one `error:` line on stderr
/// and a nonzero exit — no panic, no backtrace.
#[test]
fn cli_exits_nonzero_with_diagnostic() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_loms"))
        .args(["sort", "--input", "/nonexistent/loms-chaos.u32"])
        .output()
        .expect("spawn loms");
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "stderr: {stderr}");

    // An invalid LOMS_FAULTS spec must warn and keep running, not abort.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_loms"))
        .env("LOMS_FAULTS", "bogus_site=0.5")
        .args(["sort", "--n", "4096"])
        .output()
        .expect("spawn loms");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
}
