//! Plan-vs-interpreter differential suite: every device family is
//! lowered to a [`CompiledPlan`] and executed against the enum-tree
//! interpreter (`ExecScratch`) on the same inputs — random sorted lists
//! and exhaustive sorted-0-1 patterns, in both `Fast` and `Strict`
//! modes. The full flat vector is compared (not just the output ranks),
//! so every intermediate mux write must agree bit-for-bit.
//!
//! A second tier covers the lane executor (`sortnet::lanes`): every
//! family's [`LanePlan`] — pruned and unpruned — must be bit-exact with
//! `CompiledPlan::run_batch` on whole batches, including batch sizes
//! that are *not* multiples of `LANES` (the scalar-tail path) and
//! multi-thread sharding.

use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::lanes::{self, LanePlan, LaneScratch, LANES};
use loms::sortnet::loms::{loms_2way, loms_3way_median, loms_kway};
use loms::sortnet::mwms::mwms_3way;
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::sortnet::{batcher, s2ms, MergeDevice};
use loms::util::Rng;

/// Every family the paper builds or compares against.
fn family_devices() -> Vec<MergeDevice> {
    vec![
        // LOMS 2-way across column counts and unequal sizes.
        loms_2way(8, 8, 2),
        loms_2way(16, 16, 4),
        loms_2way(7, 5, 3),
        loms_2way(1, 9, 2),
        // LOMS k-way.
        loms_kway(&[7, 7, 7]),
        loms_kway(&[3, 3, 3, 3]),
        // S2MS, equal and unequal.
        s2ms::s2ms(8, 8),
        s2ms::s2ms(5, 12),
        // Batcher baselines.
        batcher::odd_even_merge(8),
        batcher::bitonic_merge(8),
        // MWMS baseline (SortN column/row stages).
        mwms_3way(5),
    ]
}

/// Run the interpreter and the plan on identical flat vectors; assert
/// the entire vectors and the read-out outputs agree.
fn assert_equivalent(d: &MergeDevice, plan: &CompiledPlan, lists: &[Vec<u32>], mode: ExecMode) {
    let mut vi = d.load_inputs(lists);
    let mut vp = vi.clone();
    let ri = ExecScratch::new().run(d, &mut vi, mode, None);
    let rp = plan.run_row(&mut vp, mode, None, &mut PlanScratch::new());
    match (ri, rp) {
        (Ok(()), Ok(())) => {
            assert_eq!(vi, vp, "{} flat vectors diverge ({mode:?})", d.name);
            let plan_out = plan
                .merge_row(lists, mode, &mut PlanScratch::new())
                .unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert_eq!(d.read_outputs(&vi), plan_out, "{} outputs diverge", d.name);
        }
        (Err(ei), Err(ep)) => {
            assert_eq!(
                (ei.stage, ei.block),
                (ep.stage, ep.block),
                "{} strict violations at different sites",
                d.name
            );
        }
        (ri, rp) => panic!("{}: interpreter {ri:?} but plan {rp:?}", d.name),
    }
}

#[test]
fn every_family_matches_on_random_inputs_fast_and_strict() {
    let mut rng = Rng::new(0xD1FF);
    for d in family_devices() {
        let plan = CompiledPlan::compile(&d).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(plan.depth(), d.depth(), "{}", d.name);
        for _ in 0..40 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 1 << 16)).collect();
            for mode in [ExecMode::Fast, ExecMode::Strict] {
                assert_equivalent(&d, &plan, &lists, mode);
            }
        }
    }
}

#[test]
fn every_family_matches_on_all_sorted01_patterns() {
    for d in family_devices() {
        let plan = CompiledPlan::compile(&d).unwrap_or_else(|e| panic!("{e}"));
        // Odometer over all sorted 0-1 patterns (∏ size_l + 1 of them).
        let sizes = d.list_sizes.clone();
        let mut zeros = vec![0usize; sizes.len()];
        'patterns: loop {
            let lists: Vec<Vec<u32>> = sizes
                .iter()
                .zip(&zeros)
                .map(|(&s, &z)| (0..s).map(|i| u32::from(i >= z)).collect())
                .collect();
            for mode in [ExecMode::Fast, ExecMode::Strict] {
                assert_equivalent(&d, &plan, &lists, mode);
            }
            let mut l = 0;
            loop {
                if l == sizes.len() {
                    break 'patterns;
                }
                zeros[l] += 1;
                if zeros[l] <= sizes[l] {
                    break;
                }
                zeros[l] = 0;
                l += 1;
            }
        }
    }
}

#[test]
fn pruned_plans_match_unpruned_outputs() {
    // Pruning drops muxes a stage provably never fires; the *outputs*
    // must stay bit-identical (intermediate dead positions may differ).
    // (loms_kway(&[3,3,3,3]) rather than [7,7,7]: equal odd k-way sizes
    // carry a median tap, and median-tapped devices are never pruned.)
    let mut rng = Rng::new(0xBEEF);
    for d in [mwms_3way(5), loms_kway(&[3, 3, 3, 3])] {
        let plain = CompiledPlan::compile(&d).unwrap();
        let pruned = CompiledPlan::compile_pruned(&d).unwrap();
        assert!(pruned.is_pruned());
        let mut s1 = PlanScratch::new();
        let mut s2 = PlanScratch::new();
        for _ in 0..50 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 500)).collect();
            let a = plain.merge_row(&lists, ExecMode::Fast, &mut s1).unwrap();
            let b = pruned.merge_row(&lists, ExecMode::Strict, &mut s2).unwrap();
            assert_eq!(a, b, "{}", d.name);
        }
    }
}

/// Row-major flat batch of sorted random lists for a device.
fn flat_batch(rng: &mut Rng, d: &MergeDevice, batch: usize) -> Vec<Vec<u32>> {
    d.list_sizes
        .iter()
        .map(|&s| {
            let mut flat = Vec::with_capacity(batch * s);
            for _ in 0..batch {
                flat.extend(rng.sorted_list(s, 1 << 16));
            }
            flat
        })
        .collect()
}

/// The scalar reference: `CompiledPlan::run_batch` in Fast mode.
fn scalar_batch(plan: &CompiledPlan, lists: &[Vec<u32>], batch: usize) -> Vec<u32> {
    let mut out = Vec::new();
    plan.run_batch(lists, batch, ExecMode::Fast, &mut PlanScratch::new(), &mut out)
        .unwrap_or_else(|e| panic!("{}: {e}", plan.name));
    out
}

/// Scalar plans to test a device's lane expansion against: always the
/// plain lowering, plus the pruned one when the auto policy prunes
/// (exercising FilterN shadow slots and tap cones).
fn plans_for(d: &MergeDevice) -> Vec<CompiledPlan> {
    let mut plans = vec![CompiledPlan::compile(d).unwrap_or_else(|e| panic!("{e}"))];
    let auto = CompiledPlan::compile_auto(d).unwrap_or_else(|e| panic!("{e}"));
    if auto.is_pruned() {
        plans.push(auto);
    }
    plans
}

#[test]
fn lane_executor_bit_exact_with_plan_run_batch() {
    // Every family, ragged sizes included; batch sizes straddle tile
    // boundaries so both the transposed path and the scalar tail run
    // (batch < LANES → tail only; multiples of LANES → tiles only).
    let mut rng = Rng::new(0x1A5E5);
    let mut devices = family_devices();
    devices.push(loms_3way_median(5)); // native FilterN (stale untapped positions)
    for d in devices {
        for plan in plans_for(&d) {
            let lane = LanePlan::compile(&plan);
            assert_eq!(lane.total_outputs(), plan.total_outputs(), "{}", d.name);
            assert_eq!(lane.list_sizes(), plan.list_sizes(), "{}", d.name);
            for batch in [1usize, LANES - 1, LANES, LANES + 3, 2 * LANES, 3 * LANES + 7] {
                let lists = flat_batch(&mut rng, &d, batch);
                let want = scalar_batch(&plan, &lists, batch);
                let mut got = Vec::new();
                lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got)
                    .unwrap_or_else(|e| panic!("{}: {e}", d.name));
                assert_eq!(
                    got,
                    want,
                    "{} pruned={} batch={batch}",
                    d.name,
                    plan.is_pruned()
                );
            }
        }
    }
}

#[test]
fn lane_executor_matches_on_all_sorted01_patterns_as_one_batch() {
    // Exhaustive: every sorted-0-1 pattern of every family, packed into
    // a single batch (whose size is in general NOT a multiple of LANES —
    // the tail rows get exhaustive coverage too).
    for d in family_devices() {
        for plan in plans_for(&d) {
            let lane = LanePlan::compile(&plan);
            let sizes = d.list_sizes.clone();
            let mut rows: Vec<Vec<Vec<u32>>> = Vec::new();
            let mut zeros = vec![0usize; sizes.len()];
            'patterns: loop {
                rows.push(
                    sizes
                        .iter()
                        .zip(&zeros)
                        .map(|(&s, &z)| (0..s).map(|i| u32::from(i >= z)).collect())
                        .collect(),
                );
                let mut l = 0;
                loop {
                    if l == sizes.len() {
                        break 'patterns;
                    }
                    zeros[l] += 1;
                    if zeros[l] <= sizes[l] {
                        break;
                    }
                    zeros[l] = 0;
                    l += 1;
                }
            }
            let batch = rows.len();
            let lists: Vec<Vec<u32>> = (0..sizes.len())
                .map(|l| rows.iter().flat_map(|r| r[l].iter().copied()).collect())
                .collect();
            let want = scalar_batch(&plan, &lists, batch);
            let mut got = Vec::new();
            lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got)
                .unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert_eq!(got, want, "{} pruned={} ({batch} patterns)", d.name, plan.is_pruned());
        }
    }
}

#[test]
fn sharded_lane_execution_matches_scalar_for_any_thread_count() {
    let mut rng = Rng::new(0xCAFE);
    for d in [loms_2way(8, 8, 2), loms_2way(7, 5, 3), loms_kway(&[7, 7, 7])] {
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let batch = 7 * LANES + 9; // several tiles + a tail in the last shard
        let lists = flat_batch(&mut rng, &d, batch);
        let want = scalar_batch(&plan, &lists, batch);
        for threads in [1usize, 2, 3, 5, 16] {
            let mut got = Vec::new();
            lanes::run_batch_sharded(&lane, &plan, &lists, batch, threads, &mut got)
                .unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert_eq!(got, want, "{} threads={threads}", d.name);
        }
    }
}

#[test]
fn strict_violation_sites_agree_between_plan_and_interpreter() {
    // Deliberately unsorted runs through an S2MS device: both executors
    // must flag the same (stage, block) in strict mode.
    let d = s2ms::s2ms(4, 4);
    let plan = CompiledPlan::compile(&d).unwrap();
    let lists = vec![vec![9u32, 1, 2, 3], vec![1, 2, 3, 4]];
    assert_equivalent(&d, &plan, &lists, ExecMode::Strict);
    // Fast mode tolerates the garbage identically on both paths.
    assert_equivalent(&d, &plan, &lists, ExecMode::Fast);
}
