//! Plan-vs-interpreter differential suite: every device family is
//! lowered to a [`CompiledPlan`] and executed against the enum-tree
//! interpreter (`ExecScratch`) on the same inputs — random sorted lists
//! and exhaustive sorted-0-1 patterns, in both `Fast` and `Strict`
//! modes. The full flat vector is compared (not just the output ranks),
//! so every intermediate mux write must agree bit-for-bit.

use loms::sortnet::exec::{ExecMode, ExecScratch};
use loms::sortnet::loms::{loms_2way, loms_kway};
use loms::sortnet::mwms::mwms_3way;
use loms::sortnet::plan::{CompiledPlan, PlanScratch};
use loms::sortnet::{batcher, s2ms, MergeDevice};
use loms::util::Rng;

/// Every family the paper builds or compares against.
fn family_devices() -> Vec<MergeDevice> {
    vec![
        // LOMS 2-way across column counts and unequal sizes.
        loms_2way(8, 8, 2),
        loms_2way(16, 16, 4),
        loms_2way(7, 5, 3),
        loms_2way(1, 9, 2),
        // LOMS k-way.
        loms_kway(&[7, 7, 7]),
        loms_kway(&[3, 3, 3, 3]),
        // S2MS, equal and unequal.
        s2ms::s2ms(8, 8),
        s2ms::s2ms(5, 12),
        // Batcher baselines.
        batcher::odd_even_merge(8),
        batcher::bitonic_merge(8),
        // MWMS baseline (SortN column/row stages).
        mwms_3way(5),
    ]
}

/// Run the interpreter and the plan on identical flat vectors; assert
/// the entire vectors and the read-out outputs agree.
fn assert_equivalent(d: &MergeDevice, plan: &CompiledPlan, lists: &[Vec<u32>], mode: ExecMode) {
    let mut vi = d.load_inputs(lists);
    let mut vp = vi.clone();
    let ri = ExecScratch::new().run(d, &mut vi, mode, None);
    let rp = plan.run_row(&mut vp, mode, None, &mut PlanScratch::new());
    match (ri, rp) {
        (Ok(()), Ok(())) => {
            assert_eq!(vi, vp, "{} flat vectors diverge ({mode:?})", d.name);
            let plan_out = plan
                .merge_row(lists, mode, &mut PlanScratch::new())
                .unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert_eq!(d.read_outputs(&vi), plan_out, "{} outputs diverge", d.name);
        }
        (Err(ei), Err(ep)) => {
            assert_eq!(
                (ei.stage, ei.block),
                (ep.stage, ep.block),
                "{} strict violations at different sites",
                d.name
            );
        }
        (ri, rp) => panic!("{}: interpreter {ri:?} but plan {rp:?}", d.name),
    }
}

#[test]
fn every_family_matches_on_random_inputs_fast_and_strict() {
    let mut rng = Rng::new(0xD1FF);
    for d in family_devices() {
        let plan = CompiledPlan::compile(&d).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(plan.depth(), d.depth(), "{}", d.name);
        for _ in 0..40 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 1 << 16)).collect();
            for mode in [ExecMode::Fast, ExecMode::Strict] {
                assert_equivalent(&d, &plan, &lists, mode);
            }
        }
    }
}

#[test]
fn every_family_matches_on_all_sorted01_patterns() {
    for d in family_devices() {
        let plan = CompiledPlan::compile(&d).unwrap_or_else(|e| panic!("{e}"));
        // Odometer over all sorted 0-1 patterns (∏ size_l + 1 of them).
        let sizes = d.list_sizes.clone();
        let mut zeros = vec![0usize; sizes.len()];
        'patterns: loop {
            let lists: Vec<Vec<u32>> = sizes
                .iter()
                .zip(&zeros)
                .map(|(&s, &z)| (0..s).map(|i| u32::from(i >= z)).collect())
                .collect();
            for mode in [ExecMode::Fast, ExecMode::Strict] {
                assert_equivalent(&d, &plan, &lists, mode);
            }
            let mut l = 0;
            loop {
                if l == sizes.len() {
                    break 'patterns;
                }
                zeros[l] += 1;
                if zeros[l] <= sizes[l] {
                    break;
                }
                zeros[l] = 0;
                l += 1;
            }
        }
    }
}

#[test]
fn pruned_plans_match_unpruned_outputs() {
    // Pruning drops muxes a stage provably never fires; the *outputs*
    // must stay bit-identical (intermediate dead positions may differ).
    // (loms_kway(&[3,3,3,3]) rather than [7,7,7]: equal odd k-way sizes
    // carry a median tap, and median-tapped devices are never pruned.)
    let mut rng = Rng::new(0xBEEF);
    for d in [mwms_3way(5), loms_kway(&[3, 3, 3, 3])] {
        let plain = CompiledPlan::compile(&d).unwrap();
        let pruned = CompiledPlan::compile_pruned(&d).unwrap();
        assert!(pruned.is_pruned());
        let mut s1 = PlanScratch::new();
        let mut s2 = PlanScratch::new();
        for _ in 0..50 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 500)).collect();
            let a = plain.merge_row(&lists, ExecMode::Fast, &mut s1).unwrap();
            let b = pruned.merge_row(&lists, ExecMode::Strict, &mut s2).unwrap();
            assert_eq!(a, b, "{}", d.name);
        }
    }
}

#[test]
fn strict_violation_sites_agree_between_plan_and_interpreter() {
    // Deliberately unsorted runs through an S2MS device: both executors
    // must flag the same (stage, block) in strict mode.
    let d = s2ms::s2ms(4, 4);
    let plan = CompiledPlan::compile(&d).unwrap();
    let lists = vec![vec![9u32, 1, 2, 3], vec![1, 2, 3, 4]];
    assert_equivalent(&d, &plan, &lists, ExecMode::Strict);
    // Fast mode tolerates the garbage identically on both paths.
    assert_equivalent(&d, &plan, &lists, ExecMode::Fast);
}
