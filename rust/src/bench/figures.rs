//! One constructor per paper figure/table (§VII). Every number comes
//! from the frozen, once-calibrated cost model; curve shapes, crossovers
//! and speedups are consequences of the network structures.

use super::{timing, FigReport, Series};
use crate::fpga::{CostModel, Methodology, ULTRASCALE_PLUS, VERSAL_PRIME};
use crate::sortnet::loms::{loms_2way, loms_3way_median, loms_kway, loms_kway_validated, table1_stage_count};
use crate::sortnet::mwms::{
    mwms_3way_cost_proxy, mwms_3way_median_cost_proxy, mwms_3way_min_stages, paper_stage_counts,
};
use crate::sortnet::validate::validate_merge_01;
use crate::sortnet::{batcher, s2ms};

/// Output sizes used by the 2-way speed/LUT figures.
const SMALL_OUTS: [usize; 5] = [4, 8, 16, 32, 64];

fn batcher_vs_s2ms_speed(width: usize, id: &str) -> FigReport {
    let mut series = Vec::new();
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        let m = CostModel::new(fpga, Methodology::TwoInsLut, width);
        series.push(Series {
            label: format!("Batcher {}", fpga.name),
            points: SMALL_OUTS
                .iter()
                .map(|&o| (o, m.delay_ns(&batcher::odd_even_merge(o / 2))))
                .collect(),
        });
    }
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        let m = CostModel::new(fpga, Methodology::TwoInsLut, width);
        series.push(Series {
            label: format!("S2MS {}", fpga.name),
            points: SMALL_OUTS.iter().map(|&o| (o, m.delay_ns(&s2ms::s2ms(o / 2, o / 2)))).collect(),
        });
    }
    FigReport {
        id: id.into(),
        title: format!("Batcher vs Single-Stage 2-way Merge speed, {width}-bit values"),
        x_label: "outputs".into(),
        y_label: "propagation delay (ns)".into(),
        series,
        notes: vec![
            "OEMS and Bitonic have identical delays per FPGA (plotted as 'Batcher')".into(),
        ],
    }
}

/// Fig. 11: 8-bit Batcher vs S2MS speed on both FPGAs.
pub fn fig11() -> FigReport {
    let mut f = batcher_vs_s2ms_speed(8, "fig11");
    let v = f.series.iter().find(|s| s.label == "Batcher xcvm1102").unwrap().points.clone();
    let u = f.series.iter().find(|s| s.label == "Batcher xcku5p").unwrap().points.clone();
    let versal_faster = v.iter().zip(&u).all(|(a, b)| a.1 <= b.1);
    f.notes.push(format!("8-bit: Versal Batcher faster than US+ across sizes = {versal_faster}"));
    f
}

/// Fig. 12: 32-bit version (Versal/US+ Batcher ordering reverses).
pub fn fig12() -> FigReport {
    let mut f = batcher_vs_s2ms_speed(32, "fig12");
    let v = f.series.iter().find(|s| s.label == "Batcher xcvm1102").unwrap().points.clone();
    let u = f.series.iter().find(|s| s.label == "Batcher xcku5p").unwrap().points.clone();
    let versal_slower = v.iter().zip(&u).all(|(a, b)| a.1 >= b.1);
    f.notes.push(format!("32-bit: Versal Batcher slower than US+ across sizes = {versal_slower}"));
    f
}

/// Fig. 13: 32-bit LUT usage — OEMS, Bitonic (identical on both FPGAs),
/// S2MS on each FPGA.
pub fn fig13() -> FigReport {
    let mut series = Vec::new();
    let us = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
    series.push(Series {
        label: "OEMS (both FPGAs)".into(),
        points: SMALL_OUTS.iter().map(|&o| (o, us.luts(&batcher::odd_even_merge(o / 2)) as f64)).collect(),
    });
    series.push(Series {
        label: "Bitonic (both FPGAs)".into(),
        points: SMALL_OUTS.iter().map(|&o| (o, us.luts(&batcher::bitonic_merge(o / 2)) as f64)).collect(),
    });
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        let m = CostModel::new(fpga, Methodology::TwoInsLut, 32);
        series.push(Series {
            label: format!("S2MS {}", fpga.name),
            points: SMALL_OUTS.iter().map(|&o| (o, m.luts(&s2ms::s2ms(o / 2, o / 2)) as f64)).collect(),
        });
    }
    FigReport {
        id: "fig13".into(),
        title: "Batcher vs Single-Stage 2-way Merge LUTs, 32-bit values".into(),
        x_label: "outputs".into(),
        y_label: "LUTs".into(),
        series,
        notes: vec!["Batcher merge sorters use the fewest LUTs overall".into()],
    }
}

/// Figs. 14/15: 32-bit Versal 4insLUT — Bitonic vs S2MS vs 2-col LOMS,
/// small devices (4–16 outputs). `luts=false` → speed, else LUTs.
fn fig14_15(luts: bool) -> FigReport {
    let outs = [4usize, 8, 16];
    let m4 = CostModel::new(VERSAL_PRIME, Methodology::FourInsLut, 32);
    let m2 = CostModel::new(VERSAL_PRIME, Methodology::TwoInsLut, 32);
    let y = |model: &CostModel, d: &crate::sortnet::MergeDevice| -> f64 {
        if luts {
            model.luts(d) as f64
        } else {
            model.delay_ns(d)
        }
    };
    let mut series = vec![
        Series {
            label: "Bitonic (2insLUT)".into(),
            points: outs.iter().map(|&o| (o, y(&m2, &batcher::bitonic_merge(o / 2)))).collect(),
        },
        Series {
            label: "S2MS 4insLUT".into(),
            points: outs.iter().map(|&o| (o, y(&m4, &s2ms::s2ms(o / 2, o / 2)))).collect(),
        },
        Series {
            label: "LOMS 2col 4insLUT".into(),
            points: outs
                .iter()
                .filter(|&&o| o >= 8)
                .map(|&o| (o, y(&m4, &loms_2way(o / 2, o / 2, 2))))
                .collect(),
        },
    ];
    // Crossover notes (the paper's §VII-B claims).
    let note = if luts {
        let s2ms4 = m4.luts(&s2ms::s2ms(2, 2));
        let bit4 = m2.luts(&batcher::bitonic_merge(2));
        let loms8 = m4.luts(&loms_2way(4, 4, 2));
        let bit8 = m2.luts(&batcher::bitonic_merge(4));
        format!(
            "4-out S2MS uses fewer LUTs than Bitonic: {} ({s2ms4} vs {bit4}); \
             8-out LOMS fewer than Bitonic: {} ({loms8} vs {bit8})",
            s2ms4 < bit4,
            loms8 < bit8
        )
    } else {
        "4insLUT devices remain faster than comparable Bitonic".into()
    };
    series.retain(|s| !s.points.is_empty());
    FigReport {
        id: if luts { "fig15".into() } else { "fig14".into() },
        title: format!(
            "32-bit Versal 4insLUT S2MS/LOMS vs Bitonic — {}",
            if luts { "LUT resources" } else { "speed" }
        ),
        x_label: "outputs".into(),
        y_label: if luts { "LUTs".into() } else { "propagation delay (ns)".into() },
        series,
        notes: vec![note],
    }
}

pub fn fig14() -> FigReport {
    fig14_15(false)
}

pub fn fig15() -> FigReport {
    fig14_15(true)
}

/// Figs. 16/17: 32-bit Ultrascale+ 2insLUT — Bitonic vs S2MS vs LOMS
/// 2/4/8-col, up to 256 outputs, with the fit boundary (Fig. 10 marks).
fn fig16_17(luts: bool) -> FigReport {
    let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
    let y = |d: &crate::sortnet::MergeDevice| -> f64 {
        if luts {
            m.luts(d) as f64
        } else {
            m.delay_ns(d)
        }
    };
    let outs_all = [8usize, 16, 32, 64, 128, 256];
    let mut series = vec![
        Series {
            label: "Bitonic".into(),
            points: outs_all.iter().map(|&o| (o, y(&batcher::bitonic_merge(o / 2)))).collect(),
        },
        Series {
            label: "S2MS".into(),
            points: outs_all
                .iter()
                .filter(|&&o| m.report(&s2ms::s2ms(o / 2, o / 2)).fits)
                .map(|&o| (o, y(&s2ms::s2ms(o / 2, o / 2))))
                .collect(),
        },
    ];
    for cols in [2usize, 4, 8] {
        let min_outs = 4 * cols; // Fig. 10: smallest per column count
        series.push(Series {
            label: format!("LOMS {cols}col"),
            points: outs_all
                .iter()
                .filter(|&&o| o >= min_outs)
                .filter(|&&o| m.report(&loms_2way(o / 2, o / 2, cols)).fits)
                .map(|&o| (o, y(&loms_2way(o / 2, o / 2, cols))))
                .collect(),
        });
    }
    let mut notes = Vec::new();
    // The headline anchor (abstract): UP-32/DN-32 2col LOMS.
    let loms64 = m.delay_ns(&loms_2way(32, 32, 2));
    let bat64 = m.delay_ns(&batcher::odd_even_merge(32));
    notes.push(format!(
        "headline: 64-out 2col LOMS = {loms64:.2} ns (paper 2.24), speedup vs Batcher = {:.2} (paper 2.63)",
        bat64 / loms64
    ));
    // Fig. 10 fit marks.
    for (o, name, fits) in [
        (64usize, "S2MS", m.report(&s2ms::s2ms(32, 32)).fits),
        (128, "S2MS", m.report(&s2ms::s2ms(64, 64)).fits),
        (256, "S2MS", m.report(&s2ms::s2ms(128, 128)).fits),
        (256, "LOMS 2col", m.report(&loms_2way(128, 128, 2)).fits),
        (256, "LOMS 8col", m.report(&loms_2way(128, 128, 8)).fits),
    ] {
        notes.push(format!("fit(xcku5p): {name} {o}-out = {fits}"));
    }
    FigReport {
        id: if luts { "fig17".into() } else { "fig16".into() },
        title: format!(
            "32-bit Ultrascale+ 2insLUT S2MS/LOMS vs Bitonic — {}",
            if luts { "LUT resources" } else { "speed" }
        ),
        x_label: "outputs".into(),
        y_label: if luts { "LUTs".into() } else { "propagation delay (ns)".into() },
        series,
        notes,
    }
}

pub fn fig16() -> FigReport {
    fig16_17(false)
}

pub fn fig17() -> FigReport {
    fig16_17(true)
}

/// Figs. 18/19: 3c_7r 3-way median / full-merge propagation delays for
/// LOMS vs the MWMS baseline (priced at the paper's stage counts), per
/// FPGA, at 8 and 32 bits. x-axis = value width.
fn fig18_19(median: bool) -> FigReport {
    let widths = [8usize, 32];
    let mut series = Vec::new();
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        series.push(Series {
            label: format!("LOMS {}", fpga.name),
            points: widths
                .iter()
                .map(|&w| {
                    let m = CostModel::new(fpga, Methodology::TwoInsLut, w);
                    let y = if median {
                        m.median_delay_ns(&loms_3way_median(7)).unwrap()
                    } else {
                        m.delay_ns(&loms_kway(&[7, 7, 7]))
                    };
                    (w, y)
                })
                .collect(),
        });
    }
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        series.push(Series {
            label: format!("MWMS {}", fpga.name),
            points: widths
                .iter()
                .map(|&w| {
                    let m = CostModel::new(fpga, Methodology::TwoInsLut, w);
                    let y = if median {
                        m.delay_ns(&mwms_3way_median_cost_proxy(7))
                    } else {
                        m.delay_ns(&mwms_3way_cost_proxy(7))
                    };
                    (w, y)
                })
                .collect(),
        });
    }
    let m32 = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
    let (loms_d, mwms_d) = if median {
        (
            m32.median_delay_ns(&loms_3way_median(7)).unwrap(),
            m32.delay_ns(&mwms_3way_median_cost_proxy(7)),
        )
    } else {
        (m32.delay_ns(&loms_kway(&[7, 7, 7])), m32.delay_ns(&mwms_3way_cost_proxy(7)))
    };
    let (paper_lo, paper_hi) = if median { (1.45, 1.48) } else { (1.34, 1.36) };
    let notes = vec![
        format!(
            "32-bit US+ speedup LOMS vs MWMS = {:.2} (paper range {paper_lo}-{paper_hi})",
            mwms_d / loms_d
        ),
        format!(
            "MWMS priced at the paper's stage counts {:?}; our validated reconstruction needs (6, 5) — see sortnet::mwms docs",
            paper_stage_counts()
        ),
    ];
    FigReport {
        id: if median { "fig18".into() } else { "fig19".into() },
        title: format!(
            "3c_7r 3-way {} propagation delays",
            if median { "median merge" } else { "full merge" }
        ),
        x_label: "value width (bits)".into(),
        y_label: "propagation delay (ns)".into(),
        series,
        notes,
    }
}

pub fn fig18() -> FigReport {
    fig18_19(true)
}

pub fn fig19() -> FigReport {
    fig18_19(false)
}

/// Fig. 20: 3c_7r full-merge LUT usage (MWMS identical on both FPGAs).
/// The MWMS baseline is cone-pruned (`sortnet::prune`) — the fairest LUT
/// count our reconstruction supports; see the figure note for the
/// remaining reconstruction gap vs the paper's claim.
pub fn fig20() -> FigReport {
    let widths = [8usize, 32];
    let mwms_pruned = crate::sortnet::prune::prune(&crate::sortnet::mwms::mwms_3way(7))
        .expect("prune mwms")
        .0;
    let mut series = Vec::new();
    for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
        series.push(Series {
            label: format!("LOMS {}", fpga.name),
            points: widths
                .iter()
                .map(|&w| {
                    (w, CostModel::new(fpga, Methodology::TwoInsLut, w).luts(&loms_kway(&[7, 7, 7])) as f64)
                })
                .collect(),
        });
    }
    series.push(Series {
        label: "MWMS pruned (both FPGAs)".into(),
        points: widths
            .iter()
            .map(|&w| {
                (
                    w,
                    CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, w)
                        .luts(&mwms_pruned) as f64,
                )
            })
            .collect(),
    });
    let l = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32).luts(&loms_kway(&[7, 7, 7]));
    let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32).luts(&mwms_pruned);
    FigReport {
        id: "fig20".into(),
        title: "3c_7r 3-way full merge LUT resources".into(),
        x_label: "value width (bits)".into(),
        y_label: "LUTs".into(),
        series,
        notes: vec![format!(
            "MWMS fewer LUTs than LOMS (paper claim): {} ({m} vs {l}). Known reconstruction gap:              our MWMS uses full 7-sorter column stages where the authors' device [4] composes              narrower N-sorters/N-filters; cone-pruning recovers ~35% but not the ordering.",
            m < l
        )],
    }
}

/// Fig. 10: the S2MS-device matrix inside S2MS/LOMS sorters with
/// xcku5p 32-bit 2insLUT fit marks (diagonal cells of the paper).
pub fn fig10() -> FigReport {
    let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
    let mut notes = Vec::new();
    let mut series = Vec::new();
    for (label, cols) in [("LOMS 8col", Some(8usize)), ("LOMS 4col", Some(4)), ("LOMS 2col", Some(2)), ("S2MS", None)] {
        let mut points = Vec::new();
        for outs in [4usize, 8, 16, 32, 64, 128, 256] {
            let (min_outs, dev) = match cols {
                Some(c) => (4 * c, Some(loms_2way(outs / 2, outs / 2, c.max(2)))),
                None => (4, Some(s2ms::s2ms(outs / 2, outs / 2))),
            };
            if outs < min_outs {
                continue;
            }
            let d = dev.unwrap();
            let rep = m.report(&d);
            points.push((outs, if rep.fits { 1.0 } else { 0.0 }));
            if !rep.fits {
                notes.push(format!("{label} {outs}-out: does NOT fit xcku5p ({} LUTs > {} budget)", rep.luts, m.fpga.fit_budget()));
            }
        }
        series.push(Series { label: label.into(), points });
    }
    FigReport {
        id: "fig10".into(),
        title: "S2MS device matrix: fit (1) / no-fit (0) on xcku5p, 32-bit 2insLUT".into(),
        x_label: "outputs".into(),
        y_label: "fits".into(),
        series,
        notes,
    }
}

/// Table 1: column/row sorts required per k — claimed vs validated (our
/// reconstruction, equal 2-value lists keep validation exhaustive).
/// `max_validate_k` bounds the exhaustive pass: pattern count is 3^k,
/// so k = 14 costs minutes — the `table1_kway_stages` bench sweeps the
/// full table, the in-process default stops at 9.
pub fn table1_to(max_validate_k: usize) -> FigReport {
    let mut claimed = Vec::new();
    let mut validated = Vec::new();
    for k in 2..=14usize {
        claimed.push((k, table1_stage_count(k) as f64));
        if k > max_validate_k {
            continue;
        }
        let v = if k == 2 {
            let d = loms_2way(2, 2, 2);
            validate_merge_01(&d).unwrap();
            d.depth()
        } else {
            loms_kway_validated(&vec![2; k]).map(|d| d.depth()).unwrap_or(0)
        };
        validated.push((k, v as f64));
    }
    let agree = claimed
        .iter()
        .zip(&validated)
        .filter(|((_, c), (_, v))| v > &0.0 && v <= c)
        .count();
    let note = format!(
        "k where validated ≤ claimed: {agree}/{} (validated up to k={max_validate_k})",
        validated.len()
    );
    FigReport {
        id: "table1".into(),
        title: "Total column/row sorts for a k-way merge (claimed vs validated)".into(),
        x_label: "k lists".into(),
        y_label: "stages".into(),
        series: vec![
            Series { label: "paper Table 1".into(), points: claimed },
            Series { label: "validated (r=2 equal lists)".into(), points: validated },
        ],
        notes: vec![note],
    }
}

/// Table 1 with the default validation bound.
pub fn table1() -> FigReport {
    table1_to(9)
}

/// MWMS reconstruction summary (supplement to Figs. 18-20 notes).
pub fn mwms_note() -> String {
    format!(
        "MWMS 3c_7r reconstruction: validated full merge needs {} stages (paper: {}), median {} (paper: {})",
        mwms_3way_min_stages(7),
        paper_stage_counts().0,
        crate::sortnet::mwms::mwms_3way_median(7).depth(),
        paper_stage_counts().1
    )
}

/// Extension (not a paper figure): full 64-input sorters composed from
/// each merge family (§II's deployment) on the xcku5p cost model —
/// delay and LUTs per composition.
pub fn ext_sorters() -> FigReport {
    use crate::sortnet::sorter::{sorter, MergeFamily};
    let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
    let families = [
        ("OEMS tree", MergeFamily::OddEven),
        ("Bitonic tree", MergeFamily::Bitonic),
        ("S2MS tree", MergeFamily::S2ms),
        ("LOMS-2col tree", MergeFamily::Loms { cols: 2 }),
    ];
    let sizes = [8usize, 16, 32, 64];
    let mut series = Vec::new();
    for (label, fam) in families {
        series.push(Series {
            label: format!("{label} delay"),
            points: sizes.iter().map(|&n| (n, m.delay_ns(&sorter(n, fam)))).collect(),
        });
        series.push(Series {
            label: format!("{label} kLUT"),
            points: sizes.iter().map(|&n| (n, m.luts(&sorter(n, fam)) as f64 / 1000.0)).collect(),
        });
    }
    FigReport {
        id: "ext_sorters".into(),
        title: "Extension: full sorters composed per merge family (xcku5p, 32-bit)".into(),
        x_label: "inputs".into(),
        y_label: "ns / kLUT".into(),
        series,
        notes: vec!["not a paper figure — §II deployment ablation".into()],
    }
}

/// Extension (not a paper figure): software batch-execution throughput
/// of the four executor variants, side by side on the serving shapes —
/// the per-row enum-tree interpreter, [`crate::sortnet::plan`]'s
/// `run_batch`, the transposed lane executor
/// ([`crate::sortnet::lanes`]), and lanes + multi-core sharding.
/// y = ns per merged row; wall-clock via [`timing::bench`].
///
/// Deliberately NOT part of [`all_figures`]: unlike every paper figure
/// it measures wall-clock (machine-dependent, ~2 s to run), so it is
/// only produced when explicitly requested (`loms report --figure
/// ext_plan_throughput`, or the `net_exec_throughput` bench).
pub fn ext_plan_throughput() -> FigReport {
    use crate::sortnet::exec::{ExecMode, ExecScratch};
    use crate::sortnet::lanes::{self, LanePlan, LaneScratch};
    use crate::sortnet::plan::{CompiledPlan, PlanScratch};
    use crate::util::Rng;
    let mut rng = Rng::new(42);
    // The default artifact set's 2col serving shapes, loms2_up32_dn32_b256
    // (the headline batch shape) first.
    let shapes = [(32usize, 256usize), (64, 128)];
    let mut interp_pts = Vec::new();
    let mut plan_pts = Vec::new();
    let mut lane_pts = Vec::new();
    let mut shard_pts = Vec::new();
    let mut notes = vec!["not a paper figure — host serving path, ns per merged row".into()];
    for (m, batch) in shapes {
        let outs = 2 * m;
        let d = loms_2way(m, m, 2);
        let lists: Vec<Vec<u32>> = (0..2)
            .map(|_| {
                let mut flat = Vec::with_capacity(batch * m);
                for _ in 0..batch {
                    flat.extend(rng.sorted_list(m, 1 << 20));
                }
                flat
            })
            .collect();
        let rows = batch as f64;
        let mut out: Vec<u32> = Vec::with_capacity(batch * outs);
        let mut scratch = ExecScratch::new();
        let mut v = vec![0u32; d.n];
        let mi = timing::bench(&format!("interp b{batch} {outs}-out"), || {
            out.clear();
            for row in 0..batch {
                for (l, &s) in [m, m].iter().enumerate() {
                    let slice = &lists[l][row * s..(row + 1) * s];
                    for (i, &x) in slice.iter().enumerate() {
                        v[d.input_map[l][i]] = x;
                    }
                }
                scratch.run(&d, &mut v, ExecMode::Fast, None).unwrap();
                out.extend(d.output_perm.iter().map(|&p| v[p]));
            }
            std::hint::black_box(&out);
        });
        interp_pts.push((outs, mi.mean_ns / rows));
        let plan = CompiledPlan::compile_auto(&d).expect("valid device");
        let mut ps = PlanScratch::new();
        let mp = timing::bench(&format!("plan b{batch} {outs}-out"), || {
            out.clear();
            plan.run_batch(&lists, batch, ExecMode::Fast, &mut ps, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        plan_pts.push((outs, mp.mean_ns / rows));
        let lane = LanePlan::compile(&plan);
        let mut ls = LaneScratch::new();
        let ml = timing::bench(&format!("lanes b{batch} {outs}-out"), || {
            out.clear();
            lane.run_batch(&plan, &lists, batch, &mut ls, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        lane_pts.push((outs, ml.mean_ns / rows));
        let threads = lanes::forced_threads(batch);
        let mt = timing::bench(&format!("lanes+{threads}thr b{batch} {outs}-out"), || {
            out.clear();
            lanes::run_batch_sharded(&lane, &plan, &lists, batch, threads, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        shard_pts.push((outs, mt.mean_ns / rows));
        notes.push(format!(
            "loms2_up{m}_dn{m}_b{batch}: plan {:.2}x, lanes {:.2}x, lanes+{threads}thr {:.2}x \
             vs interpreter ({} CAS/tile over {} slots)",
            mi.mean_ns / mp.mean_ns,
            mi.mean_ns / ml.mean_ns,
            mi.mean_ns / mt.mean_ns,
            lane.cas_count(),
            lane.slots(),
        ));
    }
    FigReport {
        id: "ext_plan_throughput".into(),
        title: "Extension: interpreter vs plan vs lanes vs lanes+threads batch throughput (LOMS 2col)"
            .into(),
        x_label: "outputs".into(),
        y_label: "ns/row".into(),
        series: vec![
            Series { label: "interpreter".into(), points: interp_pts },
            Series { label: "compiled plan".into(), points: plan_pts },
            Series { label: "lane plan".into(), points: lane_pts },
            Series { label: "lanes+threads".into(), points: shard_pts },
        ],
        notes,
    }
}

/// Every figure in §VII, in paper order.
pub fn all_figures() -> Vec<FigReport> {
    vec![
        table1(),
        fig10(),
        fig11(),
        fig12(),
        fig13(),
        fig14(),
        fig15(),
        fig16(),
        fig17(),
        fig18(),
        fig19(),
        fig20(),
        ext_sorters(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_throughput_figure_builds() {
        // Wall-clock figure (not in all_figures): smoke-test its shape —
        // all four executor variants over both serving shapes.
        let f = ext_plan_throughput();
        assert_eq!(f.series.len(), 4);
        assert!(f.series.iter().all(|s| s.points.len() == 2));
        assert!(f.series.iter().all(|s| s.points.iter().all(|&(_, ns)| ns > 0.0)));
        // The serving shape is named in the notes.
        assert!(f.notes.iter().any(|n| n.contains("loms2_up32_dn32_b256")));
    }

    #[test]
    fn all_figures_build_and_have_series() {
        for f in all_figures() {
            assert!(!f.series.is_empty(), "{}", f.id);
            assert!(f.series.iter().any(|s| !s.points.is_empty()), "{}", f.id);
            let csv = f.to_csv();
            assert!(csv.contains(&f.id));
            assert!(!f.to_table().is_empty());
        }
    }

    #[test]
    fn fig16_headline_shape_holds() {
        let f = fig16();
        // S2MS fastest, then LOMS, then Bitonic at 64 outputs.
        let at = |label: &str, x: usize| {
            f.series
                .iter()
                .find(|s| s.label == label)
                .and_then(|s| s.points.iter().find(|&&(px, _)| px == x))
                .map(|&(_, y)| y)
        };
        let s2 = at("S2MS", 64).unwrap();
        let lo = at("LOMS 2col", 64).unwrap();
        let bi = at("Bitonic", 64).unwrap();
        assert!(s2 < lo && lo < bi, "s2ms {s2} loms {lo} bitonic {bi}");
        // S2MS series stops before 128 (doesn't fit), LOMS continues.
        assert!(at("S2MS", 128).is_none());
        assert!(at("LOMS 2col", 128).is_some());
        assert!(at("LOMS 8col", 256).is_some());
    }

    #[test]
    fn fig18_19_speedups_in_paper_ballpark() {
        for (f, lo, hi) in [(fig18(), 1.2, 2.2), (fig19(), 1.1, 2.0)] {
            let note = &f.notes[0];
            let speedup: f64 = note
                .split('=')
                .nth(1)
                .unwrap()
                .trim()
                .split(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap();
            assert!(speedup > lo && speedup < hi, "{}: {note}", f.id);
        }
    }

    #[test]
    fn table1_validated_within_claims() {
        let t = table1_to(7);
        let claimed = &t.series[0].points;
        let validated = &t.series[1].points;
        for ((k, c), (_, v)) in claimed.iter().zip(validated) {
            assert!(*v > 0.0, "k={k} failed to validate");
            assert!(v <= c, "k={k}: validated {v} > claimed {c}");
        }
    }
}
