//! Figure/table regeneration harness — shared by `benches/*` and the
//! `loms report` CLI. One function per paper figure; each returns a
//! [`FigReport`] whose rows/series mirror what the paper plots, computed
//! from the frozen FPGA cost model (DESIGN.md §2 for the substitution).

pub mod figures;
pub mod timing;

use std::fmt::Write as _;

/// Whether this bench invocation asked for smoke mode (`--smoke` on
/// the bench binary's argv — e.g. `cargo bench --bench X -- --smoke` —
/// or `BENCH_SMOKE=1` in the environment). Smoke mode runs the same
/// code paths over tiny shapes so CI can execute every harness in
/// seconds; explicit `BENCH_*` size overrides still win where a bench
/// honours them.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    /// (x, y) points; x is outputs (2-way figures) or bit-width (3-way).
    pub points: Vec<(usize, f64)>,
}

/// A regenerated figure/table.
#[derive(Debug, Clone)]
pub struct FigReport {
    pub id: String,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
    /// Free-form annotation lines (headline numbers, fit marks, notes).
    pub notes: Vec<String>,
}

impl FigReport {
    /// CSV: `figure,series,x,y` rows plus `#`-prefixed notes.
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# {}: {}", self.id, self.title);
        let _ = writeln!(s, "# x = {}, y = {}", self.x_label, self.y_label);
        for n in &self.notes {
            let _ = writeln!(s, "# {n}");
        }
        let _ = writeln!(s, "figure,series,x,y");
        for ser in &self.series {
            for &(x, y) in &ser.points {
                let _ = writeln!(s, "{},{},{},{}", self.id, ser.label, x, y);
            }
        }
        s
    }

    /// Human-readable table: series as columns over the x values.
    pub fn to_table(&self) -> String {
        let mut xs: Vec<usize> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|&(x, _)| x))
            .collect();
        xs.sort_unstable();
        xs.dedup();
        let mut s = String::new();
        let _ = writeln!(s, "== {} — {} ==", self.id, self.title);
        for n in &self.notes {
            let _ = writeln!(s, "   {n}");
        }
        let _ = write!(s, "{:>8}", self.x_label);
        for ser in &self.series {
            let _ = write!(s, "{:>24}", ser.label);
        }
        let _ = writeln!(s);
        for x in xs {
            let _ = write!(s, "{x:>8}");
            for ser in &self.series {
                match ser.points.iter().find(|&&(px, _)| px == x) {
                    Some(&(_, y)) => {
                        let _ = write!(s, "{y:>24.3}");
                    }
                    None => {
                        let _ = write!(s, "{:>24}", "-");
                    }
                }
            }
            let _ = writeln!(s);
        }
        s
    }

    /// Write the CSV under `bench_out/` (created if needed) and return
    /// the path.
    pub fn save_csv(&self, dir: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = std::path::Path::new(dir).join(format!("{}.csv", self.id));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}
