//! Minimal wall-clock benchmarking harness (the offline build has no
//! criterion): warmup + N timed iterations, reporting ns/op with a
//! simple min/median/mean spread. Used by `benches/*.rs`.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12.0} ns/op (median {:>12.0}, min {:>12.0}, n={})",
            self.name, self.mean_ns, self.median_ns, self.min_ns, self.iters
        )
    }
}

/// Time `f` (which should perform one operation) with auto-scaled
/// iteration counts: warms up, then runs enough iterations to pass
/// ~200 ms of total measurement, batched to amortise timer overhead.
/// Under [`super::smoke_mode`] the budgets shrink ~20x (same code
/// path, noisier numbers) so CI can execute every harness in seconds.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Measurement {
    let smoke = super::smoke_mode();
    let (warmup_ms, sample_ns, samples) =
        if smoke { (5, 500_000.0, 8usize) } else { (50, 5_000_000.0, 40usize) };
    // Warmup + calibration.
    let t0 = Instant::now();
    let mut calib_iters = 0usize;
    while t0.elapsed().as_millis() < warmup_ms {
        f();
        calib_iters += 1;
    }
    let per_op = t0.elapsed().as_nanos() as f64 / calib_iters as f64;
    let batch = ((sample_ns / per_op).ceil() as usize).clamp(1, 100_000);
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Measurement {
        name: name.to_string(),
        iters: batch * samples,
        mean_ns: mean,
        median_ns: times[times.len() / 2],
        min_ns: times[0],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let m = bench("noop-ish", || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.median_ns);
        assert!(!m.row().is_empty());
    }
}
