//! Observability: histograms, tracing, and the stats export surface.
//!
//! Dependency-free (std + `util::Json` only) and wired through every
//! layer of the stack:
//!
//! * [`hist`] — the lock-light log-linear histogram and the single
//!   percentile definition shared by `coordinator/metrics.rs`,
//!   `net/client.rs`, the bench harnesses, and `stream` phase stats.
//! * [`trace`] — per-request trace ids (minted at the net edge,
//!   carried in the v1.2 frame field), the bounded span ring, and the
//!   JSONL span exporter behind `loms serve --trace-sample N`.
//! * [`expo`] — the stats wire document: builds the JSON served by the
//!   `Stats` protocol frame, `loms stats --addr`, and the periodic
//!   `--metrics-interval` emitter in `loms serve`.
//!
//! The contract throughout: recording must be cheap enough to leave on
//! (`benches/service_pipeline.rs` asserts obs-on vs obs-off throughput
//! within 3%), and every retained structure is fixed-memory.

pub mod expo;
pub mod hist;
pub mod trace;

pub use hist::{percentile_us, us_from_duration, us_from_f64, Hist, HistStats};
pub use trace::{write_spans_jsonl, SpanEvent, Tracer};
