//! Per-request tracing: trace ids minted at the net edge, a bounded
//! in-memory ring of structured span events, and a JSONL exporter.
//!
//! A trace id is a nonzero `u64`. The net server mints one for every
//! request that arrives without one (clients may pre-mint their own and
//! send it in the v1.1 frame field, so a caller can follow its own
//! request end-to-end). `0` means "untraced" and encodes to a
//! byte-identical v1 frame.
//!
//! Span events are only *retained* for sampled traces (`trace % N == 0`
//! for sample rate `N`; `N = 0` disables retention entirely), so the
//! steady-state cost of tracing is one modulo per request. Retained
//! events go into a fixed-capacity ring; when full, the oldest event is
//! dropped and counted — memory is bounded no matter how long the
//! server runs.
//!
//! Span taxonomy (DESIGN.md "Observability"):
//!   request path — `admit`, `queue`, `assemble`, `execute` (with
//!   `artifact` + SIMD `tier` attrs), `respond`
//!   extsort path — `run_form`, `merge`, and the `io_wait` phases
//!   surfaced per-phase by `stream/io.rs` histograms.

use crate::util::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Bounded span-ring capacity (events, not traces).
pub const RING_CAP: usize = 8192;

/// One structured span event. Times are microseconds since the owning
/// [`Tracer`]'s epoch, so events from one process order totally.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub trace: u64,
    pub name: &'static str,
    pub start_us: u64,
    pub dur_us: u64,
    /// Artifact executed, for `execute` spans.
    pub artifact: Option<Arc<str>>,
    /// SIMD tier / backend label, for `execute` spans.
    pub tier: Option<&'static str>,
}

impl SpanEvent {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("trace", Json::int(self.trace as i64)),
            ("span", Json::str(self.name)),
            ("start_us", Json::int(self.start_us as i64)),
            ("dur_us", Json::int(self.dur_us as i64)),
        ];
        if let Some(a) = &self.artifact {
            fields.push(("artifact", Json::str(a.as_ref())));
        }
        if let Some(t) = self.tier {
            fields.push(("tier", Json::str(t)));
        }
        Json::obj(fields)
    }
}

/// Trace-id minter plus sampled span ring. One per [`Metrics`]
/// (i.e. one per `MergeService`).
///
/// [`Metrics`]: crate::coordinator::Metrics
#[derive(Debug)]
pub struct Tracer {
    epoch: Instant,
    next: AtomicU64,
    sample: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<SpanEvent>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer {
            epoch: Instant::now(),
            next: AtomicU64::new(1),
            sample: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Mint a fresh nonzero trace id.
    pub fn mint(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }

    /// Set the sample rate: retain spans for traces with
    /// `trace % n == 0`; `0` disables span retention.
    pub fn set_sample(&self, n: u64) {
        self.sample.store(n, Ordering::Relaxed);
    }

    pub fn sample(&self) -> u64 {
        self.sample.load(Ordering::Relaxed)
    }

    /// Should spans for `trace` be retained? The per-request fast path:
    /// one load and (if sampling is on) one modulo.
    pub fn sampled(&self, trace: u64) -> bool {
        if trace == 0 {
            return false;
        }
        let n = self.sample.load(Ordering::Relaxed);
        n != 0 && trace % n == 0
    }

    /// Microseconds since this tracer's epoch.
    pub fn now_us(&self) -> u64 {
        (self.epoch.elapsed().as_nanos() / 1_000) as u64
    }

    /// Retain one span event (caller has already checked [`sampled`]).
    ///
    /// [`sampled`]: Tracer::sampled
    pub fn record(&self, ev: SpanEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() >= RING_CAP {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(ev);
    }

    /// Events evicted because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Number of retained events currently in the ring.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every retained event out of the ring (oldest first).
    pub fn drain(&self) -> Vec<SpanEvent> {
        self.ring.lock().unwrap().drain(..).collect()
    }
}

/// Write span events as JSONL (one compact object per line) — the
/// `--trace-sample N` exporter in `loms serve` and the integration
/// tests share this.
pub fn write_spans_jsonl(events: &[SpanEvent], w: &mut impl std::io::Write) -> std::io::Result<()> {
    for ev in events {
        writeln!(w, "{}", ev.to_json().to_string())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_nonzero_and_unique() {
        let t = Tracer::new();
        let a = t.mint();
        let b = t.mint();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn sampling_gates_retention() {
        let t = Tracer::new();
        assert!(!t.sampled(4), "retention off by default");
        t.set_sample(2);
        assert!(t.sampled(4));
        assert!(!t.sampled(5));
        assert!(!t.sampled(0), "untraced never sampled");
        t.set_sample(1);
        assert!(t.sampled(7), "sample=1 retains everything");
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::new();
        for i in 0..(RING_CAP as u64 + 10) {
            t.record(SpanEvent {
                trace: i + 1,
                name: "admit",
                start_us: i,
                dur_us: 0,
                artifact: None,
                tier: None,
            });
        }
        assert_eq!(t.len(), RING_CAP);
        assert_eq!(t.dropped(), 10);
        let evs = t.drain();
        assert_eq!(evs.len(), RING_CAP);
        // Oldest 10 were evicted; ring starts at trace 11.
        assert_eq!(evs[0].trace, 11);
        assert!(t.is_empty());
    }

    #[test]
    fn jsonl_round_trips_through_util_json() {
        let ev = SpanEvent {
            trace: 42,
            name: "execute",
            start_us: 100,
            dur_us: 250,
            artifact: Some(Arc::from("loms2_up32_dn32_b256")),
            tier: Some("avx2"),
        };
        let mut buf = Vec::new();
        write_spans_jsonl(&[ev], &mut buf).unwrap();
        let line = String::from_utf8(buf).unwrap();
        let parsed = Json::parse(line.trim()).unwrap();
        let obj = match parsed {
            Json::Obj(m) => m,
            other => panic!("expected object, got {other:?}"),
        };
        assert_eq!(obj.get("trace"), Some(&Json::int(42)));
        assert_eq!(obj.get("span"), Some(&Json::str("execute")));
        assert_eq!(obj.get("artifact"), Some(&Json::str("loms2_up32_dn32_b256")));
        assert_eq!(obj.get("tier"), Some(&Json::str("avx2")));
    }
}
