//! Lock-light log-linear latency histogram — the one percentile
//! definition for the whole stack.
//!
//! Values are microseconds in a fixed HDR-style log-linear layout:
//! unit-width buckets below [`SUB`], then [`SUB`] sub-buckets per
//! power-of-two octave (4 significant bits ⇒ ≤ 1/16 relative bucket
//! width) up to ~2^37 µs (~38 hours); anything larger clamps into the
//! top bucket. Memory is fixed ([`N_BUCKETS`] counters, ~4 KiB), so a
//! histogram can sit on every artifact and stage of a server and be
//! merged, snapshotted, and shipped over the stats frame at any time.
//!
//! Recording is a handful of `Relaxed` atomic adds — no lock, no
//! allocation — cheap enough to leave on in production (the
//! `service_pipeline` bench guards the obs-on vs obs-off delta).
//! Reads ([`Hist::snapshot`], [`Hist::percentile`]) copy the counters
//! once and compute from the copy, so a snapshot taken while other
//! threads record is internally consistent with *some* interleaving of
//! the concurrent records.
//!
//! Percentile definition (everywhere: `coordinator/metrics.rs`,
//! `net/client.rs`, the benches, the stats frame): rank
//! `ceil(q · count)` (clamped to `[1, count]`) over the recorded
//! multiset, reported as the covering bucket's **last** value, capped
//! at the exact recorded maximum. Unit buckets report exactly; wider
//! buckets over-report by at most 1/16 — never under.

use crate::util::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Significant bits per octave (sub-bucket resolution).
const SUB_BITS: usize = 4;
/// Sub-buckets per octave; also the width of the unit-bucket prefix.
const SUB: usize = 1 << SUB_BITS;
/// Log-linear octaves after the unit prefix (top octave starts at
/// `SUB << (TIERS - 1)` = 2^36 µs).
const TIERS: usize = 33;
/// Total bucket count.
pub const N_BUCKETS: usize = SUB + SUB * TIERS;

/// Bucket index for a microsecond value (total function — large values
/// clamp into the top bucket).
fn bucket_index(us: u64) -> usize {
    if us < SUB as u64 {
        return us as usize;
    }
    let top = 63 - us.leading_zeros() as usize; // >= SUB_BITS
    let g = top - SUB_BITS;
    if g >= TIERS {
        return N_BUCKETS - 1;
    }
    let sub = ((us >> g) & (SUB as u64 - 1)) as usize;
    SUB + g * SUB + sub
}

/// Smallest value mapping into bucket `i`.
fn bucket_floor(i: usize) -> u64 {
    if i < SUB {
        i as u64
    } else {
        let g = (i - SUB) / SUB;
        ((SUB + (i - SUB) % SUB) as u64) << g
    }
}

/// Largest value mapping into bucket `i` (the percentile
/// representative, before the exact-max cap).
fn bucket_last(i: usize) -> u64 {
    if i < SUB {
        i as u64
    } else {
        bucket_floor(i) + (1u64 << ((i - SUB) / SUB)) - 1
    }
}

/// Microseconds from a wall duration, rounded half-up (so a 1.5 µs
/// stage records as 2, and sub-microsecond work still lands in bucket
/// 0/1 rather than vanishing).
pub fn us_from_duration(d: Duration) -> u64 {
    ((d.as_nanos() + 500) / 1_000) as u64
}

/// Microseconds from an `f64` sample (the bench/client sample shape),
/// rounded to nearest — the same quantization as [`us_from_duration`]
/// so histograms built from either agree.
pub fn us_from_f64(us: f64) -> u64 {
    if us <= 0.0 {
        0
    } else {
        us.round() as u64
    }
}

/// A mergeable fixed-memory log-linear histogram of microsecond values.
pub struct Hist {
    counts: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Hist({s:?})")
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one microsecond value (lock-free, `Relaxed` adds).
    pub fn record(&self, us: u64) {
        self.counts[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(us, Ordering::Relaxed);
        self.max.fetch_max(us, Ordering::Relaxed);
    }

    /// Record a wall duration (quantized by [`us_from_duration`]).
    pub fn record_duration(&self, d: Duration) {
        self.record(us_from_duration(d));
    }

    /// Fold `other`'s recorded values into `self` (bucket-exact: the
    /// merged histogram is identical to one that recorded the union).
    pub fn merge_from(&self, other: &Hist) {
        for (dst, src) in self.counts.iter().zip(other.counts.iter()) {
            let n = src.load(Ordering::Relaxed);
            if n > 0 {
                dst.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Total recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// One percentile (`q` in `[0, 1]`) under the shared definition.
    pub fn percentile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        percentile_of(&counts, self.max.load(Ordering::Relaxed), q)
    }

    /// Copy-once summary: count/sum/max plus the fixed percentile set.
    pub fn snapshot(&self) -> HistStats {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let max = self.max.load(Ordering::Relaxed);
        HistStats {
            count: counts.iter().sum(),
            sum_us: self.sum.load(Ordering::Relaxed),
            max_us: max,
            p50_us: percentile_of(&counts, max, 0.50),
            p90_us: percentile_of(&counts, max, 0.90),
            p99_us: percentile_of(&counts, max, 0.99),
            p999_us: percentile_of(&counts, max, 0.999),
        }
    }
}

/// The shared percentile walk over a copied bucket array.
fn percentile_of(counts: &[u64], max: u64, q: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut acc = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return bucket_last(i).min(max);
        }
    }
    max
}

/// `Copy` summary of a histogram — rides inside
/// [`crate::stream::ExtSortStats`], [`crate::coordinator::Snapshot`],
/// and the stats wire frame.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistStats {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
}

impl HistStats {
    /// Mean of the recorded values in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// The stats-frame / JSONL object form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::int(self.count as i64)),
            ("mean_us", Json::Num(self.mean_us())),
            ("p50_us", Json::int(self.p50_us as i64)),
            ("p90_us", Json::int(self.p90_us as i64)),
            ("p99_us", Json::int(self.p99_us as i64)),
            ("p999_us", Json::int(self.p999_us as i64)),
            ("max_us", Json::int(self.max_us as i64)),
        ])
    }
}

/// Percentile of raw `f64` microsecond samples through the shared
/// histogram definition — what `net/client.rs` and the bench harnesses
/// call, so wire-level and in-process percentiles agree bucket-exactly.
pub fn percentile_us(samples: &[f64], q: f64) -> f64 {
    let h = Hist::new();
    for &s in samples {
        h.record(us_from_f64(s));
    }
    h.percentile(q) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_total_and_monotone() {
        // Every index round-trips and bucket ranges tile the line.
        let mut prev_last = None;
        for i in 0..N_BUCKETS {
            let (lo, hi) = (bucket_floor(i), bucket_last(i));
            assert!(lo <= hi, "bucket {i}");
            assert_eq!(bucket_index(lo), i, "floor of bucket {i}");
            if i < N_BUCKETS - 1 {
                assert_eq!(bucket_index(hi), i, "last of bucket {i}");
            }
            if let Some(p) = prev_last {
                assert_eq!(lo, p + 1, "gap before bucket {i}");
            }
            prev_last = Some(hi);
        }
        // Out-of-range values clamp into the top bucket.
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn relative_error_is_bounded() {
        // Any recorded value's representative over-reports by < 1/16
        // and never under-reports.
        for v in [0u64, 1, 15, 16, 17, 100, 1000, 12_345, 1 << 20, (1 << 36) - 1] {
            let i = bucket_index(v);
            assert!(bucket_floor(i) <= v && v <= bucket_last(i), "{v}");
            assert!(bucket_last(i) as f64 <= v as f64 * (1.0 + 1.0 / 16.0) + 1.0, "{v}");
        }
    }

    #[test]
    fn single_value_percentiles_are_exact() {
        let h = Hist::new();
        h.record(100);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile(q), 100);
        }
        let s = h.snapshot();
        assert_eq!((s.count, s.sum_us, s.max_us, s.p50_us), (1, 100, 100, 100));
    }

    #[test]
    fn empty_hist_is_all_zero() {
        let h = Hist::new();
        assert_eq!(h.snapshot(), HistStats::default());
        assert_eq!(h.percentile(0.5), 0);
    }

    #[test]
    fn merge_equals_union() {
        let (a, b, u) = (Hist::new(), Hist::new(), Hist::new());
        for v in [3u64, 17, 17, 250, 9_000] {
            a.record(v);
            u.record(v);
        }
        for v in [1u64, 40, 40_000, 1 << 30] {
            b.record(v);
            u.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.snapshot(), u.snapshot());
    }

    #[test]
    fn duration_and_f64_quantize_identically() {
        for us in [0u64, 1, 2, 999, 1000, 123_456] {
            assert_eq!(us_from_duration(Duration::from_micros(us)), us);
            assert_eq!(us_from_f64(us as f64), us);
        }
        assert_eq!(us_from_duration(Duration::from_nanos(1_500)), 2);
        assert_eq!(us_from_f64(1.5), 2);
        assert_eq!(us_from_f64(-3.0), 0);
    }

    #[test]
    fn percentile_us_matches_hist_on_whole_samples() {
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let h = Hist::new();
        for &s in &samples {
            h.record(s as u64);
        }
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(percentile_us(&samples, q), h.percentile(q) as f64);
        }
    }
}
