//! The stats export surface: one JSON document shape shared by the
//! `Stats` protocol frame (`loms stats --addr`), the periodic
//! `--metrics-interval` JSONL emitter in `loms serve`, and the
//! integration tests.
//!
//! Grammar (all latency objects are
//! [`HistStats::to_json`](crate::obs::hist::HistStats::to_json):
//! `{count, mean_us, p50_us, p90_us, p99_us, p999_us, max_us}`):
//!
//! ```text
//! { "requests": n, "responses": n, "batches": n, "stage_batches": n,
//!   "rows_real": n, "rows_padded": n, "software_served": n,
//!   "rejected": n, "pending": n,
//!   "latency": <hist>,
//!   "stages": { "queue_wait": <hist>, "assemble": <hist>,
//!               "execute": <hist>, "respond": <hist> },
//!   "artifacts": { "<name>": { "batches": n, "rows": n,
//!                              "execute": <hist> }, ... },
//!   "net": { "connections": n, "frames_in": n, "decode_errors": n,
//!            "responses": n, "errors": n },
//!   "faults": { "faults_injected": n, "corrupt_detected": n,
//!               "retries": n, "sheds": n },
//!   "extsort": { "run_form_secs": f, "merge_secs": f,
//!                "io_wait_secs": f },
//!   "trace": { "spans_dropped": n } }
//! ```
//!
//! Key names mirror the [`Snapshot`] field names so a grep against the
//! wire document and a read of the code land in the same place.

use crate::coordinator::Snapshot;
use crate::util::Json;
use std::collections::BTreeMap;

/// Build the stats document from a service snapshot plus the live
/// queue-depth gauge (`MergeService::pending`, which a snapshot cannot
/// carry — it is computed from the submission counter).
pub fn stats_json(snap: &Snapshot, pending: u64) -> Json {
    let artifacts: BTreeMap<String, Json> = snap
        .artifacts
        .iter()
        .map(|a| {
            (
                a.name.clone(),
                Json::obj(vec![
                    ("batches", Json::int(a.batches as i64)),
                    ("rows", Json::int(a.rows as i64)),
                    ("execute", a.execute.to_json()),
                ]),
            )
        })
        .collect();
    Json::obj(vec![
        ("requests", Json::int(snap.requests as i64)),
        ("responses", Json::int(snap.responses as i64)),
        ("batches", Json::int(snap.batches as i64)),
        ("stage_batches", Json::int(snap.stage_batches as i64)),
        ("rows_real", Json::int(snap.rows_real as i64)),
        ("rows_padded", Json::int(snap.rows_padded as i64)),
        ("software_served", Json::int(snap.software_served as i64)),
        ("rejected", Json::int(snap.rejected as i64)),
        ("pending", Json::int(pending as i64)),
        ("latency", snap.latency.to_json()),
        (
            "stages",
            Json::obj(vec![
                ("queue_wait", snap.queue_wait.to_json()),
                ("assemble", snap.assemble.to_json()),
                ("execute", snap.execute.to_json()),
                ("respond", snap.respond.to_json()),
            ]),
        ),
        ("artifacts", Json::Obj(artifacts)),
        (
            "net",
            Json::obj(vec![
                ("connections", Json::int(snap.net_connections as i64)),
                ("frames_in", Json::int(snap.net_frames_in as i64)),
                ("decode_errors", Json::int(snap.net_decode_errors as i64)),
                ("responses", Json::int(snap.net_responses as i64)),
                ("errors", Json::int(snap.net_errors as i64)),
            ]),
        ),
        (
            "faults",
            Json::obj(vec![
                ("faults_injected", Json::int(snap.faults_injected as i64)),
                ("corrupt_detected", Json::int(snap.corrupt_detected as i64)),
                ("retries", Json::int(snap.retries as i64)),
                ("sheds", Json::int(snap.sheds as i64)),
            ]),
        ),
        (
            "extsort",
            Json::obj(vec![
                ("run_form_secs", Json::Num(snap.extsort_run_form_secs)),
                ("merge_secs", Json::Num(snap.extsort_merge_secs)),
                ("io_wait_secs", Json::Num(snap.extsort_io_wait_secs)),
            ]),
        ),
        ("trace", Json::obj(vec![("spans_dropped", Json::int(snap.spans_dropped as i64))])),
    ])
}

/// Render the stats document, guaranteed to fit in `max_bytes` of
/// JSON — the wire path's contract with
/// [`MAX_STATS_BYTES`](crate::net::protocol::MAX_STATS_BYTES), where
/// an oversized document must never be truncated into invalid JSON.
///
/// A server with thousands of distinct artifacts can push the full
/// document over the frame limit; per-artifact detail is the only
/// unbounded section, so when the full render is too large it is
/// elided (an empty `"artifacts"` object plus an `"artifacts_elided"`
/// count naming how many entries were dropped) and the stack-wide
/// aggregates survive. The elided form is a few KiB and always fits.
pub fn stats_json_fitted(snap: &Snapshot, pending: u64, max_bytes: usize) -> String {
    let full = stats_json(snap, pending).to_string();
    if full.len() <= max_bytes {
        return full;
    }
    let mut doc = stats_json(snap, pending);
    if let Json::Obj(m) = &mut doc {
        m.insert("artifacts".into(), Json::Obj(BTreeMap::new()));
        m.insert("artifacts_elided".into(), Json::int(snap.artifacts.len() as i64));
    }
    doc.to_string()
}

/// Validate a stats document's required shape — the contract the CI
/// smoke job and the `obs` integration suite hold the live server to.
/// Returns the first missing/ill-typed path.
pub fn check_stats_doc(doc: &Json) -> Result<(), String> {
    for key in [
        "requests",
        "responses",
        "batches",
        "stage_batches",
        "rejected",
        "pending",
    ] {
        doc.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("missing integer key {key:?}"))?;
    }
    check_hist(doc.get("latency"), "latency")?;
    let stages = doc.get("stages").ok_or("missing \"stages\"")?;
    for key in ["queue_wait", "assemble", "execute", "respond"] {
        check_hist(stages.get(key), &format!("stages.{key}"))?;
    }
    let artifacts = match doc.get("artifacts") {
        Some(Json::Obj(m)) => m,
        _ => return Err("missing object key \"artifacts\"".into()),
    };
    for (name, a) in artifacts {
        for key in ["batches", "rows"] {
            a.get(key)
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("artifact {name:?}: missing {key:?}"))?;
        }
        check_hist(a.get("execute"), &format!("artifacts.{name}.execute"))?;
    }
    let faults = doc.get("faults").ok_or("missing \"faults\"")?;
    for key in ["faults_injected", "corrupt_detected", "retries", "sheds"] {
        faults
            .get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("missing integer key faults.{key}"))?;
    }
    let net = doc.get("net").ok_or("missing \"net\"")?;
    for key in ["connections", "frames_in", "decode_errors", "responses", "errors"] {
        net.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("missing integer key net.{key}"))?;
    }
    let ext = doc.get("extsort").ok_or("missing \"extsort\"")?;
    for key in ["run_form_secs", "merge_secs", "io_wait_secs"] {
        ext.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("missing number key extsort.{key}"))?;
    }
    Ok(())
}

fn check_hist(h: Option<&Json>, path: &str) -> Result<(), String> {
    let h = h.ok_or_else(|| format!("missing histogram {path:?}"))?;
    for key in ["count", "p50_us", "p90_us", "p99_us", "p999_us", "max_us"] {
        h.get(key)
            .and_then(Json::as_i64)
            .ok_or_else(|| format!("histogram {path:?}: missing {key:?}"))?;
    }
    h.get("mean_us")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("histogram {path:?}: missing \"mean_us\""))?;
    Ok(())
}

/// Round-trip helper for the wire path: parse a received stats frame
/// body and validate its shape in one step.
pub fn parse_stats_doc(body: &str) -> Result<Json, String> {
    let doc = Json::parse(body)?;
    check_stats_doc(&doc)?;
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ArtifactSnapshot, Metrics};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn live_snapshot_produces_a_valid_doc() {
        let m = Metrics::new();
        m.on_request();
        m.on_response(Duration::from_micros(120));
        m.on_batch(1, 0);
        m.on_batch_stages(
            Duration::from_micros(50),
            Duration::from_micros(5),
            Duration::from_micros(60),
            Duration::from_micros(5),
        );
        let name: Arc<str> = "loms2_up32_dn32_b256".into();
        m.on_artifact_batch(&name, 1, Duration::from_micros(60));
        m.on_extsort_clocks(1.0, 0.5, 0.25);
        let doc = stats_json(&m.snapshot(), 3);
        check_stats_doc(&doc).unwrap();
        // Wire round-trip preserves validity.
        let doc2 = parse_stats_doc(&doc.to_string()).unwrap();
        assert_eq!(doc2.get("pending").unwrap().as_i64(), Some(3));
        let art = doc2.get("artifacts").unwrap().get("loms2_up32_dn32_b256").unwrap();
        assert_eq!(art.get("batches").unwrap().as_i64(), Some(1));
        assert_eq!(
            art.get("execute").unwrap().get("p50_us").unwrap().as_i64(),
            Some(60)
        );
        assert_eq!(
            doc2.get("extsort").unwrap().get("run_form_secs").unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn empty_snapshot_is_still_well_formed() {
        let doc = stats_json(&Metrics::new().snapshot(), 0);
        check_stats_doc(&doc).unwrap();
    }

    #[test]
    fn fitted_doc_elides_artifacts_instead_of_overflowing() {
        let mut snap = Metrics::new().snapshot();
        for i in 0..4000 {
            snap.artifacts.push(ArtifactSnapshot {
                name: format!("loms2_up32_dn32_b256_variant_{i:05}"),
                ..Default::default()
            });
        }
        let full = stats_json(&snap, 0).to_string();
        let cap = 64 << 10;
        assert!(full.len() > cap, "test premise: full doc overflows the cap");
        let fitted = stats_json_fitted(&snap, 0, cap);
        assert!(fitted.len() <= cap, "{} > {cap}", fitted.len());
        // Still valid JSON with the required shape, and honest about
        // what was dropped.
        let doc = parse_stats_doc(&fitted).unwrap();
        assert_eq!(doc.get("artifacts_elided").unwrap().as_i64(), Some(4000));
        // Under the cap, nothing is elided.
        let small = stats_json_fitted(&Metrics::new().snapshot(), 0, cap);
        let doc = parse_stats_doc(&small).unwrap();
        assert!(doc.get("artifacts_elided").is_none());
    }

    #[test]
    fn checker_names_the_missing_key() {
        let doc = Json::obj(vec![("requests", Json::int(1))]);
        let err = check_stats_doc(&doc).unwrap_err();
        assert!(err.contains("responses"), "{err}");
        // A doc with a malformed artifact entry is rejected too.
        let mut snap = Metrics::new().snapshot();
        snap.artifacts.push(ArtifactSnapshot { name: "x".into(), ..Default::default() });
        let mut doc = stats_json(&snap, 0);
        if let Json::Obj(m) = &mut doc {
            if let Some(Json::Obj(arts)) = m.get_mut("artifacts") {
                if let Some(Json::Obj(a)) = arts.get_mut("x") {
                    a.remove("execute");
                }
            }
        }
        let err = check_stats_doc(&doc).unwrap_err();
        assert!(err.contains("execute"), "{err}");
    }
}
