//! Single-Stage 2-way Merge Sorters (S2MS) [2][3].
//!
//! An S2MS UP-m/DN-n merges two sorted lists in one combinatorial stage:
//! a parallel bank of `m*n` cross comparators (`ge_{a_i, b_j}`) drives a
//! per-output multiplexer tree that routes each input directly to its
//! output rank (Fig. 9 of the paper shows the UP-2/DN-2 equations).
//!
//! Besides the executable [`MergeDevice`], this module computes the
//! *structural profile* the FPGA cost model consumes: per-output
//! candidate counts (mux-tree widths) and the comparator-bank size.

use super::network::{Block, DeviceKind, MergeDevice, Stage};

/// Structural facts about an S2MS block, independent of bit width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct S2msProfile {
    pub m: usize,
    pub n: usize,
    /// Cross comparators ge_{a_i,b_j}: m*n.
    pub comparators: usize,
    /// candidates[t] = number of inputs that can reach output rank t —
    /// the width of output t's multiplexer.
    pub candidates: Vec<usize>,
}

impl S2msProfile {
    /// Widest output multiplexer (drives series-slice count / delay).
    pub fn max_candidates(&self) -> usize {
        self.candidates.iter().copied().max().unwrap_or(1)
    }

    /// Total mux data inputs across outputs (drives LUT count).
    pub fn total_candidates(&self) -> usize {
        self.candidates.iter().sum()
    }
}

/// Candidate count for output rank `t` of an UP-m/DN-n merge:
/// `a_i` can land at rank `t` iff `i <= t` (i smaller a's precede it at
/// minimum) and `t - i <= n` (at most n b's precede it); symmetrically
/// for `b_j`.
pub fn output_candidates(m: usize, n: usize, t: usize) -> usize {
    let a = a_candidate_range(m, n, t).map_or(0, |(lo, hi)| hi - lo + 1);
    let b = a_candidate_range(n, m, t).map_or(0, |(lo, hi)| hi - lo + 1);
    a + b
}

/// Inclusive index range of list-A elements that can reach output rank t
/// in an UP-m/DN-n merge (`None` if empty).
fn a_candidate_range(m: usize, n: usize, t: usize) -> Option<(usize, usize)> {
    // a_i lands at rank i + (#b < a_i) with #b in 0..=n  =>  i <= t <= i+n.
    let lo = t.saturating_sub(n);
    let hi = t.min(m.saturating_sub(1));
    if m == 0 || lo > hi {
        None
    } else {
        Some((lo, hi))
    }
}

/// Structural profile of an UP-m/DN-n S2MS.
pub fn profile(m: usize, n: usize) -> S2msProfile {
    let total = m + n;
    S2msProfile {
        m,
        n,
        comparators: m * n,
        candidates: (0..total).map(|t| output_candidates(m, n, t)).collect(),
    }
}

/// Build the executable single-stage UP-m/DN-n merge device.
/// Any mixture of list sizes is supported (a LOMS/S2MS selling point).
pub fn s2ms(m: usize, n: usize) -> MergeDevice {
    assert!(m + n >= 1, "empty S2MS");
    let total = m + n;
    MergeDevice {
        name: format!("s2ms-up{m}-dn{n}"),
        kind: DeviceKind::S2ms,
        list_sizes: vec![m, n],
        input_map: vec![(0..m).collect(), (m..total).collect()],
        n: total,
        stages: vec![Stage::new(
            "s2ms",
            vec![Block::MergeS2 { up: (0..m).collect(), dn: (m..total).collect(), out: (0..total).collect() }],
        )],
        output_perm: (0..total).collect(),
        median_tap: None,
        grid: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{merge, ExecMode};
    use crate::sortnet::validate::{validate_merge_01, validate_merge_random};

    #[test]
    fn profile_up2_dn2_matches_fig9() {
        // Fig. 9: Out_3 and Out_0 have 2 candidates; Out_2 and Out_1 have 4.
        let p = profile(2, 2);
        assert_eq!(p.candidates, vec![2, 4, 4, 2]);
        assert_eq!(p.comparators, 4);
        assert_eq!(p.max_candidates(), 4);
    }

    #[test]
    fn candidates_symmetric_and_bounded() {
        for (m, n) in [(4usize, 4usize), (8, 8), (16, 16), (32, 32), (7, 5), (1, 8)] {
            let p = profile(m, n);
            assert_eq!(p.candidates.len(), m + n);
            for (t, &c) in p.candidates.iter().enumerate() {
                assert!(c >= 1 && c <= m + n, "({m},{n}) t={t} c={c}");
                // Symmetric devices have palindromic candidate profiles.
                if m == n {
                    assert_eq!(c, p.candidates[m + n - 1 - t]);
                }
            }
            // Extreme ranks have exactly min(k,2)-ish candidates: rank 0
            // can only be a_0 or b_0.
            assert_eq!(p.candidates[0], if m > 0 && n > 0 { 2 } else { 1 });
        }
    }

    #[test]
    fn middle_output_mux_spans_all_inputs() {
        // For m=n the middle ranks can receive *any* of the 2m inputs —
        // this is why large S2MS devices are so LUT-hungry (§VII-C).
        for m in [2usize, 4, 8, 16, 32] {
            let p = profile(m, m);
            assert_eq!(p.max_candidates(), 2 * m, "m={m}");
            assert_eq!(p.candidates[m - 1], 2 * m);
            assert_eq!(p.candidates[m], 2 * m);
        }
    }

    #[test]
    fn candidate_formula_matches_bruteforce() {
        // Brute-force over sorted 0-1 inputs: which input indices can land
        // at output t across all (m+1)(n+1) patterns (indices tracked via
        // distinct values).
        for (m, n) in [(2usize, 2usize), (3, 5), (4, 4), (1, 6)] {
            let mut reach = vec![std::collections::HashSet::new(); m + n];
            // Use strictly increasing distinct values so the merge is a
            // permutation we can invert; sweep all interleavings via 0-1
            // style cuts scaled into distinct values.
            for za in 0..=m {
                for zb in 0..=n {
                    // list a: za small values then large; same for b. Use
                    // (bucket, tiebreak) encoding; stable merge puts UP first.
                    let a: Vec<(u8, u8)> =
                        (0..m).map(|i| (if i < za { 0 } else { 1 }, i as u8)).collect();
                    let b: Vec<(u8, u8)> =
                        (0..n).map(|j| (if j < zb { 0 } else { 1 }, (m + j) as u8)).collect();
                    let mut all: Vec<(u8, u8)> = a.iter().chain(b.iter()).copied().collect();
                    // Stable merge == stable sort by bucket with UP-before-DN
                    // tie order, which the tiebreak id already encodes.
                    all.sort();
                    for (t, &(_, id)) in all.iter().enumerate() {
                        reach[t].insert(id);
                    }
                }
            }
            for t in 0..m + n {
                assert_eq!(
                    reach[t].len(),
                    output_candidates(m, n, t),
                    "(m={m},n={n},t={t})"
                );
            }
        }
    }

    #[test]
    fn s2ms_all_mixtures_validate() {
        for (m, n) in [(1usize, 1usize), (2, 2), (1, 8), (8, 1), (7, 5), (4, 4), (16, 16)] {
            let d = s2ms(m, n);
            d.check().unwrap();
            assert_eq!(d.depth(), 1, "single stage by definition");
            validate_merge_01(&d).unwrap();
        }
        validate_merge_random(&s2ms(32, 32), 25, 7).unwrap();
    }

    #[test]
    fn s2ms_merges_example() {
        let d = s2ms(3, 4);
        let out = merge(&d, &[vec![2u32, 9, 11], vec![1, 3, 10, 12]], ExecMode::Strict).unwrap();
        assert_eq!(out, vec![1, 2, 3, 9, 10, 11, 12]);
    }
}
