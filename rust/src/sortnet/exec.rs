//! Bit-exact software execution of [`MergeDevice`]s.
//!
//! Execution is *faithful to the hardware semantics*: a `Cas` block
//! compare-exchanges, an `S2MS` block performs the two-run merge its mux
//! equations implement (correct only when its input runs are sorted — the
//! physical device has the same precondition), `SortN`/`FilterN` blocks
//! sort their inputs. [`ExecMode::Strict`] additionally checks every
//! precondition, which is how device validation proves a network correct
//! for *all* inputs (see [`crate::sortnet::validate`]).
//!
//! [`ExecScratch`] is the *interpreter*: it walks the device's enum tree
//! directly, which keeps per-stage granularity for analyses like
//! [`crate::sortnet::prune`] and serves as the differential reference.
//! Hot paths execute through the lowered IR in [`crate::sortnet::plan`]
//! instead; the [`merge`]/[`median`] helpers here compile-and-run a
//! [`CompiledPlan`].

use super::network::{Block, MergeDevice};
use super::plan::{CompiledPlan, PlanScratch};

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Trust preconditions (hot path).
    Fast,
    /// Check every block precondition; used by the validators.
    Strict,
}

/// Error raised in strict mode when a hardware precondition is violated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PreconditionViolation {
    pub stage: usize,
    pub block: usize,
    /// Batch row that tripped the violation, when raised by a batch
    /// executor ([`crate::sortnet::plan::CompiledPlan::run_batch`] and
    /// friends); `None` from single-row entry points.
    pub row: Option<usize>,
    pub detail: String,
}

impl PreconditionViolation {
    /// Tag the error with the batch row it came from.
    pub(crate) fn with_row(mut self, row: usize) -> Self {
        self.row = Some(row);
        self
    }

    /// Shift the row context by `by` (used when a sub-range of a batch
    /// ran through a nested executor, e.g. the lane executor's scalar
    /// tail or a thread shard).
    pub(crate) fn offset_row(mut self, by: usize) -> Self {
        self.row = Some(self.row.map_or(by, |r| r + by));
        self
    }
}

impl std::fmt::Display for PreconditionViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if let Some(row) = self.row {
            write!(f, "row {row}: ")?;
        }
        write!(f, "stage {} block {}: {}", self.stage, self.block, self.detail)
    }
}

impl std::error::Error for PreconditionViolation {}

/// Scratch buffers reused across executions — the hot path allocates
/// nothing per call once warmed.
#[derive(Default)]
pub struct ExecScratch<T> {
    buf: Vec<T>,
}

impl<T: Copy + Ord + Default> ExecScratch<T> {
    pub fn new() -> Self {
        ExecScratch { buf: Vec::new() }
    }

    /// Execute one block in-place over `v`.
    fn apply_block(
        &mut self,
        b: &Block,
        v: &mut [T],
        mode: ExecMode,
        si: usize,
        bi: usize,
    ) -> Result<(), PreconditionViolation> {
        match b {
            Block::Cas { lo, hi } => {
                if v[*lo] > v[*hi] {
                    v.swap(*lo, *hi);
                }
            }
            Block::SortN { pos } => {
                self.buf.clear();
                self.buf.extend(pos.iter().map(|&p| v[p]));
                self.buf.sort_unstable();
                for (i, &p) in pos.iter().enumerate() {
                    v[p] = self.buf[i];
                }
            }
            Block::MergeS2 { up, dn, out } => {
                if mode == ExecMode::Strict {
                    for w in [up, dn] {
                        if w.windows(2).any(|pair| v[pair[0]] > v[pair[1]]) {
                            return Err(PreconditionViolation {
                                stage: si,
                                block: bi,
                                row: None,
                                detail: "S2MS input run not sorted".into(),
                            });
                        }
                    }
                }
                // Two-pointer merge — the functional content of the
                // S2MS output mux equations (Fig. 9 of the paper).
                self.buf.clear();
                self.buf.reserve(up.len() + dn.len());
                let (mut i, mut j) = (0usize, 0usize);
                while i < up.len() && j < dn.len() {
                    // Stable: UP values win ties (paper's sorters are stable).
                    if v[up[i]] <= v[dn[j]] {
                        self.buf.push(v[up[i]]);
                        i += 1;
                    } else {
                        self.buf.push(v[dn[j]]);
                        j += 1;
                    }
                }
                self.buf.extend(up[i..].iter().map(|&p| v[p]));
                self.buf.extend(dn[j..].iter().map(|&p| v[p]));
                for (t, &p) in out.iter().enumerate() {
                    v[p] = self.buf[t];
                }
            }
            Block::FilterN { pos, taps } => {
                self.buf.clear();
                self.buf.extend(pos.iter().map(|&p| v[p]));
                self.buf.sort_unstable();
                for &t in taps {
                    v[pos[t]] = self.buf[t];
                }
            }
        }
        Ok(())
    }

    /// Execute a single stage (used by the pruning analysis).
    pub fn run_stage(
        &mut self,
        d: &MergeDevice,
        stage: usize,
        v: &mut [T],
        mode: ExecMode,
    ) -> Result<(), PreconditionViolation> {
        for (bi, b) in d.stages[stage].blocks.iter().enumerate() {
            self.apply_block(b, v, mode, stage, bi)?;
        }
        Ok(())
    }

    /// Execute the full device over a flat vector (already loaded via
    /// [`MergeDevice::load_inputs`]). Runs all stages unless
    /// `stop_after` limits the stage count (median taps).
    pub fn run(
        &mut self,
        d: &MergeDevice,
        v: &mut [T],
        mode: ExecMode,
        stop_after: Option<usize>,
    ) -> Result<(), PreconditionViolation> {
        let last = stop_after.unwrap_or(d.stages.len());
        for (si, stage) in d.stages.iter().take(last).enumerate() {
            for (bi, b) in stage.blocks.iter().enumerate() {
                self.apply_block(b, v, mode, si, bi)?;
            }
        }
        Ok(())
    }
}

/// Convenience: merge `lists` through the device; returns the sorted
/// output. Panics on malformed devices/inputs (strict-mode errors
/// propagate). Compiles and runs a [`CompiledPlan`] — hot paths that
/// merge repeatedly should compile once and reuse the plan.
pub fn merge<T: Copy + Ord + Default>(
    d: &MergeDevice,
    lists: &[Vec<T>],
    mode: ExecMode,
) -> Result<Vec<T>, PreconditionViolation> {
    let plan = CompiledPlan::compile(d).unwrap_or_else(|e| panic!("merge: {e}"));
    plan.merge_row(lists, mode, &mut PlanScratch::new())
}

/// Convenience: run only up to the median tap and return the median.
/// `None` if the device has no tap. Compiles and runs a [`CompiledPlan`].
pub fn median<T: Copy + Ord + Default>(
    d: &MergeDevice,
    lists: &[Vec<T>],
    mode: ExecMode,
) -> Result<Option<T>, PreconditionViolation> {
    let plan = CompiledPlan::compile(d).unwrap_or_else(|e| panic!("median: {e}"));
    plan.median_row(lists, mode, &mut PlanScratch::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::network::{DeviceKind, Stage};

    fn dev(stages: Vec<Stage>, n: usize) -> MergeDevice {
        MergeDevice {
            name: "t".into(),
            kind: DeviceKind::NSorter,
            list_sizes: vec![n],
            input_map: vec![(0..n).collect()],
            n,
            stages,
            output_perm: (0..n).collect(),
            median_tap: None,
            grid: None,
        }
    }

    #[test]
    fn cas_block_orders_pair() {
        let d = dev(vec![Stage::new("s", vec![Block::Cas { lo: 0, hi: 1 }])], 2);
        let mut v = vec![9u32, 3];
        ExecScratch::new().run(&d, &mut v, ExecMode::Fast, None).unwrap();
        assert_eq!(v, vec![3, 9]);
    }

    #[test]
    fn sortn_block_sorts() {
        let d = dev(vec![Stage::new("s", vec![Block::SortN { pos: vec![3, 1, 0, 2] }])], 4);
        let mut v = vec![4u32, 3, 2, 1];
        ExecScratch::new().run(&d, &mut v, ExecMode::Fast, None).unwrap();
        // sorted ascending into listed order [3,1,0,2]
        assert_eq!(v[3], 1);
        assert_eq!(v[1], 2);
        assert_eq!(v[0], 3);
        assert_eq!(v[2], 4);
    }

    #[test]
    fn s2ms_block_merges_runs() {
        let d = dev(
            vec![Stage::new("s", vec![Block::MergeS2 { up: vec![0, 1], dn: vec![2, 3], out: vec![0, 1, 2, 3] }])],
            4,
        );
        let mut v = vec![2u32, 7, 1, 9];
        ExecScratch::new().run(&d, &mut v, ExecMode::Strict, None).unwrap();
        assert_eq!(v, vec![1, 2, 7, 9]);
    }

    #[test]
    fn s2ms_strict_detects_unsorted_run() {
        let d = dev(
            vec![Stage::new("s", vec![Block::MergeS2 { up: vec![0, 1], dn: vec![2, 3], out: vec![0, 1, 2, 3] }])],
            4,
        );
        let mut v = vec![7u32, 2, 1, 9]; // up run descending: violation
        let err = ExecScratch::new().run(&d, &mut v, ExecMode::Strict, None);
        assert!(err.is_err());
        // Fast mode does not check (garbage-in tolerated, like hardware).
        let mut v2 = vec![7u32, 2, 1, 9];
        ExecScratch::new().run(&d, &mut v2, ExecMode::Fast, None).unwrap();
    }

    #[test]
    fn filter_writes_only_taps() {
        let d = dev(
            vec![Stage::new("s", vec![Block::FilterN { pos: vec![0, 1, 2], taps: vec![1] }])],
            3,
        );
        let mut v = vec![30u32, 10, 20];
        ExecScratch::new().run(&d, &mut v, ExecMode::Fast, None).unwrap();
        assert_eq!(v[1], 20); // median landed at pos[1]
        assert_eq!(v[0], 30); // untouched
        assert_eq!(v[2], 20); // untouched
    }

    #[test]
    fn merge_helper_roundtrip() {
        let d = MergeDevice {
            name: "m".into(),
            kind: DeviceKind::S2ms,
            list_sizes: vec![2, 2],
            input_map: vec![vec![0, 1], vec![2, 3]],
            n: 4,
            stages: vec![Stage::new(
                "s",
                vec![Block::MergeS2 { up: vec![0, 1], dn: vec![2, 3], out: vec![0, 1, 2, 3] }],
            )],
            output_perm: vec![0, 1, 2, 3],
            median_tap: None,
            grid: None,
        };
        let out = merge(&d, &[vec![1u32, 5], vec![2, 9]], ExecMode::Strict).unwrap();
        assert_eq!(out, vec![1, 2, 5, 9]);
    }

    #[test]
    fn stable_ties_prefer_up() {
        // Stability is observable with (value, origin) pairs via Ord on tuples.
        let d = dev(
            vec![Stage::new("s", vec![Block::MergeS2 { up: vec![0], dn: vec![1], out: vec![0, 1] }])],
            2,
        );
        let mut v = vec![(5u32, 0u8), (5u32, 1u8)];
        ExecScratch::new().run(&d, &mut v, ExecMode::Fast, None).unwrap();
        assert_eq!(v, vec![(5, 0), (5, 1)]);
    }
}
