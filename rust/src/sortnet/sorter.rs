//! Complete sorters composed from merge devices — the deployment the
//! paper's introduction motivates (§II): a first rank of parallel
//! 2-sorters turns an unsorted list into sorted pairs, then a binary
//! tree of 2-way merge devices produces the sorted output. The choice
//! of merge family (Batcher / S2MS / LOMS) sets the sorter's overall
//! stage count and LUT bill — the trade the paper's figures quantify
//! per merge level.

use super::batcher::{bitonic_merge, odd_even_merge};
use super::loms::loms_2way;
use super::network::{Block, DeviceKind, MergeDevice, Stage};
use super::s2ms::s2ms;

/// Which 2-way merge family composes the sorter's merge tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeFamily {
    OddEven,
    Bitonic,
    S2ms,
    /// LOMS with the given column count at every level (columns are
    /// capped at the level's list size).
    Loms { cols: usize },
}

impl MergeFamily {
    fn merge_device(self, m: usize) -> MergeDevice {
        match self {
            MergeFamily::OddEven => odd_even_merge(m),
            MergeFamily::Bitonic => bitonic_merge(m),
            MergeFamily::S2ms => s2ms(m, m),
            MergeFamily::Loms { cols } => loms_2way(m, m, cols.min(m.max(2))),
        }
    }

    pub fn label(self) -> String {
        match self {
            MergeFamily::OddEven => "oems".into(),
            MergeFamily::Bitonic => "bims".into(),
            MergeFamily::S2ms => "s2ms".into(),
            MergeFamily::Loms { cols } => format!("loms{cols}"),
        }
    }
}

/// Build a complete sorter for `n` (power-of-2 ≥ 2) unsorted values:
/// one 2-sorter stage, then log2(n)-1 merge levels of the chosen family.
///
/// Stage structure: the merge devices of one level run in parallel, so
/// the sorter's stage sequence is the concatenation of each level's
/// stage sequence (each level's sub-devices are stage-aligned).
pub fn sorter(n: usize, family: MergeFamily) -> MergeDevice {
    assert!(n >= 2 && n.is_power_of_two(), "sorter needs a power-of-2 size, got {n}");
    // Stage 0: 2-sorters over adjacent pairs.
    let mut stages = vec![Stage::new(
        "pair-sort",
        (0..n / 2).map(|i| Block::Cas { lo: 2 * i, hi: 2 * i + 1 }).collect(),
    )];
    // `layout[rank_slot] = absolute position` of the value holding that
    // rank within its run after the completed levels. After the pair
    // stage each pair is sorted in place, so layout starts as identity.
    let mut layout: Vec<usize> = (0..n).collect();
    let mut m = 2usize;
    while m < n {
        let proto = family.merge_device(m);
        debug_assert!(proto.output_perm.iter().enumerate().all(|(r, &p)| r == p));
        let mut level_stages: Vec<Stage> = proto
            .stages
            .iter()
            .map(|s| Stage::new(format!("merge{m}-{}", s.label), vec![]))
            .collect();
        let mut next_layout = vec![0usize; n];
        for group in 0..n / (2 * m) {
            let base = group * 2 * m;
            // abs_of_proto: prototype coordinate -> absolute position.
            // Inputs: run l element i sits at layout[base + l*m + i] and
            // the prototype expects it at input_map[l][i].
            let mut abs_of_proto = vec![usize::MAX; 2 * m];
            for (l, map) in proto.input_map.iter().enumerate() {
                for (i, &pc) in map.iter().enumerate() {
                    abs_of_proto[pc] = layout[base + l * m + i];
                }
            }
            debug_assert!(abs_of_proto.iter().all(|&x| x != usize::MAX));
            for (si, stage) in proto.stages.iter().enumerate() {
                for b in &stage.blocks {
                    let nb = match b {
                        Block::Cas { lo, hi } => {
                            Block::Cas { lo: abs_of_proto[*lo], hi: abs_of_proto[*hi] }
                        }
                        Block::SortN { pos } => Block::SortN {
                            pos: pos.iter().map(|&p| abs_of_proto[p]).collect(),
                        },
                        Block::MergeS2 { up, dn, out } => Block::MergeS2 {
                            up: up.iter().map(|&p| abs_of_proto[p]).collect(),
                            dn: dn.iter().map(|&p| abs_of_proto[p]).collect(),
                            out: out.iter().map(|&p| abs_of_proto[p]).collect(),
                        },
                        Block::FilterN { pos, taps } => Block::FilterN {
                            pos: pos.iter().map(|&p| abs_of_proto[p]).collect(),
                            taps: taps.clone(),
                        },
                    };
                    level_stages[si].blocks.push(nb);
                }
            }
            // Outputs: prototype rank r lands at abs_of_proto[r].
            for r in 0..2 * m {
                next_layout[base + r] = abs_of_proto[r];
            }
        }
        stages.extend(level_stages);
        layout = next_layout;
        m *= 2;
    }
    MergeDevice {
        name: format!("sorter{n}-{}", family.label()),
        kind: DeviceKind::Loms,
        list_sizes: vec![n],
        input_map: vec![(0..n).collect()],
        n,
        stages,
        output_perm: layout,
        median_tap: None,
        grid: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{merge, ExecMode};
    use crate::sortnet::validate::validate_sorter_01;
    use crate::util::Rng;

    #[test]
    fn sorters_sort_01_exhaustive() {
        for family in [
            MergeFamily::OddEven,
            MergeFamily::Bitonic,
            MergeFamily::S2ms,
            MergeFamily::Loms { cols: 2 },
        ] {
            for n in [2usize, 4, 8, 16] {
                let d = sorter(n, family);
                d.check().unwrap_or_else(|e| panic!("{e}"));
                validate_sorter_01(&d).unwrap_or_else(|e| panic!("{family:?} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn sorters_random_differential() {
        let mut rng = Rng::new(44);
        for family in [MergeFamily::S2ms, MergeFamily::Loms { cols: 2 }, MergeFamily::OddEven] {
            let d = sorter(64, family);
            for _ in 0..20 {
                let mut data: Vec<u32> = (0..64).map(|_| rng.next_u32() >> 8).collect();
                let got = merge(&d, &[data.clone()], ExecMode::Fast).unwrap();
                data.sort_unstable();
                assert_eq!(got, data, "{family:?}");
            }
        }
    }

    #[test]
    fn loms_sorter_shallower_than_batcher_sorter() {
        // The composition inherits the paper's stage story: each LOMS
        // merge level is 2 stages, each S2MS level 1, each Batcher level
        // log2(outputs).
        let n = 64;
        let batcher_depth = sorter(n, MergeFamily::OddEven).depth();
        let loms_depth = sorter(n, MergeFamily::Loms { cols: 2 }).depth();
        let s2ms_depth = sorter(n, MergeFamily::S2ms).depth();
        assert_eq!(s2ms_depth, 1 + 5); // pairs + one stage per level
        assert_eq!(loms_depth, 1 + 2 * 5); // pairs + 2 per level... minus level-2 col skip
        assert!(loms_depth < batcher_depth, "loms {loms_depth} vs batcher {batcher_depth}");
    }
}
