//! Compiled execution plans: a [`MergeDevice`] lowered into a flat,
//! batch-executable IR.
//!
//! The devices are fixed combinatorial structures, but the interpreter
//! ([`super::exec::ExecScratch`]) re-walks an enum tree of heap-allocated
//! `Vec<usize>` index lists for every block of every row. A
//! [`CompiledPlan`] lowers the device **once** into a cache-friendly
//! struct-of-arrays form — one contiguous `u32` index arena, fixed-stride
//! [`OpRec`] records, the input map and output permutation baked into
//! flat position tables, and the maximum block width precomputed so the
//! per-op scratch buffer never reallocates. Optionally the output-cone
//! analysis ([`super::prune`]) drops muxes a stage provably never fires
//! before lowering.
//!
//! Two executors cover both call shapes in the stack:
//!
//! * [`CompiledPlan::run_row`] — drop-in for `ExecScratch::run` over a
//!   loaded flat vector; zero allocation per call once the scratch is
//!   warm.
//! * [`CompiledPlan::run_batch`] — executes a whole row-major batch (the
//!   exact shape [`crate::coordinator::Backend::execute`] receives) in
//!   one call, reusing a single row buffer across rows.
//!
//! Everything downstream — `exec::merge`/`median`, the validators, the
//! software backend, the throughput benches — routes through this IR.
//! It is also the lowering source for the lane-parallel tier
//! ([`super::lanes`]): Fast-mode batches expand further into a pure
//! compare-exchange schedule executed over transposed SIMD-friendly
//! tiles, while Strict mode, medians and validation stay here.

use super::exec::{ExecMode, PreconditionViolation};
use super::network::{Block, MergeDevice};
use super::prune::prune;
use super::validate::merge_01_pattern_count;

/// Lowered block kind. One-to-one with [`Block`], minus the embedded
/// index vectors (those live in the plan's arena).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum OpKind {
    /// Compare-and-swap of arena `[lo, hi]`.
    Cas,
    /// Sort the `a` positions at `off` ascending in listed order.
    SortN,
    /// Two-run merge: arena holds `[up(a) | dn(b) | out(a+b)]`.
    MergeS2,
    /// Partial sorter: arena holds `[pos(a) | tap ranks(b)]`.
    FilterN,
}

/// Borrowed view of one lowered op, resolved against the arena. The
/// lane expander ([`super::lanes`]) walks these to re-express the plan
/// as a pure compare-exchange schedule.
#[derive(Debug, Clone, Copy)]
pub(crate) enum PlanOp<'a> {
    Cas { lo: usize, hi: usize },
    SortN { pos: &'a [u32] },
    MergeS2 { up: &'a [u32], dn: &'a [u32], out: &'a [u32] },
    FilterN { pos: &'a [u32], taps: &'a [u32] },
}

/// One lowered block: a fixed-size record pointing into the index arena.
#[derive(Debug, Clone, Copy)]
struct OpRec {
    kind: OpKind,
    /// Start of this op's index block in the arena.
    off: u32,
    /// Primary operand count (Cas: 2, SortN/FilterN: |pos|, MergeS2: |up|).
    a: u32,
    /// Secondary operand count (MergeS2: |dn|, FilterN: |taps|, else 0).
    b: u32,
    /// Source (stage, block) for strict-mode diagnostics.
    stage: u32,
    block: u32,
}

/// Reusable execution buffers for plan execution. One scratch serves any
/// number of plans; buffers grow to the largest plan seen and are never
/// shrunk, so steady-state execution allocates nothing.
#[derive(Debug, Default)]
pub struct PlanScratch<T> {
    /// Flat value vector for row assembly (`run_batch` / `merge_row`).
    v: Vec<T>,
    /// Per-op staging buffer (block width ≤ `CompiledPlan::max_width`).
    buf: Vec<T>,
}

impl<T> PlanScratch<T> {
    pub fn new() -> Self {
        PlanScratch { v: Vec::new(), buf: Vec::new() }
    }
}

/// Append-executor plumbing shared by every batch entry point (scalar,
/// lane, sharded): grow `out` by `rows * outs` default values, run `f`
/// over the new region, and roll the growth back on error so a poisoned
/// batch appends nothing.
pub(crate) fn append_rows<T: Copy + Default, E>(
    out: &mut Vec<T>,
    rows: usize,
    outs: usize,
    f: impl FnOnce(&mut [T]) -> Result<(), E>,
) -> Result<(), E> {
    let start = out.len();
    out.resize(start + rows * outs, T::default());
    let res = f(&mut out[start..]);
    if res.is_err() {
        out.truncate(start);
    }
    res
}

/// Sorted-0-1 pattern budget under which [`CompiledPlan::compile_auto`]
/// runs the (exhaustive) pruning analysis before lowering. Covers the
/// default 2-way software artifacts up to 64+64 inputs; larger shapes —
/// and median-tapped devices, which are never pruned — lower unpruned
/// rather than pay a multi-second analysis at plan-cache fill.
const PRUNE_PATTERN_BUDGET: u128 = 5_000;

/// A [`MergeDevice`] lowered to a flat batch-executable IR.
#[derive(Debug, Clone)]
pub struct CompiledPlan {
    pub name: String,
    /// Flat vector length (total input values).
    n: usize,
    /// Contiguous index arena shared by all ops.
    arena: Vec<u32>,
    /// Lowered blocks in execution order (stage-major).
    ops: Vec<OpRec>,
    /// `stage_ops[s]` = index into `ops` where stage `s` begins;
    /// `stage_ops.last()` = `ops.len()`.
    stage_ops: Vec<u32>,
    /// Flattened input map: list-major, ascending value order.
    in_pos: Vec<u32>,
    list_sizes: Vec<usize>,
    /// `out_pos[r]` = flat position of output rank `r`.
    out_pos: Vec<u32>,
    /// Widest block — upper bound for the staging buffer.
    max_width: usize,
    /// Median tap: (stage count to run, flat position), if any.
    median: Option<(usize, usize)>,
    pruned: bool,
    removed_muxes: usize,
}

impl CompiledPlan {
    /// Lower a device as-is (structure checked, no pruning analysis).
    pub fn compile(d: &MergeDevice) -> Result<CompiledPlan, String> {
        d.check()?;
        Ok(Self::lower(d, false, 0))
    }

    /// Lower after output-cone pruning ([`super::prune::prune`]): dead
    /// output muxes are dropped and never-firing blocks disappear from
    /// the op stream. Only valid for full-merge devices — a median tap's
    /// stage index would dangle if pruning emptied an earlier stage.
    pub fn compile_pruned(d: &MergeDevice) -> Result<CompiledPlan, String> {
        if d.median_tap.is_some() {
            return Err(format!("{}: cannot prune a median-tapped device", d.name));
        }
        let (pruned, removed) = prune(d).map_err(|e| e.to_string())?;
        Ok(Self::lower(&pruned, true, removed))
    }

    /// Lower with pruning when the exhaustive analysis is cheap (pattern
    /// count ≤ [`PRUNE_PATTERN_BUDGET`] and no median tap), plain
    /// otherwise. The policy the software backend's plan cache uses.
    pub fn compile_auto(d: &MergeDevice) -> Result<CompiledPlan, String> {
        if d.median_tap.is_none() && merge_01_pattern_count(&d.list_sizes) <= PRUNE_PATTERN_BUDGET
        {
            Self::compile_pruned(d)
        } else {
            Self::compile(d)
        }
    }

    fn lower(d: &MergeDevice, pruned: bool, removed_muxes: usize) -> CompiledPlan {
        let mut arena: Vec<u32> = Vec::new();
        let mut ops: Vec<OpRec> = Vec::new();
        let mut stage_ops: Vec<u32> = Vec::with_capacity(d.stages.len() + 1);
        let mut max_width = 1usize;
        for (si, stage) in d.stages.iter().enumerate() {
            stage_ops.push(ops.len() as u32);
            for (bi, blk) in stage.blocks.iter().enumerate() {
                let off = arena.len() as u32;
                let (kind, a, b) = match blk {
                    Block::Cas { lo, hi } => {
                        arena.push(*lo as u32);
                        arena.push(*hi as u32);
                        (OpKind::Cas, 2, 0)
                    }
                    Block::SortN { pos } => {
                        arena.extend(pos.iter().map(|&p| p as u32));
                        (OpKind::SortN, pos.len(), 0)
                    }
                    Block::MergeS2 { up, dn, out } => {
                        arena.extend(up.iter().map(|&p| p as u32));
                        arena.extend(dn.iter().map(|&p| p as u32));
                        arena.extend(out.iter().map(|&p| p as u32));
                        (OpKind::MergeS2, up.len(), dn.len())
                    }
                    Block::FilterN { pos, taps } => {
                        arena.extend(pos.iter().map(|&p| p as u32));
                        arena.extend(taps.iter().map(|&t| t as u32));
                        (OpKind::FilterN, pos.len(), taps.len())
                    }
                };
                max_width = max_width.max(blk.width());
                ops.push(OpRec {
                    kind,
                    off,
                    a: a as u32,
                    b: b as u32,
                    stage: si as u32,
                    block: bi as u32,
                });
            }
        }
        stage_ops.push(ops.len() as u32);
        let mut in_pos = Vec::with_capacity(d.n);
        for m in &d.input_map {
            in_pos.extend(m.iter().map(|&p| p as u32));
        }
        CompiledPlan {
            name: d.name.clone(),
            n: d.n,
            arena,
            ops,
            stage_ops,
            in_pos,
            list_sizes: d.list_sizes.clone(),
            out_pos: d.output_perm.iter().map(|&p| p as u32).collect(),
            max_width,
            median: d.median_tap,
            pruned,
            removed_muxes,
        }
    }

    /// Flat vector length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stage count (after pruning, if applied).
    pub fn depth(&self) -> usize {
        self.stage_ops.len() - 1
    }

    /// Lowered block count.
    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    /// Index arena length (u32 slots).
    pub fn arena_len(&self) -> usize {
        self.arena.len()
    }

    /// Output width per row.
    pub fn total_outputs(&self) -> usize {
        self.out_pos.len()
    }

    pub fn list_sizes(&self) -> &[usize] {
        &self.list_sizes
    }

    /// Whether the output-cone analysis ran before lowering.
    pub fn is_pruned(&self) -> bool {
        self.pruned
    }

    /// Output muxes dropped by pruning (0 when unpruned or cone-minimal).
    pub fn removed_muxes(&self) -> usize {
        self.removed_muxes
    }

    /// Walk the lowered ops in execution order (stage-major), with arena
    /// slices resolved. Consumed by the lane expander.
    pub(crate) fn iter_ops(&self) -> impl Iterator<Item = PlanOp<'_>> + '_ {
        self.ops.iter().map(|op| {
            let off = op.off as usize;
            let (a, b) = (op.a as usize, op.b as usize);
            match op.kind {
                OpKind::Cas => PlanOp::Cas {
                    lo: self.arena[off] as usize,
                    hi: self.arena[off + 1] as usize,
                },
                OpKind::SortN => PlanOp::SortN { pos: &self.arena[off..off + a] },
                OpKind::MergeS2 => PlanOp::MergeS2 {
                    up: &self.arena[off..off + a],
                    dn: &self.arena[off + a..off + a + b],
                    out: &self.arena[off + a + b..off + 2 * (a + b)],
                },
                OpKind::FilterN => PlanOp::FilterN {
                    pos: &self.arena[off..off + a],
                    taps: &self.arena[off + a..off + a + b],
                },
            }
        })
    }

    /// Flattened input map (list-major, ascending value order).
    pub(crate) fn in_pos(&self) -> &[u32] {
        &self.in_pos
    }

    /// Flat position of each output rank.
    pub(crate) fn out_pos(&self) -> &[u32] {
        &self.out_pos
    }

    /// Execute ops `[0, end)` over the flat vector. The hot loop: every
    /// index comes from the contiguous arena, and `buf` never
    /// reallocates once warmed to `max_width` (callers warm once per
    /// entry point — see [`Self::warm_scratch`] — keeping the
    /// clear/reserve pair off the per-row path).
    fn exec_ops<T: Copy + Ord>(
        &self,
        v: &mut [T],
        buf: &mut Vec<T>,
        mode: ExecMode,
        end: usize,
    ) -> Result<(), PreconditionViolation> {
        debug_assert_eq!(v.len(), self.n);
        for op in &self.ops[..end] {
            let off = op.off as usize;
            match op.kind {
                OpKind::Cas => {
                    // Branchless min/max — same select shape as the lane
                    // executor, so both paths cost the same per value.
                    let lo = self.arena[off] as usize;
                    let hi = self.arena[off + 1] as usize;
                    let (a, b) = (v[lo], v[hi]);
                    let swap = b < a;
                    v[lo] = if swap { b } else { a };
                    v[hi] = if swap { a } else { b };
                }
                OpKind::SortN => {
                    let pos = &self.arena[off..off + op.a as usize];
                    buf.clear();
                    buf.extend(pos.iter().map(|&p| v[p as usize]));
                    buf.sort_unstable();
                    for (i, &p) in pos.iter().enumerate() {
                        v[p as usize] = buf[i];
                    }
                }
                OpKind::MergeS2 => {
                    let (a, b) = (op.a as usize, op.b as usize);
                    let up = &self.arena[off..off + a];
                    let dn = &self.arena[off + a..off + a + b];
                    let out = &self.arena[off + a + b..off + 2 * (a + b)];
                    if mode == ExecMode::Strict {
                        for run in [up, dn] {
                            if run.windows(2).any(|w| v[w[0] as usize] > v[w[1] as usize]) {
                                return Err(PreconditionViolation {
                                    stage: op.stage as usize,
                                    block: op.block as usize,
                                    row: None,
                                    detail: "S2MS input run not sorted".into(),
                                });
                            }
                        }
                    }
                    buf.clear();
                    let (mut i, mut j) = (0usize, 0usize);
                    while i < a && j < b {
                        let x = v[up[i] as usize];
                        let y = v[dn[j] as usize];
                        // Stable: UP values win ties (paper's sorters are stable).
                        if x <= y {
                            buf.push(x);
                            i += 1;
                        } else {
                            buf.push(y);
                            j += 1;
                        }
                    }
                    buf.extend(up[i..].iter().map(|&p| v[p as usize]));
                    buf.extend(dn[j..].iter().map(|&p| v[p as usize]));
                    for (t, &p) in out.iter().enumerate() {
                        v[p as usize] = buf[t];
                    }
                }
                OpKind::FilterN => {
                    let (a, b) = (op.a as usize, op.b as usize);
                    let pos = &self.arena[off..off + a];
                    let taps = &self.arena[off + a..off + a + b];
                    buf.clear();
                    buf.extend(pos.iter().map(|&p| v[p as usize]));
                    buf.sort_unstable();
                    for &t in taps {
                        let t = t as usize;
                        v[pos[t] as usize] = buf[t];
                    }
                }
            }
        }
        Ok(())
    }

    /// Op index bound for running the first `stages` stages (clamped).
    fn op_end(&self, stop_after: Option<usize>) -> usize {
        let s = stop_after.unwrap_or(self.depth()).min(self.depth());
        self.stage_ops[s] as usize
    }

    /// Warm a scratch's staging buffer to this plan's widest block —
    /// called once per public entry point so [`Self::exec_ops`] never
    /// pays the clear/reserve pair per row.
    fn warm_scratch<T>(&self, buf: &mut Vec<T>) {
        buf.clear();
        buf.reserve(self.max_width);
    }

    /// Execute over a loaded flat vector — drop-in for
    /// [`super::exec::ExecScratch::run`]. Allocates nothing once
    /// `scratch` has warmed to this plan's widest block.
    pub fn run_row<T: Copy + Ord>(
        &self,
        v: &mut [T],
        mode: ExecMode,
        stop_after: Option<usize>,
        scratch: &mut PlanScratch<T>,
    ) -> Result<(), PreconditionViolation> {
        self.warm_scratch(&mut scratch.buf);
        self.exec_ops(v, &mut scratch.buf, mode, self.op_end(stop_after))
    }

    /// Load one row of per-list inputs into the flat vector `v` (resized
    /// to `n`) via the baked input map.
    fn load_row<T: Copy + Ord + Default>(&self, lists: &[Vec<T>], v: &mut Vec<T>) {
        assert_eq!(lists.len(), self.list_sizes.len(), "{}: wrong list count", self.name);
        v.clear();
        v.resize(self.n, T::default());
        let mut ip = 0usize;
        for (l, list) in lists.iter().enumerate() {
            assert_eq!(list.len(), self.list_sizes[l], "{}: wrong size for list {l}", self.name);
            for (i, &x) in list.iter().enumerate() {
                v[self.in_pos[ip + i] as usize] = x;
            }
            ip += self.list_sizes[l];
        }
    }

    /// Merge one request: load `lists`, run all stages, return the sorted
    /// output ranks.
    pub fn merge_row<T: Copy + Ord + Default>(
        &self,
        lists: &[Vec<T>],
        mode: ExecMode,
        scratch: &mut PlanScratch<T>,
    ) -> Result<Vec<T>, PreconditionViolation> {
        let PlanScratch { v, buf } = scratch;
        self.warm_scratch(buf);
        self.load_row(lists, v);
        self.exec_ops(v, buf, mode, self.ops.len())?;
        Ok(self.out_pos.iter().map(|&p| v[p as usize]).collect())
    }

    /// Run up to the median tap and return the median (`None` when the
    /// device has no tap).
    pub fn median_row<T: Copy + Ord + Default>(
        &self,
        lists: &[Vec<T>],
        mode: ExecMode,
        scratch: &mut PlanScratch<T>,
    ) -> Result<Option<T>, PreconditionViolation> {
        let Some((stop, pos)) = self.median else {
            return Ok(None);
        };
        let PlanScratch { v, buf } = scratch;
        self.warm_scratch(buf);
        self.load_row(lists, v);
        self.exec_ops(v, buf, mode, self.op_end(Some(stop)))?;
        Ok(Some(v[pos]))
    }

    /// Execute a whole row-major batch — the exact shape
    /// [`crate::coordinator::Backend::execute`] receives: `lists[l]` is
    /// `(batch, list_sizes[l])` flattened, the merged rows are appended
    /// to `out` as `(batch, total_outputs)`. On a strict-mode error
    /// nothing is appended. One flat row buffer is reused across rows;
    /// nothing is allocated per row once `out` and `scratch` are warm.
    pub fn run_batch<T: Copy + Ord + Default>(
        &self,
        lists: &[Vec<T>],
        batch: usize,
        mode: ExecMode,
        scratch: &mut PlanScratch<T>,
        out: &mut Vec<T>,
    ) -> Result<(), PreconditionViolation> {
        let slices: Vec<&[T]> = lists.iter().map(Vec::as_slice).collect();
        append_rows(out, batch, self.out_pos.len(), |dst| {
            self.run_batch_into(&slices, batch, mode, scratch, dst)
        })
    }

    /// View-based batch executor — the scalar half of the **tile-direct
    /// serving path** (see [`super::lanes`]): each row is an un-padded
    /// request view (`rows[r][l]` is request `r`'s sorted list `l`, no
    /// longer than `list_sizes[l]`), loaded straight into the flat
    /// vector with `pad` filling the short-list tail, and each row's
    /// merged prefix is written straight into its caller-provided buffer
    /// (`outs[r].len()` ≤ `total_outputs()` — typically the request's
    /// real output width, since `pad` sentinels sort to the tail). No
    /// intermediate row-major batch buffer exists on this path. Strict
    /// mode checks every block precondition per row, exactly like
    /// [`Self::run_batch_into`]; errors carry the failing row.
    pub fn run_view_batch_into<T: Copy + Ord + Default>(
        &self,
        rows: &[&[Vec<T>]],
        pad: T,
        mode: ExecMode,
        scratch: &mut PlanScratch<T>,
        outs: &mut [&mut [T]],
    ) -> Result<(), PreconditionViolation> {
        assert_eq!(rows.len(), outs.len(), "{}: rows vs output buffers", self.name);
        let PlanScratch { v, buf } = scratch;
        v.clear();
        v.resize(self.n, T::default());
        self.warm_scratch(buf);
        let end = self.ops.len();
        for (row, lists) in rows.iter().enumerate() {
            assert_eq!(lists.len(), self.list_sizes.len(), "{}: row {row} list count", self.name);
            let mut ip = 0usize;
            for (l, &cap) in self.list_sizes.iter().enumerate() {
                let src = &lists[l];
                assert!(src.len() <= cap, "{}: row {row} list {l} exceeds device slot", self.name);
                for (i, &x) in src.iter().enumerate() {
                    v[self.in_pos[ip + i] as usize] = x;
                }
                for i in src.len()..cap {
                    v[self.in_pos[ip + i] as usize] = pad;
                }
                ip += cap;
            }
            self.exec_ops(v, buf, mode, end).map_err(|e| e.with_row(row))?;
            let dst = &mut *outs[row];
            assert!(dst.len() <= self.out_pos.len(), "{}: row {row} output too wide", self.name);
            for (t, &p) in self.out_pos.iter().take(dst.len()).enumerate() {
                dst[t] = v[p as usize];
            }
        }
        Ok(())
    }

    /// Rank-then-permute twin of [`Self::run_view_batch_into`] — the
    /// scalar tail of the key-value serving path (see
    /// [`super::lanes::LanePlan::run_view_batch_perm_into`]). Each key
    /// is packed with its list-major origin rank into a `u64`
    /// ([`super::lanes::pack_kv`]); the unmodified comparator stream
    /// orders the packed values, and the gathered output prefix unpacks
    /// into the merged keys plus the permutation carrying each output
    /// slot's origin index. Payloads never enter the flat vector — the
    /// caller applies the permutation to its payload column once per
    /// row. Runs in fast mode: packed inputs satisfy the sortedness
    /// preconditions exactly when the raw keys do, and the distinct
    /// origins make the packed elements unique, so the network output is
    /// the one stable (key, origin)-lexicographic merge.
    pub fn run_view_batch_perm_into(
        &self,
        rows: &[&[Vec<u32>]],
        scratch: &mut PlanScratch<u64>,
        out_keys: &mut [&mut [u32]],
        out_perm: &mut [&mut [u32]],
    ) -> Result<(), PreconditionViolation> {
        use super::lanes::{pack_kv, KV_PAD};
        assert_eq!(rows.len(), out_keys.len(), "{}: rows vs key buffers", self.name);
        assert_eq!(rows.len(), out_perm.len(), "{}: rows vs perm buffers", self.name);
        let PlanScratch { v, buf } = scratch;
        v.clear();
        v.resize(self.n, 0u64);
        self.warm_scratch(buf);
        let end = self.ops.len();
        for (row, lists) in rows.iter().enumerate() {
            assert_eq!(lists.len(), self.list_sizes.len(), "{}: row {row} list count", self.name);
            let mut ip = 0usize;
            let mut origin = 0u32;
            for (l, &cap) in self.list_sizes.iter().enumerate() {
                let src = &lists[l];
                assert!(src.len() <= cap, "{}: row {row} list {l} exceeds device slot", self.name);
                for (i, &x) in src.iter().enumerate() {
                    v[self.in_pos[ip + i] as usize] = pack_kv(x, origin + i as u32);
                }
                for i in src.len()..cap {
                    v[self.in_pos[ip + i] as usize] = KV_PAD;
                }
                origin += src.len() as u32;
                ip += cap;
            }
            self.exec_ops(v, buf, ExecMode::Fast, end).map_err(|e| e.with_row(row))?;
            let keys = &mut *out_keys[row];
            let perm = &mut *out_perm[row];
            assert_eq!(keys.len(), perm.len(), "{}: row {row} key/perm widths", self.name);
            assert!(keys.len() <= self.out_pos.len(), "{}: row {row} output too wide", self.name);
            for (t, &p) in self.out_pos.iter().take(keys.len()).enumerate() {
                let packed = v[p as usize];
                keys[t] = (packed >> 32) as u32;
                perm[t] = packed as u32;
            }
        }
        Ok(())
    }

    /// Slice-level batch executor behind [`Self::run_batch`]: rows are
    /// read from `lists[l]` (row-major `(batch, list_sizes[l])`) and
    /// written to `dst` (`batch * total_outputs()`, fully overwritten).
    /// The lane executor's scalar tail and the sharded backend call this
    /// directly on sub-ranges. Strict-mode errors carry the failing
    /// [`PreconditionViolation::row`], so a poisoned batch names the
    /// request that tripped it.
    pub fn run_batch_into<T: Copy + Ord + Default>(
        &self,
        lists: &[&[T]],
        batch: usize,
        mode: ExecMode,
        scratch: &mut PlanScratch<T>,
        dst: &mut [T],
    ) -> Result<(), PreconditionViolation> {
        assert_eq!(lists.len(), self.list_sizes.len(), "{}: wrong list count", self.name);
        for (l, &s) in self.list_sizes.iter().enumerate() {
            assert_eq!(lists[l].len(), batch * s, "{}: list {l} flat length", self.name);
        }
        let outs = self.out_pos.len();
        assert_eq!(dst.len(), batch * outs, "{}: output buffer length", self.name);
        let PlanScratch { v, buf } = scratch;
        v.clear();
        v.resize(self.n, T::default());
        self.warm_scratch(buf);
        let end = self.ops.len();
        for row in 0..batch {
            let mut ip = 0usize;
            for (l, &s) in self.list_sizes.iter().enumerate() {
                let src = &lists[l][row * s..(row + 1) * s];
                for (i, &x) in src.iter().enumerate() {
                    v[self.in_pos[ip + i] as usize] = x;
                }
                ip += s;
            }
            self.exec_ops(v, buf, mode, end).map_err(|e| e.with_row(row))?;
            let row_dst = &mut dst[row * outs..(row + 1) * outs];
            for (t, &p) in self.out_pos.iter().enumerate() {
                row_dst[t] = v[p as usize];
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::ExecScratch;
    use crate::sortnet::loms::{loms_2way, loms_3way_median, loms_kway};
    use crate::sortnet::mwms::mwms_3way;
    use crate::sortnet::{batcher, s2ms};
    use crate::util::Rng;

    fn interp_outputs(d: &MergeDevice, lists: &[Vec<u32>], mode: ExecMode) -> Vec<u32> {
        let mut v = d.load_inputs(lists);
        ExecScratch::new().run(d, &mut v, mode, None).unwrap();
        d.read_outputs(&v)
    }

    #[test]
    fn plan_matches_interpreter_on_random_inputs() {
        let mut rng = Rng::new(11);
        for d in [
            loms_2way(8, 8, 2),
            loms_2way(7, 5, 2),
            s2ms::s2ms(6, 6),
            batcher::odd_even_merge(8),
            loms_kway(&[7, 7, 7]),
        ] {
            let plan = CompiledPlan::compile(&d).unwrap();
            let mut scratch = PlanScratch::new();
            for _ in 0..25 {
                let lists: Vec<Vec<u32>> =
                    d.list_sizes.iter().map(|&s| rng.sorted_list(s, 500)).collect();
                let want = interp_outputs(&d, &lists, ExecMode::Fast);
                let got = plan.merge_row(&lists, ExecMode::Fast, &mut scratch).unwrap();
                assert_eq!(got, want, "{}", d.name);
            }
        }
    }

    #[test]
    fn run_row_is_drop_in_for_exec_scratch_run() {
        let d = loms_2way(8, 8, 4);
        let plan = CompiledPlan::compile(&d).unwrap();
        let mut rng = Rng::new(3);
        let lists = vec![rng.sorted_list(8, 100), rng.sorted_list(8, 100)];
        let mut vi = d.load_inputs(&lists);
        let mut vp = vi.clone();
        ExecScratch::new().run(&d, &mut vi, ExecMode::Strict, None).unwrap();
        plan.run_row(&mut vp, ExecMode::Strict, None, &mut PlanScratch::new()).unwrap();
        assert_eq!(vi, vp);
    }

    #[test]
    fn run_batch_matches_per_row_execution() {
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        let batch = 17;
        let mut rng = Rng::new(21);
        let rows: Vec<Vec<Vec<u32>>> = (0..batch)
            .map(|_| vec![rng.sorted_list(8, 1000), rng.sorted_list(8, 1000)])
            .collect();
        let lists: Vec<Vec<u32>> = (0..2)
            .map(|l| rows.iter().flat_map(|r| r[l].iter().copied()).collect())
            .collect();
        let mut out = Vec::new();
        let mut scratch = PlanScratch::new();
        plan.run_batch(&lists, batch, ExecMode::Strict, &mut scratch, &mut out).unwrap();
        assert_eq!(out.len(), batch * plan.total_outputs());
        for (row, req) in rows.iter().enumerate() {
            let want = interp_outputs(&d, req, ExecMode::Fast);
            assert_eq!(&out[row * 16..(row + 1) * 16], &want[..], "row {row}");
        }
    }

    #[test]
    fn pruned_plan_bit_identical_and_smaller() {
        let d = mwms_3way(5);
        let plan = CompiledPlan::compile(&d).unwrap();
        let pruned = CompiledPlan::compile_pruned(&d).unwrap();
        assert!(pruned.is_pruned());
        assert!(pruned.removed_muxes() > 0);
        assert!(pruned.op_count() <= plan.op_count());
        let mut rng = Rng::new(7);
        let mut s1 = PlanScratch::new();
        let mut s2 = PlanScratch::new();
        for _ in 0..30 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 200)).collect();
            let a = plan.merge_row(&lists, ExecMode::Fast, &mut s1).unwrap();
            let b = pruned.merge_row(&lists, ExecMode::Fast, &mut s2).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn compile_auto_prunes_small_skips_large_and_tapped() {
        let small = CompiledPlan::compile_auto(&loms_kway(&[3, 3, 3, 3])).unwrap();
        assert!(small.is_pruned());
        let large = CompiledPlan::compile_auto(&loms_2way(128, 128, 4)).unwrap();
        assert!(!large.is_pruned());
        // Median-tapped devices (loms_kway with equal odd sizes sets a
        // tap) are never pruned — the tap's stage index must stay valid.
        let tapped = loms_kway(&[7, 7, 7]);
        assert!(tapped.median_tap.is_some());
        assert!(!CompiledPlan::compile_auto(&tapped).unwrap().is_pruned());
    }

    #[test]
    fn median_row_matches_interpreter_median() {
        let d = loms_3way_median(7);
        assert!(d.median_tap.is_some());
        let plan = CompiledPlan::compile(&d).unwrap();
        assert!(CompiledPlan::compile_pruned(&d).is_err());
        let mut rng = Rng::new(13);
        let mut scratch = PlanScratch::new();
        for _ in 0..20 {
            let lists: Vec<Vec<u32>> =
                d.list_sizes.iter().map(|&s| rng.sorted_list(s, 99)).collect();
            let got = plan.median_row(&lists, ExecMode::Strict, &mut scratch).unwrap().unwrap();
            let want = crate::sortnet::exec::median(&d, &lists, ExecMode::Strict)
                .unwrap()
                .unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn strict_mode_reports_same_violation_site() {
        // Up-run descending violates the S2MS precondition; the plan must
        // report the same (stage, block) the interpreter does.
        let d = s2ms::s2ms(2, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        let mut v = vec![7u32, 2, 1, 9];
        let ie = ExecScratch::new().run(&d, &mut v.clone(), ExecMode::Strict, None).unwrap_err();
        let pe = plan
            .run_row(&mut v, ExecMode::Strict, None, &mut PlanScratch::new())
            .unwrap_err();
        assert_eq!((ie.stage, ie.block), (pe.stage, pe.block));
        // Fast mode tolerates garbage-in, like the hardware.
        plan.run_row(&mut vec![7u32, 2, 1, 9], ExecMode::Fast, None, &mut PlanScratch::new())
            .unwrap();
    }

    #[test]
    fn strict_batch_error_carries_failing_row() {
        // Rows 0 and 1 are valid; row 2's UP run descends, so the batch
        // must be rejected with the row index in the violation context.
        let d = s2ms::s2ms(2, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        let lists = vec![vec![1u32, 2, 3, 4, 9, 1], vec![5, 6, 7, 8, 2, 3]];
        let mut out = Vec::new();
        let err = plan
            .run_batch(&lists, 3, ExecMode::Strict, &mut PlanScratch::new(), &mut out)
            .unwrap_err();
        assert_eq!(err.row, Some(2));
        assert!(err.to_string().contains("row 2"), "{err}");
        // A poisoned batch appends nothing.
        assert!(out.is_empty());
        // Single-row entry points leave the row context unset.
        let mut v = vec![9u32, 1, 2, 3];
        let e = plan
            .run_row(&mut v, ExecMode::Strict, None, &mut PlanScratch::new())
            .unwrap_err();
        assert_eq!(e.row, None);
    }

    #[test]
    fn view_batch_matches_padded_row_major_batch() {
        // The view-based path (ragged requests, inline pad fill, per-row
        // output buffers) must be byte-exact with the old
        // assemble-then-execute path: pad each request to the device
        // shape, run the row-major batch, slice each row's real prefix.
        const PAD: u32 = u32::MAX;
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        let mut rng = Rng::new(0x71EE);
        for batch in [1usize, 5, 17] {
            let reqs: Vec<Vec<Vec<u32>>> = (0..batch)
                .map(|_| {
                    let (la, lb) = (rng.range(1, 9), rng.range(1, 9));
                    vec![rng.sorted_list(la, 1000), rng.sorted_list(lb, 1000)]
                })
                .collect();
            // Old path: row-major assembly padded to the device shape.
            let lists: Vec<Vec<u32>> = (0..2)
                .map(|l| {
                    let mut flat = Vec::new();
                    for r in &reqs {
                        flat.extend_from_slice(&r[l]);
                        flat.resize(flat.len() + (8 - r[l].len()), PAD);
                    }
                    flat
                })
                .collect();
            for mode in [ExecMode::Fast, ExecMode::Strict] {
                let mut old = Vec::new();
                plan.run_batch(&lists, batch, mode, &mut PlanScratch::new(), &mut old).unwrap();
                let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
                let mut merged: Vec<Vec<u32>> =
                    reqs.iter().map(|r| vec![0; r[0].len() + r[1].len()]).collect();
                let mut outs: Vec<&mut [u32]> =
                    merged.iter_mut().map(|v| v.as_mut_slice()).collect();
                plan.run_view_batch_into(&rows, PAD, mode, &mut PlanScratch::new(), &mut outs)
                    .unwrap();
                for (row, got) in merged.iter().enumerate() {
                    assert_eq!(
                        &old[row * 16..row * 16 + got.len()],
                        &got[..],
                        "row {row} ({mode:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn view_batch_strict_error_carries_row() {
        const PAD: u32 = u32::MAX;
        let d = s2ms::s2ms(2, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        let good = vec![vec![1u32, 2], vec![3, 4]];
        let bad = vec![vec![9u32, 1], vec![2, 3]]; // UP run descends
        let rows: Vec<&[Vec<u32>]> = vec![&good[..], &bad[..]];
        let mut merged = vec![vec![0u32; 4], vec![0u32; 4]];
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        let err = plan
            .run_view_batch_into(&rows, PAD, ExecMode::Strict, &mut PlanScratch::new(), &mut outs)
            .unwrap_err();
        assert_eq!(err.row, Some(1));
    }

    #[test]
    fn compile_rejects_invalid_device() {
        let mut d = loms_2way(2, 2, 2);
        d.output_perm = vec![0, 0, 1, 2];
        assert!(CompiledPlan::compile(&d).is_err());
    }

    #[test]
    fn plan_shape_accessors() {
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile(&d).unwrap();
        assert_eq!(plan.n(), 16);
        assert_eq!(plan.total_outputs(), 16);
        assert_eq!(plan.depth(), d.depth());
        assert_eq!(plan.list_sizes(), &[8, 8]);
        assert!(plan.op_count() > 0);
        assert!(plan.arena_len() >= plan.op_count());
    }
}
