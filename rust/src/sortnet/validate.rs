//! Correctness validation of merge devices via the 0-1 principle.
//!
//! For *merge* devices (sorted input lists) the 0-1 principle specialises:
//! a sorted 0-1 list of length `s` has exactly `s+1` distinct patterns
//! (the number of leading zeros), so a k-way merge device is correct for
//! **all** inputs iff it is correct for the `∏ (s_l + 1)` sorted 0-1
//! input combinations — exhaustively checkable even for 256-value devices.
//!
//! Strict execution (precondition checks on every `S2MS` block) during
//! validation extends the guarantee to the hardware semantics: if no 0-1
//! pattern violates a block precondition, no real-valued input can either
//! (a descent in a real-valued run implies a descent in its threshold
//! projection at any cut between the two values).
//!
//! Validators execute through the compiled plan ([`super::plan`]) in
//! strict mode — the proof covers the exact IR the serving hot path
//! runs, not just the structural device description.

use super::exec::ExecMode;
use super::network::MergeDevice;
use super::plan::{CompiledPlan, PlanScratch};

/// Validation failure detail.
#[derive(Debug, Clone)]
pub struct ValidationError {
    pub device: String,
    pub detail: String,
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.device, self.detail)
    }
}

impl std::error::Error for ValidationError {}

/// Iterate all sorted 0-1 patterns for the device's input lists, calling
/// `f(lists)` for each. Pattern count = ∏ (size_l + 1).
fn for_each_sorted01<F: FnMut(&[Vec<u8>]) -> Result<(), ValidationError>>(
    sizes: &[usize],
    mut f: F,
) -> Result<(), ValidationError> {
    let k = sizes.len();
    let mut zeros = vec![0usize; k]; // list l = zeros[l] zeros then ones
    loop {
        let lists: Vec<Vec<u8>> = sizes
            .iter()
            .zip(&zeros)
            .map(|(&s, &z)| {
                let mut v = vec![0u8; s];
                for x in v.iter_mut().skip(z) {
                    *x = 1;
                }
                v
            })
            .collect();
        f(&lists)?;
        // Odometer increment.
        let mut l = 0;
        loop {
            if l == k {
                return Ok(());
            }
            zeros[l] += 1;
            if zeros[l] <= sizes[l] {
                break;
            }
            zeros[l] = 0;
            l += 1;
        }
    }
}

/// Number of sorted 0-1 patterns a merge validation will run.
pub fn merge_01_pattern_count(sizes: &[usize]) -> u128 {
    sizes.iter().map(|&s| (s + 1) as u128).product()
}

/// Exhaustive sorted-0-1 validation of a merge device: every pattern must
/// execute without precondition violation and produce a sorted output.
/// Also checks the median tap (if any) against the true median.
pub fn validate_merge_01(d: &MergeDevice) -> Result<(), ValidationError> {
    let plan = CompiledPlan::compile(d)
        .map_err(|e| ValidationError { device: d.name.clone(), detail: e })?;
    let mut scratch = PlanScratch::new();
    for_each_sorted01(&d.list_sizes, |lists| {
        let out = plan.merge_row(lists, ExecMode::Strict, &mut scratch).map_err(|e| {
            ValidationError {
                device: d.name.clone(),
                detail: format!("precondition violated on {lists:?}: {e}"),
            }
        })?;
        if out.windows(2).any(|w| w[0] > w[1]) {
            return Err(ValidationError {
                device: d.name.clone(),
                detail: format!("unsorted output {out:?} for input {lists:?}"),
            });
        }
        // Median tap check (only defined for odd totals).
        if d.median_tap.is_some() {
            let got = plan
                .median_row(lists, ExecMode::Strict, &mut scratch)
                .map_err(|e| ValidationError {
                    device: d.name.clone(),
                    detail: format!("median-path precondition violated: {e}"),
                })?
                .expect("median tap present");
            let mut all: Vec<u8> = lists.iter().flatten().copied().collect();
            all.sort_unstable();
            let want = all[all.len() / 2];
            if got != want {
                return Err(ValidationError {
                    device: d.name.clone(),
                    detail: format!("median tap got {got} want {want} for input {lists:?}"),
                });
            }
        }
        Ok(())
    })
}

/// Exhaustive sorted-0-1 validation of a *median-only* device (e.g. the
/// Fig.-18 LOMS/MWMS median filters): checks only the median tap, since
/// such devices do not build the full sorted output.
pub fn validate_median_01(d: &MergeDevice) -> Result<(), ValidationError> {
    d.median_tap.ok_or_else(|| ValidationError {
        device: d.name.clone(),
        detail: "device has no median tap".into(),
    })?;
    let plan = CompiledPlan::compile(d)
        .map_err(|e| ValidationError { device: d.name.clone(), detail: e })?;
    let mut scratch = PlanScratch::new();
    for_each_sorted01(&d.list_sizes, |lists| {
        let got = plan
            .median_row(lists, ExecMode::Strict, &mut scratch)
            .map_err(|e| ValidationError {
                device: d.name.clone(),
                detail: format!("precondition violated on {lists:?}: {e}"),
            })?
            .expect("median tap present");
        let mut all: Vec<u8> = lists.iter().flatten().copied().collect();
        all.sort_unstable();
        let want = all[all.len() / 2];
        if got != want {
            return Err(ValidationError {
                device: d.name.clone(),
                detail: format!("median got {got} want {want} for {lists:?}"),
            });
        }
        Ok(())
    })
}

/// Exhaustive 0-1 validation for full sorters (unsorted input): all 2^n
/// binary vectors. Only feasible for small n (caller's responsibility;
/// asserts n <= 24).
pub fn validate_sorter_01(d: &MergeDevice) -> Result<(), ValidationError> {
    d.check().map_err(|e| ValidationError { device: d.name.clone(), detail: e })?;
    let n = d.n;
    assert!(n <= 24, "exhaustive 0-1 sorter validation limited to n<=24");
    assert_eq!(d.list_sizes.len(), 1, "sorter validation expects a single unsorted list");
    let plan = CompiledPlan::compile(d)
        .map_err(|e| ValidationError { device: d.name.clone(), detail: e })?;
    let mut scratch = PlanScratch::new();
    for bits in 0u32..(1u32 << n) {
        let list: Vec<u8> = (0..n).map(|i| ((bits >> i) & 1) as u8).collect();
        let out = plan
            .merge_row(&[list.clone()], ExecMode::Strict, &mut scratch)
            .map_err(|e| ValidationError {
                device: d.name.clone(),
                detail: format!("precondition violated on {bits:b}: {e}"),
            })?;
        if out.windows(2).any(|w| w[0] > w[1]) {
            return Err(ValidationError {
                device: d.name.clone(),
                detail: format!("unsorted output {out:?} for input {list:?}"),
            });
        }
    }
    Ok(())
}

/// Randomised differential validation against `sort()` on u32 values —
/// a belt-and-braces complement to the exhaustive 0-1 proofs (checks
/// value routing, not just order).
pub fn validate_merge_random(d: &MergeDevice, iters: usize, seed: u64) -> Result<(), ValidationError> {
    let mut rng = crate::util::Rng::new(seed);
    let plan = CompiledPlan::compile(d)
        .map_err(|e| ValidationError { device: d.name.clone(), detail: e })?;
    let mut scratch = PlanScratch::new();
    for it in 0..iters {
        let lists: Vec<Vec<u32>> = d.list_sizes.iter().map(|&s| rng.sorted_list(s, 1000)).collect();
        let got = plan.merge_row(&lists, ExecMode::Strict, &mut scratch).map_err(|e| {
            ValidationError {
                device: d.name.clone(),
                detail: format!("iter {it}: precondition violated: {e}"),
            }
        })?;
        let mut want: Vec<u32> = lists.iter().flatten().copied().collect();
        want.sort_unstable();
        if got != want {
            return Err(ValidationError {
                device: d.name.clone(),
                detail: format!("iter {it}: got {got:?} want {want:?} for {lists:?}"),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::network::{Block, DeviceKind, Stage};

    fn s2ms_2x2() -> MergeDevice {
        MergeDevice {
            name: "s2ms-2-2".into(),
            kind: DeviceKind::S2ms,
            list_sizes: vec![2, 2],
            input_map: vec![vec![0, 1], vec![2, 3]],
            n: 4,
            stages: vec![Stage::new("m", vec![Block::MergeS2 { up: vec![0, 1], dn: vec![2, 3], out: vec![0, 1, 2, 3] }])],
            output_perm: vec![0, 1, 2, 3],
            median_tap: None,
            grid: None,
        }
    }

    #[test]
    fn pattern_count() {
        assert_eq!(merge_01_pattern_count(&[2, 2]), 9);
        assert_eq!(merge_01_pattern_count(&[7, 7, 7]), 512);
        assert_eq!(merge_01_pattern_count(&[32, 32]), 33 * 33);
    }

    #[test]
    fn valid_merge_passes() {
        validate_merge_01(&s2ms_2x2()).unwrap();
        validate_merge_random(&s2ms_2x2(), 50, 1).unwrap();
    }

    #[test]
    fn broken_merge_fails() {
        let mut d = s2ms_2x2();
        // Swap two outputs: still a permutation, but not sorted.
        d.output_perm = vec![1, 0, 2, 3];
        assert!(validate_merge_01(&d).is_err());
    }

    #[test]
    fn incomplete_network_fails() {
        // A single CAS cannot merge 2+2: validation must catch it.
        let d = MergeDevice {
            name: "bogus".into(),
            kind: DeviceKind::OddEvenMerge,
            list_sizes: vec![2, 2],
            input_map: vec![vec![0, 1], vec![2, 3]],
            n: 4,
            stages: vec![Stage::new("s", vec![Block::Cas { lo: 1, hi: 2 }])],
            output_perm: vec![0, 1, 2, 3],
            median_tap: None,
            grid: None,
        };
        assert!(validate_merge_01(&d).is_err());
    }

    #[test]
    fn bad_median_tap_fails() {
        let mut d = s2ms_2x2();
        d.list_sizes = vec![2, 1];
        d.input_map = vec![vec![0, 1], vec![2]];
        d.n = 3;
        d.stages = vec![Stage::new("m", vec![Block::MergeS2 { up: vec![0, 1], dn: vec![2], out: vec![0, 1, 2] }])];
        d.output_perm = vec![0, 1, 2];
        d.median_tap = Some((1, 0)); // position 0 is the min, not median
        assert!(validate_merge_01(&d).is_err());
        d.median_tap = Some((1, 1)); // correct
        validate_merge_01(&d).unwrap();
    }
}
