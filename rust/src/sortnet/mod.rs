//! Sorting-network substrate: construction, execution and validation of
//! every merge device the paper builds or compares against.
//!
//! * [`network`] — the [`network::MergeDevice`] representation.
//! * [`exec`] — bit-exact software execution (hardware semantics).
//! * [`validate`] — exhaustive sorted-0-1-principle correctness proofs.
//! * [`batcher`] — Odd-Even / Bitonic merge baselines [1].
//! * [`s2ms`] — Single-Stage 2-way Merge Sorters [2][3].
//! * [`nsorter`] — single-stage N-sorters / N-filters [20][21].
//! * [`loms`] — List Offset Merge Sorters (the paper's contribution).
//! * [`mwms`] — Multiway Merge Sorting Network baseline [4][5].
//! * [`plan`] — compiled execution plans (flat batch-executable IR).
//! * [`lanes`] — lane-parallel plans: pure-CAS expansion executed over
//!   transposed batch tiles, plus multi-core batch sharding.
//! * [`json`] — device (de)serialisation.

pub mod batcher;
pub mod exec;
pub mod json;
pub mod lanes;
pub mod loms;
pub mod mwms;
pub mod network;
pub mod nsorter;
pub mod plan;
pub mod prune;
pub mod s2ms;
pub mod sorter;
pub mod validate;

pub use exec::{merge, ExecMode, ExecScratch};
pub use lanes::{LanePlan, LaneScratch, LANES};
pub use network::{Block, DeviceKind, MergeDevice, Stage};
pub use plan::{CompiledPlan, PlanScratch};
