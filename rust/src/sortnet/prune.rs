//! Output-cone pruning — the generalization of the paper's Fig.-6
//! observation that after stage 2 most 3c_7r cells are "already sorted"
//! (lavender cells) and stage 3 only needs edge-pair sorters.
//!
//! For every (stage, position) we decide, by exhaustive sorted-0-1
//! analysis, whether the stage can EVER change the value at that
//! position. Positions a stage provably never changes need no output
//! multiplexer in that stage's hardware: `SortN` blocks become
//! `FilterN`s tapping only the mutable ranks, and compare-exchange
//! blocks that never fire disappear. The comparator banks stay (they
//! feed the remaining outputs); functional behaviour is bit-identical —
//! [`prune`] re-validates the result.
//!
//! The 0-1 argument: if a stage changed a position on some real input,
//! it would change it on the threshold projection that separates the old
//! and new values, so "never changes on all 0-1 patterns" is exact.

use super::exec::{ExecMode, ExecScratch};
use super::network::{Block, MergeDevice, Stage};
use super::validate::{merge_01_pattern_count, validate_merge_01, ValidationError};

/// Per-stage set of positions the stage can change (union over all
/// sorted-0-1 inputs).
pub fn mutable_positions(d: &MergeDevice) -> Result<Vec<Vec<bool>>, ValidationError> {
    assert!(
        merge_01_pattern_count(&d.list_sizes) <= 5_000_000,
        "pruning analysis infeasible for {:?}",
        d.list_sizes
    );
    let mut mutable = vec![vec![false; d.n]; d.stages.len()];
    let sizes = &d.list_sizes;
    let mut zeros = vec![0usize; sizes.len()];
    let mut scratch = ExecScratch::new();
    loop {
        let lists: Vec<Vec<u8>> = sizes
            .iter()
            .zip(&zeros)
            .map(|(&s, &z)| {
                let mut v = vec![0u8; s];
                for x in v.iter_mut().skip(z) {
                    *x = 1;
                }
                v
            })
            .collect();
        let mut v = d.load_inputs(&lists);
        for (si, _) in d.stages.iter().enumerate() {
            let before = v.clone();
            // run just this stage
            scratch
                .run_stage(d, si, &mut v, ExecMode::Fast)
                .map_err(|e| ValidationError { device: d.name.clone(), detail: e.to_string() })?;
            for p in 0..d.n {
                if v[p] != before[p] {
                    mutable[si][p] = true;
                }
            }
        }
        // Odometer.
        let mut l = 0;
        loop {
            if l == sizes.len() {
                return Ok(mutable);
            }
            zeros[l] += 1;
            if zeros[l] <= sizes[l] {
                break;
            }
            zeros[l] = 0;
            l += 1;
        }
    }
}

/// Prune a device: drop output muxes (and whole blocks) a stage provably
/// never uses. Returns the pruned device (re-validated) plus the number
/// of output muxes removed.
pub fn prune(d: &MergeDevice) -> Result<(MergeDevice, usize), ValidationError> {
    let mutable = mutable_positions(d)?;
    let mut pruned = d.clone();
    let mut removed = 0usize;
    for (si, stage) in d.stages.iter().enumerate() {
        let mut blocks = Vec::with_capacity(stage.blocks.len());
        for b in &stage.blocks {
            match b {
                Block::Cas { lo, hi } => {
                    if mutable[si][*lo] || mutable[si][*hi] {
                        blocks.push(b.clone());
                    } else {
                        removed += 2;
                    }
                }
                Block::SortN { pos } => {
                    let taps: Vec<usize> = pos
                        .iter()
                        .enumerate()
                        .filter(|(_, &p)| mutable[si][p])
                        .map(|(t, _)| t)
                        .collect();
                    removed += pos.len() - taps.len();
                    if taps.is_empty() {
                        // whole block is a no-op
                    } else if taps.len() == pos.len() {
                        blocks.push(b.clone());
                    } else {
                        blocks.push(Block::FilterN { pos: pos.clone(), taps });
                    }
                }
                Block::FilterN { pos, taps } => {
                    let kept: Vec<usize> = taps
                        .iter()
                        .copied()
                        .filter(|&t| mutable[si][pos[t]])
                        .collect();
                    removed += taps.len() - kept.len();
                    if !kept.is_empty() {
                        blocks.push(Block::FilterN { pos: pos.clone(), taps: kept });
                    }
                }
                Block::MergeS2 { up, dn, out } => {
                    // S2MS outputs are cheap to prune the same way, but a
                    // partially-pruned S2MS is still modelled as a full
                    // block; only drop it when it is a complete no-op.
                    if out.iter().any(|&p| mutable[si][p]) {
                        blocks.push(b.clone());
                    } else {
                        removed += up.len() + dn.len();
                    }
                }
            }
        }
        pruned.stages[si] = Stage::new(format!("{}*", stage.label), blocks);
    }
    pruned.stages.retain(|s| !s.blocks.is_empty());
    pruned.name = format!("{}-pruned", d.name);
    validate_merge_01(&pruned)?;
    Ok((pruned, removed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::loms::loms_kway;
    use crate::sortnet::mwms::mwms_3way;
    use crate::sortnet::validate::validate_merge_random;

    #[test]
    fn loms_3c7r_is_already_minimal() {
        // A satisfying check of the paper's design: the 3c_7r LOMS with
        // its edge-pair stage 3 has NOTHING to prune — every built mux
        // can fire on some input. The list-offset setup is doing exactly
        // the work pruning would otherwise recover.
        let d = loms_kway(&[7, 7, 7]);
        let (p, removed) = prune(&d).unwrap();
        assert_eq!(removed, 0, "LOMS 3c_7r should already be cone-minimal");
        validate_merge_random(&p, 50, 1).unwrap();
        assert_eq!(p.depth(), d.depth());
    }

    #[test]
    fn pruned_mwms_still_valid() {
        let d = mwms_3way(5);
        let (p, removed) = prune(&d).unwrap();
        assert!(removed > 0);
        validate_merge_random(&p, 50, 2).unwrap();
    }

    #[test]
    fn mutable_positions_monotone_shrink() {
        // Later stages of a correct merge touch fewer positions.
        let d = mwms_3way(7);
        let m = mutable_positions(&d).unwrap();
        let first: usize = m[0].iter().filter(|&&x| x).count();
        let last: usize = m.last().unwrap().iter().filter(|&&x| x).count();
        assert!(last < first, "first stage {first}, last {last}");
    }
}
