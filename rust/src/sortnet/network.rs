//! Core representation of hardware merge/sort devices.
//!
//! Every device in the paper — Batcher Odd-Even / Bitonic merge networks,
//! Single-Stage 2-way Merge Sorters (S2MS), single-stage N-sorters and
//! N-filters, List Offset Merge Sorters (LOMS) and Multiway Merge Sorting
//! Networks (MWMS) — is described as a [`MergeDevice`]: a fixed sequence of
//! [`Stage`]s, each a set of disjoint [`Block`]s operating in parallel on
//! positions of a flat value vector.
//!
//! The representation is *structural*: it captures exactly the facts the
//! FPGA cost model needs (block type, operand counts, stage sequencing)
//! while remaining bit-exact executable in software (see [`crate::sortnet::exec`]).

/// One hardware block within a stage. All blocks are combinatorial,
/// data-oblivious structures; semantics are "read the listed positions,
/// write back the sorted permutation of those values into the same
/// positions, ascending in listed order".
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Block {
    /// 2-sorter (compare-and-swap): after execution
    /// `v[lo] <= v[hi]`. The basic Batcher building block.
    Cas { lo: usize, hi: usize },
    /// Single-stage N-sorter (Kent/Pattichis [20][21]): all-pairs
    /// comparator bank + rank decode + per-output mux. Sorts `pos`
    /// (arbitrary input order) ascending into `pos`.
    SortN { pos: Vec<usize> },
    /// Single-Stage 2-way Merge Sorter (S2MS, [2][3]): merges the sorted
    /// ascending run at `up` with the sorted ascending run at `dn`,
    /// writing rank `t` of the merged result to `out[t]`. `out` must be a
    /// permutation of `up ∪ dn` (S2MS output ports are distinct wires; the
    /// in-place array is a simulation artifact).
    ///
    /// Hardware precondition: both runs are already sorted. Violations are
    /// detected by strict execution (the physical device would emit
    /// garbage); validation proves preconditions hold for all inputs.
    MergeS2 { up: Vec<usize>, dn: Vec<usize>, out: Vec<usize> },
    /// Single-stage N-filter: like `SortN` but only the outputs at
    /// `taps` (ranks into the sorted order of `pos`) are physically
    /// built. Execution writes only the tapped ranks (other positions
    /// become dead in subsequent stages). Used by MWMS median devices.
    FilterN { pos: Vec<usize>, taps: Vec<usize> },
}

impl Block {
    /// Positions this block reads.
    pub fn reads(&self) -> Vec<usize> {
        match self {
            Block::Cas { lo, hi } => vec![*lo, *hi],
            Block::SortN { pos } => pos.clone(),
            Block::MergeS2 { up, dn, .. } => up.iter().chain(dn.iter()).copied().collect(),
            Block::FilterN { pos, .. } => pos.clone(),
        }
    }

    /// Positions this block writes (for `FilterN` only the tapped ranks'
    /// positions are meaningful, but the whole span is claimed so that
    /// stage-disjointness checking stays conservative).
    pub fn writes(&self) -> Vec<usize> {
        self.reads()
    }

    /// Number of values entering the block.
    pub fn width(&self) -> usize {
        match self {
            Block::Cas { .. } => 2,
            Block::SortN { pos } => pos.len(),
            Block::MergeS2 { up, dn, .. } => up.len() + dn.len(),
            Block::FilterN { pos, .. } => pos.len(),
        }
    }

    /// Short structural tag, used in reports.
    pub fn kind(&self) -> &'static str {
        match self {
            Block::Cas { .. } => "cas",
            Block::SortN { .. } => "sortN",
            Block::MergeS2 { .. } => "s2ms",
            Block::FilterN { .. } => "filterN",
        }
    }
}

/// A stage: blocks that operate concurrently. Their position sets must be
/// pairwise disjoint ([`MergeDevice::check`] enforces it).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Stage {
    pub blocks: Vec<Block>,
    /// Human-readable label, e.g. `"col-sort"` / `"row-sort"`.
    pub label: String,
}

impl Stage {
    pub fn new(label: impl Into<String>, blocks: Vec<Block>) -> Self {
        Stage { blocks, label: label.into() }
    }
}

/// Device family, used by the FPGA cost model and reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// Batcher Odd-Even merge network.
    OddEvenMerge,
    /// Batcher Bitonic merge network.
    BitonicMerge,
    /// Single-Stage 2-way Merge Sorter.
    S2ms,
    /// List Offset Merge Sorter (2-way or k-way).
    Loms,
    /// Multiway Merge Sorting Network (baseline, reconstruction of [4]).
    Mwms,
    /// Single-stage N-sorter used standalone.
    NSorter,
}

/// A complete combinatorial merge device.
///
/// Input contract: input list `l` (sorted ascending) is loaded element by
/// element at the flat positions `input_map[l]` (ascending value order).
/// After all stages run, output rank `r` (ascending) is read from flat
/// position `output_perm[r]`.
#[derive(Debug, Clone)]
pub struct MergeDevice {
    pub name: String,
    pub kind: DeviceKind,
    /// Sizes of the k sorted input lists.
    pub list_sizes: Vec<usize>,
    /// `input_map[l][i]` = flat position of list `l`'s i-th smallest value.
    pub input_map: Vec<Vec<usize>>,
    /// Total number of values (= sum of list sizes = flat vector length).
    pub n: usize,
    pub stages: Vec<Stage>,
    /// `output_perm[r]` = flat position holding output rank `r`.
    pub output_perm: Vec<usize>,
    /// If the device exposes an early median tap: (stage index *after*
    /// which the median is valid, flat position of the median).
    pub median_tap: Option<(usize, usize)>,
    /// Geometry metadata for LOMS/MWMS devices: (columns, rows).
    pub grid: Option<(usize, usize)>,
}

impl MergeDevice {
    /// Total number of input values across all lists.
    pub fn total_inputs(&self) -> usize {
        self.list_sizes.iter().sum()
    }

    /// Structural sanity: maps are permutations, stages touch valid
    /// positions, blocks within a stage are disjoint.
    pub fn check(&self) -> Result<(), String> {
        let n = self.n;
        if self.total_inputs() != n {
            return Err(format!("{}: list sizes sum {} != n {}", self.name, self.total_inputs(), n));
        }
        let mut seen = vec![false; n];
        for (l, m) in self.input_map.iter().enumerate() {
            if m.len() != self.list_sizes[l] {
                return Err(format!("{}: input_map[{l}] len {} != list size {}", self.name, m.len(), self.list_sizes[l]));
            }
            for &p in m {
                if p >= n {
                    return Err(format!("{}: input_map position {p} out of range", self.name));
                }
                if seen[p] {
                    return Err(format!("{}: input_map position {p} duplicated", self.name));
                }
                seen[p] = true;
            }
        }
        if seen.iter().any(|s| !s) {
            return Err(format!("{}: input_map does not cover all positions", self.name));
        }
        if self.output_perm.len() != n {
            return Err(format!("{}: output_perm len {} != n {}", self.name, self.output_perm.len(), n));
        }
        let mut seen = vec![false; n];
        for &p in &self.output_perm {
            if p >= n || seen[p] {
                return Err(format!("{}: output_perm invalid at {p}", self.name));
            }
            seen[p] = true;
        }
        for (si, stage) in self.stages.iter().enumerate() {
            let mut touched = vec![false; n];
            for b in &stage.blocks {
                if let Block::Cas { lo, hi } = b {
                    if lo == hi {
                        return Err(format!("{}: stage {si} CAS with lo==hi", self.name));
                    }
                }
                if let Block::MergeS2 { up, dn, out } = b {
                    if up.is_empty() && dn.is_empty() {
                        return Err(format!("{}: stage {si} empty MergeS2", self.name));
                    }
                    let mut ins: Vec<usize> = up.iter().chain(dn.iter()).copied().collect();
                    let mut outs = out.clone();
                    ins.sort_unstable();
                    outs.sort_unstable();
                    if ins != outs {
                        return Err(format!(
                            "{}: stage {si} MergeS2 out is not a permutation of up ∪ dn",
                            self.name
                        ));
                    }
                }
                if let Block::FilterN { pos, taps } = b {
                    for &t in taps {
                        if t >= pos.len() {
                            return Err(format!("{}: stage {si} FilterN tap {t} out of range", self.name));
                        }
                    }
                }
                for p in b.reads() {
                    if p >= n {
                        return Err(format!("{}: stage {si} position {p} out of range", self.name));
                    }
                    if touched[p] {
                        return Err(format!("{}: stage {si} position {p} used by two blocks", self.name));
                    }
                    touched[p] = true;
                }
            }
        }
        if let Some((si, p)) = self.median_tap {
            if si > self.stages.len() || p >= n {
                return Err(format!("{}: median tap out of range", self.name));
            }
        }
        Ok(())
    }

    /// Number of stages (the paper's primary speed driver).
    pub fn depth(&self) -> usize {
        self.stages.len()
    }

    /// Total compare-and-swap count, counting an N-block as its
    /// all-pairs comparator bank (what the hardware builds).
    pub fn comparator_count(&self) -> usize {
        self.stages
            .iter()
            .flat_map(|s| &s.blocks)
            .map(|b| match b {
                Block::Cas { .. } => 1,
                Block::SortN { pos } => pos.len() * (pos.len().saturating_sub(1)) / 2,
                Block::MergeS2 { up, dn, .. } => up.len() * dn.len(),
                Block::FilterN { pos, .. } => pos.len() * (pos.len().saturating_sub(1)) / 2,
            })
            .sum()
    }

    /// Load sorted input lists into a flat vector per `input_map`.
    /// Panics if list counts/sizes mismatch (callers validate).
    pub fn load_inputs<T: Copy + Default>(&self, lists: &[Vec<T>]) -> Vec<T> {
        assert_eq!(lists.len(), self.list_sizes.len(), "{}: wrong list count", self.name);
        let mut v = vec![T::default(); self.n];
        for (l, list) in lists.iter().enumerate() {
            assert_eq!(list.len(), self.list_sizes[l], "{}: wrong size for list {l}", self.name);
            for (i, &x) in list.iter().enumerate() {
                v[self.input_map[l][i]] = x;
            }
        }
        v
    }

    /// Read the sorted output out of a flat vector per `output_perm`.
    pub fn read_outputs<T: Copy>(&self, v: &[T]) -> Vec<T> {
        self.output_perm.iter().map(|&p| v[p]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_device() -> MergeDevice {
        MergeDevice {
            name: "tiny".into(),
            kind: DeviceKind::OddEvenMerge,
            list_sizes: vec![1, 1],
            input_map: vec![vec![0], vec![1]],
            n: 2,
            stages: vec![Stage::new("s0", vec![Block::Cas { lo: 0, hi: 1 }])],
            output_perm: vec![0, 1],
            median_tap: None,
            grid: None,
        }
    }

    #[test]
    fn check_accepts_valid() {
        tiny_device().check().unwrap();
    }

    #[test]
    fn check_rejects_overlapping_blocks() {
        let mut d = tiny_device();
        d.stages[0].blocks.push(Block::Cas { lo: 1, hi: 0 });
        assert!(d.check().is_err());
    }

    #[test]
    fn check_rejects_bad_output_perm() {
        let mut d = tiny_device();
        d.output_perm = vec![0, 0];
        assert!(d.check().is_err());
    }

    #[test]
    fn check_rejects_incomplete_input_map() {
        let mut d = tiny_device();
        d.input_map = vec![vec![0], vec![0]];
        assert!(d.check().is_err());
    }

    #[test]
    fn load_read_roundtrip() {
        let d = tiny_device();
        let v = d.load_inputs(&[vec![7u32], vec![3u32]]);
        assert_eq!(v, vec![7, 3]);
        assert_eq!(d.read_outputs(&v), vec![7, 3]);
    }

    #[test]
    fn comparator_counts() {
        assert_eq!(tiny_device().comparator_count(), 1);
        let b = Block::SortN { pos: vec![0, 1, 2, 3] };
        assert_eq!(
            match &b {
                Block::SortN { pos } => pos.len() * (pos.len() - 1) / 2,
                _ => 0,
            },
            6
        );
    }

    #[test]
    fn block_reads_and_width() {
        let b = Block::MergeS2 { up: vec![0, 1], dn: vec![2, 3, 4], out: vec![0, 1, 2, 3, 4] };
        assert_eq!(b.width(), 5);
        assert_eq!(b.reads(), vec![0, 1, 2, 3, 4]);
        assert_eq!(b.kind(), "s2ms");
    }
}
