//! Single-stage N-sorters and N-filters (Kent/Pattichis [20][21]).
//!
//! An N-sorter sorts N *unsorted* values in one combinatorial stage:
//! all C(N,2) pairwise comparators run in parallel, each input's output
//! rank is decoded from its comparison bits (a popcount), and one N-wide
//! multiplexer per output routes the value. An N-filter builds only a
//! subset of the output ranks (e.g. the median), saving the mux logic of
//! the unbuilt outputs.
//!
//! These are the row sorters of LOMS devices with >2 columns and the
//! building blocks of the MWMS baseline.

use super::network::{Block, DeviceKind, MergeDevice, Stage};

/// Structural profile of a single-stage N-sorter/N-filter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NSorterProfile {
    pub n: usize,
    /// All-pairs comparator bank: C(N,2).
    pub comparators: usize,
    /// Output ranks physically built (all N for a full sorter).
    pub outputs_built: usize,
    /// Each built output is an N-wide mux.
    pub mux_width: usize,
}

/// Profile of a full N-sorter.
pub fn sorter_profile(n: usize) -> NSorterProfile {
    NSorterProfile { n, comparators: n * n.saturating_sub(1) / 2, outputs_built: n, mux_width: n }
}

/// Profile of an N-filter building `outputs_built` ranks.
pub fn filter_profile(n: usize, outputs_built: usize) -> NSorterProfile {
    NSorterProfile {
        n,
        comparators: n * n.saturating_sub(1) / 2,
        outputs_built,
        mux_width: n,
    }
}

/// Standalone N-sorter device (sorts one unsorted list of n values).
pub fn nsorter(n: usize) -> MergeDevice {
    assert!(n >= 1);
    MergeDevice {
        name: format!("nsorter-{n}"),
        kind: DeviceKind::NSorter,
        list_sizes: vec![n],
        input_map: vec![(0..n).collect()],
        n,
        stages: vec![Stage::new("sort", vec![Block::SortN { pos: (0..n).collect() }])],
        output_perm: (0..n).collect(),
        median_tap: None,
        grid: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{merge, ExecMode};
    use crate::sortnet::validate::validate_sorter_01;

    #[test]
    fn profiles() {
        let p = sorter_profile(7);
        assert_eq!(p.comparators, 21);
        assert_eq!(p.outputs_built, 7);
        let f = filter_profile(7, 1);
        assert_eq!(f.comparators, 21);
        assert_eq!(f.outputs_built, 1);
    }

    #[test]
    fn nsorter_sorts_and_validates() {
        for n in [1usize, 2, 3, 5, 8] {
            let d = nsorter(n);
            d.check().unwrap();
            assert_eq!(d.depth(), 1);
            if n >= 2 {
                validate_sorter_01(&d).unwrap();
            }
        }
        let out = merge(&nsorter(5), &[vec![9u32, 1, 7, 3, 3]], ExecMode::Fast).unwrap();
        assert_eq!(out, vec![1, 3, 3, 7, 9]);
    }
}
