//! Kenneth Batcher's classic merge networks [1]: Odd-Even Merge and
//! Bitonic Merge — the paper's 2-way state-of-the-art baselines — plus the
//! full sorters built from them.
//!
//! As in the paper (§VI), merge devices are built for equal power-of-2
//! input list sizes; Batcher networks are awkward for anything else, which
//! is one of LOMS/S2MS's selling points.

use super::network::{Block, DeviceKind, MergeDevice, Stage};

/// Stages of compare-exchange pairs `(lo, hi)` (ascending orientation).
type CasStages = Vec<Vec<(usize, usize)>>;

fn is_pow2(x: usize) -> bool {
    x != 0 && x & (x - 1) == 0
}

/// Batcher odd-even merge over the index slice `idx`, whose first half
/// and second half each hold a sorted ascending run. `idx.len()` must be
/// a power of two. Returns comparator stages; depth = log2(len).
fn odd_even_merge_stages(idx: &[usize]) -> CasStages {
    let n = idx.len();
    assert!(is_pow2(n) && n >= 2);
    if n == 2 {
        return vec![vec![(idx[0], idx[1])]];
    }
    let even: Vec<usize> = idx.iter().step_by(2).copied().collect();
    let odd: Vec<usize> = idx.iter().skip(1).step_by(2).copied().collect();
    let se = odd_even_merge_stages(&even);
    let so = odd_even_merge_stages(&odd);
    debug_assert_eq!(se.len(), so.len());
    let mut stages: CasStages = se
        .into_iter()
        .zip(so)
        .map(|(mut e, o)| {
            e.extend(o);
            e
        })
        .collect();
    // Final fix-up stage: compare idx[2i+1] with idx[2i+2].
    let fixup: Vec<(usize, usize)> = (0..n / 2 - 1).map(|i| (idx[2 * i + 1], idx[2 * i + 2])).collect();
    stages.push(fixup);
    stages
}

/// Bitonic merge over `idx` holding a bitonic sequence (first half
/// ascending, second half descending). Depth = log2(len).
fn bitonic_merge_stages(idx: &[usize]) -> CasStages {
    let n = idx.len();
    assert!(is_pow2(n) && n >= 2);
    let mut stages = CasStages::new();
    let mut span = n / 2;
    while span >= 1 {
        let mut stage = Vec::with_capacity(n / 2);
        let mut block = 0;
        while block < n {
            for i in block..block + span {
                stage.push((idx[i], idx[i + span]));
            }
            block += 2 * span;
        }
        stages.push(stage);
        span /= 2;
    }
    stages
}

fn stages_to_device(
    name: String,
    kind: DeviceKind,
    m: usize,
    n_b: usize,
    input_map: Vec<Vec<usize>>,
    cas: CasStages,
) -> MergeDevice {
    let n = m + n_b;
    let stages = cas
        .into_iter()
        .enumerate()
        .map(|(i, pairs)| {
            Stage::new(
                format!("cas-{i}"),
                pairs.into_iter().map(|(lo, hi)| Block::Cas { lo, hi }).collect(),
            )
        })
        .collect();
    MergeDevice {
        name,
        kind,
        list_sizes: vec![m, n_b],
        input_map,
        n,
        stages,
        output_perm: (0..n).collect(),
        median_tap: None,
        grid: None,
    }
}

/// Batcher Odd-Even 2-way merge of two sorted lists, each of (power-of-2)
/// size `m`. Depth = log2(2m) stages.
pub fn odd_even_merge(m: usize) -> MergeDevice {
    assert!(is_pow2(m), "Batcher odd-even merge requires power-of-2 list size, got {m}");
    let n = 2 * m;
    // A at positions 0..m ascending, B at m..2m ascending.
    let idx: Vec<usize> = (0..n).collect();
    // Odd-even merge expects the two runs interleaved as one slice with
    // first half = A, second half = B; the classic recursion operates on
    // the concatenation directly.
    let cas = odd_even_merge_stages(&idx);
    stages_to_device(
        format!("oem-up{m}-dn{m}"),
        DeviceKind::OddEvenMerge,
        m,
        m,
        vec![(0..m).collect(), (m..n).collect()],
        cas,
    )
}

/// Batcher Bitonic 2-way merge of two sorted lists, each of (power-of-2)
/// size `m`. The B list is loaded reversed (forming a bitonic sequence);
/// depth = log2(2m) stages.
pub fn bitonic_merge(m: usize) -> MergeDevice {
    assert!(is_pow2(m), "Bitonic merge requires power-of-2 list size, got {m}");
    let n = 2 * m;
    let idx: Vec<usize> = (0..n).collect();
    let cas = bitonic_merge_stages(&idx);
    stages_to_device(
        format!("bims-up{m}-dn{m}"),
        DeviceKind::BitonicMerge,
        m,
        m,
        // B reversed: its smallest value sits at the highest position.
        vec![(0..m).collect(), (m..n).rev().collect()],
        cas,
    )
}

/// Full Batcher odd-even merge sorter over `n` (power-of-2) unsorted
/// values: the classic log2(n)(log2(n)+1)/2-stage network.
pub fn oems_sorter(n: usize) -> MergeDevice {
    assert!(is_pow2(n) && n >= 2);
    fn sort_rec(idx: &[usize]) -> CasStages {
        if idx.len() == 1 {
            return vec![];
        }
        let (lo, hi) = idx.split_at(idx.len() / 2);
        let sl = sort_rec(lo);
        let sh = sort_rec(hi);
        debug_assert_eq!(sl.len(), sh.len());
        let mut stages: CasStages = sl
            .into_iter()
            .zip(sh)
            .map(|(mut a, b)| {
                a.extend(b);
                a
            })
            .collect();
        stages.extend(odd_even_merge_stages(idx));
        stages
    }
    let idx: Vec<usize> = (0..n).collect();
    let cas = sort_rec(&idx);
    let mut d = stages_to_device(
        format!("oems-sort{n}"),
        DeviceKind::OddEvenMerge,
        n,
        0,
        vec![(0..n).collect(), vec![]],
        cas,
    );
    d.list_sizes = vec![n]; // one *unsorted* input list
    d.input_map = vec![(0..n).collect()];
    d
}

/// Full bitonic sorter over `n` (power-of-2) unsorted values.
pub fn bitonic_sorter(n: usize) -> MergeDevice {
    assert!(is_pow2(n) && n >= 2);
    fn sort_rec(idx: &[usize], ascending: bool) -> CasStages {
        if idx.len() == 1 {
            return vec![];
        }
        let (lo, hi) = idx.split_at(idx.len() / 2);
        let sl = sort_rec(lo, true);
        let sh = sort_rec(hi, false);
        let mut stages: CasStages = sl
            .into_iter()
            .zip(sh)
            .map(|(mut a, b)| {
                a.extend(b);
                a
            })
            .collect();
        let merged = bitonic_merge_stages(idx);
        for st in merged {
            let st = st
                .into_iter()
                .map(|(a, b)| if ascending { (a, b) } else { (b, a) })
                .collect();
            stages.push(st);
        }
        stages
    }
    let idx: Vec<usize> = (0..n).collect();
    let cas = sort_rec(&idx, true);
    let mut d = stages_to_device(
        format!("bims-sort{n}"),
        DeviceKind::BitonicMerge,
        n,
        0,
        vec![(0..n).collect(), vec![]],
        cas,
    );
    d.list_sizes = vec![n];
    d.input_map = vec![(0..n).collect()];
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{merge, ExecMode};
    use crate::sortnet::validate::{validate_merge_01, validate_sorter_01};

    #[test]
    fn oem_depth_is_log2_outputs() {
        for m in [1usize, 2, 4, 8, 16, 32] {
            let d = odd_even_merge(m);
            d.check().unwrap();
            assert_eq!(d.depth(), (2 * m).ilog2() as usize, "m={m}");
        }
    }

    #[test]
    fn bitonic_depth_is_log2_outputs() {
        for m in [1usize, 2, 4, 8, 16, 32] {
            let d = bitonic_merge(m);
            d.check().unwrap();
            assert_eq!(d.depth(), (2 * m).ilog2() as usize, "m={m}");
        }
    }

    #[test]
    fn oem_merges_known_example() {
        let d = odd_even_merge(4);
        let out = merge(&d, &[vec![1u32, 4, 6, 9], vec![2, 3, 7, 20]], ExecMode::Fast).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 6, 7, 9, 20]);
    }

    #[test]
    fn bitonic_merges_known_example() {
        let d = bitonic_merge(4);
        let out = merge(&d, &[vec![1u32, 4, 6, 9], vec![2, 3, 7, 20]], ExecMode::Fast).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 6, 7, 9, 20]);
    }

    #[test]
    fn oem_validates_01_up_to_32() {
        for m in [1usize, 2, 4, 8, 16, 32] {
            validate_merge_01(&odd_even_merge(m)).unwrap();
        }
    }

    #[test]
    fn bitonic_validates_01_up_to_32() {
        for m in [1usize, 2, 4, 8, 16, 32] {
            validate_merge_01(&bitonic_merge(m)).unwrap();
        }
    }

    #[test]
    fn oem_comparator_count_matches_formula() {
        // Batcher OEM(n,n) uses n*log2(n) + 1 comparators... verify the
        // recurrence C(2n) = 2C(n) + n - 1, C(2)=1 instead of a closed form.
        fn expect(m: usize) -> usize {
            if m == 1 {
                1
            } else {
                2 * expect(m / 2) + m - 1
            }
        }
        for m in [1usize, 2, 4, 8, 16, 32, 64] {
            assert_eq!(odd_even_merge(m).comparator_count(), expect(m), "m={m}");
        }
    }

    #[test]
    fn bitonic_comparator_count_is_half_n_log_n() {
        for m in [2usize, 4, 8, 16, 32] {
            let n = 2 * m;
            assert_eq!(bitonic_merge(m).comparator_count(), n / 2 * n.ilog2() as usize);
        }
    }

    #[test]
    fn full_sorters_sort() {
        for n in [2usize, 4, 8, 16] {
            validate_sorter_01(&oems_sorter(n)).unwrap();
            validate_sorter_01(&bitonic_sorter(n)).unwrap();
        }
    }
}
