//! JSON (de)serialisation of [`MergeDevice`]s (in-crate JSON — see
//! [`crate::util::json`]).
//!
//! Used for (a) the `loms netgen` CLI (export networks for inspection or
//! for the Python compile path), and (b) the golden-vector cross-check
//! between this crate and `python/compile/netgen` (two independent
//! implementations of the paper's constructions must agree structurally).

use super::network::{Block, DeviceKind, MergeDevice, Stage};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

fn kind_str(k: DeviceKind) -> &'static str {
    match k {
        DeviceKind::OddEvenMerge => "odd_even_merge",
        DeviceKind::BitonicMerge => "bitonic_merge",
        DeviceKind::S2ms => "s2ms",
        DeviceKind::Loms => "loms",
        DeviceKind::Mwms => "mwms",
        DeviceKind::NSorter => "nsorter",
    }
}

fn kind_parse(s: &str) -> Result<DeviceKind> {
    Ok(match s {
        "odd_even_merge" => DeviceKind::OddEvenMerge,
        "bitonic_merge" => DeviceKind::BitonicMerge,
        "s2ms" => DeviceKind::S2ms,
        "loms" => DeviceKind::Loms,
        "mwms" => DeviceKind::Mwms,
        "nsorter" => DeviceKind::NSorter,
        other => bail!("unknown device kind {other:?}"),
    })
}

fn block_json(b: &Block) -> Json {
    match b {
        Block::Cas { lo, hi } => Json::obj(vec![
            ("type", Json::str("cas")),
            ("lo", Json::int(*lo as i64)),
            ("hi", Json::int(*hi as i64)),
        ]),
        Block::SortN { pos } => Json::obj(vec![
            ("type", Json::str("sortN")),
            ("pos", Json::usize_arr(pos.iter().copied())),
        ]),
        Block::MergeS2 { up, dn, out } => Json::obj(vec![
            ("type", Json::str("s2ms")),
            ("up", Json::usize_arr(up.iter().copied())),
            ("dn", Json::usize_arr(dn.iter().copied())),
            ("out", Json::usize_arr(out.iter().copied())),
        ]),
        Block::FilterN { pos, taps } => Json::obj(vec![
            ("type", Json::str("filterN")),
            ("pos", Json::usize_arr(pos.iter().copied())),
            ("taps", Json::usize_arr(taps.iter().copied())),
        ]),
    }
}

fn block_parse(j: &Json) -> Result<Block> {
    let ty = j.get("type").and_then(Json::as_str).ok_or_else(|| anyhow!("block missing type"))?;
    Ok(match ty {
        "cas" => Block::Cas {
            lo: j.get("lo").and_then(Json::as_usize).ok_or_else(|| anyhow!("cas.lo"))?,
            hi: j.get("hi").and_then(Json::as_usize).ok_or_else(|| anyhow!("cas.hi"))?,
        },
        "sortN" => Block::SortN { pos: j.get_usizes("pos").ok_or_else(|| anyhow!("sortN.pos"))? },
        "s2ms" => Block::MergeS2 {
            up: j.get_usizes("up").ok_or_else(|| anyhow!("s2ms.up"))?,
            dn: j.get_usizes("dn").ok_or_else(|| anyhow!("s2ms.dn"))?,
            out: j.get_usizes("out").ok_or_else(|| anyhow!("s2ms.out"))?,
        },
        "filterN" => Block::FilterN {
            pos: j.get_usizes("pos").ok_or_else(|| anyhow!("filterN.pos"))?,
            taps: j.get_usizes("taps").ok_or_else(|| anyhow!("filterN.taps"))?,
        },
        other => bail!("unknown block type {other:?}"),
    })
}

/// Serialise a device to pretty JSON.
pub fn to_json(d: &MergeDevice) -> String {
    let stages = d
        .stages
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("label", Json::str(s.label.clone())),
                ("blocks", Json::arr(s.blocks.iter().map(block_json))),
            ])
        })
        .collect::<Vec<_>>();
    let mut fields = vec![
        ("name", Json::str(d.name.clone())),
        ("kind", Json::str(kind_str(d.kind))),
        ("list_sizes", Json::usize_arr(d.list_sizes.iter().copied())),
        (
            "input_map",
            Json::arr(d.input_map.iter().map(|m| Json::usize_arr(m.iter().copied()))),
        ),
        ("n", Json::int(d.n as i64)),
        ("stages", Json::arr(stages)),
        ("output_perm", Json::usize_arr(d.output_perm.iter().copied())),
    ];
    if let Some((stage, pos)) = d.median_tap {
        fields.push(("median_tap", Json::usize_arr([stage, pos])));
    }
    if let Some((cols, rows)) = d.grid {
        fields.push(("grid", Json::usize_arr([cols, rows])));
    }
    Json::obj(fields).to_string_pretty()
}

/// Parse a device from JSON and run its structural check.
pub fn from_json(s: &str) -> Result<MergeDevice> {
    let j = Json::parse(s).map_err(|e| anyhow!("parsing MergeDevice JSON: {e}"))?;
    let name = j.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("missing name"))?.to_string();
    let kind = kind_parse(j.get("kind").and_then(Json::as_str).ok_or_else(|| anyhow!("missing kind"))?)?;
    let list_sizes = j.get_usizes("list_sizes").ok_or_else(|| anyhow!("missing list_sizes"))?;
    let input_map = j
        .get("input_map")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing input_map"))?
        .iter()
        .map(|m| m.as_arr().and_then(|a| a.iter().map(Json::as_usize).collect()))
        .collect::<Option<Vec<Vec<usize>>>>()
        .ok_or_else(|| anyhow!("bad input_map"))?;
    let n = j.get("n").and_then(Json::as_usize).ok_or_else(|| anyhow!("missing n"))?;
    let stages = j
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing stages"))?
        .iter()
        .map(|s| {
            let label = s.get("label").and_then(Json::as_str).unwrap_or("").to_string();
            let blocks = s
                .get("blocks")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("stage missing blocks"))?
                .iter()
                .map(block_parse)
                .collect::<Result<Vec<_>>>()?;
            Ok(Stage { label, blocks })
        })
        .collect::<Result<Vec<_>>>()?;
    let output_perm = j.get_usizes("output_perm").ok_or_else(|| anyhow!("missing output_perm"))?;
    let median_tap = j.get_usizes("median_tap").map(|v| (v[0], v[1]));
    let grid = j.get_usizes("grid").map(|v| (v[0], v[1]));
    let d = MergeDevice { name, kind, list_sizes, input_map, n, stages, output_perm, median_tap, grid };
    d.check().map_err(anyhow::Error::msg)?;
    Ok(d)
}

/// Write a device to a file.
pub fn write_file(d: &MergeDevice, path: impl AsRef<Path>) -> Result<()> {
    std::fs::write(path.as_ref(), to_json(d))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Read a device from a file.
pub fn read_file(path: impl AsRef<Path>) -> Result<MergeDevice> {
    let s = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    from_json(&s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::{batcher, loms, mwms, s2ms};

    #[test]
    fn roundtrip_all_kinds() {
        for d in [
            batcher::odd_even_merge(4),
            batcher::bitonic_merge(4),
            s2ms::s2ms(3, 5),
            loms::loms_2way(8, 8, 2),
            loms::loms_kway(&[7, 7, 7]),
            loms::loms_3way_median(7),
            mwms::mwms_3way(3),
        ] {
            let j = to_json(&d);
            let d2 = from_json(&j).unwrap();
            assert_eq!(d.name, d2.name);
            assert_eq!(d.kind, d2.kind);
            assert_eq!(d.stages, d2.stages);
            assert_eq!(d.input_map, d2.input_map);
            assert_eq!(d.output_perm, d2.output_perm);
            assert_eq!(d.median_tap, d2.median_tap);
            assert_eq!(d.grid, d2.grid);
        }
    }

    #[test]
    fn from_json_rejects_broken_device() {
        let d = s2ms::s2ms(2, 2);
        let j = to_json(&d).replace("\"output_perm\": [\n    0,", "\"output_perm\": [\n    3,");
        assert!(from_json(&j).is_err(), "duplicate output positions must fail check()");
    }

    #[test]
    fn file_roundtrip() {
        let d = loms::loms_2way(4, 4, 2);
        let path = std::env::temp_dir().join("loms_json_test.json");
        write_file(&d, &path).unwrap();
        let d2 = read_file(&path).unwrap();
        assert_eq!(d.stages, d2.stages);
        let _ = std::fs::remove_file(path);
    }
}
