//! Lane-parallel execution plans: a [`CompiledPlan`] expanded into a pure
//! compare-exchange schedule and executed over a **transposed,
//! value-major batch tile**.
//!
//! The devices are data-oblivious comparator networks — the same fixed
//! schedule runs for every row — so a batch does not have to be executed
//! row by row. A [`LanePlan`] re-expresses every plan op as plain
//! 2-input compare-exchange (CAS) steps (the reduction Shi et al. use
//! for n-sorter networks, and the structure FLiMS exploits for wide
//! parallel merging):
//!
//! * `SortN` blocks expand through the general odd-even merge-sort
//!   recursion (the arbitrary-size form of the Batcher networks in
//!   [`super::batcher`]);
//! * `MergeS2` blocks expand through the general odd-even **merge**
//!   (Knuth 5.3.4, arbitrary run lengths) — valid whenever the block's
//!   hardware precondition (sorted input runs) holds, which device
//!   validation proves for every sorted input;
//! * `FilterN` blocks copy their inputs into *shadow slots*, run the
//!   sorter network there, and keep only the comparator cone feeding the
//!   tapped ranks (the [`super::prune`]-style output-cone idea applied
//!   to a single block) — untapped positions keep their stale values
//!   exactly like the scalar executor;
//! * `Cas` blocks pass through unchanged.
//!
//! Instead of physically permuting values, the expansion tracks a
//! position→slot renaming (`loc`): an odd-even merge leaves rank `t` in
//! some input slot, and the device's `out[t]` position is simply
//! re-pointed there. The schedule stays 100% CAS + copy.
//!
//! Execution is transposed: a tile holds [`LANES`] consecutive batch
//! rows in value-major order (`tile[slot * LANES + lane]`), so every
//! CAS is an elementwise branchless min/max over two contiguous
//! [`LANES`]-wide chunks — the shape rustc autovectorizes for `u32`.
//! A batch of `B` rows runs as `B / LANES` tiles plus a scalar
//! [`CompiledPlan`] tail for the remainder; [`run_batch_sharded`]
//! additionally splits the tiles across OS threads
//! (`std::thread::scope`, no added dependencies), each shard writing a
//! disjoint range of the output buffer.
//!
//! Two batch entry points share the tile executor. The **row-major**
//! path ([`LanePlan::run_batch_into`]) reads pre-assembled flat lists —
//! the shape the PJRT artifacts consume. The **tile-direct view** path
//! ([`LanePlan::run_view_batch_into`], [`run_view_batch_sharded`]) is
//! the serving hot path: it scatters straight from ragged per-request
//! list views into the tile (padding short lists inline) and gathers
//! each lane's output cone straight into that row's caller-provided
//! response buffer — the whole batch is copied exactly twice
//! (request → tile, tile → response), with no list-major scratch,
//! row-major assembly, padding rows, or whole-batch output buffer in
//! between.
//!
//! Equality contract: on **valid inputs** (each list sorted ascending —
//! what the service admits) the lane executor is bit-exact with
//! [`CompiledPlan::run_batch`]; `rust/tests/plan_differential.rs`
//! enforces this for every device family, ragged sizes included, with
//! batch sizes that are not multiples of [`LANES`]. Fast-mode
//! garbage-in (unsorted runs feeding a `MergeS2`) produces *different*
//! garbage than the scalar two-pointer merge, exactly as the physical
//! S2MS would; Strict mode, medians and the validators therefore stay
//! on [`CompiledPlan`].

use super::exec::{ExecMode, PreconditionViolation};
use super::plan::{append_rows, CompiledPlan, PlanOp, PlanScratch};

/// Rows per tile. 16 × `u32` = 64 bytes: one AVX-512 register or two
/// AVX2 registers per chunk — wide enough to keep the min/max stream
/// vectorized, small enough that a tile of any characterized device
/// stays in L1.
pub const LANES: usize = 16;

/// One step of the lane schedule. Slot indices address tile chunks
/// (`slot * LANES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneOp {
    /// Elementwise compare-exchange: per lane, `min → lo`, `max → hi`.
    Cas { lo: u32, hi: u32 },
    /// Chunk copy `dst ← src` (FilterN shadow-slot loads).
    Copy { dst: u32, src: u32 },
}

/// Reusable lane-execution buffers: the transposed tile plus a scalar
/// [`PlanScratch`] for the tail rows. Grows to the largest plan seen.
#[derive(Debug, Default)]
pub struct LaneScratch<T> {
    tile: Vec<T>,
    tail: PlanScratch<T>,
}

impl<T> LaneScratch<T> {
    pub fn new() -> Self {
        LaneScratch { tile: Vec::new(), tail: PlanScratch::new() }
    }
}

/// A [`CompiledPlan`] expanded to a pure CAS/copy schedule over tile
/// slots, executable [`LANES`] rows at a time in value-major layout.
#[derive(Debug, Clone)]
pub struct LanePlan {
    name: String,
    list_sizes: Vec<usize>,
    /// Device flat-vector length (slots `0..n` are the live positions).
    n: usize,
    /// Tile height: `n` plus FilterN shadow slots.
    slots: usize,
    ops: Vec<LaneOp>,
    /// Flattened input map, list-major (loads hit the identity renaming).
    in_slot: Vec<u32>,
    /// `out_slot[r]` = tile slot holding output rank `r` after all ops.
    out_slot: Vec<u32>,
    cas_count: usize,
    copy_count: usize,
}

/// General odd-even merge (Batcher / Knuth 5.3.4, arbitrary run
/// lengths) over slot lists `a` and `b`, each holding a sorted run in
/// ascending rank order. Emits CAS steps in dependency order and
/// returns the slots of the merged sequence in ascending rank order.
fn emit_merge(a: &[u32], b: &[u32], ops: &mut Vec<LaneOp>) -> Vec<u32> {
    if a.is_empty() {
        return b.to_vec();
    }
    if b.is_empty() {
        return a.to_vec();
    }
    if a.len() == 1 && b.len() == 1 {
        ops.push(LaneOp::Cas { lo: a[0], hi: b[0] });
        return vec![a[0], b[0]];
    }
    fn even(s: &[u32]) -> Vec<u32> {
        s.iter().copied().step_by(2).collect()
    }
    fn odd(s: &[u32]) -> Vec<u32> {
        s.iter().copied().skip(1).step_by(2).collect()
    }
    let e = emit_merge(&even(a), &even(b), ops);
    let o = emit_merge(&odd(a), &odd(b), ops);
    // Interleave by rank (e0, o0, e1, o1, …) and fix the single possible
    // inversion per pair: rank 2i+1 = min(o_i, e_{i+1}), 2i+2 = max.
    // |e| − |o| = (|a| mod 2) + (|b| mod 2) ∈ {0, 1, 2}; unpaired tail
    // elements are already in place by the 0-1 argument.
    let mut w = Vec::with_capacity(a.len() + b.len());
    w.push(e[0]);
    for (i, &oi) in o.iter().enumerate() {
        if i + 1 < e.len() {
            ops.push(LaneOp::Cas { lo: oi, hi: e[i + 1] });
            w.push(oi);
            w.push(e[i + 1]);
        } else {
            w.push(oi);
        }
    }
    if e.len() > o.len() + 1 {
        w.extend_from_slice(&e[o.len() + 1..]);
    }
    w
}

/// Odd-even merge sort over an arbitrary slot count: recursive halving,
/// then [`emit_merge`]. Returns the slots in ascending rank order.
fn emit_sorter(slots: &[u32], ops: &mut Vec<LaneOp>) -> Vec<u32> {
    if slots.len() <= 1 {
        return slots.to_vec();
    }
    let (lo, hi) = slots.split_at(slots.len() / 2);
    let a = emit_sorter(lo, ops);
    let b = emit_sorter(hi, ops);
    emit_merge(&a, &b, ops)
}

impl LanePlan {
    /// Expand a compiled plan into the CAS/copy lane schedule. Pruned
    /// plans expand their pruned op stream (FilterN tap cones shrink the
    /// emitted networks further).
    pub fn compile(plan: &CompiledPlan) -> LanePlan {
        let n = plan.n();
        // Position → slot renaming; starts as the identity.
        let mut loc: Vec<u32> = (0..n as u32).collect();
        let mut slots = n;
        let mut ops: Vec<LaneOp> = Vec::new();
        for op in plan.iter_ops() {
            match op {
                PlanOp::Cas { lo, hi } => {
                    ops.push(LaneOp::Cas { lo: loc[lo], hi: loc[hi] });
                }
                PlanOp::SortN { pos } => {
                    let s: Vec<u32> = pos.iter().map(|&p| loc[p as usize]).collect();
                    let w = emit_sorter(&s, &mut ops);
                    for (i, &p) in pos.iter().enumerate() {
                        loc[p as usize] = w[i];
                    }
                }
                PlanOp::MergeS2 { up, dn, out } => {
                    let su: Vec<u32> = up.iter().map(|&p| loc[p as usize]).collect();
                    let sd: Vec<u32> = dn.iter().map(|&p| loc[p as usize]).collect();
                    let w = emit_merge(&su, &sd, &mut ops);
                    for (t, &p) in out.iter().enumerate() {
                        loc[p as usize] = w[t];
                    }
                }
                PlanOp::FilterN { pos, taps } => {
                    // Sort in shadow slots so untapped positions keep
                    // their (possibly stale) values, as in hardware.
                    let sh: Vec<u32> = (slots as u32..(slots + pos.len()) as u32).collect();
                    slots += pos.len();
                    let mut net: Vec<LaneOp> = Vec::new();
                    let w = emit_sorter(&sh, &mut net);
                    // Output-cone pruning at block granularity: walk the
                    // network backward keeping only comparators that feed
                    // a tapped rank.
                    let mut needed = vec![false; slots];
                    for &t in taps {
                        needed[w[t as usize] as usize] = true;
                    }
                    let mut kept: Vec<LaneOp> = Vec::with_capacity(net.len());
                    for &cas in net.iter().rev() {
                        let LaneOp::Cas { lo, hi } = cas else { unreachable!() };
                        if needed[lo as usize] || needed[hi as usize] {
                            needed[lo as usize] = true;
                            needed[hi as usize] = true;
                            kept.push(cas);
                        }
                    }
                    for (i, &p) in pos.iter().enumerate() {
                        if needed[sh[i] as usize] {
                            ops.push(LaneOp::Copy { dst: sh[i], src: loc[p as usize] });
                        }
                    }
                    ops.extend(kept.iter().rev());
                    for &t in taps {
                        loc[pos[t as usize] as usize] = w[t as usize];
                    }
                }
            }
        }
        let cas_count = ops.iter().filter(|o| matches!(o, LaneOp::Cas { .. })).count();
        let copy_count = ops.len() - cas_count;
        LanePlan {
            name: plan.name.clone(),
            list_sizes: plan.list_sizes().to_vec(),
            n,
            slots,
            ops,
            in_slot: plan.in_pos().to_vec(),
            out_slot: plan.out_pos().iter().map(|&p| loc[p as usize]).collect(),
            cas_count,
            copy_count,
        }
    }

    /// Device flat-vector length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Tile height in slots (`n()` + FilterN shadow slots).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Compare-exchange steps per tile.
    pub fn cas_count(&self) -> usize {
        self.cas_count
    }

    /// Chunk-copy steps per tile (FilterN shadow loads).
    pub fn copy_count(&self) -> usize {
        self.copy_count
    }

    /// Output width per row.
    pub fn total_outputs(&self) -> usize {
        self.out_slot.len()
    }

    pub fn list_sizes(&self) -> &[usize] {
        &self.list_sizes
    }

    /// Panic unless `scalar` is the plan this lane plan was expanded
    /// from (the tail rows run through it, so a shape-coincident plan of
    /// a *different* device would silently give the tail different
    /// semantics — the name pins the device, shape checks catch stale
    /// rebuilds).
    fn check_tail_plan(&self, scalar: &CompiledPlan) {
        assert_eq!(
            (scalar.name.as_str(), scalar.list_sizes(), scalar.total_outputs()),
            (self.name.as_str(), self.list_sizes(), self.out_slot.len()),
            "lane plan and scalar tail plan mismatch"
        );
    }

    /// Run the CAS/copy schedule over a loaded tile.
    #[inline]
    fn exec_tile_ops<T: Copy + Ord>(&self, tile: &mut [T]) {
        for op in &self.ops {
            match *op {
                LaneOp::Cas { lo, hi } => cas_lanes(tile, lo as usize, hi as usize),
                LaneOp::Copy { dst, src } => {
                    let s0 = src as usize * LANES;
                    tile.copy_within(s0..s0 + LANES, dst as usize * LANES);
                }
            }
        }
    }

    /// Execute one full tile: scatter rows `row0 .. row0+LANES` into the
    /// value-major tile, run the CAS/copy schedule, gather the rows into
    /// `dst` (row-major, `LANES * total_outputs()` long).
    fn run_tile<T: Copy + Ord>(&self, lists: &[&[T]], row0: usize, tile: &mut [T], dst: &mut [T]) {
        let mut ip = 0usize;
        for (l, &s) in self.list_sizes.iter().enumerate() {
            for lane in 0..LANES {
                let src = &lists[l][(row0 + lane) * s..(row0 + lane + 1) * s];
                for (i, &x) in src.iter().enumerate() {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = x;
                }
            }
            ip += s;
        }
        self.exec_tile_ops(tile);
        let outs = self.out_slot.len();
        for lane in 0..LANES {
            let row_dst = &mut dst[lane * outs..(lane + 1) * outs];
            for (r, &sl) in self.out_slot.iter().enumerate() {
                row_dst[r] = tile[sl as usize * LANES + lane];
            }
        }
    }

    /// Execute one full tile **straight from ragged request views**: the
    /// tentpole of the tile-direct serving path. Rows
    /// `row0 .. row0+LANES` (all real — callers only hand full tiles
    /// here) are scattered from each request's un-padded lists into the
    /// value-major tile with `pad` filling the short-list tail in the
    /// same pass — the batch's *only* input copy. After the schedule
    /// runs, each lane's output cone is gathered straight into that
    /// row's caller-provided buffer (`outs[r].len()` values, typically
    /// the request's real output width — `pad` sorts to the tail, so the
    /// prefix is the true merge). No list-major scratch, no row-major
    /// assembly, no whole-batch output buffer.
    fn run_tile_view<T: Copy + Ord>(
        &self,
        rows: &[&[Vec<T>]],
        row0: usize,
        pad: T,
        tile: &mut [T],
        outs: &mut [&mut [T]],
    ) {
        let mut ip = 0usize;
        for (l, &cap) in self.list_sizes.iter().enumerate() {
            for lane in 0..LANES {
                let src = &rows[row0 + lane][l];
                for (i, &x) in src.iter().enumerate() {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = x;
                }
                for i in src.len()..cap {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = pad;
                }
            }
            ip += cap;
        }
        self.exec_tile_ops(tile);
        for lane in 0..LANES {
            let dst = &mut *outs[row0 + lane];
            for (t, &sl) in self.out_slot.iter().take(dst.len()).enumerate() {
                dst[t] = tile[sl as usize * LANES + lane];
            }
        }
    }

    /// View-based batch executor — the two-copy serving path. `rows[r]`
    /// is request `r`'s un-padded lists (each sorted, no longer than the
    /// device's `list_sizes`); `outs[r]` is the destination for row
    /// `r`'s merged prefix (at most `total_outputs()` wide). Full tiles
    /// run through [`Self::run_tile_view`]; the `rows.len() % LANES`
    /// tail runs through the scalar plan's matching view path
    /// ([`CompiledPlan::run_view_batch_into`], Fast mode). Unlike the
    /// row-major path there are **no padding rows at all** — partial
    /// batches execute only their real rows.
    pub fn run_view_batch_into<T: Copy + Ord + Default>(
        &self,
        scalar: &CompiledPlan,
        rows: &[&[Vec<T>]],
        pad: T,
        scratch: &mut LaneScratch<T>,
        outs: &mut [&mut [T]],
    ) -> Result<(), PreconditionViolation> {
        self.check_tail_plan(scalar);
        assert_eq!(rows.len(), outs.len(), "{}: rows vs output buffers", self.name);
        let total = self.out_slot.len();
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), self.list_sizes.len(), "{}: row {r} list count", self.name);
            for (l, &cap) in self.list_sizes.iter().enumerate() {
                assert!(row[l].len() <= cap, "{}: row {r} list {l} exceeds device slot", self.name);
            }
            assert!(outs[r].len() <= total, "{}: row {r} output too wide", self.name);
        }
        if scratch.tile.len() < self.slots * LANES {
            scratch.tile.resize(self.slots * LANES, T::default());
        }
        let tiles = rows.len() / LANES;
        for t in 0..tiles {
            self.run_tile_view(rows, t * LANES, pad, &mut scratch.tile, outs);
        }
        let done = tiles * LANES;
        if done < rows.len() {
            scalar
                .run_view_batch_into(
                    &rows[done..],
                    pad,
                    ExecMode::Fast,
                    &mut scratch.tail,
                    &mut outs[done..],
                )
                .map_err(|e| e.offset_row(done))?;
        }
        Ok(())
    }

    /// Slice-level batch executor: `lists[l]` is row-major
    /// `(batch, list_sizes[l])`, `dst` is `batch * total_outputs()` and
    /// fully overwritten. Full tiles run transposed; the `batch % LANES`
    /// tail runs through `scalar` ([`CompiledPlan::run_batch_into`],
    /// Fast mode). Infallible on admitted (sorted) inputs.
    pub fn run_batch_into<T: Copy + Ord + Default>(
        &self,
        scalar: &CompiledPlan,
        lists: &[&[T]],
        batch: usize,
        scratch: &mut LaneScratch<T>,
        dst: &mut [T],
    ) -> Result<(), PreconditionViolation> {
        self.check_tail_plan(scalar);
        assert_eq!(lists.len(), self.list_sizes.len(), "{}: wrong list count", self.name);
        for (l, &s) in self.list_sizes.iter().enumerate() {
            assert_eq!(lists[l].len(), batch * s, "{}: list {l} flat length", self.name);
        }
        let outs = self.out_slot.len();
        assert_eq!(dst.len(), batch * outs, "{}: output buffer length", self.name);
        if scratch.tile.len() < self.slots * LANES {
            scratch.tile.resize(self.slots * LANES, T::default());
        }
        let tiles = batch / LANES;
        for t in 0..tiles {
            self.run_tile(
                lists,
                t * LANES,
                &mut scratch.tile,
                &mut dst[t * LANES * outs..(t + 1) * LANES * outs],
            );
        }
        let done = tiles * LANES;
        if done < batch {
            let tail: Vec<&[T]> =
                lists.iter().zip(&self.list_sizes).map(|(l, &s)| &l[done * s..]).collect();
            let tail_dst = &mut dst[done * outs..];
            scalar
                .run_batch_into(&tail, batch - done, ExecMode::Fast, &mut scratch.tail, tail_dst)
                .map_err(|e| e.offset_row(done))?;
        }
        Ok(())
    }

    /// Vec-append convenience over [`Self::run_batch_into`] — the same
    /// call shape as [`CompiledPlan::run_batch`].
    pub fn run_batch<T: Copy + Ord + Default>(
        &self,
        scalar: &CompiledPlan,
        lists: &[Vec<T>],
        batch: usize,
        scratch: &mut LaneScratch<T>,
        out: &mut Vec<T>,
    ) -> Result<(), PreconditionViolation> {
        let slices: Vec<&[T]> = lists.iter().map(Vec::as_slice).collect();
        append_rows(out, batch, self.out_slot.len(), |dst| {
            self.run_batch_into(scalar, &slices, batch, scratch, dst)
        })
    }
}

/// Elementwise branchless compare-exchange of two [`LANES`]-wide tile
/// chunks: per lane, `min → lo`, `max → hi`. Fixed-size array views give
/// rustc a compile-time trip count (vectorizes to pminu/pmaxu for u32).
#[inline]
fn cas_lanes<T: Copy + Ord>(tile: &mut [T], lo: usize, hi: usize) {
    debug_assert_ne!(lo, hi);
    let (lo_off, hi_off) = (lo * LANES, hi * LANES);
    let (x, y) = if lo_off < hi_off {
        let (head, tail) = tile.split_at_mut(hi_off);
        (&mut head[lo_off..lo_off + LANES], &mut tail[..LANES])
    } else {
        let (head, tail) = tile.split_at_mut(lo_off);
        (&mut tail[..LANES], &mut head[hi_off..hi_off + LANES])
    };
    let x: &mut [T; LANES] = x.try_into().expect("lo chunk is LANES wide");
    let y: &mut [T; LANES] = y.try_into().expect("hi chunk is LANES wide");
    for (p, q) in x.iter_mut().zip(y.iter_mut()) {
        let (a, b) = (*p, *q);
        let swap = b < a;
        *p = if swap { b } else { a };
        *q = if swap { a } else { b };
    }
}

/// Shard a batch across `threads` scoped OS threads: tile-aligned row
/// ranges (the `batch % LANES` tail rows land in the last non-empty
/// shard), one fresh [`LaneScratch`] per thread, disjoint output
/// slices. `threads <= 1` degrades to the single-threaded executor.
pub fn run_batch_sharded<T: Copy + Ord + Default + Send + Sync>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    lists: &[Vec<T>],
    batch: usize,
    threads: usize,
    out: &mut Vec<T>,
) -> Result<(), PreconditionViolation> {
    if threads <= 1 {
        return lane.run_batch(scalar, lists, batch, &mut LaneScratch::new(), out);
    }
    let outs = lane.total_outputs();
    let slices: Vec<&[T]> = lists.iter().map(Vec::as_slice).collect();
    let tiles = batch / LANES;
    // One shard per thread at most, at least one tile per shard; with no
    // full tile at all, a single shard just runs the scalar tail.
    let shards = if tiles == 0 { 1 } else { threads.min(tiles) };
    let tiles_per = tiles.div_ceil(shards);
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(shards);
    let mut row = 0usize;
    for i in 0..shards {
        let hi = if i == shards - 1 { batch } else { ((i + 1) * tiles_per * LANES).min(batch) };
        if hi > row {
            ranges.push((row, hi));
            row = hi;
        }
    }
    let slices_ref = &slices;
    append_rows(out, batch, outs, |dst| {
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(ranges.len());
            let mut rest = dst;
            for &(lo, hi) in &ranges {
                let (chunk, tail) = rest.split_at_mut((hi - lo) * outs);
                rest = tail;
                handles.push(s.spawn(move || -> Result<(), PreconditionViolation> {
                    let shard: Vec<&[T]> = slices_ref
                        .iter()
                        .zip(lane.list_sizes())
                        .map(|(l, &sz)| &l[lo * sz..hi * sz])
                        .collect();
                    lane.run_batch_into(scalar, &shard, hi - lo, &mut LaneScratch::new(), chunk)
                        .map_err(|e| e.offset_row(lo))
                }));
            }
            let mut first_err = None;
            for h in handles {
                if let Err(e) = h.join().expect("lane shard panicked") {
                    first_err.get_or_insert(e);
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })
    })
}

/// Shard the **view-based** (tile-direct) batch across `threads` scoped
/// OS threads: tile-aligned row ranges, one fresh [`LaneScratch`] per
/// thread, each shard writing its own disjoint sub-slice of the per-row
/// output buffers. `threads <= 1` degrades to the single-threaded view
/// executor. The view twin of [`run_batch_sharded`].
pub fn run_view_batch_sharded<T: Copy + Ord + Default + Send + Sync>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<T>]],
    pad: T,
    threads: usize,
    outs: &mut [&mut [T]],
) -> Result<(), PreconditionViolation> {
    if threads <= 1 {
        return lane.run_view_batch_into(scalar, rows, pad, &mut LaneScratch::new(), outs);
    }
    assert_eq!(rows.len(), outs.len(), "{}: rows vs output buffers", lane.name);
    let real = rows.len();
    let tiles = real / LANES;
    let shards = if tiles == 0 { 1 } else { threads.min(tiles) };
    let tiles_per = tiles.div_ceil(shards);
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(shards);
    let mut row = 0usize;
    for i in 0..shards {
        let hi = if i == shards - 1 { real } else { ((i + 1) * tiles_per * LANES).min(real) };
        if hi > row {
            ranges.push((row, hi));
            row = hi;
        }
    }
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ranges.len());
        let mut rest = outs;
        for &(lo, hi) in &ranges {
            let (chunk, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let shard_rows = &rows[lo..hi];
            handles.push(s.spawn(move || -> Result<(), PreconditionViolation> {
                lane.run_view_batch_into(scalar, shard_rows, pad, &mut LaneScratch::new(), chunk)
                    .map_err(|e| e.offset_row(lo))
            }));
        }
        let mut first_err = None;
        for h in handles {
            if let Err(e) = h.join().expect("lane view shard panicked") {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// View-based batch execution with the standard shard policy applied:
/// shards across cores when [`auto_threads`] says the batch amortizes
/// thread spawn, otherwise runs single-threaded on the caller's
/// `scratch`. The one entry point shared by every tile-direct consumer
/// — [`crate::coordinator::SoftwareBackend`]'s serving path and the
/// streaming merge engine's block kernel
/// ([`crate::stream::merge2::BlockKernel`]) — so the policy lives in
/// exactly one place.
pub fn run_view_batch_auto<T: Copy + Ord + Default + Send + Sync>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<T>]],
    pad: T,
    scratch: &mut LaneScratch<T>,
    outs: &mut [&mut [T]],
) -> Result<(), PreconditionViolation> {
    let threads = auto_threads(rows.len(), scalar.n());
    if threads > 1 {
        run_view_batch_sharded(lane, scalar, rows, pad, threads, outs)
    } else {
        lane.run_view_batch_into(scalar, rows, pad, scratch, outs)
    }
}

/// Shard-count policy for [`crate::coordinator::SoftwareBackend`]: one
/// shard per core, but only when every shard gets at least two full
/// tiles AND each shard carries enough values (`batch * row_values`) to
/// amortize thread spawn (~tens of µs). Small serving batches (e.g.
/// 256 × 64 values) stay single-threaded on purpose.
pub fn auto_threads(batch: usize, row_values: usize) -> usize {
    const MIN_VALUES_PER_SHARD: usize = 1 << 15;
    let by_work = batch.saturating_mul(row_values) / MIN_VALUES_PER_SHARD;
    let cap = by_work.min(forced_threads(batch));
    if cap <= 1 {
        return 1;
    }
    cap
}

/// Thread count the benches/figure harness uses to *force* sharding on
/// a shape regardless of [`auto_threads`]' work floor (so the
/// lanes+threads variant is measured even where the backend would stay
/// inline): every core, capped so each shard still gets at least two
/// full tiles.
pub fn forced_threads(batch: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min((batch / (2 * LANES)).max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::loms::{loms_2way, loms_3way_median, loms_kway};
    use crate::sortnet::mwms::mwms_3way;
    use crate::sortnet::s2ms;
    use crate::util::Rng;

    fn flat_batch(rng: &mut Rng, sizes: &[usize], batch: usize, max: u32) -> Vec<Vec<u32>> {
        sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::with_capacity(batch * s);
                for _ in 0..batch {
                    flat.extend(rng.sorted_list(s, max));
                }
                flat
            })
            .collect()
    }

    fn scalar_outputs(plan: &CompiledPlan, lists: &[Vec<u32>], batch: usize) -> Vec<u32> {
        let mut out = Vec::new();
        plan.run_batch(lists, batch, ExecMode::Fast, &mut PlanScratch::new(), &mut out).unwrap();
        out
    }

    #[test]
    fn merge_network_is_correct_for_all_run_lengths() {
        // Exhaustive sorted-0-1 check of the general odd-even merge: for
        // every (a, b) up to 9×9 and every zero split, the emitted CAS
        // schedule must leave the rank-order slots sorted.
        for a in 0..=9usize {
            for b in 0..=9usize {
                if a + b == 0 {
                    continue;
                }
                let slots: Vec<u32> = (0..(a + b) as u32).collect();
                let mut ops = Vec::new();
                let w = emit_merge(&slots[..a], &slots[a..], &mut ops);
                assert_eq!(w.len(), a + b, "a={a} b={b}");
                for za in 0..=a {
                    for zb in 0..=b {
                        let mut v: Vec<u32> = (0..a).map(|i| u32::from(i >= za)).collect();
                        v.extend((0..b).map(|j| u32::from(j >= zb)));
                        for op in &ops {
                            let LaneOp::Cas { lo, hi } = *op else { unreachable!() };
                            let (x, y) = (v[lo as usize], v[hi as usize]);
                            v[lo as usize] = x.min(y);
                            v[hi as usize] = x.max(y);
                        }
                        let got: Vec<u32> = w.iter().map(|&s| v[s as usize]).collect();
                        assert!(
                            got.windows(2).all(|p| p[0] <= p[1]),
                            "a={a} b={b} za={za} zb={zb}: {got:?}"
                        );
                        assert_eq!(got.iter().filter(|&&x| x == 0).count(), za + zb);
                    }
                }
            }
        }
    }

    #[test]
    fn sorter_network_sorts_all_01_inputs() {
        for n in 1..=8usize {
            let slots: Vec<u32> = (0..n as u32).collect();
            let mut ops = Vec::new();
            let w = emit_sorter(&slots, &mut ops);
            assert_eq!(w.len(), n);
            for pattern in 0..(1u32 << n) {
                let mut v: Vec<u32> = (0..n).map(|i| (pattern >> i) & 1).collect();
                for op in &ops {
                    let LaneOp::Cas { lo, hi } = *op else { unreachable!() };
                    let (x, y) = (v[lo as usize], v[hi as usize]);
                    v[lo as usize] = x.min(y);
                    v[hi as usize] = x.max(y);
                }
                let got: Vec<u32> = w.iter().map(|&s| v[s as usize]).collect();
                assert!(got.windows(2).all(|p| p[0] <= p[1]), "n={n} pattern={pattern:b}");
            }
        }
    }

    #[test]
    fn lane_plan_matches_scalar_on_random_batches() {
        let mut rng = Rng::new(0x1A7E5);
        for d in [
            loms_2way(8, 8, 2),
            loms_2way(7, 5, 3),
            loms_kway(&[7, 7, 7]),
            s2ms::s2ms(6, 6),
            s2ms::s2ms(1, 9),
            crate::sortnet::batcher::odd_even_merge(8),
            mwms_3way(5),
        ] {
            let plan = CompiledPlan::compile(&d).unwrap();
            let lane = LanePlan::compile(&plan);
            assert_eq!(lane.total_outputs(), plan.total_outputs(), "{}", d.name);
            for batch in [1usize, LANES - 1, LANES, 2 * LANES + 5] {
                let lists = flat_batch(&mut rng, &d.list_sizes, batch, 10_000);
                let want = scalar_outputs(&plan, &lists, batch);
                let mut got = Vec::new();
                lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got)
                    .unwrap();
                assert_eq!(got, want, "{} batch={batch}", d.name);
            }
        }
    }

    #[test]
    fn pruned_filter_blocks_expand_with_shadow_slots() {
        // Pruned MWMS carries FilterN blocks; the lane expansion must add
        // shadow slots and a strictly smaller network than the full sort.
        let d = mwms_3way(5);
        let pruned = CompiledPlan::compile_pruned(&d).unwrap();
        assert!(pruned.removed_muxes() > 0);
        let lane = LanePlan::compile(&pruned);
        // Shadow slots appear exactly when the pruned plan carries
        // FilterN blocks (partially-pruned sorters), and each shadow
        // slot in a tap cone is fed by one copy.
        assert_eq!(lane.slots() > lane.n(), lane.copy_count() > 0);
        let unpruned_lane = LanePlan::compile(&CompiledPlan::compile(&d).unwrap());
        assert!(
            lane.cas_count() <= unpruned_lane.cas_count(),
            "pruning must not grow the CAS schedule ({} vs {})",
            lane.cas_count(),
            unpruned_lane.cas_count()
        );
        let mut rng = Rng::new(77);
        let batch = LANES + 3;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 500);
        let want = scalar_outputs(&pruned, &lists, batch);
        let mut got = Vec::new();
        lane.run_batch(&pruned, &lists, batch, &mut LaneScratch::new(), &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn native_filter_device_keeps_stale_positions() {
        // loms_3way_median builds a FilterN natively (not via pruning):
        // untapped outputs stay stale, and the scalar plan's full-merge
        // output reflects that. The lane plan must agree exactly.
        let d = loms_3way_median(5);
        let plan = CompiledPlan::compile(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(5);
        let batch = 2 * LANES + 1;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 99);
        let want = scalar_outputs(&plan, &lists, batch);
        let mut got = Vec::new();
        lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn sharded_matches_single_thread_and_offsets_rows() {
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0x5AAD);
        let batch = 5 * LANES + 11;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 1 << 20);
        let want = scalar_outputs(&plan, &lists, batch);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut got = Vec::new();
            run_batch_sharded(&lane, &plan, &lists, batch, threads, &mut got).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    /// Ragged random requests for a device: per-row lists each at most
    /// the device slot size.
    fn ragged_rows(rng: &mut Rng, sizes: &[usize], real: usize, max: u32) -> Vec<Vec<Vec<u32>>> {
        (0..real)
            .map(|_| {
                sizes
                    .iter()
                    .map(|&cap| {
                        let len = rng.range(1, cap + 1);
                        rng.sorted_list(len, max)
                    })
                    .collect()
            })
            .collect()
    }

    /// The old assemble-then-execute reference: pad each request to the
    /// device shape, run the row-major lane batch, slice real prefixes.
    fn padded_reference(
        lane: &LanePlan,
        plan: &CompiledPlan,
        reqs: &[Vec<Vec<u32>>],
        pad: u32,
    ) -> Vec<Vec<u32>> {
        let sizes = lane.list_sizes().to_vec();
        let lists: Vec<Vec<u32>> = (0..sizes.len())
            .map(|l| {
                let mut flat = Vec::new();
                for r in reqs {
                    flat.extend_from_slice(&r[l]);
                    flat.resize(flat.len() + (sizes[l] - r[l].len()), pad);
                }
                flat
            })
            .collect();
        let mut out = Vec::new();
        lane.run_batch(plan, &lists, reqs.len(), &mut LaneScratch::new(), &mut out).unwrap();
        let total = lane.total_outputs();
        reqs.iter()
            .enumerate()
            .map(|(row, r)| {
                let want: usize = r.iter().map(Vec::len).sum();
                out[row * total..row * total + want].to_vec()
            })
            .collect()
    }

    #[test]
    fn view_path_matches_padded_row_major_path() {
        // The tile-direct path (ragged views, inline pad fill, per-row
        // gather) must be byte-exact with assemble-then-execute across
        // tile boundaries: tail-only, exact tiles, tiles + tail.
        const PAD: u32 = u32::MAX;
        let mut rng = Rng::new(0x71D1);
        for d in [loms_2way(8, 8, 2), loms_2way(7, 5, 3), loms_kway(&[7, 7, 7]), s2ms::s2ms(6, 6)]
        {
            let plan = CompiledPlan::compile_auto(&d).unwrap();
            let lane = LanePlan::compile(&plan);
            for real in [1usize, LANES - 1, LANES, 2 * LANES, 2 * LANES + 5] {
                let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
                let want = padded_reference(&lane, &plan, &reqs, PAD);
                let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
                let mut merged: Vec<Vec<u32>> = reqs
                    .iter()
                    .map(|r| vec![0u32; r.iter().map(Vec::len).sum()])
                    .collect();
                let mut outs: Vec<&mut [u32]> =
                    merged.iter_mut().map(|v| v.as_mut_slice()).collect();
                lane.run_view_batch_into(&plan, &rows, PAD, &mut LaneScratch::new(), &mut outs)
                    .unwrap();
                assert_eq!(merged, want, "{} real={real}", d.name);
            }
        }
    }

    #[test]
    fn sharded_view_path_matches_single_thread() {
        const PAD: u32 = u32::MAX;
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0x5A4D);
        let real = 5 * LANES + 11;
        let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
        let want = padded_reference(&lane, &plan, &reqs, PAD);
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            run_view_batch_sharded(&lane, &plan, &rows, PAD, threads, &mut outs).unwrap();
            assert_eq!(merged, want, "threads={threads}");
        }
    }

    #[test]
    fn auto_view_path_matches_explicit_paths() {
        // run_view_batch_auto must be byte-exact with the explicit view
        // executors on both sides of the shard threshold.
        const PAD: u32 = u32::MAX;
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0xA07);
        for real in [3usize, 4 * LANES + 7] {
            let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
            let want = padded_reference(&lane, &plan, &reqs, PAD);
            let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            run_view_batch_auto(&lane, &plan, &rows, PAD, &mut LaneScratch::new(), &mut outs)
                .unwrap();
            assert_eq!(merged, want, "real={real}");
        }
    }

    #[test]
    fn auto_threads_policy_bounds() {
        // Too few tiles or too little work: stay single-threaded.
        assert_eq!(auto_threads(LANES, 1 << 20), 1);
        assert_eq!(auto_threads(256, 64), 1, "serving shape b256×64 stays inline");
        // Huge batches may shard (bounded by core count, so only ≥ 1 is
        // portable to assert).
        assert!(auto_threads(1 << 16, 512) >= 1);
        assert!(auto_threads(1 << 16, 512) <= std::thread::available_parallelism().unwrap().get());
    }

    #[test]
    fn schedule_is_pure_cas_plus_filter_copies() {
        // Families without FilterN lower to a copy-free pure CAS stream.
        for d in [loms_2way(8, 8, 2), s2ms::s2ms(8, 8), loms_kway(&[3, 3, 3, 3])] {
            let lane = LanePlan::compile(&CompiledPlan::compile(&d).unwrap());
            assert_eq!(lane.copy_count(), 0, "{}", d.name);
            assert!(lane.cas_count() > 0, "{}", d.name);
            assert_eq!(lane.slots(), lane.n(), "{}", d.name);
        }
    }
}
