//! Lane-parallel execution plans: a [`CompiledPlan`] expanded into a pure
//! compare-exchange schedule and executed over a **transposed,
//! value-major batch tile**.
//!
//! The devices are data-oblivious comparator networks — the same fixed
//! schedule runs for every row — so a batch does not have to be executed
//! row by row. A [`LanePlan`] re-expresses every plan op as plain
//! 2-input compare-exchange (CAS) steps (the reduction Shi et al. use
//! for n-sorter networks, and the structure FLiMS exploits for wide
//! parallel merging):
//!
//! * `SortN` blocks expand through the general odd-even merge-sort
//!   recursion (the arbitrary-size form of the Batcher networks in
//!   [`super::batcher`]);
//! * `MergeS2` blocks expand through the general odd-even **merge**
//!   (Knuth 5.3.4, arbitrary run lengths) — valid whenever the block's
//!   hardware precondition (sorted input runs) holds, which device
//!   validation proves for every sorted input;
//! * `FilterN` blocks copy their inputs into *shadow slots*, run the
//!   sorter network there, and keep only the comparator cone feeding the
//!   tapped ranks (the [`super::prune`]-style output-cone idea applied
//!   to a single block) — untapped positions keep their stale values
//!   exactly like the scalar executor;
//! * `Cas` blocks pass through unchanged.
//!
//! Instead of physically permuting values, the expansion tracks a
//! position→slot renaming (`loc`): an odd-even merge leaves rank `t` in
//! some input slot, and the device's `out[t]` position is simply
//! re-pointed there. The schedule stays 100% CAS + copy.
//!
//! Execution is transposed: a tile holds [`LANES`] consecutive batch
//! rows in value-major order (`tile[slot * LANES + lane]`), so every
//! CAS is an elementwise branchless min/max over two contiguous
//! [`LANES`]-wide chunks — the shape rustc autovectorizes for `u32`.
//! A batch of `B` rows runs as `B / LANES` tiles plus a scalar
//! [`CompiledPlan`] tail for the remainder; [`run_batch_sharded`]
//! additionally splits the tiles across OS threads
//! (`std::thread::scope`, no added dependencies), each shard writing a
//! disjoint range of the output buffer.
//!
//! Two batch entry points share the tile executor. The **row-major**
//! path ([`LanePlan::run_batch_into`]) reads pre-assembled flat lists —
//! the shape the PJRT artifacts consume. The **tile-direct view** path
//! ([`LanePlan::run_view_batch_into`], [`run_view_batch_sharded`]) is
//! the serving hot path: it scatters straight from ragged per-request
//! list views into the tile (padding short lists inline) and gathers
//! each lane's output cone straight into that row's caller-provided
//! response buffer — the whole batch is copied exactly twice
//! (request → tile, tile → response), with no list-major scratch,
//! row-major assembly, padding rows, or whole-batch output buffer in
//! between.
//!
//! Equality contract: on **valid inputs** (each list sorted ascending —
//! what the service admits) the lane executor is bit-exact with
//! [`CompiledPlan::run_batch`]; `rust/tests/plan_differential.rs`
//! enforces this for every device family, ragged sizes included, with
//! batch sizes that are not multiples of [`LANES`]. Fast-mode
//! garbage-in (unsorted runs feeding a `MergeS2`) produces *different*
//! garbage than the scalar two-pointer merge, exactly as the physical
//! S2MS would; Strict mode, medians and the validators therefore stay
//! on [`CompiledPlan`].
//!
//! **Explicit SIMD dispatch.** The per-chunk min/max kernel is no
//! longer left to autovectorization: [`LaneElem`] carries explicit
//! `std::arch` kernels (AVX2 `_mm256_min_epu32`/`_mm256_max_epu32`,
//! NEON `vminq_u32`/`vmaxq_u32`, and biased-compare 64-bit variants)
//! behind a [`SimdTier`] chosen once per process — runtime feature
//! detection, overridable via the `LOMS_SIMD` env var (`scalar`,
//! `portable`, `avx2`, `neon`) and [`force_tier`] for differential
//! tests. Every tier is bit-exact with every other; the dispatch tests
//! prove it across all default artifacts.
//!
//! **Key-value rows.** Payloads never enter the tile. The
//! rank-then-permute path ([`LanePlan::run_view_batch_perm_into`])
//! packs each key with its list-major origin index into one `u64`
//! (`key << 32 | origin`), runs the *same* CAS schedule over `u64`
//! chunks — all elements distinct, so the network computes the stable
//! (key, origin)-lexicographic merge — and unpacks each output into the
//! merged key plus the output **permutation**. The caller applies that
//! permutation to the payload column once per row; payload bytes move
//! exactly once and no compare-exchange ever touches them.

use super::exec::{ExecMode, PreconditionViolation};
use super::plan::{append_rows, CompiledPlan, PlanOp, PlanScratch};
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// Rows per tile. 16 × `u32` = 64 bytes: one AVX-512 register or two
/// AVX2 registers per chunk — wide enough to keep the min/max stream
/// vectorized, small enough that a tile of any characterized device
/// stays in L1.
pub const LANES: usize = 16;

/// Which compare-exchange kernel executes the CAS schedule. Every tier
/// produces bit-identical output; they differ only in how the
/// per-chunk min/max is issued.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SimdTier {
    /// Per-element compare-and-swap reference (branchy, never
    /// vectorized) — the differential baseline.
    Scalar = 0,
    /// Branchless select loop over `[T; LANES]` — safe code the
    /// compiler may autovectorize; the fallback on every host.
    Portable = 1,
    /// Explicit 256-bit x86 kernels (`_mm256_min_epu32` /
    /// `_mm256_max_epu32`; biased `_mm256_cmpgt_epi64` + blend for
    /// `u64`). Selected only when runtime detection proves AVX2.
    Avx2 = 2,
    /// Explicit 128-bit aarch64 kernels (`vminq_u32` / `vmaxq_u32`;
    /// `vcgtq_u64` + `vbslq_u64` for `u64`).
    Neon = 3,
}

impl SimdTier {
    fn from_u8(raw: u8) -> SimdTier {
        match raw {
            0 => SimdTier::Scalar,
            1 => SimdTier::Portable,
            2 => SimdTier::Avx2,
            _ => SimdTier::Neon,
        }
    }

    /// Parse the `LOMS_SIMD` spelling.
    pub fn parse(s: &str) -> Option<SimdTier> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdTier::Scalar),
            "portable" => Some(SimdTier::Portable),
            "avx2" => Some(SimdTier::Avx2),
            "neon" => Some(SimdTier::Neon),
            _ => None,
        }
    }

    /// The `LOMS_SIMD` spelling of this tier — [`SimdTier::parse`]'s
    /// inverse, used as the `tier` attribute on execute spans and
    /// per-artifact stats.
    pub fn label(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Portable => "portable",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Whether this tier's kernels may run on this host. `Scalar` and
    /// `Portable` always can; the explicit tiers require their
    /// architecture (and, for AVX2, runtime CPU feature detection).
    pub fn available(self) -> bool {
        match self {
            SimdTier::Scalar | SimdTier::Portable => true,
            SimdTier::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdTier::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Best tier this host supports (feature detection runs once).
fn best_tier() -> SimdTier {
    if SimdTier::Avx2.available() {
        SimdTier::Avx2
    } else if SimdTier::Neon.available() {
        SimdTier::Neon
    } else {
        SimdTier::Portable
    }
}

/// Every tier runnable on this host, `Scalar` first — the set the
/// dispatch differential tests iterate.
pub fn available_tiers() -> Vec<SimdTier> {
    let mut tiers = vec![SimdTier::Scalar, SimdTier::Portable];
    let best = best_tier();
    if best != SimdTier::Portable {
        tiers.push(best);
    }
    tiers
}

static DEFAULT_TIER: OnceLock<SimdTier> = OnceLock::new();
/// `u8::MAX` = no override; otherwise a forced tier ([`force_tier`]).
static FORCED_TIER: AtomicU8 = AtomicU8::new(u8::MAX);

fn default_tier() -> SimdTier {
    *DEFAULT_TIER.get_or_init(|| {
        let best = best_tier();
        match std::env::var("LOMS_SIMD") {
            Ok(v) => match SimdTier::parse(&v) {
                Some(t) if t.available() => t,
                Some(t) => {
                    eprintln!("LOMS_SIMD={v}: {t:?} unavailable on this host; using {best:?}");
                    best
                }
                None => {
                    eprintln!(
                        "LOMS_SIMD={v}: unknown tier (scalar|portable|avx2|neon); using {best:?}"
                    );
                    best
                }
            },
            Err(_) => best,
        }
    })
}

/// The tier the executors dispatch on, resolved once per batch entry:
/// a [`force_tier`] override if set, else `LOMS_SIMD`, else the best
/// detected kernel. Invariant relied on by the `unsafe` kernels: this
/// never returns a tier whose [`SimdTier::available`] is false.
pub fn active_tier() -> SimdTier {
    match FORCED_TIER.load(Ordering::Relaxed) {
        u8::MAX => default_tier(),
        raw => SimdTier::from_u8(raw),
    }
}

/// Force a dispatch tier process-wide (`None` clears the override) —
/// the hook the dispatch-tier differential tests use to run the same
/// batch through every kernel. Returns `false` (and changes nothing)
/// if the tier cannot run on this host, preserving the
/// [`active_tier`] availability invariant.
pub fn force_tier(tier: Option<SimdTier>) -> bool {
    match tier {
        None => {
            FORCED_TIER.store(u8::MAX, Ordering::Relaxed);
            true
        }
        Some(t) if t.available() => {
            FORCED_TIER.store(t as u8, Ordering::Relaxed);
            true
        }
        Some(_) => false,
    }
}

/// A tile element the lane executors can run: carries the per-tier
/// compare-exchange kernels and the scratch pool for its type. `u32`
/// is the key path; `u64` is the packed (key, origin) rank-then-permute
/// path.
pub trait LaneElem: Copy + Ord + Default + Send + Sync + 'static {
    /// Elementwise compare-exchange of two [`LANES`]-wide chunks under
    /// `tier`: per lane, `min → x`, `max → y`. Must be bit-exact across
    /// tiers. Callers guarantee `tier.available()` (the [`active_tier`]
    /// invariant).
    fn cas_chunks(tier: SimdTier, x: &mut [Self; LANES], y: &mut [Self; LANES]);

    /// The process-wide pool of reusable [`LaneScratch`]es for this
    /// element type (see [`LaneScratch::take`]).
    fn scratch_pool() -> &'static Mutex<Vec<LaneScratch<Self>>>;
}

/// Per-element reference kernel: branchy compare-and-swap. Never
/// vectorizes — the tier every other kernel is differenced against.
#[inline]
fn cas_chunks_scalar<T: Copy + Ord>(x: &mut [T; LANES], y: &mut [T; LANES]) {
    for (p, q) in x.iter_mut().zip(y.iter_mut()) {
        if *q < *p {
            std::mem::swap(p, q);
        }
    }
}

/// Branchless select loop — safe portable code with a compile-time
/// trip count (the shape rustc autovectorizes when it can).
#[inline]
fn cas_chunks_portable<T: Copy + Ord>(x: &mut [T; LANES], y: &mut [T; LANES]) {
    for (p, q) in x.iter_mut().zip(y.iter_mut()) {
        let (a, b) = (*p, *q);
        let swap = b < a;
        *p = if swap { b } else { a };
        *q = if swap { a } else { b };
    }
}

/// 16 × u32 min/max as two 256-bit AVX2 vector pairs.
///
/// # Safety
/// The CPU must support AVX2 (callers check via the [`active_tier`]
/// availability invariant). Loads/stores use the unaligned intrinsics,
/// so no alignment precondition — though tile chunks are 64-byte
/// aligned ([`LaneScratch`]), making every access aligned in practice.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn cas_chunks_u32_avx2(x: &mut [u32; LANES], y: &mut [u32; LANES]) {
    use std::arch::x86_64::*;
    let px = x.as_mut_ptr().cast::<__m256i>();
    let py = y.as_mut_ptr().cast::<__m256i>();
    for i in 0..LANES / 8 {
        // SAFETY: i ∈ {0, 1}; both arrays hold LANES = 16 u32s, so each
        // 8-wide load/store stays in bounds.
        let a = _mm256_loadu_si256(px.add(i));
        let b = _mm256_loadu_si256(py.add(i));
        _mm256_storeu_si256(px.add(i), _mm256_min_epu32(a, b));
        _mm256_storeu_si256(py.add(i), _mm256_max_epu32(a, b));
    }
}

/// 16 × u64 min/max as four 256-bit AVX2 vector pairs. AVX2 has no
/// unsigned 64-bit min/max (those are AVX-512), so both operands are
/// biased into signed order, compared with `_mm256_cmpgt_epi64`, and
/// the originals blended by the mask.
///
/// # Safety
/// The CPU must support AVX2 (callers check via the [`active_tier`]
/// availability invariant); unaligned intrinsics, no alignment
/// precondition.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn cas_chunks_u64_avx2(x: &mut [u64; LANES], y: &mut [u64; LANES]) {
    use std::arch::x86_64::*;
    let px = x.as_mut_ptr().cast::<__m256i>();
    let py = y.as_mut_ptr().cast::<__m256i>();
    let bias = _mm256_set1_epi64x(i64::MIN);
    for i in 0..LANES / 4 {
        // SAFETY: i ∈ 0..4; both arrays hold LANES = 16 u64s, so each
        // 4-wide load/store stays in bounds.
        let a = _mm256_loadu_si256(px.add(i));
        let b = _mm256_loadu_si256(py.add(i));
        let gt = _mm256_cmpgt_epi64(_mm256_xor_si256(a, bias), _mm256_xor_si256(b, bias));
        _mm256_storeu_si256(px.add(i), _mm256_blendv_epi8(a, b, gt));
        _mm256_storeu_si256(py.add(i), _mm256_blendv_epi8(b, a, gt));
    }
}

/// 16 × u32 min/max as four 128-bit NEON vector pairs.
///
/// # Safety
/// aarch64 baseline includes NEON; both arrays hold LANES = 16 u32s, so
/// each 4-wide load/store stays in bounds.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn cas_chunks_u32_neon(x: &mut [u32; LANES], y: &mut [u32; LANES]) {
    use std::arch::aarch64::*;
    let px = x.as_mut_ptr();
    let py = y.as_mut_ptr();
    for i in 0..LANES / 4 {
        let a = vld1q_u32(px.add(4 * i));
        let b = vld1q_u32(py.add(4 * i));
        vst1q_u32(px.add(4 * i), vminq_u32(a, b));
        vst1q_u32(py.add(4 * i), vmaxq_u32(a, b));
    }
}

/// 16 × u64 min/max as eight 128-bit NEON vector pairs (`vcgtq_u64`
/// compare + `vbslq_u64` select — NEON has no 64-bit min/max either).
///
/// # Safety
/// aarch64 baseline includes NEON; both arrays hold LANES = 16 u64s, so
/// each 2-wide load/store stays in bounds.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn cas_chunks_u64_neon(x: &mut [u64; LANES], y: &mut [u64; LANES]) {
    use std::arch::aarch64::*;
    let px = x.as_mut_ptr();
    let py = y.as_mut_ptr();
    for i in 0..LANES / 2 {
        let a = vld1q_u64(px.add(2 * i));
        let b = vld1q_u64(py.add(2 * i));
        let gt = vcgtq_u64(a, b);
        vst1q_u64(px.add(2 * i), vbslq_u64(gt, b, a));
        vst1q_u64(py.add(2 * i), vbslq_u64(gt, a, b));
    }
}

impl LaneElem for u32 {
    #[inline]
    fn cas_chunks(tier: SimdTier, x: &mut [u32; LANES], y: &mut [u32; LANES]) {
        match tier {
            SimdTier::Scalar => cas_chunks_scalar(x, y),
            SimdTier::Portable => cas_chunks_portable(x, y),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the active_tier invariant — Avx2 is dispatched
            // only after runtime detection proved the feature.
            SimdTier::Avx2 => unsafe { cas_chunks_u32_avx2(x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is part of the aarch64 baseline.
            SimdTier::Neon => unsafe { cas_chunks_u32_neon(x, y) },
            // A tier compiled out on this architecture can only appear
            // if the availability invariant were broken — stay correct.
            _ => cas_chunks_portable(x, y),
        }
    }

    fn scratch_pool() -> &'static Mutex<Vec<LaneScratch<u32>>> {
        static POOL: Mutex<Vec<LaneScratch<u32>>> = Mutex::new(Vec::new());
        &POOL
    }
}

impl LaneElem for u64 {
    #[inline]
    fn cas_chunks(tier: SimdTier, x: &mut [u64; LANES], y: &mut [u64; LANES]) {
        match tier {
            SimdTier::Scalar => cas_chunks_scalar(x, y),
            SimdTier::Portable => cas_chunks_portable(x, y),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: the active_tier invariant — Avx2 is dispatched
            // only after runtime detection proved the feature.
            SimdTier::Avx2 => unsafe { cas_chunks_u64_avx2(x, y) },
            #[cfg(target_arch = "aarch64")]
            // SAFETY: NEON is part of the aarch64 baseline.
            SimdTier::Neon => unsafe { cas_chunks_u64_neon(x, y) },
            // A tier compiled out on this architecture can only appear
            // if the availability invariant were broken — stay correct.
            _ => cas_chunks_portable(x, y),
        }
    }

    fn scratch_pool() -> &'static Mutex<Vec<LaneScratch<u64>>> {
        static POOL: Mutex<Vec<LaneScratch<u64>>> = Mutex::new(Vec::new());
        &POOL
    }
}

/// One step of the lane schedule. Slot indices address tile chunks
/// (`slot * LANES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneOp {
    /// Elementwise compare-exchange: per lane, `min → lo`, `max → hi`.
    Cas { lo: u32, hi: u32 },
    /// Chunk copy `dst ← src` (FilterN shadow-slot loads).
    Copy { dst: u32, src: u32 },
}

/// One tile slot's worth of values, pinned to a cache line: the SIMD
/// kernels' loads and stores all land 64-byte aligned (`LANES` × u32 =
/// one line, `LANES` × u64 = two).
#[repr(C, align(64))]
#[derive(Debug, Clone, Copy)]
struct TileChunk<T>([T; LANES]);

/// Reusable lane-execution buffers: the transposed tile (64-byte
/// aligned, chunk per slot) plus a scalar [`PlanScratch`] for the tail
/// rows. Grows to the largest plan seen; shard workers recycle them
/// through the per-type pool ([`Self::take`] / [`Self::put`]) instead
/// of reallocating per batch.
#[derive(Debug, Default)]
pub struct LaneScratch<T> {
    chunks: Vec<TileChunk<T>>,
    tail: PlanScratch<T>,
}

/// Pool cap per element type — far above any realistic shard count;
/// overflow returns are simply dropped.
const MAX_POOLED_SCRATCHES: usize = 64;

impl<T> LaneScratch<T> {
    pub fn new() -> Self {
        LaneScratch { chunks: Vec::new(), tail: PlanScratch::new() }
    }
}

impl<T: Copy + Default> LaneScratch<T> {
    /// The flat value-major tile, grown to `slots` chunks. The base
    /// pointer is 64-byte aligned and every slot chunk starts on an
    /// aligned boundary.
    fn tile_mut(&mut self, slots: usize) -> &mut [T] {
        assert_eq!(
            std::mem::size_of::<TileChunk<T>>(),
            LANES * std::mem::size_of::<T>(),
            "TileChunk<T> must be padding-free"
        );
        if self.chunks.len() < slots {
            self.chunks.resize(slots, TileChunk([T::default(); LANES]));
        }
        let chunks = &mut self.chunks[..slots];
        // SAFETY: TileChunk is repr(C) around a single [T; LANES] array
        // and the assert above proves its stride equals LANES values, so
        // `slots` contiguous chunks are exactly `slots * LANES`
        // contiguous, initialized `T`s.
        unsafe {
            std::slice::from_raw_parts_mut(chunks.as_mut_ptr().cast::<T>(), slots * LANES)
        }
    }
}

impl<T: LaneElem> LaneScratch<T> {
    /// Grab a pooled scratch — warmed tiles are recycled across batches
    /// and shard workers instead of being reallocated per call.
    pub fn take() -> LaneScratch<T> {
        T::scratch_pool().lock().ok().and_then(|mut p| p.pop()).unwrap_or_default()
    }

    /// Return a scratch to the pool (bounded; overflow is dropped).
    pub fn put(self) {
        if let Ok(mut pool) = T::scratch_pool().lock() {
            if pool.len() < MAX_POOLED_SCRATCHES {
                pool.push(self);
            }
        }
    }
}

/// A [`CompiledPlan`] expanded to a pure CAS/copy schedule over tile
/// slots, executable [`LANES`] rows at a time in value-major layout.
#[derive(Debug, Clone)]
pub struct LanePlan {
    name: String,
    list_sizes: Vec<usize>,
    /// Device flat-vector length (slots `0..n` are the live positions).
    n: usize,
    /// Tile height: `n` plus FilterN shadow slots.
    slots: usize,
    ops: Vec<LaneOp>,
    /// Flattened input map, list-major (loads hit the identity renaming).
    in_slot: Vec<u32>,
    /// `out_slot[r]` = tile slot holding output rank `r` after all ops.
    out_slot: Vec<u32>,
    cas_count: usize,
    copy_count: usize,
}

/// General odd-even merge (Batcher / Knuth 5.3.4, arbitrary run
/// lengths) over slot lists `a` and `b`, each holding a sorted run in
/// ascending rank order. Emits CAS steps in dependency order and
/// returns the slots of the merged sequence in ascending rank order.
fn emit_merge(a: &[u32], b: &[u32], ops: &mut Vec<LaneOp>) -> Vec<u32> {
    if a.is_empty() {
        return b.to_vec();
    }
    if b.is_empty() {
        return a.to_vec();
    }
    if a.len() == 1 && b.len() == 1 {
        ops.push(LaneOp::Cas { lo: a[0], hi: b[0] });
        return vec![a[0], b[0]];
    }
    fn even(s: &[u32]) -> Vec<u32> {
        s.iter().copied().step_by(2).collect()
    }
    fn odd(s: &[u32]) -> Vec<u32> {
        s.iter().copied().skip(1).step_by(2).collect()
    }
    let e = emit_merge(&even(a), &even(b), ops);
    let o = emit_merge(&odd(a), &odd(b), ops);
    // Interleave by rank (e0, o0, e1, o1, …) and fix the single possible
    // inversion per pair: rank 2i+1 = min(o_i, e_{i+1}), 2i+2 = max.
    // |e| − |o| = (|a| mod 2) + (|b| mod 2) ∈ {0, 1, 2}; unpaired tail
    // elements are already in place by the 0-1 argument.
    let mut w = Vec::with_capacity(a.len() + b.len());
    w.push(e[0]);
    for (i, &oi) in o.iter().enumerate() {
        if i + 1 < e.len() {
            ops.push(LaneOp::Cas { lo: oi, hi: e[i + 1] });
            w.push(oi);
            w.push(e[i + 1]);
        } else {
            w.push(oi);
        }
    }
    if e.len() > o.len() + 1 {
        w.extend_from_slice(&e[o.len() + 1..]);
    }
    w
}

/// Odd-even merge sort over an arbitrary slot count: recursive halving,
/// then [`emit_merge`]. Returns the slots in ascending rank order.
fn emit_sorter(slots: &[u32], ops: &mut Vec<LaneOp>) -> Vec<u32> {
    if slots.len() <= 1 {
        return slots.to_vec();
    }
    let (lo, hi) = slots.split_at(slots.len() / 2);
    let a = emit_sorter(lo, ops);
    let b = emit_sorter(hi, ops);
    emit_merge(&a, &b, ops)
}

impl LanePlan {
    /// Expand a compiled plan into the CAS/copy lane schedule. Pruned
    /// plans expand their pruned op stream (FilterN tap cones shrink the
    /// emitted networks further).
    pub fn compile(plan: &CompiledPlan) -> LanePlan {
        let n = plan.n();
        // Position → slot renaming; starts as the identity.
        let mut loc: Vec<u32> = (0..n as u32).collect();
        let mut slots = n;
        let mut ops: Vec<LaneOp> = Vec::new();
        for op in plan.iter_ops() {
            match op {
                PlanOp::Cas { lo, hi } => {
                    ops.push(LaneOp::Cas { lo: loc[lo], hi: loc[hi] });
                }
                PlanOp::SortN { pos } => {
                    let s: Vec<u32> = pos.iter().map(|&p| loc[p as usize]).collect();
                    let w = emit_sorter(&s, &mut ops);
                    for (i, &p) in pos.iter().enumerate() {
                        loc[p as usize] = w[i];
                    }
                }
                PlanOp::MergeS2 { up, dn, out } => {
                    let su: Vec<u32> = up.iter().map(|&p| loc[p as usize]).collect();
                    let sd: Vec<u32> = dn.iter().map(|&p| loc[p as usize]).collect();
                    let w = emit_merge(&su, &sd, &mut ops);
                    for (t, &p) in out.iter().enumerate() {
                        loc[p as usize] = w[t];
                    }
                }
                PlanOp::FilterN { pos, taps } => {
                    // Sort in shadow slots so untapped positions keep
                    // their (possibly stale) values, as in hardware.
                    let sh: Vec<u32> = (slots as u32..(slots + pos.len()) as u32).collect();
                    slots += pos.len();
                    let mut net: Vec<LaneOp> = Vec::new();
                    let w = emit_sorter(&sh, &mut net);
                    // Output-cone pruning at block granularity: walk the
                    // network backward keeping only comparators that feed
                    // a tapped rank.
                    let mut needed = vec![false; slots];
                    for &t in taps {
                        needed[w[t as usize] as usize] = true;
                    }
                    let mut kept: Vec<LaneOp> = Vec::with_capacity(net.len());
                    for &cas in net.iter().rev() {
                        let LaneOp::Cas { lo, hi } = cas else { unreachable!() };
                        if needed[lo as usize] || needed[hi as usize] {
                            needed[lo as usize] = true;
                            needed[hi as usize] = true;
                            kept.push(cas);
                        }
                    }
                    for (i, &p) in pos.iter().enumerate() {
                        if needed[sh[i] as usize] {
                            ops.push(LaneOp::Copy { dst: sh[i], src: loc[p as usize] });
                        }
                    }
                    ops.extend(kept.iter().rev());
                    for &t in taps {
                        loc[pos[t as usize] as usize] = w[t as usize];
                    }
                }
            }
        }
        let cas_count = ops.iter().filter(|o| matches!(o, LaneOp::Cas { .. })).count();
        let copy_count = ops.len() - cas_count;
        LanePlan {
            name: plan.name.clone(),
            list_sizes: plan.list_sizes().to_vec(),
            n,
            slots,
            ops,
            in_slot: plan.in_pos().to_vec(),
            out_slot: plan.out_pos().iter().map(|&p| loc[p as usize]).collect(),
            cas_count,
            copy_count,
        }
    }

    /// Device flat-vector length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Tile height in slots (`n()` + FilterN shadow slots).
    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Compare-exchange steps per tile.
    pub fn cas_count(&self) -> usize {
        self.cas_count
    }

    /// Chunk-copy steps per tile (FilterN shadow loads).
    pub fn copy_count(&self) -> usize {
        self.copy_count
    }

    /// Output width per row.
    pub fn total_outputs(&self) -> usize {
        self.out_slot.len()
    }

    pub fn list_sizes(&self) -> &[usize] {
        &self.list_sizes
    }

    /// Panic unless `scalar` is the plan this lane plan was expanded
    /// from (the tail rows run through it, so a shape-coincident plan of
    /// a *different* device would silently give the tail different
    /// semantics — the name pins the device, shape checks catch stale
    /// rebuilds).
    fn check_tail_plan(&self, scalar: &CompiledPlan) {
        assert_eq!(
            (scalar.name.as_str(), scalar.list_sizes(), scalar.total_outputs()),
            (self.name.as_str(), self.list_sizes(), self.out_slot.len()),
            "lane plan and scalar tail plan mismatch"
        );
    }

    /// Run the CAS/copy schedule over a loaded tile with `tier`'s
    /// kernels.
    #[inline]
    fn exec_tile_ops<T: LaneElem>(&self, tier: SimdTier, tile: &mut [T]) {
        for op in &self.ops {
            match *op {
                LaneOp::Cas { lo, hi } => cas_lanes(tier, tile, lo as usize, hi as usize),
                LaneOp::Copy { dst, src } => {
                    let s0 = src as usize * LANES;
                    tile.copy_within(s0..s0 + LANES, dst as usize * LANES);
                }
            }
        }
    }

    /// Execute one full tile: scatter rows `row0 .. row0+LANES` into the
    /// value-major tile, run the CAS/copy schedule, gather the rows into
    /// `dst` (row-major, `LANES * total_outputs()` long).
    fn run_tile<T: LaneElem>(
        &self,
        tier: SimdTier,
        lists: &[&[T]],
        row0: usize,
        tile: &mut [T],
        dst: &mut [T],
    ) {
        let mut ip = 0usize;
        for (l, &s) in self.list_sizes.iter().enumerate() {
            for lane in 0..LANES {
                let src = &lists[l][(row0 + lane) * s..(row0 + lane + 1) * s];
                for (i, &x) in src.iter().enumerate() {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = x;
                }
            }
            ip += s;
        }
        self.exec_tile_ops(tier, tile);
        let outs = self.out_slot.len();
        for lane in 0..LANES {
            let row_dst = &mut dst[lane * outs..(lane + 1) * outs];
            for (r, &sl) in self.out_slot.iter().enumerate() {
                row_dst[r] = tile[sl as usize * LANES + lane];
            }
        }
    }

    /// Execute one full tile **straight from ragged request views**: the
    /// tentpole of the tile-direct serving path. Rows
    /// `row0 .. row0+LANES` (all real — callers only hand full tiles
    /// here) are scattered from each request's un-padded lists into the
    /// value-major tile with `pad` filling the short-list tail in the
    /// same pass — the batch's *only* input copy. After the schedule
    /// runs, each lane's output cone is gathered straight into that
    /// row's caller-provided buffer (`outs[r].len()` values, typically
    /// the request's real output width — `pad` sorts to the tail, so the
    /// prefix is the true merge). No list-major scratch, no row-major
    /// assembly, no whole-batch output buffer.
    fn run_tile_view<T: LaneElem>(
        &self,
        tier: SimdTier,
        rows: &[&[Vec<T>]],
        row0: usize,
        pad: T,
        tile: &mut [T],
        outs: &mut [&mut [T]],
    ) {
        let mut ip = 0usize;
        for (l, &cap) in self.list_sizes.iter().enumerate() {
            for lane in 0..LANES {
                let src = &rows[row0 + lane][l];
                for (i, &x) in src.iter().enumerate() {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = x;
                }
                for i in src.len()..cap {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = pad;
                }
            }
            ip += cap;
        }
        self.exec_tile_ops(tier, tile);
        for lane in 0..LANES {
            let dst = &mut *outs[row0 + lane];
            for (t, &sl) in self.out_slot.iter().take(dst.len()).enumerate() {
                dst[t] = tile[sl as usize * LANES + lane];
            }
        }
    }

    /// The rank-then-permute twin of [`Self::run_tile_view`]: scatter
    /// each row's **keys packed with their list-major origin index**
    /// (`key << 32 | origin`, pad slots = `u64::MAX`) into a `u64`
    /// tile, run the identical CAS schedule — every element distinct,
    /// so the network computes the stable (key, origin) merge — and
    /// unpack each output slot into the merged key and the origin that
    /// produced it. Payloads are never scattered, compared, or moved
    /// here; the caller applies `perm` to its payload column once.
    fn run_tile_view_perm(
        &self,
        tier: SimdTier,
        rows: &[&[Vec<u32>]],
        row0: usize,
        tile: &mut [u64],
        out_keys: &mut [&mut [u32]],
        out_perm: &mut [&mut [u32]],
    ) {
        let mut ip = 0usize;
        for (l, &cap) in self.list_sizes.iter().enumerate() {
            for lane in 0..LANES {
                let row = rows[row0 + lane];
                // Origin base: keys of this row's earlier lists (the
                // permutation indexes the row's concatenated column).
                let base: usize = row[..l].iter().map(Vec::len).sum();
                let src = &row[l];
                for (i, &x) in src.iter().enumerate() {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] =
                        pack_kv(x, (base + i) as u32);
                }
                for i in src.len()..cap {
                    tile[self.in_slot[ip + i] as usize * LANES + lane] = KV_PAD;
                }
            }
            ip += cap;
        }
        self.exec_tile_ops(tier, tile);
        for lane in 0..LANES {
            let keys = &mut *out_keys[row0 + lane];
            let perm = &mut *out_perm[row0 + lane];
            for (t, &sl) in self.out_slot.iter().take(keys.len()).enumerate() {
                let v = tile[sl as usize * LANES + lane];
                keys[t] = (v >> 32) as u32;
                perm[t] = v as u32;
            }
        }
    }

    /// View-based batch executor — the two-copy serving path. `rows[r]`
    /// is request `r`'s un-padded lists (each sorted, no longer than the
    /// device's `list_sizes`); `outs[r]` is the destination for row
    /// `r`'s merged prefix (at most `total_outputs()` wide). Full tiles
    /// run through [`Self::run_tile_view`]; the `rows.len() % LANES`
    /// tail runs through the scalar plan's matching view path
    /// ([`CompiledPlan::run_view_batch_into`], Fast mode). Unlike the
    /// row-major path there are **no padding rows at all** — partial
    /// batches execute only their real rows.
    pub fn run_view_batch_into<T: LaneElem>(
        &self,
        scalar: &CompiledPlan,
        rows: &[&[Vec<T>]],
        pad: T,
        scratch: &mut LaneScratch<T>,
        outs: &mut [&mut [T]],
    ) -> Result<(), PreconditionViolation> {
        self.check_tail_plan(scalar);
        assert_eq!(rows.len(), outs.len(), "{}: rows vs output buffers", self.name);
        let total = self.out_slot.len();
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), self.list_sizes.len(), "{}: row {r} list count", self.name);
            for (l, &cap) in self.list_sizes.iter().enumerate() {
                assert!(row[l].len() <= cap, "{}: row {r} list {l} exceeds device slot", self.name);
            }
            assert!(outs[r].len() <= total, "{}: row {r} output too wide", self.name);
        }
        let tier = active_tier();
        let tile = scratch.tile_mut(self.slots);
        let tiles = rows.len() / LANES;
        for t in 0..tiles {
            self.run_tile_view(tier, rows, t * LANES, pad, tile, outs);
        }
        let done = tiles * LANES;
        if done < rows.len() {
            scalar
                .run_view_batch_into(
                    &rows[done..],
                    pad,
                    ExecMode::Fast,
                    &mut scratch.tail,
                    &mut outs[done..],
                )
                .map_err(|e| e.offset_row(done))?;
        }
        Ok(())
    }

    /// Rank-then-permute batch executor — the key-value serving path.
    /// `rows[r]` is request `r`'s un-padded **key** lists (sorted, no
    /// longer than the device's `list_sizes`); `out_keys[r]` receives
    /// row `r`'s merged key prefix and `out_perm[r]` (same width) the
    /// **output permutation**: `out_perm[r][t]` is the index into row
    /// `r`'s concatenated list-major input column whose key landed at
    /// output rank `t`. Apply it to a payload column of the same
    /// concatenation order (`payload_out[t] = payload[perm[t]]`) to
    /// move every payload exactly once.
    ///
    /// Duplicate keys resolve by origin — list-major, i.e. the first
    /// list's occurrence wins ties, matching the scalar stable merge —
    /// so the emitted permutation is deterministic, and the key stream
    /// equals [`Self::run_view_batch_into`]'s output on the same rows.
    /// Full tiles run packed `u64` chunks; the tail runs the scalar
    /// plan's matching packed path
    /// ([`CompiledPlan::run_view_batch_perm_into`]).
    pub fn run_view_batch_perm_into(
        &self,
        scalar: &CompiledPlan,
        rows: &[&[Vec<u32>]],
        scratch: &mut LaneScratch<u64>,
        out_keys: &mut [&mut [u32]],
        out_perm: &mut [&mut [u32]],
    ) -> Result<(), PreconditionViolation> {
        self.check_tail_plan(scalar);
        assert_eq!(rows.len(), out_keys.len(), "{}: rows vs key buffers", self.name);
        assert_eq!(rows.len(), out_perm.len(), "{}: rows vs perm buffers", self.name);
        let total = self.out_slot.len();
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), self.list_sizes.len(), "{}: row {r} list count", self.name);
            for (l, &cap) in self.list_sizes.iter().enumerate() {
                assert!(row[l].len() <= cap, "{}: row {r} list {l} exceeds device slot", self.name);
            }
            assert!(out_keys[r].len() <= total, "{}: row {r} output too wide", self.name);
            assert_eq!(
                out_keys[r].len(),
                out_perm[r].len(),
                "{}: row {r} key/perm width mismatch",
                self.name
            );
        }
        let tier = active_tier();
        let tile = scratch.tile_mut(self.slots);
        let tiles = rows.len() / LANES;
        for t in 0..tiles {
            self.run_tile_view_perm(tier, rows, t * LANES, tile, out_keys, out_perm);
        }
        let done = tiles * LANES;
        if done < rows.len() {
            scalar
                .run_view_batch_perm_into(
                    &rows[done..],
                    &mut scratch.tail,
                    &mut out_keys[done..],
                    &mut out_perm[done..],
                )
                .map_err(|e| e.offset_row(done))?;
        }
        Ok(())
    }

    /// Slice-level batch executor: `lists[l]` is row-major
    /// `(batch, list_sizes[l])`, `dst` is `batch * total_outputs()` and
    /// fully overwritten. Full tiles run transposed; the `batch % LANES`
    /// tail runs through `scalar` ([`CompiledPlan::run_batch_into`],
    /// Fast mode). Infallible on admitted (sorted) inputs.
    pub fn run_batch_into<T: LaneElem>(
        &self,
        scalar: &CompiledPlan,
        lists: &[&[T]],
        batch: usize,
        scratch: &mut LaneScratch<T>,
        dst: &mut [T],
    ) -> Result<(), PreconditionViolation> {
        self.check_tail_plan(scalar);
        assert_eq!(lists.len(), self.list_sizes.len(), "{}: wrong list count", self.name);
        for (l, &s) in self.list_sizes.iter().enumerate() {
            assert_eq!(lists[l].len(), batch * s, "{}: list {l} flat length", self.name);
        }
        let outs = self.out_slot.len();
        assert_eq!(dst.len(), batch * outs, "{}: output buffer length", self.name);
        let tier = active_tier();
        let tile = scratch.tile_mut(self.slots);
        let tiles = batch / LANES;
        for t in 0..tiles {
            self.run_tile(
                tier,
                lists,
                t * LANES,
                tile,
                &mut dst[t * LANES * outs..(t + 1) * LANES * outs],
            );
        }
        let done = tiles * LANES;
        if done < batch {
            let tail: Vec<&[T]> =
                lists.iter().zip(&self.list_sizes).map(|(l, &s)| &l[done * s..]).collect();
            let tail_dst = &mut dst[done * outs..];
            scalar
                .run_batch_into(&tail, batch - done, ExecMode::Fast, &mut scratch.tail, tail_dst)
                .map_err(|e| e.offset_row(done))?;
        }
        Ok(())
    }

    /// Vec-append convenience over [`Self::run_batch_into`] — the same
    /// call shape as [`CompiledPlan::run_batch`].
    pub fn run_batch<T: LaneElem>(
        &self,
        scalar: &CompiledPlan,
        lists: &[Vec<T>],
        batch: usize,
        scratch: &mut LaneScratch<T>,
        out: &mut Vec<T>,
    ) -> Result<(), PreconditionViolation> {
        let slices: Vec<&[T]> = lists.iter().map(Vec::as_slice).collect();
        append_rows(out, batch, self.out_slot.len(), |dst| {
            self.run_batch_into(scalar, &slices, batch, scratch, dst)
        })
    }
}

/// Elementwise compare-exchange of two [`LANES`]-wide tile chunks: per
/// lane, `min → lo`, `max → hi`, through `tier`'s explicit kernel
/// ([`LaneElem::cas_chunks`]).
#[inline]
fn cas_lanes<T: LaneElem>(tier: SimdTier, tile: &mut [T], lo: usize, hi: usize) {
    debug_assert_ne!(lo, hi);
    let (lo_off, hi_off) = (lo * LANES, hi * LANES);
    let (x, y) = if lo_off < hi_off {
        let (head, tail) = tile.split_at_mut(hi_off);
        (&mut head[lo_off..lo_off + LANES], &mut tail[..LANES])
    } else {
        let (head, tail) = tile.split_at_mut(lo_off);
        (&mut tail[..LANES], &mut head[hi_off..hi_off + LANES])
    };
    let x: &mut [T; LANES] = x.try_into().expect("lo chunk is LANES wide");
    let y: &mut [T; LANES] = y.try_into().expect("hi chunk is LANES wide");
    T::cas_chunks(tier, x, y);
}

/// Pack a key with its origin for the rank-then-permute path: the key
/// occupies the high 32 bits (drives the ordering), the origin the low
/// 32 (breaks every tie deterministically — origins are distinct per
/// row, so packed elements are distinct and the comparator network's
/// output is the unique stable (key, origin) merge).
#[inline]
pub(crate) fn pack_kv(key: u32, origin: u32) -> u64 {
    (u64::from(key) << 32) | u64::from(origin)
}

/// Packed pad for unused key-value slots: sorts after every real
/// element (equality would need `key == u32::MAX` AND `origin ==
/// u32::MAX`; real origins are row ranks, far below `u32::MAX`).
pub(crate) const KV_PAD: u64 = u64::MAX;

/// Shard a batch across `threads` scoped OS threads: tile-aligned row
/// ranges (the `batch % LANES` tail rows land in the last non-empty
/// shard), one **pooled** [`LaneScratch`] per thread (taken at shard
/// start, returned at shard end — no per-call tile reallocation),
/// disjoint output slices. `threads <= 1` degrades to the
/// single-threaded executor.
pub fn run_batch_sharded<T: LaneElem>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    lists: &[Vec<T>],
    batch: usize,
    threads: usize,
    out: &mut Vec<T>,
) -> Result<(), PreconditionViolation> {
    if threads <= 1 {
        let mut scratch = LaneScratch::take();
        let res = lane.run_batch(scalar, lists, batch, &mut scratch, out);
        scratch.put();
        return res;
    }
    let outs = lane.total_outputs();
    let slices: Vec<&[T]> = lists.iter().map(Vec::as_slice).collect();
    // One shard per thread at most, at least one tile per shard; with no
    // full tile at all, a single shard just runs the scalar tail.
    let ranges = shard_ranges(batch, threads);
    let slices_ref = &slices;
    append_rows(out, batch, outs, |dst| {
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(ranges.len());
            let mut rest = dst;
            for &(lo, hi) in &ranges {
                let (chunk, tail) = rest.split_at_mut((hi - lo) * outs);
                rest = tail;
                handles.push(s.spawn(move || -> Result<(), PreconditionViolation> {
                    let shard: Vec<&[T]> = slices_ref
                        .iter()
                        .zip(lane.list_sizes())
                        .map(|(l, &sz)| &l[lo * sz..hi * sz])
                        .collect();
                    let mut scratch = LaneScratch::take();
                    let res = lane
                        .run_batch_into(scalar, &shard, hi - lo, &mut scratch, chunk)
                        .map_err(|e| e.offset_row(lo));
                    scratch.put();
                    res
                }));
            }
            let mut first_err = None;
            for h in handles {
                if let Err(e) = h.join().expect("lane shard panicked") {
                    first_err.get_or_insert(e);
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })
    })
}

/// Tile-aligned shard ranges for a `real`-row batch: at most `threads`
/// shards, at least one tile each, tail rows in the last shard.
fn shard_ranges(real: usize, threads: usize) -> Vec<(usize, usize)> {
    let tiles = real / LANES;
    let shards = if tiles == 0 { 1 } else { threads.min(tiles) };
    let tiles_per = tiles.div_ceil(shards);
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(shards);
    let mut row = 0usize;
    for i in 0..shards {
        let hi = if i == shards - 1 { real } else { ((i + 1) * tiles_per * LANES).min(real) };
        if hi > row {
            ranges.push((row, hi));
            row = hi;
        }
    }
    ranges
}

/// Shard the **view-based** (tile-direct) batch across `threads` scoped
/// OS threads: tile-aligned row ranges, one **pooled** [`LaneScratch`]
/// per thread, each shard writing its own disjoint sub-slice of the
/// per-row output buffers. `threads <= 1` degrades to the
/// single-threaded view executor. The view twin of
/// [`run_batch_sharded`].
pub fn run_view_batch_sharded<T: LaneElem>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<T>]],
    pad: T,
    threads: usize,
    outs: &mut [&mut [T]],
) -> Result<(), PreconditionViolation> {
    if threads <= 1 {
        let mut scratch = LaneScratch::take();
        let res = lane.run_view_batch_into(scalar, rows, pad, &mut scratch, outs);
        scratch.put();
        return res;
    }
    assert_eq!(rows.len(), outs.len(), "{}: rows vs output buffers", lane.name);
    let ranges = shard_ranges(rows.len(), threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ranges.len());
        let mut rest = outs;
        for &(lo, hi) in &ranges {
            let (chunk, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let shard_rows = &rows[lo..hi];
            handles.push(s.spawn(move || -> Result<(), PreconditionViolation> {
                let mut scratch = LaneScratch::take();
                let res = lane
                    .run_view_batch_into(scalar, shard_rows, pad, &mut scratch, chunk)
                    .map_err(|e| e.offset_row(lo));
                scratch.put();
                res
            }));
        }
        let mut first_err = None;
        for h in handles {
            if let Err(e) = h.join().expect("lane view shard panicked") {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// Shard the rank-then-permute batch across `threads` scoped OS
/// threads — the key-value twin of [`run_view_batch_sharded`], with
/// both the key and permutation output arrays split into the same
/// disjoint shard sub-slices.
pub fn run_view_batch_perm_sharded(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<u32>]],
    threads: usize,
    out_keys: &mut [&mut [u32]],
    out_perm: &mut [&mut [u32]],
) -> Result<(), PreconditionViolation> {
    if threads <= 1 {
        let mut scratch = LaneScratch::take();
        let res = lane.run_view_batch_perm_into(scalar, rows, &mut scratch, out_keys, out_perm);
        scratch.put();
        return res;
    }
    assert_eq!(rows.len(), out_keys.len(), "{}: rows vs key buffers", lane.name);
    assert_eq!(rows.len(), out_perm.len(), "{}: rows vs perm buffers", lane.name);
    let ranges = shard_ranges(rows.len(), threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ranges.len());
        let mut rest_keys = out_keys;
        let mut rest_perm = out_perm;
        for &(lo, hi) in &ranges {
            let (key_chunk, key_tail) = rest_keys.split_at_mut(hi - lo);
            let (perm_chunk, perm_tail) = rest_perm.split_at_mut(hi - lo);
            rest_keys = key_tail;
            rest_perm = perm_tail;
            let shard_rows = &rows[lo..hi];
            handles.push(s.spawn(move || -> Result<(), PreconditionViolation> {
                let mut scratch = LaneScratch::take();
                let res = lane
                    .run_view_batch_perm_into(
                        scalar,
                        shard_rows,
                        &mut scratch,
                        key_chunk,
                        perm_chunk,
                    )
                    .map_err(|e| e.offset_row(lo));
                scratch.put();
                res
            }));
        }
        let mut first_err = None;
        for h in handles {
            if let Err(e) = h.join().expect("lane perm shard panicked") {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })
}

/// View-based batch execution with the standard shard policy applied:
/// shards across cores when [`auto_threads`] says the batch amortizes
/// thread spawn, otherwise runs single-threaded on the caller's
/// `scratch`. The one entry point shared by every tile-direct consumer
/// — [`crate::coordinator::SoftwareBackend`]'s serving path and the
/// streaming merge engine's block kernel
/// ([`crate::stream::merge2::BlockKernel`]) — so the policy lives in
/// exactly one place.
pub fn run_view_batch_auto<T: LaneElem>(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<T>]],
    pad: T,
    scratch: &mut LaneScratch<T>,
    outs: &mut [&mut [T]],
) -> Result<(), PreconditionViolation> {
    let threads = auto_threads(rows.len(), scalar.n());
    if threads > 1 {
        run_view_batch_sharded(lane, scalar, rows, pad, threads, outs)
    } else {
        lane.run_view_batch_into(scalar, rows, pad, scratch, outs)
    }
}

/// Rank-then-permute batch execution under the same shard policy —
/// the key-value twin of [`run_view_batch_auto`], shared by the
/// serving backend and the streaming key-value kernel.
pub fn run_view_batch_perm_auto(
    lane: &LanePlan,
    scalar: &CompiledPlan,
    rows: &[&[Vec<u32>]],
    scratch: &mut LaneScratch<u64>,
    out_keys: &mut [&mut [u32]],
    out_perm: &mut [&mut [u32]],
) -> Result<(), PreconditionViolation> {
    let threads = auto_threads(rows.len(), scalar.n());
    if threads > 1 {
        run_view_batch_perm_sharded(lane, scalar, rows, threads, out_keys, out_perm)
    } else {
        lane.run_view_batch_perm_into(scalar, rows, scratch, out_keys, out_perm)
    }
}

/// Shard-count policy for [`crate::coordinator::SoftwareBackend`]: one
/// shard per core, but only when every shard gets at least two full
/// tiles AND each shard carries enough values (`batch * row_values`) to
/// amortize thread spawn (~tens of µs). Small serving batches (e.g.
/// 256 × 64 values) stay single-threaded on purpose.
pub fn auto_threads(batch: usize, row_values: usize) -> usize {
    const MIN_VALUES_PER_SHARD: usize = 1 << 15;
    let by_work = batch.saturating_mul(row_values) / MIN_VALUES_PER_SHARD;
    let cap = by_work.min(forced_threads(batch));
    if cap <= 1 {
        return 1;
    }
    cap
}

/// Thread count the benches/figure harness uses to *force* sharding on
/// a shape regardless of [`auto_threads`]' work floor (so the
/// lanes+threads variant is measured even where the backend would stay
/// inline): every core, capped so each shard still gets at least two
/// full tiles.
pub fn forced_threads(batch: usize) -> usize {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    cores.min((batch / (2 * LANES)).max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::loms::{loms_2way, loms_3way_median, loms_kway};
    use crate::sortnet::mwms::mwms_3way;
    use crate::sortnet::s2ms;
    use crate::util::Rng;

    fn flat_batch(rng: &mut Rng, sizes: &[usize], batch: usize, max: u32) -> Vec<Vec<u32>> {
        sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::with_capacity(batch * s);
                for _ in 0..batch {
                    flat.extend(rng.sorted_list(s, max));
                }
                flat
            })
            .collect()
    }

    fn scalar_outputs(plan: &CompiledPlan, lists: &[Vec<u32>], batch: usize) -> Vec<u32> {
        let mut out = Vec::new();
        plan.run_batch(lists, batch, ExecMode::Fast, &mut PlanScratch::new(), &mut out).unwrap();
        out
    }

    #[test]
    fn merge_network_is_correct_for_all_run_lengths() {
        // Exhaustive sorted-0-1 check of the general odd-even merge: for
        // every (a, b) up to 9×9 and every zero split, the emitted CAS
        // schedule must leave the rank-order slots sorted.
        for a in 0..=9usize {
            for b in 0..=9usize {
                if a + b == 0 {
                    continue;
                }
                let slots: Vec<u32> = (0..(a + b) as u32).collect();
                let mut ops = Vec::new();
                let w = emit_merge(&slots[..a], &slots[a..], &mut ops);
                assert_eq!(w.len(), a + b, "a={a} b={b}");
                for za in 0..=a {
                    for zb in 0..=b {
                        let mut v: Vec<u32> = (0..a).map(|i| u32::from(i >= za)).collect();
                        v.extend((0..b).map(|j| u32::from(j >= zb)));
                        for op in &ops {
                            let LaneOp::Cas { lo, hi } = *op else { unreachable!() };
                            let (x, y) = (v[lo as usize], v[hi as usize]);
                            v[lo as usize] = x.min(y);
                            v[hi as usize] = x.max(y);
                        }
                        let got: Vec<u32> = w.iter().map(|&s| v[s as usize]).collect();
                        assert!(
                            got.windows(2).all(|p| p[0] <= p[1]),
                            "a={a} b={b} za={za} zb={zb}: {got:?}"
                        );
                        assert_eq!(got.iter().filter(|&&x| x == 0).count(), za + zb);
                    }
                }
            }
        }
    }

    #[test]
    fn sorter_network_sorts_all_01_inputs() {
        for n in 1..=8usize {
            let slots: Vec<u32> = (0..n as u32).collect();
            let mut ops = Vec::new();
            let w = emit_sorter(&slots, &mut ops);
            assert_eq!(w.len(), n);
            for pattern in 0..(1u32 << n) {
                let mut v: Vec<u32> = (0..n).map(|i| (pattern >> i) & 1).collect();
                for op in &ops {
                    let LaneOp::Cas { lo, hi } = *op else { unreachable!() };
                    let (x, y) = (v[lo as usize], v[hi as usize]);
                    v[lo as usize] = x.min(y);
                    v[hi as usize] = x.max(y);
                }
                let got: Vec<u32> = w.iter().map(|&s| v[s as usize]).collect();
                assert!(got.windows(2).all(|p| p[0] <= p[1]), "n={n} pattern={pattern:b}");
            }
        }
    }

    #[test]
    fn lane_plan_matches_scalar_on_random_batches() {
        let mut rng = Rng::new(0x1A7E5);
        for d in [
            loms_2way(8, 8, 2),
            loms_2way(7, 5, 3),
            loms_kway(&[7, 7, 7]),
            s2ms::s2ms(6, 6),
            s2ms::s2ms(1, 9),
            crate::sortnet::batcher::odd_even_merge(8),
            mwms_3way(5),
        ] {
            let plan = CompiledPlan::compile(&d).unwrap();
            let lane = LanePlan::compile(&plan);
            assert_eq!(lane.total_outputs(), plan.total_outputs(), "{}", d.name);
            for batch in [1usize, LANES - 1, LANES, 2 * LANES + 5] {
                let lists = flat_batch(&mut rng, &d.list_sizes, batch, 10_000);
                let want = scalar_outputs(&plan, &lists, batch);
                let mut got = Vec::new();
                lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got)
                    .unwrap();
                assert_eq!(got, want, "{} batch={batch}", d.name);
            }
        }
    }

    #[test]
    fn pruned_filter_blocks_expand_with_shadow_slots() {
        // Pruned MWMS carries FilterN blocks; the lane expansion must add
        // shadow slots and a strictly smaller network than the full sort.
        let d = mwms_3way(5);
        let pruned = CompiledPlan::compile_pruned(&d).unwrap();
        assert!(pruned.removed_muxes() > 0);
        let lane = LanePlan::compile(&pruned);
        // Shadow slots appear exactly when the pruned plan carries
        // FilterN blocks (partially-pruned sorters), and each shadow
        // slot in a tap cone is fed by one copy.
        assert_eq!(lane.slots() > lane.n(), lane.copy_count() > 0);
        let unpruned_lane = LanePlan::compile(&CompiledPlan::compile(&d).unwrap());
        assert!(
            lane.cas_count() <= unpruned_lane.cas_count(),
            "pruning must not grow the CAS schedule ({} vs {})",
            lane.cas_count(),
            unpruned_lane.cas_count()
        );
        let mut rng = Rng::new(77);
        let batch = LANES + 3;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 500);
        let want = scalar_outputs(&pruned, &lists, batch);
        let mut got = Vec::new();
        lane.run_batch(&pruned, &lists, batch, &mut LaneScratch::new(), &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn native_filter_device_keeps_stale_positions() {
        // loms_3way_median builds a FilterN natively (not via pruning):
        // untapped outputs stay stale, and the scalar plan's full-merge
        // output reflects that. The lane plan must agree exactly.
        let d = loms_3way_median(5);
        let plan = CompiledPlan::compile(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(5);
        let batch = 2 * LANES + 1;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 99);
        let want = scalar_outputs(&plan, &lists, batch);
        let mut got = Vec::new();
        lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn sharded_matches_single_thread_and_offsets_rows() {
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0x5AAD);
        let batch = 5 * LANES + 11;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 1 << 20);
        let want = scalar_outputs(&plan, &lists, batch);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut got = Vec::new();
            run_batch_sharded(&lane, &plan, &lists, batch, threads, &mut got).unwrap();
            assert_eq!(got, want, "threads={threads}");
        }
    }

    /// Ragged random requests for a device: per-row lists each at most
    /// the device slot size.
    fn ragged_rows(rng: &mut Rng, sizes: &[usize], real: usize, max: u32) -> Vec<Vec<Vec<u32>>> {
        (0..real)
            .map(|_| {
                sizes
                    .iter()
                    .map(|&cap| {
                        let len = rng.range(1, cap + 1);
                        rng.sorted_list(len, max)
                    })
                    .collect()
            })
            .collect()
    }

    /// The old assemble-then-execute reference: pad each request to the
    /// device shape, run the row-major lane batch, slice real prefixes.
    fn padded_reference(
        lane: &LanePlan,
        plan: &CompiledPlan,
        reqs: &[Vec<Vec<u32>>],
        pad: u32,
    ) -> Vec<Vec<u32>> {
        let sizes = lane.list_sizes().to_vec();
        let lists: Vec<Vec<u32>> = (0..sizes.len())
            .map(|l| {
                let mut flat = Vec::new();
                for r in reqs {
                    flat.extend_from_slice(&r[l]);
                    flat.resize(flat.len() + (sizes[l] - r[l].len()), pad);
                }
                flat
            })
            .collect();
        let mut out = Vec::new();
        lane.run_batch(plan, &lists, reqs.len(), &mut LaneScratch::new(), &mut out).unwrap();
        let total = lane.total_outputs();
        reqs.iter()
            .enumerate()
            .map(|(row, r)| {
                let want: usize = r.iter().map(Vec::len).sum();
                out[row * total..row * total + want].to_vec()
            })
            .collect()
    }

    #[test]
    fn view_path_matches_padded_row_major_path() {
        // The tile-direct path (ragged views, inline pad fill, per-row
        // gather) must be byte-exact with assemble-then-execute across
        // tile boundaries: tail-only, exact tiles, tiles + tail.
        const PAD: u32 = u32::MAX;
        let mut rng = Rng::new(0x71D1);
        for d in [loms_2way(8, 8, 2), loms_2way(7, 5, 3), loms_kway(&[7, 7, 7]), s2ms::s2ms(6, 6)]
        {
            let plan = CompiledPlan::compile_auto(&d).unwrap();
            let lane = LanePlan::compile(&plan);
            for real in [1usize, LANES - 1, LANES, 2 * LANES, 2 * LANES + 5] {
                let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
                let want = padded_reference(&lane, &plan, &reqs, PAD);
                let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
                let mut merged: Vec<Vec<u32>> = reqs
                    .iter()
                    .map(|r| vec![0u32; r.iter().map(Vec::len).sum()])
                    .collect();
                let mut outs: Vec<&mut [u32]> =
                    merged.iter_mut().map(|v| v.as_mut_slice()).collect();
                lane.run_view_batch_into(&plan, &rows, PAD, &mut LaneScratch::new(), &mut outs)
                    .unwrap();
                assert_eq!(merged, want, "{} real={real}", d.name);
            }
        }
    }

    #[test]
    fn sharded_view_path_matches_single_thread() {
        const PAD: u32 = u32::MAX;
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0x5A4D);
        let real = 5 * LANES + 11;
        let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
        let want = padded_reference(&lane, &plan, &reqs, PAD);
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            run_view_batch_sharded(&lane, &plan, &rows, PAD, threads, &mut outs).unwrap();
            assert_eq!(merged, want, "threads={threads}");
        }
    }

    #[test]
    fn auto_view_path_matches_explicit_paths() {
        // run_view_batch_auto must be byte-exact with the explicit view
        // executors on both sides of the shard threshold.
        const PAD: u32 = u32::MAX;
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0xA07);
        for real in [3usize, 4 * LANES + 7] {
            let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 1 << 20);
            let want = padded_reference(&lane, &plan, &reqs, PAD);
            let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            run_view_batch_auto(&lane, &plan, &rows, PAD, &mut LaneScratch::new(), &mut outs)
                .unwrap();
            assert_eq!(merged, want, "real={real}");
        }
    }

    #[test]
    fn auto_threads_policy_bounds() {
        // Too few tiles or too little work: stay single-threaded.
        assert_eq!(auto_threads(LANES, 1 << 20), 1);
        assert_eq!(auto_threads(256, 64), 1, "serving shape b256×64 stays inline");
        // Huge batches may shard (bounded by core count, so only ≥ 1 is
        // portable to assert).
        assert!(auto_threads(1 << 16, 512) >= 1);
        assert!(auto_threads(1 << 16, 512) <= std::thread::available_parallelism().unwrap().get());
    }

    #[test]
    fn schedule_is_pure_cas_plus_filter_copies() {
        // Families without FilterN lower to a copy-free pure CAS stream.
        for d in [loms_2way(8, 8, 2), s2ms::s2ms(8, 8), loms_kway(&[3, 3, 3, 3])] {
            let lane = LanePlan::compile(&CompiledPlan::compile(&d).unwrap());
            assert_eq!(lane.copy_count(), 0, "{}", d.name);
            assert!(lane.cas_count() > 0, "{}", d.name);
            assert_eq!(lane.slots(), lane.n(), "{}", d.name);
        }
    }

    #[test]
    fn tile_chunks_are_cache_line_aligned() {
        let mut s32: LaneScratch<u32> = LaneScratch::new();
        let t = s32.tile_mut(7);
        assert_eq!(t.len(), 7 * LANES);
        assert_eq!(t.as_ptr() as usize % 64, 0, "u32 tile base must be 64B aligned");
        let mut s64: LaneScratch<u64> = LaneScratch::new();
        let t = s64.tile_mut(5);
        assert_eq!(t.len(), 5 * LANES);
        assert_eq!(t.as_ptr() as usize % 64, 0, "u64 tile base must be 64B aligned");
        // Growing keeps contiguity and alignment.
        let t = s64.tile_mut(11);
        assert_eq!(t.len(), 11 * LANES);
        assert_eq!(t.as_ptr() as usize % 64, 0);
    }

    #[test]
    fn scratch_pool_recycles() {
        let mut s: LaneScratch<u32> = LaneScratch::take();
        s.tile_mut(3)[0] = 7;
        s.put();
        // The pooled scratch comes back with its allocation intact.
        let mut again: LaneScratch<u32> = LaneScratch::take();
        let _ = again.tile_mut(3);
        again.put();
    }

    #[test]
    fn every_available_tier_matches_the_scalar_plan() {
        // The dispatch differential in miniature (the full artifact
        // sweep lives in rust/tests/simd_dispatch.rs): every tier this
        // host can run must be byte-exact with CompiledPlan::run_batch.
        let mut rng = Rng::new(0x51D);
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let batch = 2 * LANES + 5;
        let lists = flat_batch(&mut rng, &d.list_sizes, batch, 1 << 20);
        let want = scalar_outputs(&plan, &lists, batch);
        for tier in available_tiers() {
            assert!(force_tier(Some(tier)), "{tier:?} reported available");
            assert_eq!(active_tier(), tier);
            let mut got = Vec::new();
            lane.run_batch(&plan, &lists, batch, &mut LaneScratch::new(), &mut got).unwrap();
            assert_eq!(got, want, "{tier:?}");
        }
        force_tier(None);
    }

    #[test]
    fn forcing_an_unavailable_tier_is_refused() {
        let all = [SimdTier::Scalar, SimdTier::Portable, SimdTier::Avx2, SimdTier::Neon];
        for t in all {
            if !t.available() {
                assert!(!force_tier(Some(t)), "{t:?}");
            }
        }
        assert!(force_tier(None));
        // Parsing covers the documented spellings, case-insensitively.
        assert_eq!(SimdTier::parse("AVX2"), Some(SimdTier::Avx2));
        assert_eq!(SimdTier::parse("portable"), Some(SimdTier::Portable));
        assert_eq!(SimdTier::parse("nope"), None);
    }

    /// Stable (key, origin) reference for the rank-then-permute path:
    /// sort the concatenated (key, origin) pairs of one row.
    fn perm_reference(row: &[Vec<u32>]) -> (Vec<u32>, Vec<u32>) {
        let mut pairs: Vec<(u32, u32)> = Vec::new();
        for list in row {
            for &k in list {
                pairs.push((k, pairs.len() as u32));
            }
        }
        pairs.sort_unstable(); // distinct (key, origin) pairs: total order
        (pairs.iter().map(|p| p.0).collect(), pairs.iter().map(|p| p.1).collect())
    }

    #[test]
    fn perm_path_emits_the_stable_permutation() {
        // Duplicate-heavy rows across tile boundaries: merged keys must
        // equal the key-only path and the permutation must be the
        // stable list-major order, on every available tier.
        let mut rng = Rng::new(0x4B56);
        for d in [loms_2way(8, 8, 2), loms_2way(7, 5, 3), loms_kway(&[7, 7, 7])] {
            let plan = CompiledPlan::compile_auto(&d).unwrap();
            let lane = LanePlan::compile(&plan);
            for real in [1usize, LANES - 1, LANES, 2 * LANES + 5] {
                // max = 8 forces heavy key duplication.
                let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 8);
                let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
                let widths: Vec<usize> =
                    reqs.iter().map(|r| r.iter().map(Vec::len).sum()).collect();
                for tier in available_tiers() {
                    assert!(force_tier(Some(tier)));
                    let mut keys: Vec<Vec<u32>> =
                        widths.iter().map(|&w| vec![0u32; w]).collect();
                    let mut perms: Vec<Vec<u32>> =
                        widths.iter().map(|&w| vec![0u32; w]).collect();
                    let mut key_outs: Vec<&mut [u32]> =
                        keys.iter_mut().map(|v| v.as_mut_slice()).collect();
                    let mut perm_outs: Vec<&mut [u32]> =
                        perms.iter_mut().map(|v| v.as_mut_slice()).collect();
                    lane.run_view_batch_perm_into(
                        &plan,
                        &rows,
                        &mut LaneScratch::new(),
                        &mut key_outs,
                        &mut perm_outs,
                    )
                    .unwrap();
                    for (r, req) in reqs.iter().enumerate() {
                        let (want_keys, want_perm) = perm_reference(req);
                        assert_eq!(keys[r], want_keys, "{} row {r} {tier:?}", d.name);
                        assert_eq!(perms[r], want_perm, "{} row {r} {tier:?}", d.name);
                    }
                }
                force_tier(None);
            }
        }
    }

    #[test]
    fn sharded_perm_path_matches_single_thread() {
        let d = loms_2way(8, 8, 2);
        let plan = CompiledPlan::compile_auto(&d).unwrap();
        let lane = LanePlan::compile(&plan);
        let mut rng = Rng::new(0x9E12);
        let real = 5 * LANES + 11;
        let reqs = ragged_rows(&mut rng, &d.list_sizes, real, 16);
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        let widths: Vec<usize> = reqs.iter().map(|r| r.iter().map(Vec::len).sum()).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            let mut keys: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
            let mut perms: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
            let mut key_outs: Vec<&mut [u32]> =
                keys.iter_mut().map(|v| v.as_mut_slice()).collect();
            let mut perm_outs: Vec<&mut [u32]> =
                perms.iter_mut().map(|v| v.as_mut_slice()).collect();
            run_view_batch_perm_sharded(&lane, &plan, &rows, threads, &mut key_outs, &mut perm_outs)
                .unwrap();
            for (r, req) in reqs.iter().enumerate() {
                let (want_keys, want_perm) = perm_reference(req);
                assert_eq!(keys[r], want_keys, "row {r} threads={threads}");
                assert_eq!(perms[r], want_perm, "row {r} threads={threads}");
            }
        }
    }
}
