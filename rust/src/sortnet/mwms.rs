//! Multiway Merge Sorting Network (MWMS) baseline — a reconstruction of
//! the state-of-the-art 3-way merge devices of Kent/Pattichis [4][5].
//!
//! The original paper was not available in this environment; what the
//! LOMS paper uses from it is (a) the device class — networks of
//! single-stage N-sorters / N-filters over a k-column array with the
//! input lists placed *without* the list offset — and (b) the stage
//! counts for the 3c_7r comparison: **5 stages for a full merge, 4 for
//! the median** (§VII-D). This module reconstructs the device class:
//! each list is its own (pre-sorted) column, and alternating full
//! row-sort / column-sort stages run until the array is provably sorted,
//! with the schedule *discovered by exhaustive sorted-0-1 validation*.
//!
//! Reconstruction gap (documented): our best validated full-sort
//! schedule for 3c_7r needs **6** stages (median: 5) — an exhaustive
//! search over full row/column-sort schedules with every row-direction
//! convention found no 5-stage solution — while the authors'
//! proprietary MWMS achieves 5 (median: 4). To avoid flattering LOMS in the Fig. 18–20 comparisons,
//! the FPGA cost model prices the MWMS baseline with the *paper's*
//! stage counts — see [`paper_stage_counts`] — while the executable
//! network keeps the validated 6-stage schedule. EXPERIMENTS.md §F18
//! carries the note.

use super::network::{Block, DeviceKind, MergeDevice, Stage};
use super::validate::{validate_median_01, validate_merge_01};

/// Build the alternating-stage MWMS 3-way device with `t` stages (row
/// sorts first — the columns are the input lists, already sorted).
fn mwms_3way_with_stages(r: usize, t: usize) -> MergeDevice {
    let k = 3usize;
    let total = k * r;
    // Grid: list l occupies column k-1-l (list 0 leftmost, matching the
    // paper's A,B,C left-to-right figures), value i at row i.
    // Flat positions in serpentine scan order (identity output_perm).
    let pos_of = |row: usize, col: usize| -> usize {
        let off = if row % 2 == 1 { col } else { k - 1 - col };
        row * k + off
    };
    let mut input_map: Vec<Vec<usize>> = Vec::with_capacity(k);
    for l in 0..k {
        let col = k - 1 - l;
        input_map.push((0..r).map(|i| pos_of(i, col)).collect());
    }
    let row_stage = |label: &str| {
        Stage::new(
            label,
            (0..r)
                .map(|row| {
                    // Serpentine ascending order = ascending flat positions.
                    Block::SortN { pos: (row * k..row * k + k).collect() }
                })
                .collect(),
        )
    };
    let col_stage = |label: &str| {
        Stage::new(
            label,
            (0..k)
                .map(|col| Block::SortN { pos: (0..r).map(|row| pos_of(row, col)).collect() })
                .collect(),
        )
    };
    let stages: Vec<Stage> = (0..t)
        .map(|s| if s % 2 == 0 { row_stage("row-sort") } else { col_stage("col-sort") })
        .collect();
    MergeDevice {
        name: format!("mwms3-{r}r-{t}st"),
        kind: DeviceKind::Mwms,
        list_sizes: vec![r; k],
        input_map,
        n: total,
        stages,
        output_perm: (0..total).collect(),
        median_tap: None,
        grid: Some((k, r)),
    }
}

/// The stage counts the paper states for the authors' MWMS 3c_7r
/// devices: (full merge, median) = (5, 4). Used by the FPGA cost model
/// so the baseline is priced as published, not as our (slightly deeper)
/// reconstruction executes.
pub fn paper_stage_counts() -> (usize, usize) {
    (5, 4)
}

/// Minimal validated stage count for an MWMS 3-way full merge of three
/// `r`-value lists.
pub fn mwms_3way_min_stages(r: usize) -> usize {
    for t in 1..=16 {
        let d = mwms_3way_with_stages(r, t);
        if validate_merge_01(&d).is_ok() {
            return t;
        }
    }
    panic!("mwms 3-way r={r}: no schedule up to 16 stages validated");
}

/// The MWMS 3-way full-merge baseline (minimal validated schedule; the
/// paper's 3c_7r device has 5 stages and tests pin that).
pub fn mwms_3way(r: usize) -> MergeDevice {
    mwms_3way_with_stages(r, mwms_3way_min_stages(r))
}

/// The MWMS 3-way *median* baseline: the shortest prefix of the
/// alternating schedule whose final stage is replaced by a single
/// N-filter tapping the centre cell, validated to deliver the true
/// median (the paper's 3c_7r median device has 4 stages).
pub fn mwms_3way_median(r: usize) -> MergeDevice {
    assert!(r % 2 == 1, "median device needs odd list size");
    let k = 3usize;
    let total = k * r;
    let centre = total / 2;
    for t in 1..=16 {
        let mut d = mwms_3way_with_stages(r, t);
        // Replace the last stage's blocks with the single filter that
        // covers the centre cell (row filter on odd stage index parity
        // handled implicitly: keep only the block containing `centre`,
        // demoted to an N-filter).
        let last = d.stages.len() - 1;
        let keep: Vec<Block> = d.stages[last]
            .blocks
            .iter()
            .filter(|b| b.reads().contains(&centre))
            .map(|b| match b {
                Block::SortN { pos } => {
                    let tap = pos.iter().position(|&p| p == centre).unwrap();
                    Block::FilterN { pos: pos.clone(), taps: vec![tap] }
                }
                other => other.clone(),
            })
            .collect();
        d.stages[last] = Stage::new("median-filter", keep);
        d.median_tap = Some((d.stages.len(), centre));
        d.name = format!("mwms3-median-{r}r-{t}st");
        if validate_median_01(&d).is_ok() {
            return d;
        }
    }
    panic!("mwms 3-way median r={r}: no schedule up to 16 stages validated");
}

/// Cost-model proxy for the authors' MWMS device: our reconstruction's
/// stage composition truncated to the *paper's* stage count (full merge:
/// 5 = 3 row-sort + 2 column-sort stages for 3c_7r). NOT functionally a
/// complete merge — used only to price the baseline as published in the
/// Fig. 18–20 comparisons (see module docs for the reconstruction gap).
pub fn mwms_3way_cost_proxy(r: usize) -> MergeDevice {
    let (full, _) = paper_stage_counts();
    let mut d = mwms_3way_with_stages(r, full);
    d.name = format!("mwms3-{r}r-paper-cost-proxy");
    d
}

/// Cost proxy for the paper's 4-stage MWMS median device: 3 alternating
/// full-sort stages + one centre N-filter.
pub fn mwms_3way_median_cost_proxy(r: usize) -> MergeDevice {
    let (_, med) = paper_stage_counts();
    let mut d = mwms_3way_with_stages(r, med);
    let total = 3 * r;
    let centre = total / 2;
    let last = d.stages.len() - 1;
    let keep: Vec<Block> = d.stages[last]
        .blocks
        .iter()
        .filter(|b| b.reads().contains(&centre))
        .map(|b| match b {
            Block::SortN { pos } => {
                let tap = pos.iter().position(|&p| p == centre).unwrap();
                Block::FilterN { pos: pos.clone(), taps: vec![tap] }
            }
            other => other.clone(),
        })
        .collect();
    d.stages[last] = Stage::new("median-filter", keep);
    d.median_tap = Some((d.stages.len(), centre));
    d.name = format!("mwms3-median-{r}r-paper-cost-proxy");
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{merge, ExecMode};

    #[test]
    fn mwms_3c7r_stage_counts() {
        // §VII-D states 5 full / 4 median for the authors' devices; our
        // validated reconstruction needs one extra full-sort stage (see
        // module docs). Pin both facts.
        assert_eq!(paper_stage_counts(), (5, 4));
        assert_eq!(mwms_3way_min_stages(7), 6);
        assert_eq!(mwms_3way_median(7).depth(), 5);
    }

    #[test]
    fn mwms_full_merges() {
        let d = mwms_3way(7);
        let out = merge(
            &d,
            &[
                (1..=7).collect::<Vec<u32>>(),
                (8..=14).collect::<Vec<u32>>(),
                (15..=21).collect::<Vec<u32>>(),
            ],
            ExecMode::Strict,
        )
        .unwrap();
        assert_eq!(out, (1..=21).collect::<Vec<u32>>());
    }

    #[test]
    fn mwms_median_correct() {
        let d = mwms_3way_median(7);
        validate_median_01(&d).unwrap();
    }

    #[test]
    fn mwms_other_sizes_validate() {
        for r in [3usize, 5] {
            let d = mwms_3way(r);
            validate_merge_01(&d).unwrap();
            let m = mwms_3way_median(r);
            validate_median_01(&m).unwrap();
        }
    }

    #[test]
    fn mwms_uses_more_stages_than_loms() {
        // The paper's core 3-way claim: LOMS needs 3 stages (2 for the
        // median) where MWMS needs 5 (4).
        use crate::sortnet::loms::loms_kway;
        let loms = loms_kway(&[7, 7, 7]);
        let mwms = mwms_3way(7);
        assert!(loms.depth() < mwms.depth(), "loms {} vs mwms {}", loms.depth(), mwms.depth());
    }
}
