//! List Offset Merge Sorters — the paper's primary contribution.
//!
//! A LOMS device arranges k sorted input lists in a 2-D *setup array*
//! with each list's order offset from the previous list's, then runs a
//! minimal sequence of alternating column-sort / row-sort stages:
//!
//! * 2-way (§IV): any two list sizes, any column count C ≥ 2; exactly
//!   2 stages — parallel S2MS column merges, then parallel row sorts.
//! * k-way (§V, Appendix A): k lists in k columns; stage counts per
//!   Table 1 (k=3 → 3 stages; the 3rd stage for full-grid 3-way devices
//!   sorts only vertical pairs in the edge columns, as in Fig. 6).
//! * Median tap (§V-A): for equal odd list sizes the output median is
//!   final after only 2 stages.
//!
//! Conventions (paper-faithful): row 0 is the **bottom** row, column 0 is
//! the **rightmost** column. Values ascend bottom-to-top. Flat positions
//! are assigned in final-output scan order, so `output_perm` is the
//! identity: 2-way scans rows bottom-up with the row minimum at Col 0;
//! k-way (k ≥ 3) scans serpentine — even rows minimum at Col 0, odd rows
//! minimum at Col k-1 (Fig. 5).

use super::network::{Block, DeviceKind, MergeDevice, Stage};

/// One populated cell of a setup array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    /// Which input list the cell's value comes from.
    pub list: usize,
    /// Ascending index of the value within its list (0 = minimum).
    pub idx: usize,
    /// Flat position in the device's value vector (= final output rank
    /// slot of this grid location).
    pub pos: usize,
}

/// A constructed setup array: `grid[row][col]`, row 0 = bottom,
/// col 0 = rightmost. `None` = unpopulated cell (only in bottom rows).
#[derive(Debug, Clone)]
pub struct SetupArray {
    pub rows: usize,
    pub cols: usize,
    pub grid: Vec<Vec<Option<Cell>>>,
    /// True for k≥3 devices: output scan is serpentine.
    pub serpentine: bool,
    pub list_sizes: Vec<usize>,
}

impl SetupArray {
    /// Flat-position scan order of a (row, col) cell; the order used to
    /// number positions. 2-way: within every row ascending ranks run from
    /// Col 0 leftward. Serpentine: odd rows run from Col k-1 rightward.
    fn scan_cols(&self, row: usize) -> Vec<usize> {
        if self.serpentine && row % 2 == 1 {
            (0..self.cols).rev().collect()
        } else {
            (0..self.cols).collect()
        }
    }

    /// Number of populated cells.
    pub fn n_values(&self) -> usize {
        self.list_sizes.iter().sum()
    }

    /// `input_map[l][i]` = flat position of list l's i-th smallest value.
    pub fn input_map(&self) -> Vec<Vec<usize>> {
        let mut map: Vec<Vec<usize>> = self.list_sizes.iter().map(|&s| vec![usize::MAX; s]).collect();
        for row in &self.grid {
            for cell in row.iter().flatten() {
                map[cell.list][cell.idx] = cell.pos;
            }
        }
        debug_assert!(map.iter().flatten().all(|&p| p != usize::MAX));
        map
    }

    /// Cells of column `c`, bottom row first.
    pub fn column(&self, c: usize) -> Vec<Cell> {
        (0..self.rows).filter_map(|r| self.grid[r][c]).collect()
    }

    /// Cells of row `r` in ascending-rank scan order.
    pub fn row_scan(&self, r: usize) -> Vec<Cell> {
        self.scan_cols(r).into_iter().filter_map(|c| self.grid[r][c]).collect()
    }
}

/// Build the §IV 2-way setup array: UP list `m` values, DN list `n`
/// values, `cols` columns. The UP (A) list fills the top rows row-major
/// descending left-to-right; the DN (B) list fills the bottom rows
/// row-major descending right-to-left (the "offset"); unpopulated cells
/// then slide to the bottom of each column and fully-empty rows vanish.
pub fn setup_2way(m: usize, n: usize, cols: usize) -> SetupArray {
    assert!(cols >= 2, "LOMS needs at least 2 columns");
    assert!(m + n >= 1);
    let ra = m.div_ceil(cols);
    let rb = n.div_ceil(cols);
    let r0 = ra + rb;
    // (row, col) -> (list, idx), staged grid before sliding.
    let mut grid: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; cols]; r0];
    // A: descending rank d (0 = max = index m-1): row r0-1 - d/cols,
    // col cols-1 - d%cols (fills each row left to right).
    for d in 0..m {
        let (r, c) = (r0 - 1 - d / cols, cols - 1 - d % cols);
        grid[r][c] = Some((0, m - 1 - d));
    }
    // B: descending rank d: row rb-1 - d/cols, col d%cols (fills each
    // row right to left — the list-offset reversal).
    for d in 0..n {
        let (r, c) = (rb - 1 - d / cols, d % cols);
        grid[r][c] = Some((1, n - 1 - d));
    }
    finish_setup(grid, cols, vec![m, n], false)
}

/// Build the Appendix-A k-way setup array (k = number of lists = number
/// of columns). List `l` is placed row-major descending with its columns
/// offset `l` to the right of the previous list's (wrapping modulo k —
/// the appendix's "slide left by k columns" step).
pub fn setup_kway(sizes: &[usize]) -> SetupArray {
    let k = sizes.len();
    assert!(k >= 2, "k-way setup needs >= 2 lists");
    let rows_per: Vec<usize> = sizes.iter().map(|&s| s.div_ceil(k)).collect();
    let r0: usize = rows_per.iter().sum();
    let mut grid: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; k]; r0];
    let mut top = r0; // exclusive top of the current list's band
    for (l, &s) in sizes.iter().enumerate() {
        let band_top = top - 1;
        for d in 0..s {
            let r = band_top - d / k;
            // Virtual column k-1-l-d%k, wrapped into 0..k.
            let v = k as isize - 1 - l as isize - (d % k) as isize;
            let c = v.rem_euclid(k as isize) as usize;
            debug_assert!(grid[r][c].is_none());
            grid[r][c] = Some((l, s - 1 - d));
        }
        top -= rows_per[l];
    }
    finish_setup(grid, k, sizes.to_vec(), k >= 3)
}

/// Shared tail of setup construction: slide values to the top of each
/// column (unpopulated cells to the bottom — Figs. 2, 3, 22), drop
/// fully-empty rows, and assign flat positions in output scan order.
fn finish_setup(
    grid: Vec<Vec<Option<(usize, usize)>>>,
    cols: usize,
    list_sizes: Vec<usize>,
    serpentine: bool,
) -> SetupArray {
    let r0 = grid.len();
    // Compact each column upward.
    let mut slid: Vec<Vec<Option<(usize, usize)>>> = vec![vec![None; cols]; r0];
    for c in 0..cols {
        let vals: Vec<(usize, usize)> = (0..r0).filter_map(|r| grid[r][c]).collect();
        // vals is bottom-up; keep order, placed into the top |vals| rows.
        let h = vals.len();
        for (i, v) in vals.into_iter().enumerate() {
            slid[r0 - h + i][c] = Some(v);
        }
    }
    // Drop fully-empty rows (all at the bottom after compaction).
    let first_populated = (0..r0)
        .find(|&r| slid[r].iter().any(Option::is_some))
        .expect("non-empty setup");
    let rows = r0 - first_populated;
    let mut arr = SetupArray {
        rows,
        cols,
        grid: vec![vec![None; cols]; rows],
        serpentine,
        list_sizes,
    };
    // Assign flat positions in scan order (bottom row first).
    let mut pos = 0usize;
    for r in 0..rows {
        for c in arr.scan_cols(r) {
            if let Some((list, idx)) = slid[first_populated + r][c] {
                arr.grid[r][c] = Some(Cell { list, idx, pos });
                pos += 1;
            }
        }
    }
    arr
}

/// Stage-1 column-sort blocks. For 2-way arrays each column holds (up to)
/// two sorted ascending runs — one per list — merged by an S2MS block;
/// columns holding a single run are already in order and need no sorter
/// (Figs. 2, 3). For k ≥ 3 each column holds up to k runs and is sorted
/// by a single-stage N-sorter.
fn column_sort_stage(arr: &SetupArray) -> Stage {
    let mut blocks = Vec::new();
    for c in 0..arr.cols {
        let cells = arr.column(c);
        if cells.len() < 2 {
            continue;
        }
        let out: Vec<usize> = cells.iter().map(|x| x.pos).collect();
        // Split into per-list runs; cells within a column are ascending
        // per list as the row increases.
        let lists_present: Vec<usize> = {
            let mut ls: Vec<usize> = cells.iter().map(|x| x.list).collect();
            ls.dedup();
            ls.sort_unstable();
            ls.dedup();
            ls
        };
        if arr.list_sizes.len() == 2 {
            let up: Vec<usize> = cells.iter().filter(|x| x.list == 0).map(|x| x.pos).collect();
            let dn: Vec<usize> = cells.iter().filter(|x| x.list == 1).map(|x| x.pos).collect();
            if up.is_empty() || dn.is_empty() {
                // Single sorted run already in column order: no hardware.
                continue;
            }
            blocks.push(Block::MergeS2 { up, dn, out });
        } else {
            if lists_present.len() <= 1 {
                continue;
            }
            blocks.push(Block::SortN { pos: out });
        }
    }
    Stage::new("col-sort", blocks)
}

/// Row-sort stage: each populated row sorted into its scan order.
/// Width-2 rows become plain 2-sorters.
fn row_sort_stage(arr: &SetupArray, label: &str) -> Stage {
    let mut blocks = Vec::new();
    for r in 0..arr.rows {
        let cells = arr.row_scan(r);
        if cells.len() < 2 {
            continue;
        }
        let pos: Vec<usize> = cells.iter().map(|x| x.pos).collect();
        if pos.len() == 2 {
            blocks.push(Block::Cas { lo: pos[0], hi: pos[1] });
        } else {
            blocks.push(Block::SortN { pos });
        }
    }
    Stage::new(label, blocks)
}

/// Full-column sort stage used by k-way devices after stage 2.
fn full_column_stage(arr: &SetupArray, label: &str) -> Stage {
    let mut blocks = Vec::new();
    for c in 0..arr.cols {
        let cells = arr.column(c);
        if cells.len() < 2 {
            continue;
        }
        blocks.push(Block::SortN { pos: cells.iter().map(|x| x.pos).collect() });
    }
    Stage::new(label, blocks)
}

/// The Fig.-6 stage-3 for full-grid 3-way devices: sort only the vertical
/// pairs in the edge columns that hold consecutive serpentine ranks.
/// Left edge (col k-1): rows (2j, 2j+1); right edge (col 0): rows
/// (2j+1, 2j+2). The centre column is untouched.
fn edge_pair_stage(arr: &SetupArray) -> Stage {
    let k = arr.cols;
    let mut blocks = Vec::new();
    let col = |c: usize, r: usize| arr.grid[r][c].map(|x| x.pos);
    let mut r = 0;
    while r + 1 < arr.rows {
        if let (Some(lo), Some(hi)) = (col(k - 1, r), col(k - 1, r + 1)) {
            blocks.push(Block::Cas { lo, hi });
        }
        r += 2;
    }
    let mut r = 1;
    while r + 1 < arr.rows {
        if let (Some(lo), Some(hi)) = (col(0, r), col(0, r + 1)) {
            blocks.push(Block::Cas { lo, hi });
        }
        r += 2;
    }
    Stage::new("edge-pair-sort", blocks)
}

/// Table 1: total alternating column/row sorts required for a k-way
/// merge. (k = 2 → 2, 3 → 3, 4–5 → 4, 6 → 5, 7–14 → 6.)
pub fn table1_stage_count(k: usize) -> usize {
    match k {
        0 | 1 => 0,
        2 => 2,
        3 => 3,
        4 | 5 => 4,
        6 => 5,
        7..=14 => 6,
        // Beyond the paper's table: continue the even/odd cadence of a
        // shear-style schedule (documented reconstruction).
        _ => 6 + (k as f64 / 7.0).log2().ceil() as usize,
    }
}

/// Build a 2-way LOMS merging sorted lists of sizes `m` (UP) and `n`
/// (DN) in a `cols`-column array: 2 stages (S2MS column merges, then
/// row sorts).
pub fn loms_2way(m: usize, n: usize, cols: usize) -> MergeDevice {
    let arr = setup_2way(m, n, cols);
    let total = m + n;
    let stages: Vec<Stage> = [column_sort_stage(&arr), row_sort_stage(&arr, "row-sort")]
        .into_iter()
        .filter(|s| !s.blocks.is_empty())
        .collect();
    MergeDevice {
        name: format!("loms2-{cols}col-up{m}-dn{n}"),
        kind: DeviceKind::Loms,
        list_sizes: vec![m, n],
        input_map: arr.input_map(),
        n: total,
        stages,
        output_perm: (0..total).collect(),
        median_tap: None,
        grid: Some((arr.cols, arr.rows)),
    }
}

/// Build a k-way LOMS (k = sizes.len() ≥ 3) with the Table-1 stage
/// schedule: full column sorts alternating with full serpentine row
/// sorts. Full-grid 3-way devices use the cheaper Fig.-6 edge-pair
/// stage 3. When all lists have the same odd size, the device carries a
/// 2-stage median tap (§V-A).
///
/// Correctness caveat: the paper specifies constructions only for k = 2
/// (§IV), k = 3 (§V-A) and *equal-size* lists (Table 1). Those
/// configurations validate exhaustively (see `tests/device_validation`).
/// For k ≥ 4 with *unequal* sizes the Table-1 stage budget can be
/// insufficient for this reconstruction — use [`loms_kway_validated`],
/// which provably extends the schedule until the device is correct.
pub fn loms_kway(sizes: &[usize]) -> MergeDevice {
    loms_kway_with_stages(sizes, None)
}

/// k-way LOMS whose schedule is *extended beyond Table 1 if needed*
/// until the exhaustive sorted-0-1 validation proves it correct.
///
/// Returns `Err` when no alternating row/column schedule up to 16
/// stages sorts the configuration — which happens for some *unequal*
/// k = 3 mixtures (e.g. [8, 1, 6]): unpopulated bottom-row holes can
/// make the serpentine rank order unreachable by row/column sorts
/// alone. Equal-size configurations always succeed (Table 1's setting;
/// many validate exactly at the Table-1 count). The paper's
/// any-mixture claim is made for 2-way devices only (§VIII).
pub fn loms_kway_validated(sizes: &[usize]) -> Result<MergeDevice, String> {
    use super::validate::{merge_01_pattern_count, validate_merge_01};
    if merge_01_pattern_count(sizes) > 5_000_000 {
        return Err(format!("validation infeasible for sizes {sizes:?}"));
    }
    let base = table1_stage_count(sizes.len());
    for extra in 0..=(16usize.saturating_sub(base)) {
        let d = loms_kway_with_stages(sizes, Some(base + extra));
        if validate_merge_01(&d).is_ok() {
            return Ok(d);
        }
    }
    Err(format!("no valid LOMS schedule for sizes {sizes:?} within 16 stages"))
}

fn loms_kway_with_stages(sizes: &[usize], n_stages_override: Option<usize>) -> MergeDevice {
    let k = sizes.len();
    assert!(k >= 3, "use loms_2way for k=2");
    // Scope matches the paper: k = 3 supports any size mixture (§V-A,
    // validated exhaustively); k ≥ 4 requires equal sizes (Table 1's
    // setting). Unequal sizes at k ≥ 4 leave unpopulated holes that the
    // alternating row/column schedule provably cannot always bridge
    // (counterexample: sizes [3,3,7,4,1] fails even with 16 stages).
    assert!(
        k == 3 || sizes.iter().all(|&s| s == sizes[0]),
        "k-way LOMS with k >= 4 requires equal list sizes (got {sizes:?})"
    );
    let arr = setup_kway(sizes);
    let total: usize = sizes.iter().sum();
    let n_stages = n_stages_override.unwrap_or_else(|| table1_stage_count(k));
    // The Fig.-6 reduced stage 3 is proven (validated) for full-grid
    // equal-odd-size 3-way devices — the configuration the paper
    // demonstrates; other shapes use a full column sort.
    let full_grid = total == arr.rows * arr.cols
        && sizes.iter().all(|&s| s == sizes[0])
        && sizes[0] % 2 == 1;
    let mut stages = vec![column_sort_stage(&arr), row_sort_stage(&arr, "row-sort")];
    for s in 2..n_stages {
        if s % 2 == 0 {
            if k == 3 && full_grid && s == 2 {
                stages.push(edge_pair_stage(&arr));
            } else {
                stages.push(full_column_stage(&arr, "col-sort"));
            }
        } else {
            stages.push(row_sort_stage(&arr, "row-sort"));
        }
    }
    let stages: Vec<Stage> = stages.into_iter().filter(|s| !s.blocks.is_empty()).collect();
    // Median tap (§V-A): for *3-way* devices with equal odd sizes, the
    // median is final after stage 2 at the centre rank's position (= the
    // rank itself; positions are assigned in output scan order). The
    // paper makes this claim for 3-way merge; it does not hold for all
    // k (validation shows k=5 counterexamples), so the tap is 3-way only.
    let equal_odd = k == 3 && sizes.iter().all(|&s| s == sizes[0]) && sizes[0] % 2 == 1;
    let median_tap = if equal_odd && total % 2 == 1 {
        Some((2.min(stages.len()), total / 2))
    } else {
        None
    };
    MergeDevice {
        name: format!("loms{k}-{}r", sizes.iter().map(|s| s.to_string()).collect::<Vec<_>>().join("_")),
        kind: DeviceKind::Loms,
        list_sizes: sizes.to_vec(),
        input_map: arr.input_map(),
        n: total,
        stages,
        output_perm: (0..total).collect(),
        median_tap,
        grid: Some((arr.cols, arr.rows)),
    }
}

/// The §V-A / Fig.-18 *median-only* 3-way LOMS device: stage 1 sorts all
/// k columns in full; stage 2 builds only a single N-filter on the middle
/// row, tapping the centre cell — 2 stages versus 4 for the MWMS median
/// baseline. Requires equal odd list sizes (odd total, centred median).
pub fn loms_3way_median(r: usize) -> MergeDevice {
    assert!(r % 2 == 1, "median device needs odd list size");
    let sizes = vec![r; 3];
    let arr = setup_kway(&sizes);
    let total = 3 * r;
    let mid_row = (total / 2) / arr.cols;
    let row_cells = arr.row_scan(mid_row);
    let pos: Vec<usize> = row_cells.iter().map(|x| x.pos).collect();
    let tap = pos.iter().position(|&p| p == total / 2).expect("centre in middle row");
    let stages = vec![
        column_sort_stage(&arr),
        Stage::new("median-filter", vec![Block::FilterN { pos, taps: vec![tap] }]),
    ];
    MergeDevice {
        name: format!("loms3-median-{r}r"),
        kind: DeviceKind::Loms,
        list_sizes: sizes,
        input_map: arr.input_map(),
        n: total,
        stages,
        output_perm: (0..total).collect(),
        median_tap: Some((2, total / 2)),
        grid: Some((arr.cols, arr.rows)),
    }
}

/// The paper's Fig.-10 matrix: the S2MS column-sorter size `(m, n)` used
/// by a 2-way LOMS with `cols` columns and `outputs` total outputs
/// (equal power-of-2 input lists).
pub fn fig10_column_sorter(outputs: usize, cols: usize) -> (usize, usize) {
    let per_col = outputs / cols;
    (per_col / 2, per_col / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::{median, merge, ExecMode};
    use crate::sortnet::validate::{validate_merge_01, validate_merge_random};

    /// Render a setup array as (list, idx) paper-style for comparisons,
    /// top row first, leftmost column first.
    fn render(arr: &SetupArray) -> Vec<Vec<Option<(usize, usize)>>> {
        (0..arr.rows)
            .rev()
            .map(|r| {
                (0..arr.cols)
                    .rev()
                    .map(|c| arr.grid[r][c].map(|x| (x.list, x.idx)))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fig1_up8_dn8_setup() {
        // Fig. 1: UP-8/DN-8, 2 columns. Top-down, [Col1, Col0] per row.
        let arr = setup_2way(8, 8, 2);
        let a = |i: usize| Some((0usize, i));
        let b = |i: usize| Some((1usize, i));
        assert_eq!(
            render(&arr),
            vec![
                vec![a(7), a(6)],
                vec![a(5), a(4)],
                vec![a(3), a(2)],
                vec![a(1), a(0)],
                vec![b(6), b(7)],
                vec![b(4), b(5)],
                vec![b(2), b(3)],
                vec![b(0), b(1)],
            ]
        );
    }

    #[test]
    fn fig2_up1_dn8_setup() {
        // Fig. 2 right: A_00 and B_07 in top row, empty cell at bottom Col 0.
        let arr = setup_2way(1, 8, 2);
        let b = |i: usize| Some((1usize, i));
        assert_eq!(
            render(&arr),
            vec![
                vec![Some((0, 0)), b(7)],
                vec![b(6), b(5)],
                vec![b(4), b(3)],
                vec![b(2), b(1)],
                vec![b(0), None],
            ]
        );
    }

    #[test]
    fn fig3_up8_dn1_setup() {
        let arr = setup_2way(8, 1, 2);
        let a = |i: usize| Some((0usize, i));
        assert_eq!(
            render(&arr),
            vec![
                vec![a(7), a(6)],
                vec![a(5), a(4)],
                vec![a(3), a(2)],
                vec![a(1), a(0)],
                vec![None, Some((1, 0))],
            ]
        );
    }

    #[test]
    fn fig3_up7_dn5_setup() {
        // Fig. 3 lower right: unpopulated row removed, 6 rows.
        let arr = setup_2way(7, 5, 2);
        let a = |i: usize| Some((0usize, i));
        let b = |i: usize| Some((1usize, i));
        assert_eq!(
            render(&arr),
            vec![
                vec![a(6), a(5)],
                vec![a(4), a(3)],
                vec![a(2), a(1)],
                vec![a(0), b(4)],
                vec![b(3), b(2)],
                vec![b(1), b(0)],
            ]
        );
    }

    #[test]
    fn fig23_3c7r_setup() {
        // Appendix A final setup array (Fig. 23 == Fig. 5 left).
        let arr = setup_kway(&[7, 7, 7]);
        let a = |i: usize| Some((0usize, i));
        let b = |i: usize| Some((1usize, i));
        let c = |i: usize| Some((2usize, i));
        assert_eq!(
            render(&arr),
            vec![
                vec![a(6), a(5), a(4)],
                vec![a(3), a(2), a(1)],
                vec![a(0), b(6), b(5)],
                vec![b(4), b(3), b(2)],
                vec![b(1), b(0), c(6)],
                vec![c(5), c(4), c(3)],
                vec![c(2), c(1), c(0)],
            ]
        );
    }

    #[test]
    fn kway_setup_agrees_with_2way_for_2_columns() {
        for (m, n) in [(8usize, 8usize), (1, 8), (8, 1), (7, 5)] {
            let a = setup_2way(m, n, 2);
            let b = setup_kway(&[m, n]);
            assert_eq!(render(&a), render(&b), "UP-{m}/DN-{n}");
        }
    }

    #[test]
    fn fig1_example_merge() {
        // Fig. 1 numeric example: A = 1,5,6,9,10,13,14,15 / B = 2,3,4,7,8,11,12,16.
        let d = loms_2way(8, 8, 2);
        let out = merge(
            &d,
            &[vec![1u32, 5, 6, 9, 10, 13, 14, 15], vec![2, 3, 4, 7, 8, 11, 12, 16]],
            ExecMode::Strict,
        )
        .unwrap();
        assert_eq!(out, (1..=16).collect::<Vec<u32>>());
    }

    #[test]
    fn fig6_worst_case_3way_example() {
        // Fig. 6: A = {1..7}, B = {8..14}, C = {15..21} arranged so the
        // setup is the paper's "worst case". Lists ascending:
        let d = loms_kway(&[7, 7, 7]);
        let a: Vec<u32> = (1..=7).collect();
        let b: Vec<u32> = (8..=14).collect();
        let c: Vec<u32> = (15..=21).collect();
        let out = merge(&d, &[a.clone(), b.clone(), c.clone()], ExecMode::Strict).unwrap();
        assert_eq!(out, (1..=21).collect::<Vec<u32>>());
        // Median after only 2 stages (paper: Row 3 Col 1 holds rank 10).
        let med = median(&d, &[a, b, c], ExecMode::Strict).unwrap();
        assert_eq!(med, Some(11));
    }

    #[test]
    fn loms_2way_depth_is_2() {
        for (m, n, c) in [(8usize, 8usize, 2usize), (16, 16, 2), (32, 32, 8), (7, 5, 2)] {
            assert_eq!(loms_2way(m, n, c).depth(), 2, "UP-{m}/DN-{n} {c}col");
        }
    }

    #[test]
    fn loms_2way_validates_all_mixtures() {
        // Equal/odd/even/empty-ish mixtures, all column counts: the
        // versatility claim (§VIII) — no size restrictions.
        for (m, n) in [(1usize, 1usize), (1, 8), (8, 1), (7, 5), (5, 7), (8, 8), (16, 16), (9, 3), (2, 13)] {
            for cols in [2usize, 4] {
                let d = loms_2way(m, n, cols);
                validate_merge_01(&d).unwrap_or_else(|e| panic!("{e}"));
            }
        }
    }

    #[test]
    fn loms_2way_large_power_of_two_validates() {
        // The study's characterized sizes (Fig. 10 matrix).
        for (outs, cols) in [(32usize, 2usize), (64, 2), (64, 4), (64, 8), (128, 4), (256, 8)] {
            let m = outs / 2;
            let d = loms_2way(m, m, cols);
            validate_merge_01(&d).unwrap_or_else(|e| panic!("{e}"));
            assert_eq!(d.depth(), 2);
        }
    }

    #[test]
    fn loms_3way_validates() {
        for sizes in [[7usize, 7, 7], [5, 5, 5], [3, 3, 3], [4, 4, 4], [7, 5, 3]] {
            let d = loms_kway(&sizes);
            validate_merge_01(&d).unwrap_or_else(|e| panic!("{e}"));
        }
        validate_merge_random(&loms_kway(&[7, 7, 7]), 100, 3).unwrap();
    }

    #[test]
    fn loms_3c7r_stage_structure_matches_paper() {
        let d = loms_kway(&[7, 7, 7]);
        assert_eq!(d.depth(), 3);
        // Stage 1: 3 full column sorts of 7 values.
        assert_eq!(d.stages[0].blocks.len(), 3);
        // Stage 2: 7 row 3-sorters.
        assert_eq!(d.stages[1].blocks.len(), 7);
        // Stage 3: edge pairs only — 3 pairs per edge column (Fig. 6).
        assert_eq!(d.stages[2].label, "edge-pair-sort");
        assert_eq!(d.stages[2].blocks.len(), 6);
        assert!(d.stages[2].blocks.iter().all(|b| matches!(b, Block::Cas { .. })));
        // Median tap: 2 stages, centre position (rank 10).
        assert_eq!(d.median_tap, Some((2, 10)));
    }

    #[test]
    fn loms_kway_4_to_8_validate() {
        for k in 3..=8usize {
            let sizes = vec![3usize; k];
            let d = loms_kway(&sizes);
            validate_merge_01(&d).unwrap_or_else(|e| panic!("k={k}: {e}"));
            assert!(d.depth() <= table1_stage_count(k), "k={k}");
        }
    }

    #[test]
    fn table1_counts() {
        assert_eq!(table1_stage_count(2), 2);
        assert_eq!(table1_stage_count(3), 3);
        assert_eq!(table1_stage_count(4), 4);
        assert_eq!(table1_stage_count(5), 4);
        assert_eq!(table1_stage_count(6), 5);
        assert_eq!(table1_stage_count(7), 6);
        assert_eq!(table1_stage_count(14), 6);
    }

    #[test]
    fn fig10_column_sorters() {
        // Fig. 10 matrix rows.
        assert_eq!(fig10_column_sorter(32, 8), (2, 2));
        assert_eq!(fig10_column_sorter(64, 8), (4, 4));
        assert_eq!(fig10_column_sorter(256, 8), (16, 16));
        assert_eq!(fig10_column_sorter(256, 4), (32, 32));
        assert_eq!(fig10_column_sorter(128, 2), (32, 32));
    }

    #[test]
    fn setup_2way_multicolumn_columns_hold_two_runs() {
        let arr = setup_2way(32, 32, 8);
        assert_eq!(arr.rows, 8);
        for c in 0..8 {
            let cells = arr.column(c);
            assert_eq!(cells.len(), 8);
            // bottom half B, top half A
            assert!(cells[..4].iter().all(|x| x.list == 1));
            assert!(cells[4..].iter().all(|x| x.list == 0));
        }
    }
}
