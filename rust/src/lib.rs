//! # loms — List Offset Merge Sorters
//!
//! A reproduction of *"Fast and Efficient Merge of Sorted Input Lists in
//! Hardware Using List Offset Merge Sorters"* (Kent & Pattichis, 2025) as
//! a three-layer Rust + JAX/Pallas system:
//!
//! * [`sortnet`] — construction, bit-exact execution and exhaustive
//!   validation of every device family in the paper (LOMS, S2MS,
//!   Batcher OEM/Bitonic, N-sorters, MWMS), plus the compiled execution
//!   plans ([`sortnet::plan`]) and their lane-parallel expansion
//!   ([`sortnet::lanes`]: transposed SIMD-friendly tiles × core
//!   sharding) the serving hot path runs on.
//! * [`fpga`] — the structural FPGA cost model (Kintex Ultrascale+ /
//!   Versal Prime; 2insLUT / 4insLUT) that regenerates the paper's
//!   propagation-delay and LUT-usage figures.
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   merge kernels (`artifacts/*.hlo.txt`) and executes them.
//! * [`coordinator`] — the batched merge service (router, dynamic
//!   batcher, workers, metrics) and the hierarchical merge planner.
//! * [`stream`] — the streaming merge engine: bounded-memory k-way
//!   merging of unbounded sorted streams (FLiMS-style block mergers
//!   composed into a lane-batched merge tree) and the run-formation +
//!   spill external sorter behind `loms sort`.
//! * [`net`] — the networked serving front-end: versioned framed-TCP
//!   protocol (v2 adds echoed request ids for multiplexing),
//!   [`net::NetServer`] (a nonblocking readiness loop over epoll/kqueue
//!   plus a fixed dispatch pool — connections bounded by memory, not
//!   threads) and the pipelined [`net::NetClient`] / load generator
//!   behind `loms serve --listen` and `loms bench-net`.
//! * [`obs`] — observability: the log-linear latency histogram (one
//!   percentile definition stack-wide), per-request tracing with a
//!   bounded span ring, and the stats wire/JSONL export surface behind
//!   `loms stats` and `loms serve --metrics-interval`.
//! * [`bench`] — figure/table regeneration harness shared by `benches/`.
//!
//! See `rust/DESIGN.md` for the system inventory and
//! `rust/EXPERIMENTS.md` for the paper-vs-measured record.

pub mod bench;
pub mod coordinator;
pub mod fpga;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod sortnet;
pub mod stream;
pub mod util;
