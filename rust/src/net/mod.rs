//! Networked merge serving: a dependency-free (`std::net` + raw-fd
//! readiness syscalls) framed-TCP front-end over the batched
//! [`crate::coordinator::MergeService`].
//!
//! The paper's LOMS devices earn their speedup only when kept
//! saturated with batches; this layer is what saturates them from
//! *outside* the process — the same thin-transport-over-batch-engine
//! split hardware merge services use (cf. FLiMS and the micro-blossom
//! hardware/service architecture). Five modules:
//!
//! * [`protocol`] — versioned length-prefixed binary frames
//!   (MergeRequest / MergeResponse / Error / Ping / Pong, KV and
//!   stats variants) with explicit size, k and list-length limits and
//!   an incremental, timeout-tolerant [`protocol::FrameReader`].
//!   Protocol v2 inserts a `u64le` request id after the type byte,
//!   echoed in every reply; payload grammars are shared byte-for-byte
//!   with v1, so the framings cannot drift.
//! * [`poll`] — the dependency-free readiness layer: thin raw-fd
//!   wrappers over `epoll` (Linux) / `kqueue` (macOS), a self-pipe
//!   waker, and a coarse timer wheel for write deadlines.
//! * [`conn`] — per-connection protocol state: the v1/v2 version
//!   latch, reply ordering (v1 in request order, v2 as completed) and
//!   the request-id lifecycle, unit-testable without sockets.
//! * [`server`] — [`NetServer`]: one nonblocking event loop serving
//!   every connection (bounded by memory, not threads) plus a small
//!   fixed worker pool for dispatch/encode; per-connection inflight
//!   quotas and write-backlog pause for fairness; admission shedding;
//!   dead-peer reaping; error *replies* (never disconnects) on
//!   malformed frames; graceful shutdown that drains in-flight
//!   batches.
//! * [`client`] — blocking [`NetClient`] with pipelined multi-request
//!   submission over v1 or v2 (explicit ids, out-of-order replies),
//!   reconnect-and-replay recovery under a [`RetryPolicy`]
//!   (exponential backoff, decorrelated jitter, per-operation deadline
//!   budget), plus the multi-connection load generator behind
//!   `loms bench-net` and `benches/net_serving.rs`.
//!
//! See `rust/DESIGN.md` §"Network serving" for the frame grammar and
//! the socket-to-tile copy count.

pub mod client;
pub mod conn;
pub mod poll;
pub mod protocol;
pub mod server;

pub use client::{
    run_load, run_load_with, LoadReport, NetClient, NetMerge, RetryPolicy, ServerError,
};
pub use protocol::{
    Frame, FrameReader, ReadFrame, MAX_FRAME_BYTES, MAX_K, MAX_LIST_LEN, MAX_REQUEST_BYTES,
    MODE_FLAG_TRACE, PROTOCOL_V2, PROTOCOL_VERSION,
};
pub use server::{NetServer, NetServerConfig};
