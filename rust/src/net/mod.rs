//! Networked merge serving: a dependency-free (`std::net`) framed-TCP
//! front-end over the batched [`crate::coordinator::MergeService`].
//!
//! The paper's LOMS devices earn their speedup only when kept
//! saturated with batches; this layer is what saturates them from
//! *outside* the process — the same thin-transport-over-batch-engine
//! split hardware merge services use (cf. FLiMS and the micro-blossom
//! hardware/service architecture). Three modules:
//!
//! * [`protocol`] — versioned length-prefixed binary frames
//!   (MergeRequest / MergeResponse / Error / Ping / Pong) with
//!   explicit size, k and list-length limits and an incremental,
//!   timeout-tolerant [`protocol::FrameReader`]. Request keys decode
//!   straight into the `Vec<u32>` lists service admission takes.
//! * [`server`] — [`NetServer`]: acceptor thread + bounded worker
//!   pool; per-connection reader/writer pair so pipelined requests
//!   overlap with response write-back; error *replies* (never
//!   disconnects) on malformed frames; graceful shutdown that drains
//!   in-flight batches.
//! * [`client`] — blocking [`NetClient`] with pipelined multi-request
//!   submission, reconnect-and-replay recovery under a [`RetryPolicy`]
//!   (exponential backoff, decorrelated jitter, per-operation deadline
//!   budget), plus the multi-connection load generator behind
//!   `loms bench-net` and `benches/net_serving.rs`.
//!
//! See `rust/DESIGN.md` §"Network serving" for the frame grammar and
//! the socket-to-tile copy count.

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{run_load, LoadReport, NetClient, NetMerge, RetryPolicy, ServerError};
pub use protocol::{
    Frame, FrameReader, ReadFrame, MAX_FRAME_BYTES, MAX_K, MAX_LIST_LEN, MAX_REQUEST_BYTES,
    MODE_FLAG_TRACE, PROTOCOL_VERSION,
};
pub use server::{NetServer, NetServerConfig};
