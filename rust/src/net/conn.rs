//! Per-connection protocol state for the event-driven server: the
//! version latch (a connection speaks v1 *or* v2, fixed by its first
//! frame) and the reply-ordering queue.
//!
//! v1 connections promise replies in request order — ordering is the
//! correlation — so completions that finish out of order are held and
//! released consecutively. v2 frames carry an explicit `u64le` request
//! id echoed in the reply, so completions append to the write buffer
//! the moment they exist; the queue only tracks which ids are in
//! flight (a duplicate in-flight id is a client protocol error, and an
//! id becomes reusable once its reply is released).
//!
//! This module is pure bookkeeping — no sockets — so the ordering and
//! id-lifecycle rules are unit-testable without a live server.

use std::collections::{BTreeMap, HashSet};

/// Protocol version latch, decided by the first decoded frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Proto {
    Unset,
    V1,
    V2,
}

/// Ordering/inflight bookkeeping for one connection's replies.
#[derive(Debug, Default)]
pub struct ReplyQueue {
    inflight: usize,
    /// Next sequence number handed to an admitted frame.
    next_seq: u64,
    /// Next sequence allowed to append to the write buffer (v1).
    next_write_seq: u64,
    /// Completed-but-unreleasable v1 replies, keyed by sequence.
    held: BTreeMap<u64, Vec<u8>>,
    held_bytes: usize,
    /// In-flight v2 request ids.
    live_ids: HashSet<u64>,
}

impl ReplyQueue {
    pub fn new() -> ReplyQueue {
        ReplyQueue::default()
    }

    /// Admit one frame that will produce exactly one reply; returns its
    /// release sequence.
    pub fn admit(&mut self) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.inflight += 1;
        seq
    }

    /// Claim a v2 request id; `false` means the id is already in
    /// flight (the caller answers with a typed error instead).
    pub fn claim_id(&mut self, id: u64) -> bool {
        self.live_ids.insert(id)
    }

    pub fn inflight(&self) -> usize {
        self.inflight
    }

    /// Bytes parked in the v1 hold queue (counted against the
    /// connection's memory budget alongside the write buffer).
    pub fn held_bytes(&self) -> usize {
        self.held_bytes
    }

    /// Complete the reply for `seq`, appending every newly releasable
    /// reply to `wbuf`. v2 (`ordered == false`) appends immediately;
    /// v1 holds out-of-order completions until the gap fills.
    /// `release_id` frees a v2 id for reuse (None for replies that
    /// never claimed one, e.g. the duplicate-id error itself).
    pub fn complete(
        &mut self,
        ordered: bool,
        seq: u64,
        release_id: Option<u64>,
        bytes: Vec<u8>,
        wbuf: &mut Vec<u8>,
    ) {
        self.inflight = self.inflight.saturating_sub(1);
        if let Some(id) = release_id {
            self.live_ids.remove(&id);
        }
        if !ordered {
            wbuf.extend_from_slice(&bytes);
            return;
        }
        self.held_bytes += bytes.len();
        self.held.insert(seq, bytes);
        while let Some(b) = self.held.remove(&self.next_write_seq) {
            self.held_bytes -= b.len();
            wbuf.extend_from_slice(&b);
            self.next_write_seq += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_out_of_order_completions_release_in_request_order() {
        let mut q = ReplyQueue::new();
        let (s0, s1, s2) = (q.admit(), q.admit(), q.admit());
        assert_eq!((s0, s1, s2), (0, 1, 2));
        assert_eq!(q.inflight(), 3);

        let mut wbuf = Vec::new();
        q.complete(true, s2, None, vec![b'C'], &mut wbuf);
        assert!(wbuf.is_empty(), "seq 2 released before 0/1");
        assert_eq!(q.held_bytes(), 1);
        q.complete(true, s0, None, vec![b'A'], &mut wbuf);
        assert_eq!(wbuf, b"A", "seq 0 releases alone; 2 still gapped");
        q.complete(true, s1, None, vec![b'B'], &mut wbuf);
        assert_eq!(wbuf, b"ABC", "filling the gap releases the held tail");
        assert_eq!(q.inflight(), 0);
        assert_eq!(q.held_bytes(), 0);
    }

    #[test]
    fn v2_completions_append_immediately_and_recycle_ids() {
        let mut q = ReplyQueue::new();
        assert!(q.claim_id(7));
        assert!(!q.claim_id(7), "duplicate in-flight id rejected");
        let s0 = q.admit();
        let mut wbuf = Vec::new();
        q.complete(false, s0, Some(7), vec![b'X'], &mut wbuf);
        assert_eq!(wbuf, b"X");
        assert!(q.claim_id(7), "id reusable after its reply released");
    }

    #[test]
    fn dup_id_error_reply_does_not_release_the_original_id() {
        let mut q = ReplyQueue::new();
        assert!(q.claim_id(42));
        let dup_seq = q.admit();
        let mut wbuf = Vec::new();
        // The duplicate's error reply releases no id…
        q.complete(false, dup_seq, None, vec![b'E'], &mut wbuf);
        assert!(!q.claim_id(42), "original 42 still in flight");
        // …only the original completion does.
        let orig = q.admit();
        q.complete(false, orig, Some(42), vec![b'R'], &mut wbuf);
        assert!(q.claim_id(42));
    }
}
