//! Blocking client for the framed merge protocol, plus the multi-
//! connection load generator behind `loms bench-net` and
//! `benches/net_serving.rs`.
//!
//! [`NetClient`] supports *pipelined* submission: any number of
//! [`NetClient::submit`] calls may be outstanding before the matching
//! [`NetClient::recv`] calls. On a v1 connection ([`NetClient::connect`])
//! responses arrive strictly in request order — the frames carry no
//! ids; ordering is the correlation. On a v2 connection
//! ([`NetClient::connect_v2`]) every submit claims a `u64` request id
//! (returned by the submit call and echoed in [`NetMerge::id`] /
//! [`ServerError::id`]), replies arrive in *completion* order, and
//! [`NetClient::recv`] matches each one to its request by id — many
//! logical callers can multiplex one connection. Encoding reuses one
//! write buffer, so a steady-state client allocates only the decoded
//! response vectors.
//!
//! # Retry and replay
//!
//! Armed with a [`RetryPolicy`] (see [`NetClient::with_retry`]), the
//! client survives connection loss: it keeps every submitted-but-
//! unanswered request *as encoded frame bytes*, and on a broken
//! stream it reconnects (exponential backoff with decorrelated
//! jitter, bounded by a per-operation deadline budget) and replays
//! the whole unanswered window in order. This is sound because merge
//! requests are **pure and idempotent** — re-executing one produces
//! byte-identical output and mutates nothing server-side — and
//! replies correlate by order (v1) or by the echoed request id (v2),
//! so a replayed stream is indistinguishable from a first
//! transmission. Server-side
//! [`code::OVERLOADED`] sheds are *not* replayed here (the reply did
//! arrive); they surface as a typed [`ServerError`] so the caller can
//! resubmit on its own schedule — [`run_load`] does exactly that.

use super::protocol::{
    self, code, encode_merge_request, encode_merge_request_kv, encode_stats_request, Frame,
    FrameReader, ReadFrame, MAX_K, MAX_LIST_LEN, MAX_REQUEST_BYTES, MODE_MERGE,
};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::io::Write;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// One merged response off the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetMerge {
    /// The request id this reply answers (0 on a v1 connection, where
    /// ordering is the correlation).
    pub id: u64,
    pub merged: Vec<u32>,
    /// Key-value requests only: the merged payload column,
    /// `payloads[t]` riding with `merged[t]`.
    pub payloads: Option<Vec<u64>>,
    /// Which artifact (or `"software"`) served it, per the server.
    pub served_by: String,
}

/// A typed server `Error` frame, surfaced from [`NetClient::recv`] so
/// callers can branch on the code (e.g. retry [`code::OVERLOADED`],
/// give up on [`code::REJECTED`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerError {
    pub code: u8,
    pub message: String,
    /// The request id the error answers (0 on a v1 connection).
    pub id: u64,
}

impl ServerError {
    /// Retryable admission shed: the request was never submitted
    /// server-side, so resending it is always safe.
    pub fn is_overloaded(&self) -> bool {
        self.code == code::OVERLOADED
    }
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error {}: {}", code_name(self.code), self.message)
    }
}

impl std::error::Error for ServerError {}

/// Reconnect-and-replay tuning for [`NetClient::with_retry`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Reconnect attempts per logical operation (one submit or recv).
    pub max_retries: u32,
    /// First backoff sleep; later sleeps use decorrelated jitter
    /// (`min(max_backoff, uniform(base, 3 × previous))`).
    pub base_backoff: Duration,
    /// Backoff ceiling.
    pub max_backoff: Duration,
    /// Total wall-clock budget for one logical operation, including
    /// every reconnect and backoff sleep.
    pub deadline: Duration,
    /// Jitter seed — deterministic per client, so tests replay.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 8,
            base_backoff: Duration::from_millis(2),
            max_backoff: Duration::from_millis(250),
            deadline: Duration::from_secs(30),
            seed: 0x5EED,
        }
    }
}

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    reader: FrameReader,
    wbuf: Vec<u8>,
    /// Requests submitted but not yet received (sanity accounting).
    inflight: usize,
    /// Resolved target, kept for reconnects.
    addr: Option<SocketAddr>,
    retry: Option<RetryPolicy>,
    jitter: crate::util::Rng,
    /// Protocol v2: frames carry request ids and replies arrive in
    /// completion order.
    proto2: bool,
    /// Next v2 request id to claim (ids are unique per connection
    /// lifetime on the client side; the server only requires them
    /// unique among in-flight requests).
    next_id: u64,
    /// Encoded request frames submitted but not yet answered, keyed by
    /// request id (0 on v1) — the replay window for reconnects (one
    /// entry per in-flight merge).
    unanswered: VecDeque<(u64, Vec<u8>)>,
    /// Previous backoff sleep (decorrelated jitter state).
    last_backoff: Duration,
    /// Successful reconnect-and-replay recoveries so far.
    retries: u64,
}

impl NetClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let resolved = addr
            .to_socket_addrs()
            .context("resolving merge server address")?
            .next();
        let stream = match resolved {
            Some(a) => TcpStream::connect(a).context("connecting to merge server")?,
            None => bail!("merge server address resolved to nothing"),
        };
        let _ = stream.set_nodelay(true);
        Ok(NetClient {
            stream,
            reader: FrameReader::new(),
            wbuf: Vec::new(),
            inflight: 0,
            addr: resolved,
            retry: None,
            jitter: crate::util::Rng::new(0x5EED),
            proto2: false,
            next_id: 1,
            unanswered: VecDeque::new(),
            last_backoff: Duration::ZERO,
            retries: 0,
        })
    }

    /// Connect speaking protocol v2: every request carries a `u64`
    /// id (returned by the submit call), replies arrive in completion
    /// order and are matched by the echoed id. The server latches the
    /// connection to v2 on the first frame.
    pub fn connect_v2(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let mut c = NetClient::connect(addr)?;
        c.proto2 = true;
        Ok(c)
    }

    /// Claim the id the next frame will carry (0 on a v1 connection,
    /// whose frames have no id field).
    fn alloc_id(&mut self) -> u64 {
        if !self.proto2 {
            return 0;
        }
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Arm reconnect-and-replay: after this, a broken connection is
    /// recovered transparently (see the module docs for why replay is
    /// sound) instead of surfacing as an error.
    pub fn with_retry(mut self, policy: RetryPolicy) -> NetClient {
        self.jitter = crate::util::Rng::new(policy.seed);
        self.retry = Some(policy);
        self
    }

    /// Successful reconnect-and-replay recoveries performed so far.
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Liveness probe: Ping, expect Pong. Must not be interleaved with
    /// outstanding merges (the Pong would arrive among their replies).
    pub fn ping(&mut self) -> Result<()> {
        anyhow::ensure!(self.inflight == 0, "ping with {} merges in flight", self.inflight);
        let id = self.alloc_id();
        if self.proto2 {
            protocol::encode_frame_v2(&Frame::Ping, id, &mut self.wbuf);
        } else {
            protocol::encode_frame(&Frame::Ping, &mut self.wbuf);
        }
        self.write_wbuf(None, "sending ping")?;
        match self.read_reply() {
            Ok((Frame::Pong, rid)) => {
                anyhow::ensure!(
                    rid.unwrap_or(0) == id,
                    "pong echoed id {:?}, expected {id}",
                    rid
                );
                Ok(())
            }
            Ok((other, _)) => bail!("expected Pong, got {other:?}"),
            Err(e) => Err(e.into_anyhow().context("awaiting pong")),
        }
    }

    /// Send one merge request without waiting (pipelined submission).
    /// Returns the request id its reply will echo (0 on v1, where the
    /// reply is correlated by order instead).
    pub fn submit(&mut self, lists: &[Vec<u32>]) -> Result<u64> {
        self.submit_traced(lists, 0)
    }

    /// [`Self::submit`] with a v1.2 trace id (0 = untraced; the frame
    /// stays byte-identical to v1). A nonzero id follows the request
    /// through admission, batching, and execution server-side — pair it
    /// with the server's `--trace-sample`/`--trace-file` exporter.
    pub fn submit_traced(&mut self, lists: &[Vec<u32>], trace: u64) -> Result<u64> {
        anyhow::ensure!(
            !lists.is_empty() && lists.len() <= MAX_K,
            "k = {} outside 1..={MAX_K}",
            lists.len()
        );
        for (l, list) in lists.iter().enumerate() {
            anyhow::ensure!(
                list.len() <= MAX_LIST_LEN,
                "list {l} length {} exceeds {MAX_LIST_LEN}",
                list.len()
            );
        }
        // Per-list limits alone don't bound the frame (64 lists ×
        // 2^20 keys ≫ the frame cap): enforce the decoder's payload
        // limit here too, so an oversized request is a clean local
        // error instead of a server-side Corrupt + connection close
        // that discards every other pipelined request.
        let trace_bytes = if trace != 0 { 8 } else { 0 };
        let payload =
            3 + trace_bytes + 4 * lists.len() + 4 * lists.iter().map(Vec::len).sum::<usize>();
        anyhow::ensure!(
            payload <= MAX_REQUEST_BYTES,
            "request payload {payload} bytes exceeds {MAX_REQUEST_BYTES}"
        );
        let id = self.alloc_id();
        if self.proto2 {
            protocol::encode_merge_request_v2(id, MODE_MERGE, trace, lists, &mut self.wbuf);
        } else {
            encode_merge_request(MODE_MERGE, trace, lists, &mut self.wbuf);
        }
        self.write_wbuf(Some(id), "sending merge request")?;
        Ok(id)
    }

    /// Send one v1.1 key-value merge request without waiting:
    /// `payloads` is the list-major column, one `u64` per key. Returns
    /// the request id like [`Self::submit`].
    pub fn submit_kv(&mut self, lists: &[Vec<u32>], payloads: &[u64]) -> Result<u64> {
        self.submit_kv_traced(lists, payloads, 0)
    }

    /// [`Self::submit_kv`] with a v1.2 trace id (0 = untraced).
    pub fn submit_kv_traced(
        &mut self,
        lists: &[Vec<u32>],
        payloads: &[u64],
        trace: u64,
    ) -> Result<u64> {
        anyhow::ensure!(
            !lists.is_empty() && lists.len() <= MAX_K,
            "k = {} outside 1..={MAX_K}",
            lists.len()
        );
        let total: usize = lists.iter().map(Vec::len).sum();
        anyhow::ensure!(
            payloads.len() == total,
            "payload column holds {} values for {total} keys",
            payloads.len()
        );
        for (l, list) in lists.iter().enumerate() {
            anyhow::ensure!(
                list.len() <= MAX_LIST_LEN,
                "list {l} length {} exceeds {MAX_LIST_LEN}",
                list.len()
            );
        }
        // Same local enforcement of the decoder's payload cap as
        // `submit` — KV keys cost 12 wire bytes each.
        let trace_bytes = if trace != 0 { 8 } else { 0 };
        let payload = 3 + trace_bytes + 4 * lists.len() + 12 * total;
        anyhow::ensure!(
            payload <= MAX_REQUEST_BYTES,
            "request payload {payload} bytes exceeds {MAX_REQUEST_BYTES}"
        );
        let id = self.alloc_id();
        if self.proto2 {
            protocol::encode_merge_request_kv_v2(
                id, MODE_MERGE, trace, lists, payloads, &mut self.wbuf,
            );
        } else {
            encode_merge_request_kv(MODE_MERGE, trace, lists, payloads, &mut self.wbuf);
        }
        self.write_wbuf(Some(id), "sending KV merge request")?;
        Ok(id)
    }

    /// Fetch the server's live stats document (v1.2 `Stats` frames).
    /// Like [`Self::ping`], must not be interleaved with outstanding
    /// merges — the reply arrives in their order. Returns the parsed
    /// JSON; shape validation is [`crate::obs::expo::check_stats_doc`].
    pub fn stats(&mut self) -> Result<Json> {
        anyhow::ensure!(self.inflight == 0, "stats with {} merges in flight", self.inflight);
        let id = self.alloc_id();
        if self.proto2 {
            protocol::encode_stats_request_v2(id, &mut self.wbuf);
        } else {
            encode_stats_request(&mut self.wbuf);
        }
        self.write_wbuf(None, "sending stats request")?;
        match self.read_reply() {
            Ok((Frame::StatsResponse { json }, _)) => {
                Json::parse(&json).map_err(|e| anyhow!("unparsable stats document: {e}"))
            }
            // A typed refusal (e.g. the stats document overflowed the
            // frame limit) surfaces as a ServerError, not a bail — the
            // caller can branch on the code.
            Ok((Frame::Error { code, message }, rid)) => {
                Err(ServerError { code, message, id: rid.unwrap_or(0) }.into())
            }
            Ok((other, _)) => bail!("expected StatsResponse, got {other:?}"),
            Err(e) => Err(e.into_anyhow().context("awaiting stats response")),
        }
    }

    /// Receive the next response: the next in-order reply on v1, the
    /// next *completed* reply (any outstanding id) on v2 — check
    /// [`NetMerge::id`] to see which request it answers. A server
    /// `Error` frame surfaces as a typed [`ServerError`] inside the
    /// `anyhow` chain — downcast to branch on the code (its `id` names
    /// the errored request on v2).
    pub fn recv(&mut self) -> Result<NetMerge> {
        anyhow::ensure!(self.inflight > 0, "recv with nothing in flight");
        let deadline = self.op_deadline();
        let mut attempts = 0u32;
        let (frame, rid) = loop {
            match self.read_reply() {
                Ok(f) => break f,
                Err(ReadError::Protocol(m)) => bail!("undecodable server frame: {m}"),
                Err(e) => {
                    // Connection-level failure with requests in flight:
                    // reconnect and replay the unanswered window, then
                    // keep waiting for a reply.
                    self.reconnect_and_replay(&mut attempts, deadline, e.into_anyhow())?;
                }
            }
        };
        // Settle the replay window: v1 answers the front request
        // (ordering is the correlation, even for error replies); v2
        // answers whichever entry the echoed id names — an id we never
        // sent (or already answered) is a peer protocol violation.
        let id = if self.proto2 {
            let Some(rid) = rid else {
                bail!("v1-framed reply on a v2 connection");
            };
            let Some(pos) = self.unanswered.iter().position(|(i, _)| *i == rid) else {
                bail!("response carries unknown request id {rid}");
            };
            self.unanswered.remove(pos);
            rid
        } else {
            anyhow::ensure!(rid.is_none(), "v2-framed reply on a v1 connection");
            self.unanswered.pop_front();
            0
        };
        self.inflight -= 1;
        self.last_backoff = Duration::ZERO;
        match frame {
            Frame::MergeResponse { served_by, merged } => {
                Ok(NetMerge { id, merged, payloads: None, served_by })
            }
            Frame::MergeResponseKV { served_by, merged, payloads } => {
                Ok(NetMerge { id, merged, payloads: Some(payloads), served_by })
            }
            Frame::Error { code, message } => Err(ServerError { code, message, id }.into()),
            other => bail!("expected MergeResponse, got {other:?}"),
        }
    }

    /// Submit and wait — the one-shot convenience.
    pub fn merge(&mut self, lists: &[Vec<u32>]) -> Result<NetMerge> {
        self.submit(lists)?;
        self.recv()
    }

    /// Key-value submit-and-wait.
    pub fn merge_kv(&mut self, lists: &[Vec<u32>], payloads: &[u64]) -> Result<NetMerge> {
        self.submit_kv(lists, payloads)?;
        self.recv()
    }

    /// Outstanding pipelined requests.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    fn op_deadline(&self) -> Instant {
        let budget = self
            .retry
            .as_ref()
            .map(|p| p.deadline)
            .unwrap_or(Duration::from_secs(86_400));
        Instant::now() + budget
    }

    /// Write the encoded frame in `wbuf`; with a [`RetryPolicy`], a
    /// failed write reconnects, replays the unanswered window, and
    /// resends. `record` appends the frame to that window under the
    /// given request id (merge requests yes, pings/stats no — those
    /// require an empty window).
    fn write_wbuf(&mut self, record: Option<u64>, what: &'static str) -> Result<()> {
        let deadline = self.op_deadline();
        let mut attempts = 0u32;
        loop {
            match self.stream.write_all(&self.wbuf) {
                Ok(()) => {
                    if let Some(id) = record {
                        self.unanswered.push_back((id, self.wbuf.clone()));
                        self.inflight += 1;
                    }
                    self.last_backoff = Duration::ZERO;
                    return Ok(());
                }
                Err(e) => {
                    self.reconnect_and_replay(&mut attempts, deadline, anyhow!(e).context(what))?
                }
            }
        }
    }

    /// Decorrelated jitter: `min(cap, uniform(base, 3 × previous))`.
    fn next_backoff(&mut self, p: &RetryPolicy) -> Duration {
        let base = (p.base_backoff.as_nanos() as u64).max(1);
        let prev = (self.last_backoff.as_nanos() as u64).max(base);
        let hi = prev.saturating_mul(3).max(base + 1);
        let d = Duration::from_nanos(base + self.jitter.below(hi - base)).min(p.max_backoff);
        self.last_backoff = d;
        d
    }

    /// Reconnect within the retry budget and replay every unanswered
    /// request frame in order. Returns only with a healthy, replayed
    /// connection — or the original error wrapped with the attempt
    /// count once the budget is exhausted.
    fn reconnect_and_replay(
        &mut self,
        attempts: &mut u32,
        deadline: Instant,
        cause: anyhow::Error,
    ) -> Result<()> {
        let (Some(policy), Some(addr)) = (self.retry.clone(), self.addr) else {
            return Err(cause);
        };
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if *attempts >= policy.max_retries || left.is_zero() {
                return Err(cause.context(format!(
                    "connection not recovered after {attempts} reconnect attempts"
                )));
            }
            *attempts += 1;
            std::thread::sleep(self.next_backoff(&policy).min(left));
            let left = deadline.saturating_duration_since(Instant::now());
            let Ok(stream) =
                TcpStream::connect_timeout(&addr, left.max(Duration::from_millis(10)))
            else {
                continue;
            };
            let _ = stream.set_nodelay(true);
            self.stream = stream;
            self.reader = FrameReader::new();
            let NetClient { stream, unanswered, .. } = self;
            if unanswered.iter().all(|(_, f)| stream.write_all(f).is_ok()) {
                self.retries += 1;
                return Ok(());
            }
            // Replay died mid-window: loop and reconnect again.
        }
    }

    fn read_reply(&mut self) -> std::result::Result<(Frame, Option<u64>), ReadError> {
        loop {
            match self.reader.read_frame(&mut self.stream) {
                Ok(ReadFrame::Frame(f)) => return Ok((f, None)),
                Ok(ReadFrame::FrameV2(f, id)) => return Ok((f, Some(id))),
                Ok(ReadFrame::Pending) => continue, // frame still arriving
                Ok(ReadFrame::Eof) => return Err(ReadError::Closed),
                Ok(ReadFrame::Malformed(m)) | Ok(ReadFrame::Corrupt(m)) => {
                    return Err(ReadError::Protocol(m))
                }
                // The client sets no read timeout, but tolerate one if
                // the caller configured the socket directly.
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue
                }
                Err(e) => return Err(ReadError::Io(e)),
            }
        }
    }
}

/// Why a reply could not be read: connection-level failures
/// (`Closed`/`Io`) are recoverable by reconnect-and-replay; a
/// `Protocol` failure means the peer speaks garbage and retrying the
/// same bytes cannot help.
enum ReadError {
    Closed,
    Io(std::io::Error),
    Protocol(String),
}

impl ReadError {
    fn into_anyhow(self) -> anyhow::Error {
        match self {
            ReadError::Closed => anyhow!("server closed the connection"),
            ReadError::Io(e) => anyhow!(e).context("reading server reply"),
            ReadError::Protocol(m) => anyhow!("undecodable server frame: {m}"),
        }
    }
}

fn code_name(c: u8) -> &'static str {
    match c {
        code::MALFORMED => "MALFORMED",
        code::REJECTED => "REJECTED",
        code::UNSUPPORTED => "UNSUPPORTED",
        code::OVERLOADED => "OVERLOADED",
        _ => "UNKNOWN",
    }
}

/// Load-generator output (one run over all connections).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub connections: usize,
    pub inflight: usize,
    /// Responses byte-identical to the scalar oracle.
    pub ok: usize,
    /// Error replies or oracle mismatches.
    pub errors: usize,
    /// Recoveries performed while driving the load: client
    /// reconnect-and-replays plus `OVERLOADED` resubmissions.
    pub retries: u64,
    /// Connections that died unrecoverably mid-load (their remaining
    /// requests are not counted in `ok`/`errors`).
    pub failed_conns: usize,
    /// One diagnostic line per failed connection.
    pub conn_errors: Vec<String>,
    pub elapsed: Duration,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl LoadReport {
    pub fn requests_per_s(&self) -> f64 {
        (self.ok + self.errors) as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// Percentile over latency samples (µs), routed through the shared
/// obs histogram ([`crate::obs::hist`]): ceil-rank selection over
/// log-linear buckets. The one percentile definition shared by the
/// load generator, `benches/net_serving.rs`, the service metrics, and
/// the stats wire endpoint — all four report identically-defined
/// p50/p99. Samples need not be sorted.
pub fn percentile_us(samples: &[f64], q: f64) -> f64 {
    crate::obs::percentile_us(samples, q)
}

/// The bench-net workload: ragged 2-way requests shaped for the
/// `loms2_up32_dn32_b256` artifact (lengths 1..=32, keys < 2^20 — well
/// clear of the PAD sentinel).
pub fn workload_lists(rng: &mut crate::util::Rng) -> Vec<Vec<u32>> {
    let la = rng.range(1, 33);
    let lb = rng.range(1, 33);
    vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)]
}

/// One in-flight load request: the original lists and payload column
/// (kept so an `OVERLOADED` shed can be resubmitted), the expected
/// output, the first-submit timestamp, and how many times it has been
/// resubmitted.
struct Pending {
    lists: Vec<Vec<u32>>,
    pays: Option<Vec<u64>>,
    want: Vec<u32>,
    want_pays: Option<Vec<u64>>,
    sent_at: Instant,
    resubmits: u32,
}

/// Most times one shed request is resubmitted before counting as an
/// error — bounds the drain loop under a permanently overloaded server.
const MAX_OVERLOAD_RESUBMITS: u32 = 64;

/// Pop the pending entry a reply settles: the front of the window on
/// v1 (ordering is the correlation), the id-matched entry on v2
/// (replies arrive in completion order).
fn take_pending(pending: &mut VecDeque<(u64, Pending)>, v2: bool, id: u64) -> Option<Pending> {
    if !v2 {
        return pending.pop_front().map(|(_, p)| p);
    }
    let pos = pending.iter().position(|(i, _)| *i == id)?;
    pending.remove(pos).map(|(_, p)| p)
}

/// Receive one response and score it against its oracle (shared by
/// the submit-loop window and the tail drain). An `OVERLOADED` shed is
/// resubmitted (bounded) instead of counted; connection-level failures
/// surface as `Err` and fail the connection.
fn drain_one(
    client: &mut NetClient,
    pending: &mut VecDeque<(u64, Pending)>,
    v2: bool,
    ok: &mut usize,
    errors: &mut usize,
    resubmits: &mut u64,
    lat_us: &mut Vec<f64>,
) -> Result<()> {
    match client.recv() {
        Ok(resp) => {
            let Some(p) = take_pending(pending, v2, resp.id) else {
                bail!("reply for untracked request id {}", resp.id);
            };
            if resp.merged == p.want && resp.payloads == p.want_pays {
                *ok += 1;
            } else {
                *errors += 1;
            }
            lat_us.push(p.sent_at.elapsed().as_nanos() as f64 / 1_000.0);
        }
        Err(e) => {
            // A server error settles its request; a connection-level
            // error (retry budget exhausted) is fatal for the whole
            // connection.
            let Some(se) = e.downcast_ref::<ServerError>() else {
                return Err(e.context("receiving load response"));
            };
            let overloaded = se.is_overloaded();
            let Some(mut p) = take_pending(pending, v2, se.id) else {
                bail!("error reply for untracked request id {}", se.id);
            };
            if overloaded && p.resubmits < MAX_OVERLOAD_RESUBMITS {
                // Shed at admission: the request was never submitted,
                // so resending is always safe. It rejoins this
                // connection's window (under the fresh id on v2), with
                // its oracle and original timestamp riding along.
                *resubmits += 1;
                p.resubmits += 1;
                std::thread::sleep(Duration::from_millis(1 << p.resubmits.min(5)));
                let id = match &p.pays {
                    Some(pays) => client.submit_kv(&p.lists, pays)?,
                    None => client.submit(&p.lists)?,
                };
                pending.push_back((id, p));
            } else {
                *errors += 1;
                lat_us.push(p.sent_at.elapsed().as_nanos() as f64 / 1_000.0);
            }
        }
    }
    Ok(())
}

/// Drive `total_requests` requests through `connections` parallel
/// clients, each keeping up to `inflight` requests pipelined. With
/// `kv`, every request carries a unique-tagged payload column. Every
/// response is checked byte-exact against a sort oracle computed at
/// submit time (`sort_unstable` of the keys; a *stable* pair sort for
/// the payload column — the protocol's duplicate-key contract);
/// mismatches and error replies count as `errors`. Latency is measured
/// per request, submit to receive.
///
/// Every client is armed with the default [`RetryPolicy`], so killed
/// connections are reconnected and replayed and `OVERLOADED` sheds are
/// resubmitted (both counted in [`LoadReport::retries`]). A connection
/// that still fails is *recorded* — its diagnostic lands in
/// [`LoadReport::conn_errors`] — instead of aborting the whole load.
pub fn run_load(
    addr: &str,
    connections: usize,
    inflight: usize,
    total_requests: usize,
    seed: u64,
    kv: bool,
) -> Result<LoadReport> {
    run_load_with(addr, connections, inflight, total_requests, seed, kv, false)
}

/// [`run_load`] with a protocol selector: `v2` drives every connection
/// over protocol v2 (explicit request ids, replies in completion
/// order, oracle matched per id) instead of v1's in-order pipeline.
pub fn run_load_with(
    addr: &str,
    connections: usize,
    inflight: usize,
    total_requests: usize,
    seed: u64,
    kv: bool,
    v2: bool,
) -> Result<LoadReport> {
    anyhow::ensure!(connections >= 1 && inflight >= 1, "need >=1 connection and inflight");
    let per_conn = total_requests.div_ceil(connections);
    let t0 = Instant::now();
    type ConnResult = Result<(usize, usize, u64, Vec<f64>)>;
    let results: Vec<std::thread::Result<ConnResult>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                s.spawn(move || -> ConnResult {
                    let raw = if v2 {
                        NetClient::connect_v2(addr)?
                    } else {
                        NetClient::connect(addr)?
                    };
                    let mut client = raw.with_retry(RetryPolicy {
                        seed: seed ^ (c as u64).wrapping_mul(0xD1B5),
                        ..RetryPolicy::default()
                    });
                    let mut rng = crate::util::Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37));
                    let mut pending: VecDeque<(u64, Pending)> = VecDeque::new();
                    let (mut ok, mut errors) = (0usize, 0usize);
                    let mut resubmits = 0u64;
                    let mut lat_us = Vec::with_capacity(per_conn);
                    for r in 0..per_conn {
                        let lists = workload_lists(&mut rng);
                        let (id, p) = if kv {
                            let keys: Vec<u32> = lists.concat();
                            // Unique tags so the oracle discriminates
                            // payload routing exactly.
                            let pays: Vec<u64> = (0..keys.len() as u64)
                                .map(|i| ((r as u64) << 16) | i)
                                .collect();
                            let mut pairs: Vec<(u32, u64)> =
                                keys.into_iter().zip(pays.iter().copied()).collect();
                            pairs.sort_by_key(|&(k, _)| k); // stable
                            let want: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
                            let want_pays: Vec<u64> = pairs.iter().map(|&(_, p)| p).collect();
                            let id = client.submit_kv(&lists, &pays)?;
                            (
                                id,
                                Pending {
                                    lists,
                                    pays: Some(pays),
                                    want,
                                    want_pays: Some(want_pays),
                                    sent_at: Instant::now(),
                                    resubmits: 0,
                                },
                            )
                        } else {
                            let mut want: Vec<u32> = lists.concat();
                            want.sort_unstable();
                            let id = client.submit(&lists)?;
                            (
                                id,
                                Pending {
                                    lists,
                                    pays: None,
                                    want,
                                    want_pays: None,
                                    sent_at: Instant::now(),
                                    resubmits: 0,
                                },
                            )
                        };
                        pending.push_back((id, p));
                        if pending.len() >= inflight {
                            drain_one(
                                &mut client, &mut pending, v2, &mut ok, &mut errors,
                                &mut resubmits, &mut lat_us,
                            )?;
                        }
                    }
                    while !pending.is_empty() {
                        drain_one(
                            &mut client, &mut pending, v2, &mut ok, &mut errors, &mut resubmits,
                            &mut lat_us,
                        )?;
                    }
                    Ok((ok, errors, resubmits + client.retries(), lat_us))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    let elapsed = t0.elapsed();
    let (mut ok, mut errors, mut retries) = (0usize, 0usize, 0u64);
    let mut failed_conns = 0usize;
    let mut conn_errors = Vec::new();
    let mut lat_us: Vec<f64> = Vec::new();
    for (c, r) in results.into_iter().enumerate() {
        match r {
            Ok(Ok((o, e, rt, l))) => {
                ok += o;
                errors += e;
                retries += rt;
                lat_us.extend(l);
            }
            Ok(Err(e)) => {
                failed_conns += 1;
                conn_errors.push(format!("connection {c}: {e:#}"));
            }
            Err(_) => {
                failed_conns += 1;
                conn_errors.push(format!("connection {c}: load thread panicked"));
            }
        }
    }
    Ok(LoadReport {
        connections,
        inflight,
        ok,
        errors,
        retries,
        failed_conns,
        conn_errors,
        elapsed,
        p50_us: percentile_us(&lat_us, 0.50),
        p99_us: percentile_us(&lat_us, 0.99),
    })
}
