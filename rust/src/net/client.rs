//! Blocking client for the framed merge protocol, plus the multi-
//! connection load generator behind `loms bench-net` and
//! `benches/net_serving.rs`.
//!
//! [`NetClient`] supports *pipelined* submission: any number of
//! [`NetClient::submit`] calls may be outstanding before the matching
//! [`NetClient::recv`] calls — responses arrive strictly in request
//! order (the protocol carries no ids; ordering is the correlation).
//! Encoding reuses one write buffer, so a steady-state client
//! allocates only the decoded response vectors.

use super::protocol::{
    self, code, encode_merge_request, encode_merge_request_kv, Frame, FrameReader, ReadFrame,
    MAX_K, MAX_LIST_LEN, MAX_REQUEST_BYTES, MODE_MERGE,
};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// One merged response off the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetMerge {
    pub merged: Vec<u32>,
    /// Key-value requests only: the merged payload column,
    /// `payloads[t]` riding with `merged[t]`.
    pub payloads: Option<Vec<u64>>,
    /// Which artifact (or `"software"`) served it, per the server.
    pub served_by: String,
}

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    stream: TcpStream,
    reader: FrameReader,
    wbuf: Vec<u8>,
    /// Requests submitted but not yet received (sanity accounting).
    inflight: usize,
}

impl NetClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).context("connecting to merge server")?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, reader: FrameReader::new(), wbuf: Vec::new(), inflight: 0 })
    }

    /// Liveness probe: Ping, expect Pong. Must not be interleaved with
    /// outstanding merges (the Pong would arrive in their order).
    pub fn ping(&mut self) -> Result<()> {
        anyhow::ensure!(self.inflight == 0, "ping with {} merges in flight", self.inflight);
        protocol::encode_frame(&Frame::Ping, &mut self.wbuf);
        self.stream.write_all(&self.wbuf).context("sending ping")?;
        match self.read_reply()? {
            Frame::Pong => Ok(()),
            other => bail!("expected Pong, got {other:?}"),
        }
    }

    /// Send one merge request without waiting (pipelined submission).
    pub fn submit(&mut self, lists: &[Vec<u32>]) -> Result<()> {
        anyhow::ensure!(
            !lists.is_empty() && lists.len() <= MAX_K,
            "k = {} outside 1..={MAX_K}",
            lists.len()
        );
        for (l, list) in lists.iter().enumerate() {
            anyhow::ensure!(
                list.len() <= MAX_LIST_LEN,
                "list {l} length {} exceeds {MAX_LIST_LEN}",
                list.len()
            );
        }
        // Per-list limits alone don't bound the frame (64 lists ×
        // 2^20 keys ≫ the frame cap): enforce the decoder's payload
        // limit here too, so an oversized request is a clean local
        // error instead of a server-side Corrupt + connection close
        // that discards every other pipelined request.
        let payload = 3 + 4 * lists.len() + 4 * lists.iter().map(Vec::len).sum::<usize>();
        anyhow::ensure!(
            payload <= MAX_REQUEST_BYTES,
            "request payload {payload} bytes exceeds {MAX_REQUEST_BYTES}"
        );
        encode_merge_request(MODE_MERGE, lists, &mut self.wbuf);
        self.stream.write_all(&self.wbuf).context("sending merge request")?;
        self.inflight += 1;
        Ok(())
    }

    /// Send one v1.1 key-value merge request without waiting:
    /// `payloads` is the list-major column, one `u64` per key.
    pub fn submit_kv(&mut self, lists: &[Vec<u32>], payloads: &[u64]) -> Result<()> {
        anyhow::ensure!(
            !lists.is_empty() && lists.len() <= MAX_K,
            "k = {} outside 1..={MAX_K}",
            lists.len()
        );
        let total: usize = lists.iter().map(Vec::len).sum();
        anyhow::ensure!(
            payloads.len() == total,
            "payload column holds {} values for {total} keys",
            payloads.len()
        );
        for (l, list) in lists.iter().enumerate() {
            anyhow::ensure!(
                list.len() <= MAX_LIST_LEN,
                "list {l} length {} exceeds {MAX_LIST_LEN}",
                list.len()
            );
        }
        // Same local enforcement of the decoder's payload cap as
        // `submit` — KV keys cost 12 wire bytes each.
        let payload = 3 + 4 * lists.len() + 12 * total;
        anyhow::ensure!(
            payload <= MAX_REQUEST_BYTES,
            "request payload {payload} bytes exceeds {MAX_REQUEST_BYTES}"
        );
        encode_merge_request_kv(MODE_MERGE, lists, payloads, &mut self.wbuf);
        self.stream.write_all(&self.wbuf).context("sending KV merge request")?;
        self.inflight += 1;
        Ok(())
    }

    /// Receive the next in-order response. An error frame surfaces as
    /// `Err` carrying the server's code and message.
    pub fn recv(&mut self) -> Result<NetMerge> {
        anyhow::ensure!(self.inflight > 0, "recv with nothing in flight");
        self.inflight -= 1;
        match self.read_reply()? {
            Frame::MergeResponse { served_by, merged } => {
                Ok(NetMerge { merged, payloads: None, served_by })
            }
            Frame::MergeResponseKV { served_by, merged, payloads } => {
                Ok(NetMerge { merged, payloads: Some(payloads), served_by })
            }
            Frame::Error { code, message } => {
                bail!("server error {}: {message}", code_name(code))
            }
            other => bail!("expected MergeResponse, got {other:?}"),
        }
    }

    /// Submit and wait — the one-shot convenience.
    pub fn merge(&mut self, lists: &[Vec<u32>]) -> Result<NetMerge> {
        self.submit(lists)?;
        self.recv()
    }

    /// Key-value submit-and-wait.
    pub fn merge_kv(&mut self, lists: &[Vec<u32>], payloads: &[u64]) -> Result<NetMerge> {
        self.submit_kv(lists, payloads)?;
        self.recv()
    }

    /// Outstanding pipelined requests.
    pub fn inflight(&self) -> usize {
        self.inflight
    }

    fn read_reply(&mut self) -> Result<Frame> {
        loop {
            match self.reader.read_frame(&mut self.stream) {
                Ok(ReadFrame::Frame(f)) => return Ok(f),
                Ok(ReadFrame::Pending) => continue, // frame still arriving
                Ok(ReadFrame::Eof) => bail!("server closed the connection"),
                Ok(ReadFrame::Malformed(m)) | Ok(ReadFrame::Corrupt(m)) => {
                    bail!("undecodable server frame: {m}")
                }
                // The client sets no read timeout, but tolerate one if
                // the caller configured the socket directly.
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue
                }
                Err(e) => return Err(anyhow!(e).context("reading server reply")),
            }
        }
    }
}

fn code_name(c: u8) -> &'static str {
    match c {
        code::MALFORMED => "MALFORMED",
        code::REJECTED => "REJECTED",
        code::UNSUPPORTED => "UNSUPPORTED",
        _ => "UNKNOWN",
    }
}

/// Load-generator output (one run over all connections).
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub connections: usize,
    pub inflight: usize,
    /// Responses byte-identical to the scalar oracle.
    pub ok: usize,
    /// Error replies or oracle mismatches.
    pub errors: usize,
    pub elapsed: Duration,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl LoadReport {
    pub fn requests_per_s(&self) -> f64 {
        (self.ok + self.errors) as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }
}

/// Ceil-index percentile over an ascending slice (µs). The one
/// definition shared by the load generator and `benches/net_serving.rs`
/// so both report identically-defined p50/p99.
pub fn percentile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).saturating_sub(1);
    sorted[idx.min(sorted.len() - 1)]
}

/// The bench-net workload: ragged 2-way requests shaped for the
/// `loms2_up32_dn32_b256` artifact (lengths 1..=32, keys < 2^20 — well
/// clear of the PAD sentinel).
pub fn workload_lists(rng: &mut crate::util::Rng) -> Vec<Vec<u32>> {
    let la = rng.range(1, 33);
    let lb = rng.range(1, 33);
    vec![rng.sorted_list(la, 1 << 20), rng.sorted_list(lb, 1 << 20)]
}

/// One oracle entry: the expected keys, the expected payload column
/// (key-value mode only), and the submit timestamp.
type Pending = (Vec<u32>, Option<Vec<u64>>, Instant);

/// Receive one in-order response and score it against its oracle
/// (shared by the submit-loop window and the tail drain).
fn drain_one(
    client: &mut NetClient,
    pending: &mut VecDeque<Pending>,
    ok: &mut usize,
    errors: &mut usize,
    lat_us: &mut Vec<f64>,
) {
    let (want, want_pays, sent_at) = pending.pop_front().expect("drain with nothing pending");
    match client.recv() {
        Ok(resp) if resp.merged == want && resp.payloads == want_pays => *ok += 1,
        Ok(_) | Err(_) => *errors += 1,
    }
    lat_us.push(sent_at.elapsed().as_nanos() as f64 / 1_000.0);
}

/// Drive `total_requests` requests through `connections` parallel
/// clients, each keeping up to `inflight` requests pipelined. With
/// `kv`, every request carries a unique-tagged payload column. Every
/// response is checked byte-exact against a sort oracle computed at
/// submit time (`sort_unstable` of the keys; a *stable* pair sort for
/// the payload column — the protocol's duplicate-key contract);
/// mismatches and error replies count as `errors`. Latency is measured
/// per request, submit to receive.
pub fn run_load(
    addr: &str,
    connections: usize,
    inflight: usize,
    total_requests: usize,
    seed: u64,
    kv: bool,
) -> Result<LoadReport> {
    anyhow::ensure!(connections >= 1 && inflight >= 1, "need >=1 connection and inflight");
    let per_conn = total_requests.div_ceil(connections);
    let t0 = Instant::now();
    let results: Vec<Result<(usize, usize, Vec<f64>)>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..connections)
            .map(|c| {
                s.spawn(move || -> Result<(usize, usize, Vec<f64>)> {
                    let mut client = NetClient::connect(addr)?;
                    let mut rng = crate::util::Rng::new(seed ^ (c as u64).wrapping_mul(0x9E37));
                    let mut pending: VecDeque<Pending> = VecDeque::new();
                    let (mut ok, mut errors) = (0usize, 0usize);
                    let mut lat_us = Vec::with_capacity(per_conn);
                    for r in 0..per_conn {
                        let lists = workload_lists(&mut rng);
                        if kv {
                            let keys: Vec<u32> = lists.concat();
                            // Unique tags so the oracle discriminates
                            // payload routing exactly.
                            let pays: Vec<u64> = (0..keys.len() as u64)
                                .map(|i| ((r as u64) << 16) | i)
                                .collect();
                            let mut pairs: Vec<(u32, u64)> =
                                keys.into_iter().zip(pays.iter().copied()).collect();
                            pairs.sort_by_key(|&(k, _)| k); // stable
                            let want: Vec<u32> = pairs.iter().map(|&(k, _)| k).collect();
                            let want_pays: Vec<u64> = pairs.iter().map(|&(_, p)| p).collect();
                            client.submit_kv(&lists, &pays)?;
                            pending.push_back((want, Some(want_pays), Instant::now()));
                        } else {
                            let mut want: Vec<u32> = lists.concat();
                            want.sort_unstable();
                            client.submit(&lists)?;
                            pending.push_back((want, None, Instant::now()));
                        }
                        if pending.len() >= inflight {
                            drain_one(
                                &mut client, &mut pending, &mut ok, &mut errors, &mut lat_us,
                            );
                        }
                    }
                    while !pending.is_empty() {
                        drain_one(&mut client, &mut pending, &mut ok, &mut errors, &mut lat_us);
                    }
                    Ok((ok, errors, lat_us))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("load thread panicked")).collect()
    });
    let elapsed = t0.elapsed();
    let (mut ok, mut errors) = (0usize, 0usize);
    let mut lat_us: Vec<f64> = Vec::new();
    for r in results {
        let (o, e, l) = r?;
        ok += o;
        errors += e;
        lat_us.extend(l);
    }
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latency"));
    Ok(LoadReport {
        connections,
        inflight,
        ok,
        errors,
        elapsed,
        p50_us: percentile_us(&lat_us, 0.50),
        p99_us: percentile_us(&lat_us, 0.99),
    })
}
