//! The wire protocol of the networked merge service: versioned,
//! length-prefixed binary frames over a byte stream (TCP in practice —
//! nothing here touches a socket).
//!
//! ## Frame grammar
//!
//! ```text
//! frame   := len:u32le body            (len = body length, 2..=MAX_FRAME_BYTES)
//! body    := version:u8 type:u8 payload
//!
//! payload by type:
//!   1 MergeRequest   mode:u8 [trace:u64le] k:u16le len[0]:u32le .. len[k-1]:u32le
//!                    keys of list 0 .. keys of list k-1   (each key u32le)
//!   2 MergeResponse  served_by_len:u8 served_by:bytes n:u32le key*n:u32le
//!   3 Error          code:u8 msg_len:u16le msg:bytes (UTF-8)
//!   4 Ping           (empty)
//!   5 Pong           (empty)
//!   6 MergeRequestKV  (v1.1) mode:u8 [trace:u64le] k:u16le len[0..k):u32le
//!                    keys of list 0 .. keys of list k-1   (each key u32le)
//!                    payload*Σlen: u64le   (list-major, one per key)
//!   7 MergeResponseKV (v1.1) served_by_len:u8 served_by:bytes
//!                    n:u32le key*n:u32le payload*n:u64le
//!   8 StatsRequest   (v1.2) (empty)
//!   9 StatsResponse  (v1.2) json_len:u32le json:bytes (UTF-8, see
//!                    crate::obs::expo for the document grammar)
//! ```
//!
//! Frame types 6/7 are the **v1.1** key-value extension. The version
//! byte stays `1` and every v1 frame is byte-identical, so a v1 client
//! works unchanged against a v1.1 server; a v1 *server* answers type
//! 6 with a `MALFORMED` error frame (unknown type) without dropping
//! the connection — exactly the forward-compatibility the `Malformed`
//! decode semantics were designed for.
//!
//! **v1.2** extends the same way twice over. (a) Request frames carry
//! an *optional* trace id: bit 7 of the mode byte
//! ([`MODE_FLAG_TRACE`]) says a `u64le` trace id follows the mode
//! byte; an untraced request (trace 0) never sets the bit, so every
//! v1/v1.1 frame is still byte-identical and an old server never sees
//! the flag from an old client. (b) The `Stats` request/response pair
//! (types 8/9) serves the live metrics document — answered even when
//! the server is shedding merge load (an operator inspecting an
//! overloaded server is exactly the point).
//!
//! ## Protocol v2: request ids
//!
//! A **v2** body is `version(=2):u8 type:u8 req_id:u64le payload` —
//! the payload grammar per type is *unchanged* from v1.2 (trace flag
//! included); the only difference is the version byte and the eight id
//! bytes between the type byte and the payload, uniformly on every
//! frame type. The id is chosen by the requester and echoed verbatim
//! in the reply, so replies may complete **out of order**, many
//! logical clients can multiplex one connection, and reconnect-replay
//! keys on ids instead of strict ordering.
//!
//! Negotiation is per connection and implicit: the first decoded frame
//! latches the connection to its version. A v1 peer never sees an id
//! (replies stay strictly in request order — ordering is the
//! correlation, exactly the v1 contract); after the latch, a frame of
//! the *other* version is answered with a typed `MALFORMED` error and
//! the connection keeps serving. On a v2 connection a request id may
//! not be reused while its reply is outstanding (duplicate in-flight
//! ids get a `MALFORMED` error echoing the id); once the reply is
//! released the id is free for reuse. v1/v1.1/v1.2 frames keep
//! decoding byte-identically — nothing about v2 moves a v1 byte.
//!
//! All integers are little-endian — the same byte order as the extsort
//! spill format ([`crate::stream::source::FileRunStream`]), so a spill
//! run can be framed without per-key byte swapping.
//!
//! ## Limits (enforced by the decoder, not just documented)
//!
//! * [`MAX_FRAME_BYTES`] — hard cap on `len`; a larger prefix is
//!   unrecoverable corruption ([`ReadFrame::Corrupt`]) because the
//!   reader cannot know where the next frame boundary would be.
//! * [`MAX_REQUEST_BYTES`] — cap on a MergeRequest payload, held
//!   slightly *below* the frame cap so the response to a maximal
//!   request (same keys plus a served-by label) still frames.
//! * [`MAX_K`] / [`MAX_LIST_LEN`] — per-request shape caps.
//!
//! ## Decode semantics
//!
//! [`FrameReader`] accumulates bytes and yields one [`ReadFrame`] per
//! call. A body that fails to decode under an intact length prefix is
//! [`ReadFrame::Malformed`]: the reader has already consumed the frame,
//! so the connection can answer with an [`Frame::Error`] and keep
//! going. Only a corrupt length prefix or a mid-frame disconnect kills
//! the connection. Request keys are decoded straight from the receive
//! buffer into per-list `Vec<u32>`s — the exact vectors handed to
//! [`crate::coordinator::MergeService::submit`] — so the socket-to-tile
//! path stays at one copy on the way in (see `rust/DESIGN.md`
//! §"Network serving").
//!
//! Sortedness is deliberately *not* checked here: admission validation
//! (sorted ascending, no `u32::MAX` sentinel) is the service's
//! contract, and the server answers violations with a
//! [`code::REJECTED`] error frame rather than a protocol error.

use std::io::{self, Read};

/// Protocol version carried in every frame body.
pub const PROTOCOL_VERSION: u8 = 1;

/// Protocol v2: same payload grammar, plus a `req_id:u64le` between
/// the type byte and the payload, echoed in replies (see the module
/// docs for the negotiation and id-lifecycle rules).
pub const PROTOCOL_V2: u8 = 2;

/// Hard cap on a frame body (`len` field). Includes headroom above
/// [`MAX_REQUEST_BYTES`] so a maximal request's response — the same
/// keys plus the served-by label and count — still fits in one frame.
pub const MAX_FRAME_BYTES: usize = (16 << 20) + 4096;

/// Cap on a MergeRequest payload (mode + k + lens + keys).
pub const MAX_REQUEST_BYTES: usize = 16 << 20;

/// Maximum lists per merge request.
pub const MAX_K: usize = 64;

/// Maximum keys per list.
pub const MAX_LIST_LEN: usize = 1 << 20;

/// Longest error message the encoder will put on the wire.
pub const MAX_ERROR_MSG: usize = 512;

/// Request mode byte: a plain k-way merge. Other *mode* values (bits
/// 0..=6) are reserved; the server answers them with
/// [`code::UNSUPPORTED`].
pub const MODE_MERGE: u8 = 0;

/// v1.2 mode-byte flag: a `u64le` trace id follows the mode byte.
/// Trace 0 ("untraced") always encodes *without* the flag, keeping
/// pre-v1.2 request frames byte-identical.
pub const MODE_FLAG_TRACE: u8 = 0x80;

/// Cap on a StatsResponse JSON body.
pub const MAX_STATS_BYTES: usize = 1 << 20;

/// Frame type bytes.
const TYPE_MERGE_REQUEST: u8 = 1;
const TYPE_MERGE_RESPONSE: u8 = 2;
const TYPE_ERROR: u8 = 3;
const TYPE_PING: u8 = 4;
const TYPE_PONG: u8 = 5;
const TYPE_MERGE_REQUEST_KV: u8 = 6;
const TYPE_MERGE_RESPONSE_KV: u8 = 7;
const TYPE_STATS_REQUEST: u8 = 8;
const TYPE_STATS_RESPONSE: u8 = 9;

/// Error frame codes.
pub mod code {
    /// The frame did not decode (bad version, type, shape or size).
    pub const MALFORMED: u8 = 1;
    /// The service refused the request (unsorted list, `u32::MAX`
    /// sentinel key, or the service is shutting down).
    pub const REJECTED: u8 = 2;
    /// Well-formed but not servable here (reserved mode byte, or a
    /// frame type this endpoint never accepts).
    pub const UNSUPPORTED: u8 = 3;
    /// The server refused the request at admission because its
    /// pending-work gauge was over the shed watermark. Unlike the
    /// other codes this one is *retryable*: the request was never
    /// submitted, so resending it later is always safe.
    pub const OVERLOADED: u8 = 4;
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// `trace` is the v1.2 optional trace id (0 = untraced; wire
    /// presence governed by [`MODE_FLAG_TRACE`]).
    MergeRequest { mode: u8, trace: u64, lists: Vec<Vec<u32>> },
    MergeResponse { served_by: String, merged: Vec<u32> },
    Error { code: u8, message: String },
    Ping,
    Pong,
    /// v1.1 key-value merge request: `payloads` is the list-major
    /// column, exactly one `u64` per key across all lists.
    MergeRequestKV { mode: u8, trace: u64, lists: Vec<Vec<u32>>, payloads: Vec<u64> },
    /// v1.1 key-value response: `payloads[t]` rides with `merged[t]`.
    MergeResponseKV { served_by: String, merged: Vec<u32>, payloads: Vec<u64> },
    /// v1.2 stats poll (empty payload; never shed).
    StatsRequest,
    /// v1.2 stats document (JSON, grammar in `crate::obs::expo`).
    StatsResponse { json: String },
}

/// Outcome of one [`FrameReader::read_frame`] call.
#[derive(Debug)]
pub enum ReadFrame {
    /// A well-formed v1/v1.1/v1.2 frame.
    Frame(Frame),
    /// A well-formed v2 frame and its request id.
    FrameV2(Frame, u64),
    /// Bytes arrived but no complete frame is buffered yet — call
    /// again. Surfacing between socket reads (instead of looping
    /// internally) lets the server re-check its shutdown flag even
    /// against a peer that trickles a large frame one byte at a time.
    Pending,
    /// Clean close at a frame boundary.
    Eof,
    /// The length prefix was intact but the body failed to decode. The
    /// bytes are consumed — the stream is still in sync and the caller
    /// may reply with an error frame and continue reading.
    Malformed(String),
    /// The length prefix itself is unusable (outside
    /// `2..=MAX_FRAME_BYTES`). Resynchronisation is impossible; the
    /// caller must close the connection after an optional error reply.
    Corrupt(String),
}

/// How many bytes one [`FrameReader::read_frame`] call asks the
/// transport for.
const READ_CHUNK: usize = 16 * 1024;

/// Incremental frame reader: accumulates stream bytes and parses one
/// frame at a time, performing at most **one** transport read per call
/// ([`ReadFrame::Pending`] when the frame is still incomplete).
/// Reads land directly in the accumulation buffer's tail — no
/// intermediate chunk copy. Tolerates read timeouts (`WouldBlock` /
/// `TimedOut` surface as `Err` with partial bytes retained), which is
/// how the server polls its shutdown flag without losing frame sync.
/// A disconnect mid-frame surfaces as `ErrorKind::UnexpectedEof`.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    /// Consumed prefix of `buf`. Parsing advances this cursor instead
    /// of draining per frame (a per-frame drain would memmove the
    /// whole residual buffer once per pipelined frame — quadratic in
    /// frames per read); the buffer compacts once per transport read.
    pos: usize,
}

impl FrameReader {
    pub fn new() -> Self {
        FrameReader::default()
    }

    pub fn read_frame<R: Read>(&mut self, r: &mut R) -> io::Result<ReadFrame> {
        if let Some(out) = self.try_parse() {
            return Ok(out);
        }
        // Compact once per transport read, not once per frame.
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        let old = self.buf.len();
        self.buf.resize(old + READ_CHUNK, 0);
        let n = match r.read(&mut self.buf[old..]) {
            Ok(n) => n,
            Err(e) => {
                self.buf.truncate(old); // keep frame sync across timeouts
                return Err(e);
            }
        };
        self.buf.truncate(old + n);
        if n == 0 {
            return if old == 0 {
                Ok(ReadFrame::Eof)
            } else {
                Err(io::Error::new(io::ErrorKind::UnexpectedEof, "peer disconnected mid-frame"))
            };
        }
        Ok(self.try_parse().unwrap_or(ReadFrame::Pending))
    }

    fn try_parse(&mut self) -> Option<ReadFrame> {
        let start = self.pos;
        if self.buf.len() - start < 4 {
            return None;
        }
        let len = u32::from_le_bytes([
            self.buf[start],
            self.buf[start + 1],
            self.buf[start + 2],
            self.buf[start + 3],
        ]) as usize;
        if len < 2 || len > MAX_FRAME_BYTES {
            // Deliberately not consumed: the stream cannot be resynced.
            return Some(ReadFrame::Corrupt(format!(
                "frame length {len} outside 2..={MAX_FRAME_BYTES}"
            )));
        }
        if self.buf.len() - start < 4 + len {
            return None;
        }
        let result = match decode_body(&self.buf[start + 4..start + 4 + len]) {
            Ok((f, None)) => ReadFrame::Frame(f),
            Ok((f, Some(id))) => ReadFrame::FrameV2(f, id),
            Err(msg) => ReadFrame::Malformed(msg),
        };
        self.pos = start + 4 + len;
        Some(result)
    }
}

/// Decode one frame body (`version type [req_id] payload`, length
/// already validated against [`MAX_FRAME_BYTES`]). The second tuple
/// element is the v2 request id (`None` for v1/v1.1/v1.2 bodies).
fn decode_body(body: &[u8]) -> Result<(Frame, Option<u64>), String> {
    debug_assert!(body.len() >= 2);
    let version = body[0];
    if version != PROTOCOL_VERSION && version != PROTOCOL_V2 {
        return Err(format!(
            "unsupported protocol version {version} (expected {PROTOCOL_VERSION} or {PROTOCOL_V2})"
        ));
    }
    let ty = body[1];
    let mut c = Cur { b: &body[2..], i: 0 };
    let req_id = if version == PROTOCOL_V2 { Some(c.u64("request id")?) } else { None };
    Ok((decode_payload(ty, &mut c)?, req_id))
}

/// Decode one frame payload; `c` sits just past the header (and, for
/// v2, past the request id), so the grammar below is version-agnostic.
fn decode_payload(ty: u8, c: &mut Cur) -> Result<Frame, String> {
    match ty {
        TYPE_MERGE_REQUEST => {
            if c.remaining() > MAX_REQUEST_BYTES {
                return Err(format!(
                    "merge request payload {} exceeds {MAX_REQUEST_BYTES} bytes",
                    c.remaining()
                ));
            }
            let (mode, trace) = c.mode_and_trace()?;
            let k = c.u16("k")? as usize;
            if k == 0 || k > MAX_K {
                return Err(format!("k = {k} outside 1..={MAX_K}"));
            }
            let mut lens = Vec::with_capacity(k);
            for l in 0..k {
                let n = c.u32("list length")? as usize;
                if n > MAX_LIST_LEN {
                    return Err(format!("list {l} length {n} exceeds {MAX_LIST_LEN}"));
                }
                lens.push(n);
            }
            let mut lists = Vec::with_capacity(k);
            for (l, &n) in lens.iter().enumerate() {
                let raw = c.bytes(n * 4, "list keys")?;
                // The one inbound copy: receive buffer → the request
                // vector that goes straight into service admission.
                let list: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                debug_assert_eq!(list.len(), n, "list {l}");
                lists.push(list);
            }
            c.done()?;
            Ok(Frame::MergeRequest { mode, trace, lists })
        }
        TYPE_MERGE_RESPONSE => {
            let label_len = c.u8("served_by length")? as usize;
            let label = c.bytes(label_len, "served_by")?;
            let served_by = std::str::from_utf8(label)
                .map_err(|_| "served_by is not UTF-8".to_string())?
                .to_string();
            let n = c.u32("key count")? as usize;
            if n > MAX_FRAME_BYTES / 4 {
                return Err(format!("response key count {n} exceeds the frame cap"));
            }
            let raw = c.bytes(n * 4, "response keys")?;
            let merged: Vec<u32> = raw
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            c.done()?;
            Ok(Frame::MergeResponse { served_by, merged })
        }
        TYPE_ERROR => {
            let code = c.u8("error code")?;
            let msg_len = c.u16("message length")? as usize;
            let msg = c.bytes(msg_len, "message")?;
            let message = std::str::from_utf8(msg)
                .map_err(|_| "error message is not UTF-8".to_string())?
                .to_string();
            c.done()?;
            Ok(Frame::Error { code, message })
        }
        TYPE_PING => {
            c.done()?;
            Ok(Frame::Ping)
        }
        TYPE_PONG => {
            c.done()?;
            Ok(Frame::Pong)
        }
        TYPE_MERGE_REQUEST_KV => {
            // Same payload cap as key-only requests — KV keys are 12
            // bytes each on the wire, so the shape cap shrinks
            // accordingly rather than the frame growing.
            if c.remaining() > MAX_REQUEST_BYTES {
                return Err(format!(
                    "merge request payload {} exceeds {MAX_REQUEST_BYTES} bytes",
                    c.remaining()
                ));
            }
            let (mode, trace) = c.mode_and_trace()?;
            let k = c.u16("k")? as usize;
            if k == 0 || k > MAX_K {
                return Err(format!("k = {k} outside 1..={MAX_K}"));
            }
            let mut lens = Vec::with_capacity(k);
            for l in 0..k {
                let n = c.u32("list length")? as usize;
                if n > MAX_LIST_LEN {
                    return Err(format!("list {l} length {n} exceeds {MAX_LIST_LEN}"));
                }
                lens.push(n);
            }
            let mut lists = Vec::with_capacity(k);
            for (l, &n) in lens.iter().enumerate() {
                let raw = c.bytes(n * 4, "list keys")?;
                let list: Vec<u32> = raw
                    .chunks_exact(4)
                    .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect();
                debug_assert_eq!(list.len(), n, "list {l}");
                lists.push(list);
            }
            // Exactly one payload per key; `done()` below rejects any
            // shorter or longer column, so width is enforced by the
            // wire format itself.
            let total: usize = lens.iter().sum();
            let raw = c.bytes(total * 8, "payload column")?;
            let payloads: Vec<u64> = raw
                .chunks_exact(8)
                .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .collect();
            c.done()?;
            Ok(Frame::MergeRequestKV { mode, trace, lists, payloads })
        }
        TYPE_MERGE_RESPONSE_KV => {
            let label_len = c.u8("served_by length")? as usize;
            let label = c.bytes(label_len, "served_by")?;
            let served_by = std::str::from_utf8(label)
                .map_err(|_| "served_by is not UTF-8".to_string())?
                .to_string();
            let n = c.u32("pair count")? as usize;
            if n > MAX_FRAME_BYTES / 12 {
                return Err(format!("response pair count {n} exceeds the frame cap"));
            }
            let raw = c.bytes(n * 4, "response keys")?;
            let merged: Vec<u32> = raw
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            let raw = c.bytes(n * 8, "response payloads")?;
            let payloads: Vec<u64> = raw
                .chunks_exact(8)
                .map(|b| u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
                .collect();
            c.done()?;
            Ok(Frame::MergeResponseKV { served_by, merged, payloads })
        }
        TYPE_STATS_REQUEST => {
            c.done()?;
            Ok(Frame::StatsRequest)
        }
        TYPE_STATS_RESPONSE => {
            let n = c.u32("stats length")? as usize;
            if n > MAX_STATS_BYTES {
                return Err(format!("stats body {n} exceeds {MAX_STATS_BYTES} bytes"));
            }
            let raw = c.bytes(n, "stats body")?;
            let json = std::str::from_utf8(raw)
                .map_err(|_| "stats body is not UTF-8".to_string())?
                .to_string();
            c.done()?;
            Ok(Frame::StatsResponse { json })
        }
        other => Err(format!("unknown frame type {other}")),
    }
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    /// Unconsumed payload bytes (for v2 this already excludes the
    /// request id, so size caps apply to the payload proper).
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        match self.b.get(self.i..self.i + n) {
            Some(s) => {
                self.i += n;
                Ok(s)
            }
            None => Err(format!("truncated payload reading {what} ({n} bytes at {})", self.i)),
        }
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16, String> {
        let b = self.bytes(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self, what: &str) -> Result<u32, String> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64, String> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a request mode byte plus the optional v1.2 trace id
    /// ([`MODE_FLAG_TRACE`]); returns the mode with the flag stripped.
    fn mode_and_trace(&mut self) -> Result<(u8, u64), String> {
        let raw = self.u8("mode")?;
        let trace =
            if raw & MODE_FLAG_TRACE != 0 { self.u64("trace id")? } else { 0 };
        Ok((raw & !MODE_FLAG_TRACE, trace))
    }

    fn done(&self) -> Result<(), String> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(format!("{} trailing bytes after payload", self.b.len() - self.i))
        }
    }
}

/// Truncate to `max` bytes on a char boundary (error/label clamping).
fn clamp_str(s: &str, max: usize) -> &str {
    if s.len() <= max {
        return s;
    }
    let mut end = max;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn begin(out: &mut Vec<u8>, ty: u8) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]); // length, patched by finish()
    out.push(PROTOCOL_VERSION);
    out.push(ty);
}

/// v2 header: version 2, type, then the echoed request id. The payload
/// that follows is byte-identical to its v1 form.
fn begin_v2(out: &mut Vec<u8>, ty: u8, req_id: u64) {
    out.clear();
    out.extend_from_slice(&[0u8; 4]); // length, patched by finish()
    out.push(PROTOCOL_V2);
    out.push(ty);
    out.extend_from_slice(&req_id.to_le_bytes());
}

fn finish(out: &mut Vec<u8>) {
    let len = (out.len() - 4) as u32;
    out[..4].copy_from_slice(&len.to_le_bytes());
}

/// Push the mode byte plus the optional trace id: the flag bit and the
/// eight id bytes appear only for a nonzero trace, so an untraced
/// request encodes byte-identically to its pre-v1.2 form.
fn push_mode_trace(out: &mut Vec<u8>, mode: u8, trace: u64) {
    debug_assert_eq!(mode & MODE_FLAG_TRACE, 0, "mode collides with the trace flag");
    if trace != 0 {
        out.push(mode | MODE_FLAG_TRACE);
        out.extend_from_slice(&trace.to_le_bytes());
    } else {
        out.push(mode);
    }
}

/// Shared payload writers: a v1 encoder is `begin` + payload +
/// `finish`, its v2 twin is `begin_v2` + the *same* payload + `finish`
/// — so the two framings cannot drift apart.
fn merge_request_payload(mode: u8, trace: u64, lists: &[Vec<u32>], out: &mut Vec<u8>) {
    debug_assert!(!lists.is_empty() && lists.len() <= MAX_K);
    push_mode_trace(out, mode, trace);
    out.extend_from_slice(&(lists.len() as u16).to_le_bytes());
    for l in lists {
        debug_assert!(l.len() <= MAX_LIST_LEN);
        out.extend_from_slice(&(l.len() as u32).to_le_bytes());
    }
    for l in lists {
        for &x in l {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

fn merge_response_payload(served_by: &str, merged: &[u32], out: &mut Vec<u8>) {
    let label = clamp_str(served_by, u8::MAX as usize);
    out.push(label.len() as u8);
    out.extend_from_slice(label.as_bytes());
    out.extend_from_slice(&(merged.len() as u32).to_le_bytes());
    for &x in merged {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn merge_request_kv_payload(
    mode: u8,
    trace: u64,
    lists: &[Vec<u32>],
    payloads: &[u64],
    out: &mut Vec<u8>,
) {
    debug_assert!(!lists.is_empty() && lists.len() <= MAX_K);
    debug_assert_eq!(payloads.len(), lists.iter().map(Vec::len).sum::<usize>());
    push_mode_trace(out, mode, trace);
    out.extend_from_slice(&(lists.len() as u16).to_le_bytes());
    for l in lists {
        debug_assert!(l.len() <= MAX_LIST_LEN);
        out.extend_from_slice(&(l.len() as u32).to_le_bytes());
    }
    for l in lists {
        for &x in l {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    for &p in payloads {
        out.extend_from_slice(&p.to_le_bytes());
    }
}

fn merge_response_kv_payload(served_by: &str, merged: &[u32], payloads: &[u64], out: &mut Vec<u8>) {
    debug_assert_eq!(merged.len(), payloads.len());
    let label = clamp_str(served_by, u8::MAX as usize);
    out.push(label.len() as u8);
    out.extend_from_slice(label.as_bytes());
    out.extend_from_slice(&(merged.len() as u32).to_le_bytes());
    for &x in merged {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &p in payloads {
        out.extend_from_slice(&p.to_le_bytes());
    }
}

fn error_payload(code: u8, message: &str, out: &mut Vec<u8>) {
    let msg = clamp_str(message, MAX_ERROR_MSG);
    out.push(code);
    out.extend_from_slice(&(msg.len() as u16).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
}

fn stats_response_payload(json: &str, out: &mut Vec<u8>) {
    debug_assert!(json.len() <= MAX_STATS_BYTES);
    out.extend_from_slice(&(json.len() as u32).to_le_bytes());
    out.extend_from_slice(json.as_bytes());
}

/// Error message used when a stats document cannot be framed.
pub const STATS_OVERFLOW_MSG: &str =
    "stats document exceeds MAX_STATS_BYTES; retry after the server elides per-artifact detail";

/// Encode a merge request directly from borrowed lists — the client's
/// hot path, which never builds a [`Frame`] (that would clone every
/// key). `out` is cleared and refilled, so a reused buffer allocates
/// nothing in steady state. `trace` 0 means untraced.
pub fn encode_merge_request(mode: u8, trace: u64, lists: &[Vec<u32>], out: &mut Vec<u8>) {
    begin(out, TYPE_MERGE_REQUEST);
    merge_request_payload(mode, trace, lists, out);
    finish(out);
}

/// v2 twin of [`encode_merge_request`].
pub fn encode_merge_request_v2(
    req_id: u64,
    mode: u8,
    trace: u64,
    lists: &[Vec<u32>],
    out: &mut Vec<u8>,
) {
    begin_v2(out, TYPE_MERGE_REQUEST, req_id);
    merge_request_payload(mode, trace, lists, out);
    finish(out);
}

/// Encode a merge response directly from the served-by label and the
/// merged keys — the server's hot path (no intermediate [`Frame`]).
pub fn encode_merge_response(served_by: &str, merged: &[u32], out: &mut Vec<u8>) {
    begin(out, TYPE_MERGE_RESPONSE);
    merge_response_payload(served_by, merged, out);
    finish(out);
}

/// v2 twin of [`encode_merge_response`].
pub fn encode_merge_response_v2(req_id: u64, served_by: &str, merged: &[u32], out: &mut Vec<u8>) {
    begin_v2(out, TYPE_MERGE_RESPONSE, req_id);
    merge_response_payload(served_by, merged, out);
    finish(out);
}

/// Encode a v1.1 key-value merge request from borrowed columns —
/// `payloads` list-major, one `u64` per key (debug-asserted; the
/// decoder enforces it on the wire).
pub fn encode_merge_request_kv(
    mode: u8,
    trace: u64,
    lists: &[Vec<u32>],
    payloads: &[u64],
    out: &mut Vec<u8>,
) {
    begin(out, TYPE_MERGE_REQUEST_KV);
    merge_request_kv_payload(mode, trace, lists, payloads, out);
    finish(out);
}

/// v2 twin of [`encode_merge_request_kv`].
pub fn encode_merge_request_kv_v2(
    req_id: u64,
    mode: u8,
    trace: u64,
    lists: &[Vec<u32>],
    payloads: &[u64],
    out: &mut Vec<u8>,
) {
    begin_v2(out, TYPE_MERGE_REQUEST_KV, req_id);
    merge_request_kv_payload(mode, trace, lists, payloads, out);
    finish(out);
}

/// Encode a v1.1 key-value merge response (the server's KV hot path).
pub fn encode_merge_response_kv(
    served_by: &str,
    merged: &[u32],
    payloads: &[u64],
    out: &mut Vec<u8>,
) {
    begin(out, TYPE_MERGE_RESPONSE_KV);
    merge_response_kv_payload(served_by, merged, payloads, out);
    finish(out);
}

/// v2 twin of [`encode_merge_response_kv`].
pub fn encode_merge_response_kv_v2(
    req_id: u64,
    served_by: &str,
    merged: &[u32],
    payloads: &[u64],
    out: &mut Vec<u8>,
) {
    begin_v2(out, TYPE_MERGE_RESPONSE_KV, req_id);
    merge_response_kv_payload(served_by, merged, payloads, out);
    finish(out);
}

/// Encode a v1.2 stats poll (empty payload).
pub fn encode_stats_request(out: &mut Vec<u8>) {
    begin(out, TYPE_STATS_REQUEST);
    finish(out);
}

/// v2 twin of [`encode_stats_request`].
pub fn encode_stats_request_v2(req_id: u64, out: &mut Vec<u8>) {
    begin_v2(out, TYPE_STATS_REQUEST, req_id);
    finish(out);
}

/// Encode a v1.2 stats response. A document over [`MAX_STATS_BYTES`]
/// is answered as a typed `Error{UNSUPPORTED}` frame instead — never
/// clamped mid-document into invalid JSON (the server elides
/// per-artifact detail first, so this fallback is a last resort).
pub fn encode_stats_response(json: &str, out: &mut Vec<u8>) {
    if json.len() > MAX_STATS_BYTES {
        encode_error(code::UNSUPPORTED, STATS_OVERFLOW_MSG, out);
        return;
    }
    begin(out, TYPE_STATS_RESPONSE);
    stats_response_payload(json, out);
    finish(out);
}

/// v2 twin of [`encode_stats_response`] (the overflow error echoes the
/// request id like any other v2 reply).
pub fn encode_stats_response_v2(req_id: u64, json: &str, out: &mut Vec<u8>) {
    if json.len() > MAX_STATS_BYTES {
        encode_error_v2(req_id, code::UNSUPPORTED, STATS_OVERFLOW_MSG, out);
        return;
    }
    begin_v2(out, TYPE_STATS_RESPONSE, req_id);
    stats_response_payload(json, out);
    finish(out);
}

/// Encode an error frame (message clamped to [`MAX_ERROR_MSG`]).
pub fn encode_error(code: u8, message: &str, out: &mut Vec<u8>) {
    begin(out, TYPE_ERROR);
    error_payload(code, message, out);
    finish(out);
}

/// v2 twin of [`encode_error`]; `req_id` echoes the offending request
/// (0 when the error is not attributable to a v2 request id).
pub fn encode_error_v2(req_id: u64, code: u8, message: &str, out: &mut Vec<u8>) {
    begin_v2(out, TYPE_ERROR, req_id);
    error_payload(code, message, out);
    finish(out);
}

/// Encode any frame (tests and the non-hot control frames; the data
/// paths use the borrowing encoders above).
pub fn encode_frame(f: &Frame, out: &mut Vec<u8>) {
    match f {
        Frame::MergeRequest { mode, trace, lists } => {
            encode_merge_request(*mode, *trace, lists, out)
        }
        Frame::MergeResponse { served_by, merged } => {
            encode_merge_response(served_by, merged, out)
        }
        Frame::Error { code, message } => encode_error(*code, message, out),
        Frame::MergeRequestKV { mode, trace, lists, payloads } => {
            encode_merge_request_kv(*mode, *trace, lists, payloads, out)
        }
        Frame::MergeResponseKV { served_by, merged, payloads } => {
            encode_merge_response_kv(served_by, merged, payloads, out)
        }
        Frame::Ping => {
            begin(out, TYPE_PING);
            finish(out);
        }
        Frame::Pong => {
            begin(out, TYPE_PONG);
            finish(out);
        }
        Frame::StatsRequest => encode_stats_request(out),
        Frame::StatsResponse { json } => encode_stats_response(json, out),
    }
}

/// Encode any frame with v2 framing and the given request id.
pub fn encode_frame_v2(f: &Frame, req_id: u64, out: &mut Vec<u8>) {
    match f {
        Frame::MergeRequest { mode, trace, lists } => {
            encode_merge_request_v2(req_id, *mode, *trace, lists, out)
        }
        Frame::MergeResponse { served_by, merged } => {
            encode_merge_response_v2(req_id, served_by, merged, out)
        }
        Frame::Error { code, message } => encode_error_v2(req_id, *code, message, out),
        Frame::MergeRequestKV { mode, trace, lists, payloads } => {
            encode_merge_request_kv_v2(req_id, *mode, *trace, lists, payloads, out)
        }
        Frame::MergeResponseKV { served_by, merged, payloads } => {
            encode_merge_response_kv_v2(req_id, served_by, merged, payloads, out)
        }
        Frame::Ping => {
            begin_v2(out, TYPE_PING, req_id);
            finish(out);
        }
        Frame::Pong => {
            begin_v2(out, TYPE_PONG, req_id);
            finish(out);
        }
        Frame::StatsRequest => encode_stats_request_v2(req_id, out),
        Frame::StatsResponse { json } => encode_stats_response_v2(req_id, json, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// Drive `read_frame` past `Pending` ticks to the next outcome.
    fn read_one<R: Read>(rd: &mut FrameReader, r: &mut R) -> io::Result<ReadFrame> {
        loop {
            match rd.read_frame(r)? {
                ReadFrame::Pending => continue,
                other => return Ok(other),
            }
        }
    }

    fn roundtrip(f: &Frame) -> Frame {
        let mut bytes = Vec::new();
        encode_frame(f, &mut bytes);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(bytes)).unwrap() {
            ReadFrame::Frame(g) => g,
            other => panic!("{f:?} decoded to {other:?}"),
        }
    }

    #[test]
    fn roundtrip_every_frame_type() {
        for f in [
            Frame::MergeRequest {
                mode: MODE_MERGE,
                trace: 0,
                lists: vec![vec![1, 2, 3], vec![2, 9]],
            },
            Frame::MergeRequest { mode: 7, trace: 0, lists: vec![vec![], vec![u32::MAX], vec![0]] },
            Frame::MergeRequest { mode: MODE_MERGE, trace: u64::MAX, lists: vec![vec![1]] },
            Frame::MergeResponse { served_by: "loms2_up32_dn32_b256".into(), merged: vec![1, 2] },
            Frame::MergeResponse { served_by: String::new(), merged: vec![] },
            Frame::Error { code: code::REJECTED, message: "list 0 is not sorted".into() },
            Frame::Ping,
            Frame::Pong,
            Frame::MergeRequestKV {
                mode: MODE_MERGE,
                trace: 0,
                lists: vec![vec![1, 2, 3], vec![2, 9]],
                payloads: vec![10, 20, 30, 40, 50],
            },
            Frame::MergeRequestKV {
                mode: MODE_MERGE,
                trace: 0xDEAD_BEEF,
                lists: vec![vec![], vec![7]],
                payloads: vec![u64::MAX],
            },
            Frame::MergeResponseKV {
                served_by: "loms2_up32_dn32_b256".into(),
                merged: vec![1, 2, 2],
                payloads: vec![10, 30, 40],
            },
            Frame::MergeResponseKV { served_by: String::new(), merged: vec![], payloads: vec![] },
            Frame::StatsRequest,
            Frame::StatsResponse { json: "{\"requests\":0}".into() },
            Frame::StatsResponse { json: String::new() },
        ] {
            assert_eq!(roundtrip(&f), f);
        }
    }

    #[test]
    fn v1_frames_are_byte_identical_under_v1_1() {
        // Neither the KV extension nor the v1.2 trace flag may move a
        // single v1 byte: same version byte, same type bytes, same
        // layouts, and an untraced request never carries the flag.
        let f =
            Frame::MergeRequest { mode: MODE_MERGE, trace: 0, lists: vec![vec![3, 5], vec![4]] };
        let mut bytes = Vec::new();
        encode_frame(&f, &mut bytes);
        assert_eq!(
            bytes,
            vec![
                25, 0, 0, 0, // len = 25 (version+type+mode+k+2 lens+3 keys)
                1, 1, // version 1, type MergeRequest
                0, // mode
                2, 0, // k = 2
                2, 0, 0, 0, 1, 0, 0, 0, // lens
                3, 0, 0, 0, 5, 0, 0, 0, 4, 0, 0, 0, // keys
            ]
        );
    }

    #[test]
    fn traced_request_carries_the_id_and_strips_the_flag() {
        let f = Frame::MergeRequest {
            mode: MODE_MERGE,
            trace: 0x0102_0304_0506_0708,
            lists: vec![vec![3, 5], vec![4]],
        };
        let mut bytes = Vec::new();
        encode_frame(&f, &mut bytes);
        // Exactly 8 bytes longer than the untraced frame, flag set in
        // the mode byte, id little-endian right after it.
        assert_eq!(bytes[4 + 2], MODE_MERGE | MODE_FLAG_TRACE);
        assert_eq!(&bytes[4 + 3..4 + 11], &[8, 7, 6, 5, 4, 3, 2, 1]);
        assert_eq!(roundtrip(&f), f); // decode strips the flag bit
    }

    #[test]
    fn kv_payload_width_is_enforced_by_the_wire() {
        // A KV request whose payload column is short or long fails
        // decode (truncated read or trailing bytes) — width mismatches
        // cannot reach the service from the wire.
        let good = Frame::MergeRequestKV {
            mode: MODE_MERGE,
            trace: 0,
            lists: vec![vec![1, 2], vec![3]],
            payloads: vec![10, 20, 30],
        };
        let mut bytes = Vec::new();
        encode_frame(&good, &mut bytes);
        let mut short = bytes.clone();
        short.truncate(bytes.len() - 8); // drop one payload
        let len = (short.len() - 4) as u32;
        short[..4].copy_from_slice(&len.to_le_bytes());
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 8]); // extra payload
        let len = (long.len() - 4) as u32;
        long[..4].copy_from_slice(&len.to_le_bytes());
        for bad in [short, long] {
            let mut rd = FrameReader::new();
            assert!(matches!(
                read_one(&mut rd, &mut Cursor::new(bad)).unwrap(),
                ReadFrame::Malformed(_)
            ));
        }
    }

    #[test]
    fn frames_split_across_reads_reassemble() {
        let f = Frame::MergeRequest {
            mode: MODE_MERGE,
            trace: 0,
            lists: vec![vec![5; 100], vec![7; 33]],
        };
        let mut bytes = Vec::new();
        encode_frame(&f, &mut bytes);
        // A reader that hands out one byte at a time.
        struct OneByte(Cursor<Vec<u8>>);
        impl std::io::Read for OneByte {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.0.read(&mut buf[..1.min(buf.len())])
            }
        }
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut OneByte(Cursor::new(bytes))).unwrap() {
            ReadFrame::Frame(g) => assert_eq!(g, f),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn eof_and_midframe_disconnect() {
        let mut rd = FrameReader::new();
        assert!(matches!(
            read_one(&mut rd, &mut Cursor::new(Vec::new())).unwrap(),
            ReadFrame::Eof
        ));
        // A valid prefix followed by disconnect.
        let mut bytes = Vec::new();
        encode_frame(&Frame::Ping, &mut bytes);
        bytes.truncate(bytes.len() - 1);
        let mut rd = FrameReader::new();
        let err = read_one(&mut rd, &mut Cursor::new(bytes)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn oversized_length_prefix_is_corrupt() {
        let mut bytes = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 16]);
        let mut rd = FrameReader::new();
        assert!(matches!(
            read_one(&mut rd, &mut Cursor::new(bytes)).unwrap(),
            ReadFrame::Corrupt(_)
        ));
        // Too-short bodies (< version + type) are corrupt as well.
        let mut rd = FrameReader::new();
        let bytes = 1u32.to_le_bytes().to_vec();
        assert!(matches!(
            read_one(&mut rd, &mut Cursor::new(bytes)).unwrap(),
            ReadFrame::Corrupt(_)
        ));
    }

    #[test]
    fn wrong_version_and_shape_violations_are_malformed() {
        let mut base = Vec::new();
        encode_frame(&Frame::Ping, &mut base);
        let mut wrong_version = base.clone();
        wrong_version[4] = PROTOCOL_VERSION + 1;
        let mut unknown_type = base.clone();
        unknown_type[5] = 200;
        for bytes in [wrong_version, unknown_type] {
            let mut rd = FrameReader::new();
            assert!(matches!(
                read_one(&mut rd, &mut Cursor::new(bytes)).unwrap(),
                ReadFrame::Malformed(_)
            ));
        }
        // k = 0, k > MAX_K, oversized list length, truncated keys,
        // trailing bytes: all body-level malformations.
        let reqs: Vec<Vec<u8>> = vec![
            request_bytes(0, &[]),
            request_bytes((MAX_K + 1) as u16, &[]),
            request_bytes(1, &[(MAX_LIST_LEN + 1) as u32]),
            request_bytes(1, &[3]), // claims 3 keys, carries none
        ];
        for bytes in reqs {
            let mut rd = FrameReader::new();
            assert!(
                matches!(
                    read_one(&mut rd, &mut Cursor::new(bytes.clone())).unwrap(),
                    ReadFrame::Malformed(_)
                ),
                "{bytes:?}"
            );
        }
    }

    /// Hand-build a request frame with an arbitrary header (no keys).
    fn request_bytes(k: u16, lens: &[u32]) -> Vec<u8> {
        let mut body = vec![PROTOCOL_VERSION, 1, MODE_MERGE];
        body.extend_from_slice(&k.to_le_bytes());
        for &l in lens {
            body.extend_from_slice(&l.to_le_bytes());
        }
        let mut out = (body.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(&body);
        out
    }

    #[test]
    fn malformed_frame_does_not_desync_the_stream() {
        // A malformed body followed by a good frame: the reader must
        // consume the bad frame and still deliver the good one.
        let mut stream = request_bytes(0, &[]);
        let mut good = Vec::new();
        encode_frame(&Frame::Ping, &mut good);
        stream.extend_from_slice(&good);
        let mut rd = FrameReader::new();
        let mut cur = Cursor::new(stream);
        assert!(matches!(read_one(&mut rd, &mut cur).unwrap(), ReadFrame::Malformed(_)));
        assert!(matches!(
            read_one(&mut rd, &mut cur).unwrap(),
            ReadFrame::Frame(Frame::Ping)
        ));
    }

    fn roundtrip_v2(f: &Frame, id: u64) -> Frame {
        let mut bytes = Vec::new();
        encode_frame_v2(f, id, &mut bytes);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(bytes)).unwrap() {
            ReadFrame::FrameV2(g, got) => {
                assert_eq!(got, id, "{f:?} echoed the wrong request id");
                g
            }
            other => panic!("{f:?} decoded to {other:?}"),
        }
    }

    #[test]
    fn v2_roundtrip_every_frame_type_echoes_the_id() {
        for (i, f) in [
            Frame::MergeRequest {
                mode: MODE_MERGE,
                trace: 0,
                lists: vec![vec![1, 2, 3], vec![2, 9]],
            },
            Frame::MergeRequest { mode: MODE_MERGE, trace: u64::MAX, lists: vec![vec![1]] },
            Frame::MergeResponse { served_by: "loms2_up32_dn32_b256".into(), merged: vec![1, 2] },
            Frame::MergeRequestKV {
                mode: MODE_MERGE,
                trace: 0,
                lists: vec![vec![1, 2, 3], vec![2, 9]],
                payloads: vec![10, 20, 30, 40, 50],
            },
            Frame::MergeResponseKV {
                served_by: String::new(),
                merged: vec![7],
                payloads: vec![u64::MAX],
            },
            Frame::Error { code: code::REJECTED, message: "list 0 is not sorted".into() },
            Frame::Ping,
            Frame::Pong,
            Frame::StatsRequest,
            Frame::StatsResponse { json: "{\"requests\":0}".into() },
        ]
        .into_iter()
        .enumerate()
        {
            // Exercise id 0, small ids, and the full u64 range.
            for id in [0u64, i as u64 + 1, u64::MAX - i as u64] {
                assert_eq!(roundtrip_v2(&f, id), f);
            }
        }
    }

    #[test]
    fn v2_framing_inserts_the_id_and_moves_no_payload_byte() {
        // The v2 frame is the v1 frame with the version byte bumped to
        // 2 and exactly 8 id bytes spliced in after the type byte —
        // the payload grammar is shared, not parallel.
        let f =
            Frame::MergeRequest { mode: MODE_MERGE, trace: 0, lists: vec![vec![3, 5], vec![4]] };
        let (mut v1, mut v2) = (Vec::new(), Vec::new());
        encode_frame(&f, &mut v1);
        encode_frame_v2(&f, 0x0102_0304_0506_0708, &mut v2);
        assert_eq!(v2.len(), v1.len() + 8);
        let len = u32::from_le_bytes(v2[..4].try_into().unwrap());
        assert_eq!(len as usize, v2.len() - 4);
        assert_eq!(v2[4], PROTOCOL_V2);
        assert_eq!(v2[5], v1[5], "type byte unchanged");
        assert_eq!(&v2[6..14], &[8, 7, 6, 5, 4, 3, 2, 1], "u64le id after type");
        assert_eq!(&v2[14..], &v1[6..], "payload bytes identical");
    }

    #[test]
    fn oversized_stats_document_becomes_a_typed_error_not_truncated_json() {
        let json = format!("{{\"pad\":\"{}\"}}", "x".repeat(MAX_STATS_BYTES + 100));
        let mut out = Vec::new();
        encode_stats_response(&json, &mut out);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(out)).unwrap() {
            ReadFrame::Frame(Frame::Error { code: c, message }) => {
                assert_eq!(c, code::UNSUPPORTED);
                assert!(message.contains("MAX_STATS_BYTES"), "{message}");
            }
            other => panic!("overflowing stats encoded as {other:?}"),
        }
        // The v2 twin echoes the poll's request id on the error.
        let mut out = Vec::new();
        encode_stats_response_v2(99, &json, &mut out);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(out)).unwrap() {
            ReadFrame::FrameV2(Frame::Error { code: c, .. }, 99) => {
                assert_eq!(c, code::UNSUPPORTED)
            }
            other => panic!("overflowing v2 stats encoded as {other:?}"),
        }
        // A document that exactly fits still rides the normal frame.
        let fits = "x".repeat(MAX_STATS_BYTES);
        let mut out = Vec::new();
        encode_stats_response(&fits, &mut out);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(out)).unwrap() {
            ReadFrame::Frame(Frame::StatsResponse { json }) => assert_eq!(json.len(), fits.len()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn clamps_labels_and_messages() {
        let mut out = Vec::new();
        encode_error(code::MALFORMED, &"x".repeat(MAX_ERROR_MSG + 100), &mut out);
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(out)).unwrap() {
            ReadFrame::Frame(Frame::Error { message, .. }) => {
                assert_eq!(message.len(), MAX_ERROR_MSG)
            }
            other => panic!("{other:?}"),
        }
        let mut out = Vec::new();
        encode_merge_response(&"é".repeat(200), &[1], &mut out); // 2-byte chars
        let mut rd = FrameReader::new();
        match read_one(&mut rd, &mut Cursor::new(out)).unwrap() {
            ReadFrame::Frame(Frame::MergeResponse { served_by, .. }) => {
                assert!(served_by.len() <= 255);
                assert!(served_by.chars().all(|c| c == 'é')); // boundary-safe clamp
            }
            other => panic!("{other:?}"),
        }
    }
}
