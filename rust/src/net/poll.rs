//! Readiness primitives for the event-driven net server: a thin
//! dependency-free poller over `epoll(7)` (Linux) / `kqueue(2)` (macOS)
//! driving raw fds, a self-pipe [`Waker`] so worker threads can
//! interrupt a blocked wait, and a coarse [`TimerWheel`] for
//! write-timeout dead-peer reaping. Only [`crate::net::NetServer`] uses
//! these; the blocking `NetClient` stays plain `std::net`.
//!
//! The FFI surface is hand-declared (the crate carries no libc
//! dependency) and deliberately tiny: create/ctl/wait on the readiness
//! fd, plus `pipe`/`fcntl`/`read`/`write`/`close` for the waker.
//! Registration is level-triggered everywhere — the server's state
//! machines re-arm interest explicitly, and bytes left in a kernel
//! buffer simply re-report on the next wait.

use std::io;
use std::os::fd::RawFd;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One readiness report. `readable`/`writable` fold error and hangup
/// conditions in (a syscall on the fd will surface the actual error);
/// `hangup` additionally marks peer-closed so callers can skip
/// pointless arm cycles.
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

/// Shared raw-fd syscalls for the self-pipe waker.
mod fdops {
    use std::io;
    use std::os::fd::RawFd;

    #[cfg(target_os = "linux")]
    const O_NONBLOCK: i32 = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: i32 = 0x0004;
    const F_GETFL: i32 = 3;
    const F_SETFL: i32 = 4;

    extern "C" {
        fn close(fd: i32) -> i32;
        fn pipe(fds: *mut i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    pub fn close_fd(fd: RawFd) {
        let _ = unsafe { close(fd) };
    }

    pub fn pipe_pair() -> io::Result<(RawFd, RawFd)> {
        let mut fds = [-1i32; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok((fds[0], fds[1]))
    }

    pub fn set_nonblocking(fd: RawFd) -> io::Result<()> {
        let flags = unsafe { fcntl(fd, F_GETFL, 0) };
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if unsafe { fcntl(fd, F_SETFL, flags | O_NONBLOCK) } < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn read_fd(fd: RawFd, buf: &mut [u8]) -> io::Result<usize> {
        let n = unsafe { read(fd, buf.as_mut_ptr(), buf.len()) };
        if n < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(n as usize)
        }
    }

    pub fn write_fd(fd: RawFd, buf: &[u8]) -> io::Result<usize> {
        let n = unsafe { write(fd, buf.as_ptr(), buf.len()) };
        if n < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(n as usize)
        }
    }
}

/// Owned raw fd, closed on drop.
struct Fd(RawFd);

impl Drop for Fd {
    fn drop(&mut self) {
        fdops::close_fd(self.0);
    }
}

#[cfg(target_os = "linux")]
mod sys {
    //! epoll ABI. Constants are arch-independent; the event struct is
    //! packed on x86-64 only (a kernel ABI quirk kept for compatibility
    //! with 32-bit epoll_event layouts).
    use std::io;
    use std::os::fd::RawFd;

    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    pub struct Event {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    pub const CTL_ADD: i32 = 1;
    pub const CTL_DEL: i32 = 2;
    pub const CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut Event) -> i32;
        fn epoll_wait(epfd: i32, events: *mut Event, maxevents: i32, timeout: i32) -> i32;
    }

    pub fn create() -> io::Result<RawFd> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(fd)
        }
    }

    pub fn ctl(epfd: RawFd, op: i32, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        let mut ev = Event { events, data };
        // DEL ignores the event argument (must tolerate NULL since 2.6.9).
        let ptr = if op == CTL_DEL { std::ptr::null_mut() } else { &mut ev as *mut Event };
        if unsafe { epoll_ctl(epfd, op, fd, ptr) } < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    pub fn wait(epfd: RawFd, buf: &mut [Event], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe { epoll_wait(epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms) };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    //! kqueue ABI (macOS / the BSDs). Read and write interest are two
    //! independent filters; the poller issues one change per filter and
    //! tolerates ENOENT on deletes so interest updates are idempotent.
    use std::io;
    use std::os::fd::RawFd;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct Kevent {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: *mut core::ffi::c_void,
    }

    impl Kevent {
        pub const ZERO: Kevent = Kevent {
            ident: 0,
            filter: 0,
            flags: 0,
            fflags: 0,
            data: 0,
            udata: std::ptr::null_mut(),
        };
    }

    #[repr(C)]
    pub struct Timespec {
        pub tv_sec: isize,
        pub tv_nsec: isize,
    }

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x1;
    pub const EV_DELETE: u16 = 0x2;
    pub const EV_ERROR: u16 = 0x4000;
    pub const EV_EOF: u16 = 0x8000;
    const ENOENT: i32 = 2;

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const Kevent,
            nchanges: i32,
            eventlist: *mut Kevent,
            nevents: i32,
            timeout: *const Timespec,
        ) -> i32;
    }

    pub fn create() -> io::Result<RawFd> {
        let fd = unsafe { kqueue() };
        if fd < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(fd)
        }
    }

    pub fn change(kq: RawFd, fd: RawFd, filter: i16, flags: u16, token: u64) -> io::Result<()> {
        let kev = Kevent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: token as *mut core::ffi::c_void,
        };
        let n = unsafe { kevent(kq, &kev, 1, std::ptr::null_mut(), 0, std::ptr::null()) };
        if n < 0 {
            let err = io::Error::last_os_error();
            // Deleting interest that was never armed is a no-op.
            if flags & EV_DELETE != 0 && err.raw_os_error() == Some(ENOENT) {
                return Ok(());
            }
            return Err(err);
        }
        Ok(())
    }

    pub fn wait(kq: RawFd, buf: &mut [Kevent], timeout: Option<&Timespec>) -> io::Result<usize> {
        let tsp = timeout.map_or(std::ptr::null(), |t| t as *const Timespec);
        loop {
            let n = unsafe {
                kevent(kq, std::ptr::null(), 0, buf.as_mut_ptr(), buf.len() as i32, tsp)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// Readiness selector over raw fds. Tokens are caller-chosen `u64`s
/// delivered back verbatim with each event; interest is level-triggered
/// and explicit (`register`/`modify`/`deregister`).
pub struct Poller {
    fd: Fd,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { fd: Fd(sys::create()?) })
    }

    fn interest(readable: bool, writable: bool) -> u32 {
        let mut ev = sys::EPOLLRDHUP;
        if readable {
            ev |= sys::EPOLLIN;
        }
        if writable {
            ev |= sys::EPOLLOUT;
        }
        ev
    }

    pub fn register(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::ctl(self.fd.0, sys::CTL_ADD, fd, Self::interest(readable, writable), token)
    }

    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::ctl(self.fd.0, sys::CTL_MOD, fd, Self::interest(readable, writable), token)
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        sys::ctl(self.fd.0, sys::CTL_DEL, fd, 0, 0)
    }

    pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let mut buf = [sys::Event { events: 0, data: 0 }; 128];
        let ms = match timeout {
            None => -1,
            // Round up so a 0.5 ms request doesn't spin at 0.
            Some(t) => t.as_nanos().div_ceil(1_000_000).min(i32::MAX as u128) as i32,
        };
        let n = sys::wait(self.fd.0, &mut buf, ms)?;
        for ev in &buf[..n] {
            let events = ev.events;
            let data = ev.data;
            out.push(PollEvent {
                token: data,
                readable: events
                    & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP)
                    != 0,
                writable: events & (sys::EPOLLOUT | sys::EPOLLHUP | sys::EPOLLERR) != 0,
                hangup: events & (sys::EPOLLHUP | sys::EPOLLERR | sys::EPOLLRDHUP) != 0,
            });
        }
        Ok(())
    }
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { fd: Fd(sys::create()?) })
    }

    fn apply(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        let (radd, wadd) = (readable, writable);
        let rflags = if radd { sys::EV_ADD } else { sys::EV_DELETE };
        let wflags = if wadd { sys::EV_ADD } else { sys::EV_DELETE };
        sys::change(self.fd.0, fd, sys::EVFILT_READ, rflags, token)?;
        sys::change(self.fd.0, fd, sys::EVFILT_WRITE, wflags, token)
    }

    pub fn register(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.apply(fd, token, readable, writable)
    }

    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.apply(fd, token, readable, writable)
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        sys::change(self.fd.0, fd, sys::EVFILT_READ, sys::EV_DELETE, 0)?;
        sys::change(self.fd.0, fd, sys::EVFILT_WRITE, sys::EV_DELETE, 0)
    }

    pub fn wait(&self, out: &mut Vec<PollEvent>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        let mut buf = [sys::Kevent::ZERO; 128];
        let ts = timeout.map(|t| sys::Timespec {
            tv_sec: t.as_secs().min(isize::MAX as u64) as isize,
            tv_nsec: t.subsec_nanos() as isize,
        });
        let n = sys::wait(self.fd.0, &mut buf, ts.as_ref())?;
        for ev in &buf[..n] {
            let err = ev.flags & sys::EV_ERROR != 0;
            let eof = ev.flags & sys::EV_EOF != 0;
            out.push(PollEvent {
                token: ev.udata as u64,
                readable: ev.filter == sys::EVFILT_READ || err,
                writable: ev.filter == sys::EVFILT_WRITE || err,
                hangup: eof || err,
            });
        }
        Ok(())
    }
}

/// Cross-thread wake handle for a blocked [`Poller::wait`]: cloneable,
/// signal-safe in spirit (one nonblocking pipe write; a full pipe means
/// a wake is already pending, so the error is ignored by design).
#[derive(Clone)]
pub struct Waker(Arc<Fd>);

impl Waker {
    pub fn wake(&self) {
        let _ = fdops::write_fd(self.0 .0, &[1u8]);
    }
}

/// Read end of the self-pipe: register `fd()` with the poller, call
/// `drain()` whenever it reports readable.
pub struct WakeReader(Fd);

impl WakeReader {
    pub fn fd(&self) -> RawFd {
        self.0 .0
    }

    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while let Ok(n) = fdops::read_fd(self.0 .0, &mut buf) {
            if n < buf.len() {
                break;
            }
        }
    }
}

/// Build a connected waker pair (nonblocking self-pipe).
pub fn wake_pair() -> io::Result<(Waker, WakeReader)> {
    let (r, w) = fdops::pipe_pair()?;
    let (r, w) = (Fd(r), Fd(w));
    fdops::set_nonblocking(r.0)?;
    fdops::set_nonblocking(w.0)?;
    Ok((Waker(Arc::new(w)), WakeReader(r)))
}

/// Coarse hashed timer wheel: O(1) insert, deadlines fire at most one
/// `granularity` late, beyond-horizon deadlines re-insert themselves
/// when the cursor reaches their slot. There is no removal — callers
/// cancel lazily by re-checking their own deadline when a token fires
/// (the server holds the authoritative per-connection deadline).
pub struct TimerWheel {
    slots: Vec<Vec<(u64, Instant)>>,
    granularity: Duration,
    cursor: usize,
    cursor_time: Instant,
    armed: usize,
}

impl TimerWheel {
    pub fn new(granularity: Duration, nslots: usize) -> TimerWheel {
        Self::with_origin(granularity, nslots, Instant::now())
    }

    pub fn with_origin(granularity: Duration, nslots: usize, origin: Instant) -> TimerWheel {
        assert!(nslots >= 4, "wheel needs room for the +1 insert offset");
        assert!(granularity > Duration::ZERO);
        TimerWheel {
            slots: vec![Vec::new(); nslots],
            granularity,
            cursor: 0,
            cursor_time: origin,
            armed: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.armed
    }

    pub fn is_empty(&self) -> bool {
        self.armed == 0
    }

    /// Poll-timeout hint: with anything armed the loop should wake at
    /// wheel resolution; otherwise it may sleep indefinitely.
    pub fn tick_hint(&self) -> Option<Duration> {
        if self.armed == 0 {
            None
        } else {
            Some(self.granularity)
        }
    }

    pub fn insert(&mut self, token: u64, deadline: Instant) {
        let nslots = self.slots.len();
        let ticks = (deadline.saturating_duration_since(self.cursor_time).as_nanos()
            / self.granularity.as_nanos()) as usize;
        // +1 keeps fresh inserts out of the slot the cursor sits on;
        // the horizon clamp makes far deadlines re-insert on drain.
        let idx = (self.cursor + 1 + ticks.min(nslots - 2)) % nslots;
        self.slots[idx].push((token, deadline));
        self.armed += 1;
    }

    pub fn advance(&mut self, now: Instant, expired: &mut Vec<u64>) {
        if self.armed == 0 {
            // Snap forward while idle so a long quiet span doesn't cost
            // one empty-slot step per elapsed tick on the next timer.
            let lag = now.saturating_duration_since(self.cursor_time);
            let ticks = (lag.as_nanos() / self.granularity.as_nanos()) as usize;
            if ticks > 0 {
                self.cursor_time += self.granularity * ticks as u32;
                self.cursor = (self.cursor + ticks % self.slots.len()) % self.slots.len();
            }
            return;
        }
        while now.saturating_duration_since(self.cursor_time) >= self.granularity {
            self.cursor_time += self.granularity;
            self.cursor = (self.cursor + 1) % self.slots.len();
            let due = std::mem::take(&mut self.slots[self.cursor]);
            for (token, deadline) in due {
                self.armed -= 1;
                if deadline <= now {
                    expired.push(token);
                } else {
                    self.insert(token, deadline);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn listener_becomes_readable_on_connect() {
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        poller.register(listener.as_raw_fd(), 7, true, false).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "{events:?}");
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                break;
            }
            assert!(Instant::now() < deadline, "listener never became readable");
        }
        poller.deregister(listener.as_raw_fd()).unwrap();
    }

    #[test]
    fn waker_interrupts_a_blocked_wait() {
        let poller = Poller::new().unwrap();
        let (waker, rx) = wake_pair().unwrap();
        poller.register(rx.fd(), 1, true, false).unwrap();
        let w2 = waker.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            w2.wake();
        });
        let mut events = Vec::new();
        let t0 = Instant::now();
        poller.wait(&mut events, Some(Duration::from_secs(10))).unwrap();
        // Either the wake already landed or we re-wait briefly; never
        // the full 10 s.
        let deadline = Instant::now() + Duration::from_secs(5);
        while !events.iter().any(|e| e.token == 1 && e.readable) {
            assert!(Instant::now() < deadline, "wake never observed");
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_secs(9));
        rx.drain();
        poller.wait(&mut events, Some(Duration::from_millis(10))).unwrap();
        assert!(events.is_empty(), "drained pipe still readable: {events:?}");
        h.join().unwrap();
    }

    #[test]
    fn wheel_fires_between_deadline_and_one_tick_late() {
        let t0 = Instant::now();
        let gran = Duration::from_millis(100);
        let mut wheel = TimerWheel::with_origin(gran, 16, t0);
        wheel.insert(1, t0 + Duration::from_millis(50));
        wheel.insert(2, t0 + Duration::from_millis(250));
        let mut expired = Vec::new();

        wheel.advance(t0 + Duration::from_millis(40), &mut expired);
        assert!(expired.is_empty(), "{expired:?}");
        // 50 ms deadline fires once the cursor passes it: ≤ one tick late.
        wheel.advance(t0 + Duration::from_millis(200), &mut expired);
        assert_eq!(expired, vec![1]);
        assert_eq!(wheel.len(), 1);

        expired.clear();
        wheel.advance(t0 + Duration::from_millis(400), &mut expired);
        assert_eq!(expired, vec![2]);
        assert!(wheel.is_empty());
        assert_eq!(wheel.tick_hint(), None);
    }

    #[test]
    fn wheel_reinserts_beyond_horizon_deadlines() {
        let t0 = Instant::now();
        let gran = Duration::from_millis(10);
        // 8 slots → 80 ms horizon, deadline 4 laps out.
        let mut wheel = TimerWheel::with_origin(gran, 8, t0);
        wheel.insert(9, t0 + Duration::from_millis(320));
        let mut expired = Vec::new();
        for step in 1..=31 {
            wheel.advance(t0 + Duration::from_millis(step * 10), &mut expired);
            assert!(expired.is_empty(), "fired early at step {step}: {expired:?}");
        }
        wheel.advance(t0 + Duration::from_millis(340), &mut expired);
        assert_eq!(expired, vec![9]);
        assert!(wheel.is_empty());
    }

    #[test]
    fn wheel_idle_snap_keeps_later_inserts_cheap_and_correct() {
        let t0 = Instant::now();
        let gran = Duration::from_millis(10);
        let mut wheel = TimerWheel::with_origin(gran, 8, t0);
        let mut expired = Vec::new();
        // Long idle gap with nothing armed…
        wheel.advance(t0 + Duration::from_secs(600), &mut expired);
        assert!(expired.is_empty());
        // …then a timer inserted relative to "now" still fires on time.
        let now = t0 + Duration::from_secs(600);
        wheel.insert(3, now + Duration::from_millis(30));
        wheel.advance(now + Duration::from_millis(20), &mut expired);
        assert!(expired.is_empty(), "{expired:?}");
        wheel.advance(now + Duration::from_millis(60), &mut expired);
        assert_eq!(expired, vec![3]);
    }
}
