//! The networked serving front-end: an event-driven framed-TCP
//! listener over a running [`MergeService`].
//!
//! Thread shape:
//!
//! * `loms-net-poll` — the readiness loop. Owns the nonblocking
//!   listener, every connection, a [`Poller`] (epoll/kqueue), and a
//!   coarse [`TimerWheel`]. It accepts, decodes frames with the
//!   incremental [`FrameReader`], sequences replies through each
//!   connection's [`ReplyQueue`], and flushes write buffers — never
//!   blocking, so served connections are bounded by memory, not
//!   threads.
//! * `loms-net-worker-*` — a small fixed pool draining decoded
//!   requests off the loop. Workers run dispatch (ping/stats/shed/
//!   validation), submit merges to the service with a completion
//!   callback ([`MergeService::submit_with`]), and encode every reply;
//!   finished frames return to the loop as `Ready` buffers via a
//!   self-pipe [`Waker`].
//!
//! Protocol negotiation: a connection speaks v1 *or* v2, latched by
//! its first decoded frame. v1 connections get replies in request
//! order (the [`ReplyQueue`] holds out-of-order completions); v2
//! frames carry a `u64le` request id echoed in the reply, so
//! completions stream out the moment they exist and many logical
//! clients can multiplex one connection. Cross-version frames after
//! the latch and duplicate in-flight v2 ids are answered with typed
//! `MALFORMED` errors on the surviving connection.
//!
//! Fairness and overload: admission shedding refuses merge work over
//! the service's pending watermark; per-connection inflight quotas
//! ([`NetServerConfig::max_inflight_per_conn`]) plus a write-backlog
//! budget pause *reading* an abusive connection, so backpressure
//! reaches it through TCP while everyone else keeps being served. A
//! peer that stops reading trips the write deadline on the timer
//! wheel and is reaped.
//!
//! Accounting: `net_frames_in` is counted at decode (on the loop);
//! `net_responses`/`net_errors` at encode (on a worker) — even when
//! the connection died in between — so the
//! `frames_in == responses + errors` balance always settles.
//!
//! Shutdown: [`NetServer::shutdown`] sets a flag and wakes the loop —
//! no loopback connection, nothing to block on. The loop closes the
//! listener, stops reading, drains every admitted request's reply to
//! the wire (the service stays up for the drain; stalled peers are
//! reaped by the write deadline), and exits when no connections
//! remain; then the service drains and the workers join. In-flight
//! batches are never dropped.

use super::conn::{Proto, ReplyQueue};
use super::poll::{self, PollEvent, Poller, TimerWheel, WakeReader, Waker};
use super::protocol::{
    self, code, encode_error, encode_error_v2, encode_merge_response, encode_merge_response_kv,
    encode_merge_response_kv_v2, encode_merge_response_v2, Frame, FrameReader, ReadFrame,
    MODE_MERGE,
};
use crate::coordinator::request::MergeResponse;
use crate::coordinator::{Metrics, MergeService};
use crate::obs::expo;
use crate::util::fault::{self, Site};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;
/// Pause reading a connection when its un-flushed reply bytes (write
/// buffer plus the v1 hold queue) reach this budget.
const WRITE_BACKLOG_PAUSE: usize = 4 << 20;
/// Compact the write buffer once this many flushed bytes sit in front.
const WBUF_COMPACT: usize = 64 << 10;
/// Write deadlines fire at most one wheel tick late.
const WHEEL_GRANULARITY: Duration = Duration::from_millis(100);
const WHEEL_SLOTS: usize = 128;
/// Poll-wait backstop when no timer is armed (wake-ups arrive via the
/// self-pipe; this only bounds a lost wake).
const MAX_POLL_WAIT: Duration = Duration::from_millis(500);

/// Rejection message shared by every path that answers for a request
/// the service refused (or could not accept during shutdown).
const REJECT_MSG: &str = "request rejected (unsorted list, u32::MAX key, or shutdown)";

/// Listener tuning.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Dispatch/encode worker threads (clamped to ≥ 1). Workers bound
    /// concurrent *execution* of request dispatch, not the number of
    /// served connections — the readiness loop serves any number of
    /// connections regardless of pool size.
    pub workers: usize,
    /// How long a connection with pending reply bytes may make no
    /// write progress before it is declared dead and reaped (via the
    /// event loop's timer wheel).
    pub write_timeout: Duration,
    /// Maximum replies a connection may have in flight. At the quota
    /// the loop stops *reading* that connection — backpressure reaches
    /// the client through TCP instead of growing server memory — while
    /// every other connection keeps being served (clamped to ≥ 1).
    pub max_inflight_per_conn: usize,
    /// Admission-level overload shedding: when the service's pending
    /// gauge ([`MergeService::pending`]) is at or above this watermark,
    /// new merge requests are answered with an
    /// [`code::OVERLOADED`] error frame instead of being
    /// submitted — the client backs off and retries, and server-side
    /// queues stay bounded under a request storm. `0` disables
    /// shedding. Pings, stats and error replies are never shed.
    pub shed_pending: u64,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            workers: 8,
            write_timeout: Duration::from_secs(10),
            max_inflight_per_conn: 256,
            shed_pending: 4096,
        }
    }
}

/// Work items flowing loop → workers (requests) and service → workers
/// (completions). `req_id` is the v2 request id (`None` on a
/// v1-framed connection) and decides the reply framing.
enum Work {
    Req { token: u64, seq: u64, req_id: Option<u64>, frame: Frame },
    Done { token: u64, seq: u64, req_id: Option<u64>, resp: Option<Box<MergeResponse>> },
}

/// A fully encoded reply headed back to the loop for sequencing.
struct Ready {
    token: u64,
    seq: u64,
    /// v2 id this reply releases for reuse (`None` for v1 replies and
    /// for errors that never claimed one, e.g. the duplicate-id error).
    release_id: Option<u64>,
    bytes: Vec<u8>,
}

/// State shared between the loop and the worker pool.
struct Shared {
    ready: Mutex<Vec<Ready>>,
    waker: Waker,
    /// Completion-callback sender slot. Workers clone a sender per
    /// merge submit; the slot is cleared after the service drains so
    /// the workers' `recv` disconnects and the pool exits.
    work_tx: Mutex<Option<mpsc::Sender<Work>>>,
}

/// A running framed-TCP front-end over a [`MergeService`].
pub struct NetServer {
    addr: SocketAddr,
    service: Arc<MergeService>,
    shutdown: Arc<AtomicBool>,
    waker: Waker,
    shared: Arc<Shared>,
    poll_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `service` until [`Self::shutdown`]. Takes ownership of the
    /// service; reach it through [`Self::service`] for in-process
    /// submission and metrics.
    pub fn start(listen: &str, service: MergeService, cfg: NetServerConfig) -> Result<NetServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen:?}"))?;
        listener.set_nonblocking(true).context("setting listener nonblocking")?;
        let addr = listener.local_addr().context("resolving listen address")?;
        let poller = Poller::new().context("creating readiness poller")?;
        let (waker, wake_rx) = poll::wake_pair().context("creating loop waker")?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, true, false)
            .context("registering listener")?;
        poller
            .register(wake_rx.fd(), TOKEN_WAKER, true, false)
            .context("registering waker")?;
        let service = Arc::new(service);
        let shutdown = Arc::new(AtomicBool::new(false));
        let (work_tx, work_rx) = mpsc::channel::<Work>();
        let shared = Arc::new(Shared {
            ready: Mutex::new(Vec::new()),
            waker: waker.clone(),
            work_tx: Mutex::new(Some(work_tx.clone())),
        });
        let work_rx = Arc::new(Mutex::new(work_rx));
        let n_workers = cfg.workers.max(1);
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let work_rx = Arc::clone(&work_rx);
            let service = Arc::clone(&service);
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("loms-net-worker-{i}"))
                    .spawn(move || worker_loop(work_rx, service, shared, cfg))
                    .context("spawning net worker")?,
            );
        }
        let el = EventLoop {
            poller,
            listener: Some(listener),
            wake_rx,
            conns: HashMap::new(),
            next_token: FIRST_CONN_TOKEN,
            wheel: TimerWheel::new(WHEEL_GRANULARITY, WHEEL_SLOTS),
            resume: Vec::new(),
            service: Arc::clone(&service),
            shared: Arc::clone(&shared),
            work_tx,
            max_inflight: cfg.max_inflight_per_conn.max(1),
            cfg,
            shutdown: Arc::clone(&shutdown),
        };
        let poll_thread = std::thread::Builder::new()
            .name("loms-net-poll".into())
            .spawn(move || el.run())
            .context("spawning net event loop")?;
        Ok(NetServer {
            addr,
            service,
            shutdown,
            waker,
            shared,
            poll_thread: Some(poll_thread),
            workers,
        })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind the listener (in-process submission, metrics).
    pub fn service(&self) -> &MergeService {
        &self.service
    }

    fn stop(&mut self) {
        let Some(h) = self.poll_thread.take() else { return }; // already stopped
        self.shutdown.store(true, Ordering::SeqCst);
        self.waker.wake();
        let _ = h.join();
        // The loop is gone; drain the service. Every in-flight
        // request's completion callback fires inside this call (each
        // holds a work-sender clone, so the pool is still reachable).
        self.service.shutdown();
        // All callback clones have fired and dropped; clearing the
        // slot drops the last sender and disconnects the worker pool.
        if let Ok(mut slot) = self.shared.work_tx.lock() {
            *slot = None;
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, drain every admitted frame
    /// and batch to the wire, then stop the service itself.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// v1.2 trace id for an inbound merge: honor the client's id, else
/// mint one at the edge — but only while sampling is on, so the
/// untraced hot path pays nothing extra.
fn net_trace(metrics: &Metrics, wire: u64) -> u64 {
    if wire != 0 {
        wire
    } else if metrics.tracer().sample() != 0 {
        metrics.tracer().mint()
    } else {
        0
    }
}

/// One connection's state on the loop thread.
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
    proto: Proto,
    queue: ReplyQueue,
    wbuf: Vec<u8>,
    wpos: usize,
    want_write: bool,
    read_paused: bool,
    /// No more reads; close once every admitted reply is flushed.
    closing: bool,
    /// Whether the fd currently has poller interest (a paused, idle
    /// connection is deregistered entirely so a peer-hangup cannot
    /// spin the level-triggered loop).
    registered: bool,
    write_deadline: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            reader: FrameReader::new(),
            proto: Proto::Unset,
            queue: ReplyQueue::new(),
            wbuf: Vec::new(),
            wpos: 0,
            want_write: false,
            read_paused: false,
            closing: false,
            registered: true,
            write_deadline: None,
        }
    }

    /// Write pending bytes; `Ok(true)` means fully drained.
    fn flush(&mut self) -> io::Result<bool> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.wpos += n;
                    // Progress: the peer is reading. Clear the deadline
                    // so it re-arms fresh if the very next write blocks.
                    self.write_deadline = None;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.wpos >= self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
            self.want_write = false;
            self.write_deadline = None;
            Ok(true)
        } else {
            if self.wpos >= WBUF_COMPACT {
                self.wbuf.drain(..self.wpos);
                self.wpos = 0;
            }
            self.want_write = true;
            Ok(false)
        }
    }

    /// Reply bytes not yet on the wire (pause-budget input).
    fn backlog(&self) -> usize {
        (self.wbuf.len() - self.wpos) + self.queue.held_bytes()
    }
}

/// Encode a protocol error on the loop thread and sequence it through
/// the reply queue (it rides behind earlier v1 replies like any other
/// completion). Counts `on_net_error` at encode, like the workers.
fn conn_error(metrics: &Metrics, conn: &mut Conn, code: u8, message: &str, echo_id: u64) {
    metrics.on_net_error();
    let mut bytes = Vec::new();
    let ordered = conn.proto != Proto::V2;
    if ordered {
        encode_error(code, message, &mut bytes);
    } else {
        encode_error_v2(echo_id, code, message, &mut bytes);
    }
    let seq = conn.queue.admit();
    conn.queue.complete(ordered, seq, None, bytes, &mut conn.wbuf);
}

/// The readiness loop (runs on `loms-net-poll`).
struct EventLoop {
    poller: Poller,
    listener: Option<TcpListener>,
    wake_rx: WakeReader,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    wheel: TimerWheel,
    /// Connections whose reads resumed this iteration — re-pumped so
    /// frames already buffered in their `FrameReader` are not stranded
    /// waiting for a readiness event that will never re-fire.
    resume: Vec<u64>,
    service: Arc<MergeService>,
    shared: Arc<Shared>,
    work_tx: mpsc::Sender<Work>,
    max_inflight: usize,
    cfg: NetServerConfig,
    shutdown: Arc<AtomicBool>,
}

enum ReadExit {
    /// Re-sync interest/flush state.
    Sync,
    /// The connection was torn down mid-read.
    Closed,
}

impl EventLoop {
    fn run(mut self) {
        let mut events: Vec<PollEvent> = Vec::new();
        let mut expired: Vec<u64> = Vec::new();
        loop {
            let timeout = self.wheel.tick_hint().unwrap_or(MAX_POLL_WAIT).min(MAX_POLL_WAIT);
            if self.poller.wait(&mut events, Some(timeout)).is_err() {
                // A transient wait failure must not spin the loop hot.
                std::thread::sleep(Duration::from_millis(5));
            }
            let evs = std::mem::take(&mut events);
            for ev in evs.iter().copied() {
                match ev.token {
                    TOKEN_LISTENER => self.accept_ready(),
                    TOKEN_WAKER => self.wake_rx.drain(),
                    token => self.conn_event(token, ev),
                }
            }
            events = evs;
            self.apply_ready();
            let resume = std::mem::take(&mut self.resume);
            for token in resume {
                self.read_token(token);
            }
            self.wheel.advance(Instant::now(), &mut expired);
            for token in expired.drain(..) {
                self.check_deadline(token);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                self.drain_for_shutdown();
                if self.conns.is_empty() {
                    break;
                }
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    self.service.metrics().on_net_connection();
                    let token = self.next_token;
                    self.next_token += 1; // tokens are never reused
                    if self.poller.register(stream.as_raw_fd(), token, true, false).is_ok() {
                        self.conns.insert(token, Conn::new(stream));
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    // Transient accept errors (EMFILE, aborted
                    // handshake): level-triggered readiness will
                    // re-report, so back off briefly instead of
                    // busy-spinning on a persistent condition.
                    std::thread::sleep(Duration::from_millis(5));
                    return;
                }
            }
        }
    }

    fn conn_event(&mut self, token: u64, ev: PollEvent) {
        if ev.writable {
            self.flush_and_sync(token);
        }
        if ev.readable {
            self.read_token(token);
        }
        // `hangup` needs no dedicated arm: reads surface Eof and
        // writes surface the error; sync handles the teardown.
    }

    /// Decode frames from one connection until it would block, pauses,
    /// or dies. One `read_frame` call does at most one transport read,
    /// and the inflight quota bounds how many frames one connection
    /// can admit per pump — no connection can starve the loop.
    fn read_token(&mut self, token: u64) {
        let exit = loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if conn.read_paused || conn.closing {
                break ReadExit::Sync;
            }
            let metrics = self.service.metrics();
            match conn.reader.read_frame(&mut conn.stream) {
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    break ReadExit::Sync;
                }
                Err(_) => break ReadExit::Closed, // disconnect (possibly mid-frame)
                Ok(ReadFrame::Pending) => continue,
                Ok(ReadFrame::Eof) => {
                    conn.closing = true;
                    break ReadExit::Sync;
                }
                Ok(ReadFrame::Corrupt(msg)) => {
                    // The stream cannot be resynced: answer and close
                    // once the error (and earlier replies) are flushed.
                    metrics.on_net_frame_in();
                    metrics.on_net_decode_error();
                    conn_error(metrics, conn, code::MALFORMED, &msg, 0);
                    conn.closing = true;
                    break ReadExit::Sync;
                }
                Ok(ReadFrame::Malformed(msg)) => {
                    // Framing intact: answer on the same connection and
                    // keep serving (no disconnect on bad frames).
                    metrics.on_net_frame_in();
                    metrics.on_net_decode_error();
                    conn_error(metrics, conn, code::MALFORMED, &msg, 0);
                }
                Ok(ReadFrame::Frame(frame)) => {
                    // Injected connection kill: drop the connection
                    // before this frame is counted or answered — the
                    // client sees an abrupt close with requests
                    // unanswered and must reconnect and replay.
                    if fault::fires(Site::NetConnReset) {
                        metrics.on_fault_injected();
                        break ReadExit::Closed;
                    }
                    metrics.on_net_frame_in();
                    if conn.proto == Proto::Unset {
                        conn.proto = Proto::V1;
                    }
                    if conn.proto == Proto::V2 {
                        conn_error(
                            metrics,
                            conn,
                            code::MALFORMED,
                            "v1-framed request on a connection negotiated to v2",
                            0,
                        );
                        continue;
                    }
                    let seq = conn.queue.admit();
                    let _ = self.work_tx.send(Work::Req { token, seq, req_id: None, frame });
                    if conn.queue.inflight() >= self.max_inflight
                        || conn.backlog() >= WRITE_BACKLOG_PAUSE
                    {
                        conn.read_paused = true;
                    }
                }
                Ok(ReadFrame::FrameV2(frame, id)) => {
                    if fault::fires(Site::NetConnReset) {
                        metrics.on_fault_injected();
                        break ReadExit::Closed;
                    }
                    metrics.on_net_frame_in();
                    if conn.proto == Proto::Unset {
                        conn.proto = Proto::V2;
                    }
                    if conn.proto == Proto::V1 {
                        conn_error(
                            metrics,
                            conn,
                            code::MALFORMED,
                            "v2-framed request on a connection negotiated to v1",
                            0,
                        );
                        continue;
                    }
                    if !conn.queue.claim_id(id) {
                        conn_error(
                            metrics,
                            conn,
                            code::MALFORMED,
                            &format!("request id {id} is already in flight on this connection"),
                            id,
                        );
                        continue;
                    }
                    let seq = conn.queue.admit();
                    let _ = self.work_tx.send(Work::Req { token, seq, req_id: Some(id), frame });
                    if conn.queue.inflight() >= self.max_inflight
                        || conn.backlog() >= WRITE_BACKLOG_PAUSE
                    {
                        conn.read_paused = true;
                    }
                }
            }
        };
        match exit {
            ReadExit::Closed => self.force_close(token),
            ReadExit::Sync => self.flush_and_sync(token),
        }
    }

    /// Drain worker-completed replies into their connections' queues
    /// and flush. Replies for connections that died in between are
    /// dropped — their metrics were already counted at encode.
    fn apply_ready(&mut self) {
        let ready = match self.shared.ready.lock() {
            Ok(mut g) => std::mem::take(&mut *g),
            Err(_) => return,
        };
        if ready.is_empty() {
            return;
        }
        let mut touched: Vec<u64> = Vec::with_capacity(ready.len());
        for r in ready {
            let Some(conn) = self.conns.get_mut(&r.token) else { continue };
            let ordered = conn.proto != Proto::V2;
            conn.queue.complete(ordered, r.seq, r.release_id, r.bytes, &mut conn.wbuf);
            if touched.last() != Some(&r.token) {
                touched.push(r.token);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for token in touched {
            self.flush_and_sync(token);
        }
    }

    /// Flush a connection's write buffer, arm the write deadline if the
    /// peer blocked us, then re-sync interest / pause / close state.
    fn flush_and_sync(&mut self, token: u64) {
        let flushed = match self.conns.get_mut(&token) {
            None => return,
            Some(conn) => conn.flush(),
        };
        match flushed {
            Err(_) => {
                self.force_close(token);
                return;
            }
            Ok(false) => {
                let mut arm = None;
                if let Some(conn) = self.conns.get_mut(&token) {
                    if conn.write_deadline.is_none() {
                        let dl = Instant::now() + self.cfg.write_timeout;
                        conn.write_deadline = Some(dl);
                        arm = Some(dl);
                    }
                }
                if let Some(dl) = arm {
                    self.wheel.insert(token, dl);
                }
            }
            Ok(true) => {}
        }
        self.sync_conn(token);
    }

    /// Recompute a connection's pause state and poller interest; close
    /// it if it is drained and closing; queue a resume re-pump if its
    /// read just unpaused.
    fn sync_conn(&mut self, token: u64) {
        let (close_now, resumed) = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            let want_pause =
                conn.queue.inflight() >= self.max_inflight || conn.backlog() >= WRITE_BACKLOG_PAUSE;
            let resumed = conn.read_paused && !want_pause && !conn.closing;
            conn.read_paused = want_pause;
            let readable = !conn.read_paused && !conn.closing;
            let writable = conn.want_write;
            let fd = conn.stream.as_raw_fd();
            if !readable && !writable {
                // Fully idle (paused or closing, nothing to write):
                // drop poller interest so a peer-hangup can't spin the
                // level-triggered loop. Progress arrives via `Ready`.
                if conn.registered {
                    let _ = self.poller.deregister(fd);
                    conn.registered = false;
                }
            } else if conn.registered {
                let _ = self.poller.modify(fd, token, readable, writable);
            } else if self.poller.register(fd, token, readable, writable).is_ok() {
                conn.registered = true;
            }
            let drained = conn.wpos >= conn.wbuf.len() && conn.queue.held_bytes() == 0;
            (conn.closing && conn.queue.inflight() == 0 && drained, resumed)
        };
        if close_now {
            self.force_close(token);
        } else if resumed {
            self.resume.push(token);
        }
    }

    /// Immediate teardown: deregister and drop the connection. Any
    /// in-flight completions for it land as unknown-token `Ready`
    /// buffers and are discarded (already counted at encode).
    fn force_close(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            if conn.registered {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
            }
        }
    }

    /// A wheel token fired: reap the connection if its authoritative
    /// deadline really passed; re-arm if the deadline moved (lazy
    /// cancellation — the wheel itself has no removal).
    fn check_deadline(&mut self, token: u64) {
        let deadline = match self.conns.get(&token) {
            None => return,
            Some(conn) => conn.write_deadline,
        };
        match deadline {
            Some(dl) if Instant::now() >= dl => self.force_close(token), // dead peer
            Some(dl) => self.wheel.insert(token, dl),
            None => {}
        }
    }

    /// Shutdown progression, run every loop iteration once the flag is
    /// set: close the listener, stop reading everywhere, and keep
    /// flushing until every connection has drained its admitted
    /// replies (the service stays up for the drain; write deadlines
    /// reap peers that stop reading).
    fn drain_for_shutdown(&mut self) {
        if let Some(listener) = self.listener.take() {
            let _ = self.poller.deregister(listener.as_raw_fd());
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            if let Some(conn) = self.conns.get_mut(&token) {
                conn.closing = true;
            }
            self.flush_and_sync(token);
        }
    }
}

/// One dispatch/encode worker.
fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<Work>>>,
    service: Arc<MergeService>,
    shared: Arc<Shared>,
    cfg: NetServerConfig,
) {
    loop {
        // Take one work item while holding the lock, release to serve.
        let work = {
            let Ok(guard) = rx.lock() else { return };
            guard.recv()
        };
        let Ok(work) = work else { return };
        match work {
            Work::Req { token, seq, req_id, frame } => {
                handle_request(token, seq, req_id, frame, &service, &shared, &cfg)
            }
            Work::Done { token, seq, req_id, resp } => {
                handle_done(token, seq, req_id, resp, service.metrics(), &shared)
            }
        }
    }
}

/// A clone of the completion sender, if the server is still serving.
fn completion_tx(shared: &Shared) -> Option<mpsc::Sender<Work>> {
    shared.work_tx.lock().ok().and_then(|slot| (*slot).clone())
}

/// Count an error at encode time and frame it for the connection's
/// negotiated protocol.
fn reply_error(metrics: &Metrics, req_id: Option<u64>, code: u8, message: &str, buf: &mut Vec<u8>) {
    metrics.on_net_error();
    match req_id {
        Some(id) => encode_error_v2(id, code, message, buf),
        None => encode_error(code, message, buf),
    }
}

/// Apply the injected write stall (on a worker thread, never the loop
/// or an executor) and hand the encoded reply back to the loop.
fn finish_reply(metrics: &Metrics, shared: &Shared, reply: Ready) {
    // Injected write stall: delay the reply long enough for the
    // client's deadline/backoff machinery to be exercised, without
    // corrupting the stream.
    if fault::fires(Site::NetWriteStall) {
        metrics.on_fault_injected();
        std::thread::sleep(Duration::from_millis(50));
    }
    if let Ok(mut g) = shared.ready.lock() {
        g.push(reply);
    }
    shared.waker.wake();
}

/// Dispatch one decoded request. Control frames and refusals are
/// answered synchronously; merges are submitted with a completion
/// callback and answered later via [`Work::Done`].
fn handle_request(
    token: u64,
    seq: u64,
    req_id: Option<u64>,
    frame: Frame,
    service: &Arc<MergeService>,
    shared: &Arc<Shared>,
    cfg: &NetServerConfig,
) {
    let metrics = service.metrics();
    let mut buf = Vec::new();
    match frame {
        Frame::Ping => {
            metrics.on_net_response();
            match req_id {
                Some(id) => protocol::encode_frame_v2(&Frame::Pong, id, &mut buf),
                None => protocol::encode_frame(&Frame::Pong, &mut buf),
            }
        }
        Frame::MergeRequest { mode, .. } | Frame::MergeRequestKV { mode, .. }
            if mode != MODE_MERGE =>
        {
            reply_error(
                metrics,
                req_id,
                code::UNSUPPORTED,
                &format!("unsupported request mode {mode}"),
                &mut buf,
            );
        }
        // Admission-level shed: refuse merge work while the service is
        // over its pending watermark. The request was never submitted,
        // so the client can always safely retry (a v2 id is released
        // by this reply and reusable for the resubmit).
        Frame::MergeRequest { .. } | Frame::MergeRequestKV { .. }
            if cfg.shed_pending > 0 && service.pending() >= cfg.shed_pending =>
        {
            metrics.on_shed();
            reply_error(
                metrics,
                req_id,
                code::OVERLOADED,
                "server overloaded, retry later",
                &mut buf,
            );
        }
        // Stats are answered even over the shed watermark — inspecting
        // an overloaded server is the poll's whole point. The document
        // is fitted to MAX_STATS_BYTES (per-artifact detail elided
        // before the frame would overflow).
        Frame::StatsRequest => {
            let json = expo::stats_json_fitted(
                &metrics.snapshot(),
                service.pending(),
                protocol::MAX_STATS_BYTES,
            );
            metrics.on_net_response();
            match req_id {
                Some(id) => protocol::encode_stats_response_v2(id, &json, &mut buf),
                None => protocol::encode_stats_response(&json, &mut buf),
            }
        }
        // The decoded lists go into admission as-is — no re-copy
        // between socket and service. The reply arrives via Done.
        Frame::MergeRequest { trace, lists, .. } => {
            let trace = net_trace(metrics, trace);
            match completion_tx(shared) {
                Some(tx) => {
                    service.submit_with(lists, trace, move |resp| {
                        let _ = tx.send(Work::Done { token, seq, req_id, resp: resp.map(Box::new) });
                    });
                    return;
                }
                None => reply_error(metrics, req_id, code::REJECTED, REJECT_MSG, &mut buf),
            }
        }
        // v1.1: the decoded payload column rides into admission beside
        // the keys, same single copy.
        Frame::MergeRequestKV { trace, lists, payloads, .. } => {
            let trace = net_trace(metrics, trace);
            match completion_tx(shared) {
                Some(tx) => {
                    service.submit_kv_with(lists, payloads, trace, move |resp| {
                        let _ = tx.send(Work::Done { token, seq, req_id, resp: resp.map(Box::new) });
                    });
                    return;
                }
                None => reply_error(metrics, req_id, code::REJECTED, REJECT_MSG, &mut buf),
            }
        }
        Frame::MergeResponse { .. }
        | Frame::MergeResponseKV { .. }
        | Frame::Error { .. }
        | Frame::StatsResponse { .. }
        | Frame::Pong => {
            reply_error(
                metrics,
                req_id,
                code::UNSUPPORTED,
                "client-only frame type sent to server",
                &mut buf,
            );
        }
    }
    finish_reply(metrics, shared, Ready { token, seq, release_id: req_id, bytes: buf });
}

/// Encode a completed merge (or its rejection) for the wire. Counted
/// here even if the connection died — the account must balance.
fn handle_done(
    token: u64,
    seq: u64,
    req_id: Option<u64>,
    resp: Option<Box<MergeResponse>>,
    metrics: &Metrics,
    shared: &Shared,
) {
    let mut buf = Vec::new();
    match resp {
        Some(resp) => {
            metrics.on_net_response();
            // The one outbound copy: response columns → frame bytes. A
            // KV request gets the KV response frame; key-only replies
            // stay byte-identical to v1 on v1 connections.
            match (req_id, &resp.payloads) {
                (Some(id), Some(pays)) => {
                    encode_merge_response_kv_v2(id, &resp.served_by, &resp.merged, pays, &mut buf)
                }
                (Some(id), None) => {
                    encode_merge_response_v2(id, &resp.served_by, &resp.merged, &mut buf)
                }
                (None, Some(pays)) => {
                    encode_merge_response_kv(&resp.served_by, &resp.merged, pays, &mut buf)
                }
                (None, None) => encode_merge_response(&resp.served_by, &resp.merged, &mut buf),
            }
        }
        None => {
            metrics.on_net_error();
            match req_id {
                Some(id) => encode_error_v2(id, code::REJECTED, REJECT_MSG, &mut buf),
                None => encode_error(code::REJECTED, REJECT_MSG, &mut buf),
            }
        }
    }
    finish_reply(metrics, shared, Ready { token, seq, release_id: req_id, bytes: buf });
}
