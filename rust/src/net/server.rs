//! The networked serving front-end: a framed-TCP listener over a
//! running [`MergeService`].
//!
//! Thread shape:
//!
//! * `loms-net-accept` — accepts connections and hands them to the
//!   worker pool over a bounded channel (backpressure: when every
//!   worker is busy and the backlog is full, `accept` stalls and the
//!   kernel's listen queue absorbs the burst).
//! * `loms-net-worker-*` — a fixed pool; each worker owns one
//!   connection at a time. Per connection the worker runs a *reader*
//!   (its own thread of control) and spawns a scoped *writer* thread,
//!   so pipelined requests decode and enter service admission while
//!   earlier responses are still being written — the wire front-end
//!   inherits the service's depth-1 execution pipeline instead of
//!   serialising it.
//!
//! Data path: frame bytes decode straight into the `Vec<u32>` lists
//! handed to [`MergeService::submit`] (one inbound copy), the service
//! runs its two-copy tile-direct path, and the response keys are
//! encoded from the response vector into the write buffer (one
//! outbound copy). No intermediate request/response structs exist on
//! the server side of the wire.
//!
//! Error policy: a malformed frame body gets an [`Frame::Error`] reply
//! on the same connection and the stream keeps going (the length
//! prefix kept it in sync); only an unusable length prefix or a
//! mid-frame disconnect closes the connection. The server never
//! panics on wire input — every decode failure is a typed reply.
//!
//! Overload policy: the per-connection reply queue is bounded
//! ([`NetServerConfig::max_inflight_per_conn`]) — a client that
//! pipelines faster than it reads stops being *read*, so backpressure
//! reaches it through TCP instead of growing server memory; a peer
//! that stops reading entirely trips the write timeout and is
//! disconnected.
//!
//! Shutdown: [`NetServer::shutdown`] stops accepting, lets every
//! worker finish its in-flight frames (readers poll the flag at
//! `read_timeout` granularity; writers drain every response already
//! admitted to the service), then joins the pool and finally shuts the
//! service down — in-flight batches are never dropped.

use super::protocol::{
    self, code, encode_error, encode_merge_response, encode_merge_response_kv,
    encode_stats_response, Frame, FrameReader, ReadFrame, MODE_MERGE,
};
use crate::coordinator::request::MergeResponse;
use crate::coordinator::{Metrics, MergeService};
use crate::obs::expo;
use crate::util::fault::{self, Site};
use anyhow::{Context, Result};
use std::io::{self, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Listener tuning.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Worker threads — the maximum number of concurrently served
    /// connections (clamped to ≥ 1).
    pub workers: usize,
    /// Socket read timeout: how often a blocked reader wakes to check
    /// the shutdown flag. Frame sync is kept across timeouts.
    pub read_timeout: Duration,
    /// Socket write timeout: how long a reply write may block on a
    /// peer that stopped reading before the connection is declared
    /// dead. Bounds how long one slow-loris client can delay worker
    /// (and therefore server) shutdown.
    pub write_timeout: Duration,
    /// Maximum replies a connection may have in flight (admitted to
    /// the service or queued for the writer). When the writer falls
    /// this far behind, the reader stops decoding new frames —
    /// backpressure reaches the client through TCP instead of growing
    /// server memory without bound (clamped to ≥ 1).
    pub max_inflight_per_conn: usize,
    /// Admission-level overload shedding: when the service's pending
    /// gauge ([`MergeService::pending`]) is at or above this watermark,
    /// new merge requests are answered with an
    /// [`code::OVERLOADED`] error frame instead of being
    /// submitted — the client backs off and retries, and server-side
    /// queues stay bounded under a request storm. `0` disables
    /// shedding. Pings and error replies are never shed.
    pub shed_pending: u64,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            workers: 8,
            read_timeout: Duration::from_millis(50),
            write_timeout: Duration::from_secs(10),
            max_inflight_per_conn: 256,
            shed_pending: 4096,
        }
    }
}

/// A running framed-TCP front-end over a [`MergeService`].
pub struct NetServer {
    addr: SocketAddr,
    service: Option<Arc<MergeService>>,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// serve `service` until [`Self::shutdown`]. Takes ownership of the
    /// service; reach it through [`Self::service`] for in-process
    /// submission and metrics.
    pub fn start(listen: &str, service: MergeService, cfg: NetServerConfig) -> Result<NetServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen:?}"))?;
        let addr = listener.local_addr().context("resolving listen address")?;
        let service = Arc::new(service);
        let shutdown = Arc::new(AtomicBool::new(false));
        let n_workers = cfg.workers.max(1);
        // Bounded hand-off: a full backlog pushes backpressure into the
        // kernel listen queue instead of growing an unbounded Vec.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(n_workers);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::with_capacity(n_workers);
        for i in 0..n_workers {
            let conn_rx = Arc::clone(&conn_rx);
            let service = Arc::clone(&service);
            let shutdown = Arc::clone(&shutdown);
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("loms-net-worker-{i}"))
                    .spawn(move || loop {
                        // Take one connection while holding the lock,
                        // release it to serve.
                        let conn = {
                            let Ok(guard) = conn_rx.lock() else { return };
                            guard.recv()
                        };
                        let Ok(stream) = conn else { return };
                        serve_conn(stream, &service, &shutdown, &cfg);
                    })
                    .context("spawning net worker")?,
            );
        }
        let accept_shutdown = Arc::clone(&shutdown);
        let accept_metrics = Arc::clone(&service);
        let acceptor = std::thread::Builder::new()
            .name("loms-net-accept".into())
            .spawn(move || {
                loop {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if accept_shutdown.load(Ordering::SeqCst) {
                                break; // the shutdown wake-up connection
                            }
                            accept_metrics.metrics().on_net_connection();
                            if conn_tx.send(stream).is_err() {
                                break;
                            }
                        }
                        Err(_) => {
                            if accept_shutdown.load(Ordering::SeqCst) {
                                break;
                            }
                            // Transient accept errors (EMFILE, aborted
                            // handshake): back off briefly instead of
                            // busy-spinning on a persistent condition.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
                // Dropping conn_tx here releases the worker pool.
            })
            .context("spawning net acceptor")?;
        Ok(NetServer { addr, service: Some(service), shutdown, acceptor: Some(acceptor), workers })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind the listener (in-process submission, metrics).
    pub fn service(&self) -> &MergeService {
        self.service.as_ref().expect("server not shut down")
    }

    fn stop(&mut self) {
        if self.acceptor.is_none() && self.workers.is_empty() {
            return; // already stopped (shutdown() runs before Drop)
        }
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the acceptor out of `accept()`; it sees the flag and
        // exits, dropping the connection channel. A wildcard bind
        // (0.0.0.0 / ::) is not self-connectable everywhere, so the
        // wake-up targets loopback on the same port, with a bounded
        // connect so a refused wake can never hang the join.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                IpAddr::V4(_) => IpAddr::V4(Ipv4Addr::LOCALHOST),
                IpAddr::V6(_) => IpAddr::V6(Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, drain every in-flight frame
    /// and batch, then stop the service itself.
    pub fn shutdown(mut self) {
        self.stop();
        if let Some(service) = self.service.take() {
            if let Ok(svc) = Arc::try_unwrap(service) {
                svc.shutdown();
            }
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop();
        // `service` (if still held) stops via its own Drop.
    }
}

/// What the reader hands the writer, in request order.
enum Reply {
    /// A merge admitted to the service — the writer awaits the
    /// response channel (closed channel = rejected).
    Merge(mpsc::Receiver<MergeResponse>),
    Pong,
    /// A v1.2 stats document, already rendered to JSON by the reader
    /// (snapshotting under the reader keeps the writer non-blocking).
    Stats(String),
    Err { code: u8, message: String },
}

/// v1.2 trace id for an inbound merge: honor the client's id, else
/// mint one at the edge — but only while sampling is on, so the
/// untraced hot path pays nothing extra.
fn net_trace(metrics: &Metrics, wire: u64) -> u64 {
    if wire != 0 {
        wire
    } else if metrics.tracer().sample() != 0 {
        metrics.tracer().mint()
    } else {
        0
    }
}

/// Serve one connection to completion (peer close, fatal frame, or
/// server shutdown). Reader runs here; the writer runs in a scoped
/// thread so responses stream back while later frames decode.
fn serve_conn(
    mut stream: TcpStream,
    service: &MergeService,
    shutdown: &AtomicBool,
    cfg: &NetServerConfig,
) {
    let metrics = service.metrics();
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(cfg.read_timeout)).is_err() {
        return;
    }
    let Ok(write_half) = stream.try_clone() else { return };
    // A peer that stops reading must not pin this worker forever: the
    // write timeout turns it into a dead-peer close.
    let _ = write_half.set_write_timeout(Some(cfg.write_timeout));
    // Bounded reply queue: when the writer falls `max_inflight` behind
    // (slow or stalled peer), the reader blocks here instead of
    // admitting more work — backpressure reaches the client via TCP,
    // and per-connection memory stays bounded.
    let (reply_tx, reply_rx) = mpsc::sync_channel::<Reply>(cfg.max_inflight_per_conn.max(1));
    std::thread::scope(|s| {
        let writer = s.spawn(|| writer_loop(write_half, reply_rx, metrics));
        let mut reader = FrameReader::new();
        loop {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            match reader.read_frame(&mut stream) {
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    continue; // shutdown poll tick; frame sync is kept
                }
                Err(_) => break, // disconnect (possibly mid-frame)
                // Partial frame: loop so the shutdown check above runs
                // between every chunk, even against a trickling peer.
                Ok(ReadFrame::Pending) => continue,
                Ok(ReadFrame::Eof) => break,
                Ok(ReadFrame::Corrupt(msg)) => {
                    // The stream cannot be resynced: answer and close.
                    metrics.on_net_frame_in();
                    metrics.on_net_decode_error();
                    let _ = reply_tx.send(Reply::Err { code: code::MALFORMED, message: msg });
                    break;
                }
                Ok(ReadFrame::Malformed(msg)) => {
                    // Framing intact: answer on the same connection and
                    // keep serving (no disconnect on bad frames).
                    metrics.on_net_frame_in();
                    metrics.on_net_decode_error();
                    let _ = reply_tx.send(Reply::Err { code: code::MALFORMED, message: msg });
                }
                Ok(ReadFrame::Frame(frame)) => {
                    // Injected connection kill: drop the connection
                    // before this frame is counted or answered — the
                    // client sees an abrupt close with requests
                    // unanswered and must reconnect and replay.
                    if fault::fires(Site::NetConnReset) {
                        metrics.on_fault_injected();
                        break;
                    }
                    metrics.on_net_frame_in();
                    let reply = match frame {
                        Frame::Ping => Reply::Pong,
                        Frame::MergeRequest { mode, .. } if mode != MODE_MERGE => Reply::Err {
                            code: code::UNSUPPORTED,
                            message: format!("unsupported request mode {mode}"),
                        },
                        Frame::MergeRequestKV { mode, .. } if mode != MODE_MERGE => Reply::Err {
                            code: code::UNSUPPORTED,
                            message: format!("unsupported request mode {mode}"),
                        },
                        // Admission-level shed: refuse merge work while
                        // the service is over its pending watermark.
                        // The request was never submitted, so the
                        // client can always safely retry.
                        Frame::MergeRequest { .. } | Frame::MergeRequestKV { .. }
                            if cfg.shed_pending > 0 && service.pending() >= cfg.shed_pending =>
                        {
                            metrics.on_shed();
                            Reply::Err {
                                code: code::OVERLOADED,
                                message: "server overloaded, retry later".into(),
                            }
                        }
                        // Stats are answered even over the shed
                        // watermark — inspecting an overloaded server
                        // is the poll's whole point. Rendering under
                        // the reader keeps the writer non-blocking.
                        Frame::StatsRequest => {
                            let doc = expo::stats_json(&metrics.snapshot(), service.pending());
                            Reply::Stats(doc.to_string())
                        }
                        // The decoded lists go into admission as-is —
                        // no re-copy between socket and service.
                        Frame::MergeRequest { trace, lists, .. } => {
                            let trace = net_trace(metrics, trace);
                            Reply::Merge(service.submit_traced(lists, trace))
                        }
                        // v1.1: the decoded payload column rides into
                        // admission beside the keys, same single copy.
                        Frame::MergeRequestKV { trace, lists, payloads, .. } => {
                            let trace = net_trace(metrics, trace);
                            Reply::Merge(service.submit_kv_traced(lists, payloads, trace))
                        }
                        Frame::MergeResponse { .. }
                        | Frame::MergeResponseKV { .. }
                        | Frame::Error { .. }
                        | Frame::StatsResponse { .. }
                        | Frame::Pong => Reply::Err {
                            code: code::UNSUPPORTED,
                            message: "client-only frame type sent to server".into(),
                        },
                    };
                    let _ = reply_tx.send(reply);
                }
            }
        }
        // Closing the reply channel lets the writer drain what is in
        // flight (including service responses not yet produced) and
        // exit — graceful per-connection shutdown.
        drop(reply_tx);
        let _ = writer.join();
    });
}

/// Drain replies in request order and write response frames. Counts
/// every frame *produced* even if the peer vanished mid-reply, so the
/// `frames_in == responses + errors` account stays balanced.
fn writer_loop(mut w: TcpStream, rx: mpsc::Receiver<Reply>, metrics: &Metrics) {
    let mut buf = Vec::new();
    let mut peer_gone = false;
    while let Ok(reply) = rx.recv() {
        match reply {
            Reply::Pong => {
                metrics.on_net_response();
                protocol::encode_frame(&Frame::Pong, &mut buf);
            }
            Reply::Stats(json) => {
                metrics.on_net_response();
                encode_stats_response(&json, &mut buf);
            }
            Reply::Err { code, message } => {
                metrics.on_net_error();
                encode_error(code, &message, &mut buf);
            }
            Reply::Merge(resp_rx) => match resp_rx.recv() {
                Ok(resp) => {
                    metrics.on_net_response();
                    // The one outbound copy: response columns → frame
                    // bytes. A KV request gets the v1.1 response frame;
                    // key-only responses stay byte-identical to v1.
                    match &resp.payloads {
                        Some(pays) => {
                            encode_merge_response_kv(&resp.served_by, &resp.merged, pays, &mut buf)
                        }
                        None => encode_merge_response(&resp.served_by, &resp.merged, &mut buf),
                    }
                }
                Err(_) => {
                    metrics.on_net_error();
                    encode_error(
                        code::REJECTED,
                        "request rejected (unsorted list, u32::MAX key, or shutdown)",
                        &mut buf,
                    );
                }
            },
        }
        // Injected write stall: delay the reply long enough for the
        // client's deadline/backoff machinery to be exercised, without
        // corrupting the stream.
        if fault::fires(Site::NetWriteStall) {
            metrics.on_fault_injected();
            std::thread::sleep(Duration::from_millis(50));
        }
        if !peer_gone && w.write_all(&buf).is_err() {
            // Keep draining so in-flight service responses are still
            // consumed and the metric account balances.
            peer_gone = true;
        }
    }
    if !peer_gone {
        let _ = w.flush();
    }
}
