//! Service metrics: counters, padding efficiency and a fixed-bucket
//! latency histogram (lock-free enough for the request path: one mutex,
//! short critical sections).

use std::sync::Mutex;
use std::time::Duration;

/// Power-of-2 latency buckets from 1 µs up to ~4 s.
const BUCKETS: usize = 23;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    rows_padded: u64,
    rows_real: u64,
    software_served: u64,
    rejected: u64,
    latency_buckets: [u64; BUCKETS],
    latency_sum_ns: u128,
    /// Batches with per-stage timing recorded (pipeline observability:
    /// the serving path is queue wait → assemble → execute → respond,
    /// and overlap only shows up when each stage is measured).
    stage_batches: u64,
    queue_wait_ns: u128,
    assemble_ns: u128,
    execute_ns: u128,
    respond_ns: u128,
    /// Network front-end counters (see `rust/src/net/server.rs`):
    /// connections accepted, complete frames received, frames that
    /// failed protocol decode, and reply frames produced (response vs
    /// error). Steady-state invariant once a connection drains:
    /// `net_frames_in == net_responses + net_errors`.
    net_connections: u64,
    net_frames_in: u64,
    net_decode_errors: u64,
    net_responses: u64,
    net_errors: u64,
    /// Robustness counters (see `rust/src/util/fault.rs` and the
    /// DESIGN.md failure model): injected faults observed, corrupt
    /// spill blocks detected, retried operations (spill re-reads plus
    /// transient exec retries), and requests shed at admission.
    faults_injected: u64,
    corrupt_detected: u64,
    retries: u64,
    sheds: u64,
}

/// Shared metrics handle.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    pub rows_padded: u64,
    pub rows_real: u64,
    pub software_served: u64,
    pub rejected: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    /// Mean per-batch stage timings (µs): how long the oldest request
    /// waited for its batch to flush, view/buffer assembly, backend
    /// execution, and response fan-out. With the pipelined engine,
    /// queue wait of batch N+1 overlaps execution of batch N.
    pub queue_wait_us_mean: f64,
    pub assemble_us_mean: f64,
    pub execute_us_mean: f64,
    pub respond_us_mean: f64,
    /// Connections accepted by the network front-end.
    pub net_connections: u64,
    /// Frames received and answered: complete frames (requests, pings,
    /// bodies that then failed to decode) plus unusable length
    /// prefixes, each of which gets exactly one reply. Partial frames
    /// cut off by a disconnect are not counted (no reply is possible).
    pub net_frames_in: u64,
    /// Frames whose body (or length prefix) failed protocol decode;
    /// each was answered with an Error frame.
    pub net_decode_errors: u64,
    /// Reply frames produced with a payload (MergeResponse / Pong).
    pub net_responses: u64,
    /// Error frames produced (decode failures, rejected requests,
    /// unsupported modes, shed overloads). Once every connection
    /// drains, `net_frames_in == net_responses + net_errors`.
    pub net_errors: u64,
    /// Faults fired by the deterministic injection harness
    /// (`LOMS_FAULTS`); always 0 in production runs.
    pub faults_injected: u64,
    /// Corrupt spill blocks detected by checksum verification.
    pub corrupt_detected: u64,
    /// Operations retried after a transient failure (spill block
    /// re-reads, transient exec retries).
    pub retries: u64,
    /// Requests refused at admission because the service was over its
    /// pending-work watermark (answered with an `OVERLOADED` error).
    pub sheds: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn on_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn on_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn on_batch(&self, real_rows: usize, padded_rows: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.rows_real += real_rows as u64;
        g.rows_padded += padded_rows as u64;
    }

    pub fn on_software(&self) {
        self.inner.lock().unwrap().software_served += 1;
    }

    /// Record one executed batch's per-stage timing (queue wait /
    /// assemble / execute / respond).
    pub fn on_batch_stages(
        &self,
        queue_wait: Duration,
        assemble: Duration,
        execute: Duration,
        respond: Duration,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.stage_batches += 1;
        g.queue_wait_ns += queue_wait.as_nanos();
        g.assemble_ns += assemble.as_nanos();
        g.execute_ns += execute.as_nanos();
        g.respond_ns += respond.as_nanos();
    }

    pub fn on_net_connection(&self) {
        self.inner.lock().unwrap().net_connections += 1;
    }

    pub fn on_net_frame_in(&self) {
        self.inner.lock().unwrap().net_frames_in += 1;
    }

    pub fn on_net_decode_error(&self) {
        self.inner.lock().unwrap().net_decode_errors += 1;
    }

    pub fn on_net_response(&self) {
        self.inner.lock().unwrap().net_responses += 1;
    }

    pub fn on_net_error(&self) {
        self.inner.lock().unwrap().net_errors += 1;
    }

    pub fn on_fault_injected(&self) {
        self.inner.lock().unwrap().faults_injected += 1;
    }

    pub fn on_corrupt_detected(&self) {
        self.inner.lock().unwrap().corrupt_detected += 1;
    }

    pub fn on_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    pub fn on_shed(&self) {
        self.inner.lock().unwrap().sheds += 1;
    }

    /// Requests answered or rejected by the service so far — the cheap
    /// half of the pending-work gauge the server's admission check
    /// reads on every frame (`snapshot()` would be far too heavy
    /// there). Sheds are deliberately excluded: a shed request is
    /// refused *before* it is submitted, so it never enters the
    /// submitted count this is subtracted from.
    pub fn settled(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.responses + g.rejected
    }

    pub fn on_response(&self, latency: Duration) {
        let mut g = self.inner.lock().unwrap();
        g.responses += 1;
        let ns = latency.as_nanos();
        g.latency_sum_ns += ns;
        let us = (ns / 1_000).max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(BUCKETS - 1);
        g.latency_buckets[bucket] += 1;
    }

    fn percentile(buckets: &[u64; BUCKETS], total: u64, q: f64) -> f64 {
        if total == 0 {
            return 0.0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                // midpoint of the bucket [2^i, 2^(i+1)) µs
                return (1u64 << i) as f64 * 1.5;
            }
        }
        (1u64 << (BUCKETS - 1)) as f64
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        Snapshot {
            requests: g.requests,
            responses: g.responses,
            batches: g.batches,
            rows_padded: g.rows_padded,
            rows_real: g.rows_real,
            software_served: g.software_served,
            rejected: g.rejected,
            mean_latency_us: if g.responses == 0 {
                0.0
            } else {
                g.latency_sum_ns as f64 / g.responses as f64 / 1_000.0
            },
            p50_latency_us: Self::percentile(&g.latency_buckets, g.responses, 0.50),
            p99_latency_us: Self::percentile(&g.latency_buckets, g.responses, 0.99),
            queue_wait_us_mean: Self::stage_mean(g.queue_wait_ns, g.stage_batches),
            assemble_us_mean: Self::stage_mean(g.assemble_ns, g.stage_batches),
            execute_us_mean: Self::stage_mean(g.execute_ns, g.stage_batches),
            respond_us_mean: Self::stage_mean(g.respond_ns, g.stage_batches),
            net_connections: g.net_connections,
            net_frames_in: g.net_frames_in,
            net_decode_errors: g.net_decode_errors,
            net_responses: g.net_responses,
            net_errors: g.net_errors,
            faults_injected: g.faults_injected,
            corrupt_detected: g.corrupt_detected,
            retries: g.retries,
            sheds: g.sheds,
        }
    }

    fn stage_mean(sum_ns: u128, batches: u64) -> f64 {
        if batches == 0 {
            0.0
        } else {
            sum_ns as f64 / batches as f64 / 1_000.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_request();
        m.on_request();
        m.on_batch(3, 1);
        m.on_response(Duration::from_micros(100));
        m.on_response(Duration::from_micros(200));
        m.on_batch_stages(
            Duration::from_micros(500),
            Duration::from_micros(10),
            Duration::from_micros(80),
            Duration::from_micros(20),
        );
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.rows_real, 3);
        assert_eq!(s.rows_padded, 1);
        assert!(s.mean_latency_us >= 100.0 && s.mean_latency_us <= 200.0);
        assert!(s.p50_latency_us > 0.0);
        assert!(s.p99_latency_us >= s.p50_latency_us);
        assert_eq!(s.queue_wait_us_mean, 500.0);
        assert_eq!(s.assemble_us_mean, 10.0);
        assert_eq!(s.execute_us_mean, 80.0);
        assert_eq!(s.respond_us_mean, 20.0);
    }

    #[test]
    fn net_counters_accumulate_and_balance() {
        let m = Metrics::new();
        m.on_net_connection();
        // Three frames: a served request, a ping, a malformed body.
        m.on_net_frame_in();
        m.on_net_response();
        m.on_net_frame_in();
        m.on_net_response();
        m.on_net_frame_in();
        m.on_net_decode_error();
        m.on_net_error();
        let s = m.snapshot();
        assert_eq!(s.net_connections, 1);
        assert_eq!(s.net_frames_in, 3);
        assert_eq!(s.net_decode_errors, 1);
        assert_eq!(s.net_responses, 2);
        assert_eq!(s.net_errors, 1);
        assert_eq!(s.net_frames_in, s.net_responses + s.net_errors);
    }

    #[test]
    fn robustness_counters_accumulate() {
        let m = Metrics::new();
        m.on_fault_injected();
        m.on_corrupt_detected();
        m.on_retry();
        m.on_retry();
        m.on_shed();
        let s = m.snapshot();
        assert_eq!(s.faults_injected, 1);
        assert_eq!(s.corrupt_detected, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.sheds, 1);
        // Sheds happen before submission, so they never settle work.
        assert_eq!(m.settled(), 0);
        assert_eq!(s.rejected, 0);
    }

    #[test]
    fn settled_counts_responses_and_rejections() {
        let m = Metrics::new();
        m.on_response(Duration::from_micros(10));
        m.on_response(Duration::from_micros(10));
        m.on_rejected();
        assert_eq!(m.settled(), 3);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s, Snapshot::default());
    }
}
