//! Service metrics: counters, padding efficiency, per-stage and
//! per-artifact latency histograms ([`crate::obs::Hist`] — the one
//! percentile definition shared with `net/client.rs` and the benches),
//! and the service [`Tracer`].
//!
//! Layout: plain counters live behind one mutex with short critical
//! sections (as before); every latency distribution is a lock-free
//! log-linear histogram recorded with relaxed atomics, cheap enough to
//! leave on (`benches/service_pipeline.rs` guards the obs-on vs
//! obs-off throughput delta). The `detail` switch exists *only* for
//! that guard's obs-off row: it gates histogram/trace recording, never
//! the counters.

use crate::obs::{Hist, HistStats, Tracer};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    responses: u64,
    batches: u64,
    rows_padded: u64,
    rows_real: u64,
    software_served: u64,
    rejected: u64,
    latency_sum_ns: u128,
    /// Batches with per-stage timing recorded (pipeline observability:
    /// the serving path is queue wait → assemble → execute → respond,
    /// and overlap only shows up when each stage is measured).
    stage_batches: u64,
    queue_wait_ns: u128,
    assemble_ns: u128,
    execute_ns: u128,
    respond_ns: u128,
    /// Network front-end counters (see `rust/src/net/server.rs`):
    /// connections accepted, complete frames received, frames that
    /// failed protocol decode, and reply frames produced (response vs
    /// error). Steady-state invariant once a connection drains:
    /// `net_frames_in == net_responses + net_errors` (promoted to
    /// [`Snapshot::check`]).
    net_connections: u64,
    net_frames_in: u64,
    net_decode_errors: u64,
    net_responses: u64,
    net_errors: u64,
    /// Robustness counters (see `rust/src/util/fault.rs` and the
    /// DESIGN.md failure model): injected faults observed, corrupt
    /// spill blocks detected, retried operations (spill re-reads plus
    /// transient exec retries), and requests shed at admission.
    faults_injected: u64,
    corrupt_detected: u64,
    retries: u64,
    sheds: u64,
    /// Cumulative external-sort phase clocks reported into this
    /// service's stats surface (`on_extsort_clocks`) — zero on a
    /// pure-serve workload.
    extsort_run_form_secs: f64,
    extsort_merge_secs: f64,
    extsort_io_wait_secs: f64,
}

/// Per-artifact observability: batch count, real rows served, and the
/// execute-stage latency distribution. All relaxed atomics — recorded
/// outside any lock.
#[derive(Debug, Default)]
struct ArtifactObs {
    batches: AtomicU64,
    rows: AtomicU64,
    execute: Hist,
}

/// Shared metrics handle.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// End-to-end response latency.
    latency: Hist,
    /// Per-stage batch histograms (same stages as the `*_ns` mean sums).
    queue_wait: Hist,
    assemble: Hist,
    execute: Hist,
    respond: Hist,
    /// Keyed by artifact name (plus `"software"` for the fallback pool).
    artifacts: Mutex<HashMap<Arc<str>, Arc<ArtifactObs>>>,
    tracer: Tracer,
    /// Inverted so `derive(Default)` means detail *on*.
    detail_off: AtomicBool,
}

/// One artifact's slice of a [`Snapshot`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactSnapshot {
    pub name: String,
    pub batches: u64,
    pub rows: u64,
    pub execute: HistStats,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Snapshot {
    pub requests: u64,
    pub responses: u64,
    pub batches: u64,
    /// Batches with per-stage timing recorded. Increments after
    /// `batches` for the same batch (single executor thread), so at any
    /// instant `batches <= stage_batches + 1`; drained and error-free
    /// they are equal ([`Snapshot::check`]).
    pub stage_batches: u64,
    pub rows_padded: u64,
    pub rows_real: u64,
    pub software_served: u64,
    pub rejected: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    /// Full end-to-end latency distribution (p50/p90/p99/p999/max).
    pub latency: HistStats,
    /// Mean per-batch stage timings (µs): how long the oldest request
    /// waited for its batch to flush, view/buffer assembly, backend
    /// execution, and response fan-out. With the pipelined engine,
    /// queue wait of batch N+1 overlaps execution of batch N.
    pub queue_wait_us_mean: f64,
    pub assemble_us_mean: f64,
    pub execute_us_mean: f64,
    pub respond_us_mean: f64,
    /// Per-stage batch latency distributions.
    pub queue_wait: HistStats,
    pub assemble: HistStats,
    pub execute: HistStats,
    pub respond: HistStats,
    /// Per-artifact batch/row counts and execute histograms, sorted by
    /// artifact name (includes `"software"` once the fallback serves).
    pub artifacts: Vec<ArtifactSnapshot>,
    /// Connections accepted by the network front-end.
    pub net_connections: u64,
    /// Frames received and answered: complete frames (requests, pings,
    /// bodies that then failed to decode) plus unusable length
    /// prefixes, each of which gets exactly one reply. Partial frames
    /// cut off by a disconnect are not counted (no reply is possible).
    pub net_frames_in: u64,
    /// Frames whose body (or length prefix) failed protocol decode;
    /// each was answered with an Error frame.
    pub net_decode_errors: u64,
    /// Reply frames produced with a payload (MergeResponse / Pong /
    /// StatsResponse).
    pub net_responses: u64,
    /// Error frames produced (decode failures, rejected requests,
    /// unsupported modes, shed overloads). Once every connection
    /// drains, `net_frames_in == net_responses + net_errors`.
    pub net_errors: u64,
    /// Faults fired by the deterministic injection harness
    /// (`LOMS_FAULTS`); always 0 in production runs.
    pub faults_injected: u64,
    /// Corrupt spill blocks detected by checksum verification.
    pub corrupt_detected: u64,
    /// Operations retried after a transient failure (spill block
    /// re-reads, transient exec retries).
    pub retries: u64,
    /// Requests refused at admission because the service was over its
    /// pending-work watermark (answered with an `OVERLOADED` error).
    pub sheds: u64,
    /// Cumulative extsort phase clocks reported to this service (zero
    /// on a pure-serve workload).
    pub extsort_run_form_secs: f64,
    pub extsort_merge_secs: f64,
    pub extsort_io_wait_secs: f64,
    /// Span events evicted from the trace ring (ring full).
    pub spans_dropped: u64,
}

impl Snapshot {
    /// Drained-state balance invariants, shared by the test suites and
    /// `debug_assert!`ed (in their always-true transient form) at
    /// snapshot time. Valid once every connection has drained and no
    /// batch failed at execute:
    ///
    /// * every answered frame got exactly one reply,
    /// * every counted batch also recorded its stage split,
    /// * every admitted request settled as a response or a rejection,
    /// * the latency histogram (when recording was on) saw every
    ///   response.
    pub fn check(&self) -> Result<(), String> {
        let mut violations = Vec::new();
        if self.net_frames_in != self.net_responses + self.net_errors {
            violations.push(format!(
                "net_frames_in {} != net_responses {} + net_errors {}",
                self.net_frames_in, self.net_responses, self.net_errors
            ));
        }
        if self.stage_batches != self.batches {
            violations.push(format!(
                "stage_batches {} != batches {}",
                self.stage_batches, self.batches
            ));
        }
        if self.requests != self.responses + self.rejected {
            violations.push(format!(
                "requests {} != responses {} + rejected {}",
                self.requests, self.responses, self.rejected
            ));
        }
        // When the detail switch was off, the histogram is empty; any
        // other count must match responses exactly.
        if self.latency.count != 0 && self.latency.count != self.responses {
            violations.push(format!(
                "latency histogram count {} != responses {}",
                self.latency.count, self.responses
            ));
        }
        if violations.is_empty() {
            Ok(())
        } else {
            Err(violations.join("; "))
        }
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// The service tracer (trace-id minting + sampled span ring).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Histogram/trace recording switch — exists for the obs-overhead
    /// bench guard's obs-off row. Counters are never gated.
    pub fn set_detail(&self, on: bool) {
        self.detail_off.store(!on, Ordering::Relaxed);
    }

    pub fn detail(&self) -> bool {
        !self.detail_off.load(Ordering::Relaxed)
    }

    pub fn on_request(&self) {
        self.inner.lock().unwrap().requests += 1;
    }

    pub fn on_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn on_batch(&self, real_rows: usize, padded_rows: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.rows_real += real_rows as u64;
        g.rows_padded += padded_rows as u64;
    }

    pub fn on_software(&self) {
        self.inner.lock().unwrap().software_served += 1;
    }

    /// Record one executed batch's per-stage timing (queue wait /
    /// assemble / execute / respond).
    pub fn on_batch_stages(
        &self,
        queue_wait: Duration,
        assemble: Duration,
        execute: Duration,
        respond: Duration,
    ) {
        {
            let mut g = self.inner.lock().unwrap();
            g.stage_batches += 1;
            g.queue_wait_ns += queue_wait.as_nanos();
            g.assemble_ns += assemble.as_nanos();
            g.execute_ns += execute.as_nanos();
            g.respond_ns += respond.as_nanos();
        }
        if self.detail() {
            self.queue_wait.record_duration(queue_wait);
            self.assemble.record_duration(assemble);
            self.execute.record_duration(execute);
            self.respond.record_duration(respond);
        }
    }

    /// Record one executed batch against its artifact (or `"software"`
    /// for the fallback pool): batch count, real rows, execute latency.
    pub fn on_artifact_batch(&self, name: &Arc<str>, rows: u64, execute: Duration) {
        if !self.detail() {
            return;
        }
        let obs = {
            let mut g = self.artifacts.lock().unwrap();
            match g.get(name.as_ref()) {
                Some(o) => Arc::clone(o),
                None => {
                    let o = Arc::new(ArtifactObs::default());
                    g.insert(Arc::clone(name), Arc::clone(&o));
                    o
                }
            }
        };
        obs.batches.fetch_add(1, Ordering::Relaxed);
        obs.rows.fetch_add(rows, Ordering::Relaxed);
        obs.execute.record_duration(execute);
    }

    pub fn on_net_connection(&self) {
        self.inner.lock().unwrap().net_connections += 1;
    }

    pub fn on_net_frame_in(&self) {
        self.inner.lock().unwrap().net_frames_in += 1;
    }

    pub fn on_net_decode_error(&self) {
        self.inner.lock().unwrap().net_decode_errors += 1;
    }

    pub fn on_net_response(&self) {
        self.inner.lock().unwrap().net_responses += 1;
    }

    pub fn on_net_error(&self) {
        self.inner.lock().unwrap().net_errors += 1;
    }

    pub fn on_fault_injected(&self) {
        self.inner.lock().unwrap().faults_injected += 1;
    }

    pub fn on_corrupt_detected(&self) {
        self.inner.lock().unwrap().corrupt_detected += 1;
    }

    pub fn on_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    pub fn on_shed(&self) {
        self.inner.lock().unwrap().sheds += 1;
    }

    /// Accumulate external-sort phase clocks into the stats surface
    /// (`loms sort` and the planner report their `ExtSortStats` here
    /// when a service is around to own the numbers).
    pub fn on_extsort_clocks(&self, run_form_secs: f64, merge_secs: f64, io_wait_secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.extsort_run_form_secs += run_form_secs;
        g.extsort_merge_secs += merge_secs;
        g.extsort_io_wait_secs += io_wait_secs;
    }

    /// Requests answered or rejected by the service so far — the cheap
    /// half of the pending-work gauge the server's admission check
    /// reads on every frame (`snapshot()` would be far too heavy
    /// there). Sheds are deliberately excluded: a shed request is
    /// refused *before* it is submitted, so it never enters the
    /// submitted count this is subtracted from.
    pub fn settled(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.responses + g.rejected
    }

    pub fn on_response(&self, latency: Duration) {
        {
            let mut g = self.inner.lock().unwrap();
            g.responses += 1;
            g.latency_sum_ns += latency.as_nanos();
        }
        if self.detail() {
            self.latency.record_duration(latency);
        }
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut artifacts: Vec<ArtifactSnapshot> = {
            let g = self.artifacts.lock().unwrap();
            g.iter()
                .map(|(k, v)| ArtifactSnapshot {
                    name: k.to_string(),
                    batches: v.batches.load(Ordering::Relaxed),
                    rows: v.rows.load(Ordering::Relaxed),
                    execute: v.execute.snapshot(),
                })
                .collect()
        };
        artifacts.sort_by(|a, b| a.name.cmp(&b.name));
        let latency = self.latency.snapshot();
        let g = self.inner.lock().unwrap();
        // Transient forms of the Snapshot::check balance invariants —
        // true at *any* instant given the recording order (frame before
        // reply; batch before its stage split, one executor thread).
        debug_assert!(
            g.net_frames_in >= g.net_responses + g.net_errors,
            "net frames_in {} < responses {} + errors {}",
            g.net_frames_in,
            g.net_responses,
            g.net_errors
        );
        debug_assert!(
            g.batches <= g.stage_batches + 1,
            "batches {} ran ahead of stage_batches {}",
            g.batches,
            g.stage_batches
        );
        Snapshot {
            requests: g.requests,
            responses: g.responses,
            batches: g.batches,
            stage_batches: g.stage_batches,
            rows_padded: g.rows_padded,
            rows_real: g.rows_real,
            software_served: g.software_served,
            rejected: g.rejected,
            mean_latency_us: if g.responses == 0 {
                0.0
            } else {
                g.latency_sum_ns as f64 / g.responses as f64 / 1_000.0
            },
            p50_latency_us: latency.p50_us as f64,
            p99_latency_us: latency.p99_us as f64,
            latency,
            queue_wait_us_mean: Self::stage_mean(g.queue_wait_ns, g.stage_batches),
            assemble_us_mean: Self::stage_mean(g.assemble_ns, g.stage_batches),
            execute_us_mean: Self::stage_mean(g.execute_ns, g.stage_batches),
            respond_us_mean: Self::stage_mean(g.respond_ns, g.stage_batches),
            queue_wait: self.queue_wait.snapshot(),
            assemble: self.assemble.snapshot(),
            execute: self.execute.snapshot(),
            respond: self.respond.snapshot(),
            artifacts,
            net_connections: g.net_connections,
            net_frames_in: g.net_frames_in,
            net_decode_errors: g.net_decode_errors,
            net_responses: g.net_responses,
            net_errors: g.net_errors,
            faults_injected: g.faults_injected,
            corrupt_detected: g.corrupt_detected,
            retries: g.retries,
            sheds: g.sheds,
            extsort_run_form_secs: g.extsort_run_form_secs,
            extsort_merge_secs: g.extsort_merge_secs,
            extsort_io_wait_secs: g.extsort_io_wait_secs,
            spans_dropped: self.tracer.dropped(),
        }
    }

    fn stage_mean(sum_ns: u128, batches: u64) -> f64 {
        if batches == 0 {
            0.0
        } else {
            sum_ns as f64 / batches as f64 / 1_000.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_request();
        m.on_request();
        m.on_batch(3, 1);
        m.on_response(Duration::from_micros(100));
        m.on_response(Duration::from_micros(200));
        m.on_batch_stages(
            Duration::from_micros(500),
            Duration::from_micros(10),
            Duration::from_micros(80),
            Duration::from_micros(20),
        );
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.stage_batches, 1);
        assert_eq!(s.rows_real, 3);
        assert_eq!(s.rows_padded, 1);
        assert!(s.mean_latency_us >= 100.0 && s.mean_latency_us <= 200.0);
        assert!(s.p50_latency_us > 0.0);
        assert!(s.p99_latency_us >= s.p50_latency_us);
        assert_eq!(s.queue_wait_us_mean, 500.0);
        assert_eq!(s.assemble_us_mean, 10.0);
        assert_eq!(s.execute_us_mean, 80.0);
        assert_eq!(s.respond_us_mean, 20.0);
        // Stage histograms agree with the exact means on whole-µs input.
        assert_eq!(s.queue_wait.count, 1);
        assert_eq!(s.queue_wait.p50_us, 500);
        assert_eq!(s.execute.p50_us, 80);
        assert_eq!(s.latency.count, 2);
        assert_eq!(s.latency.max_us, 200);
    }

    #[test]
    fn latency_percentiles_share_the_hist_definition() {
        // The Snapshot p50/p99 and the raw histogram are the same
        // numbers — one percentile definition everywhere.
        let m = Metrics::new();
        for us in 1..=1000u64 {
            m.on_response(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.p50_latency_us, s.latency.p50_us as f64);
        assert_eq!(s.p99_latency_us, s.latency.p99_us as f64);
        let direct = crate::obs::percentile_us(
            &(1..=1000).map(|i| i as f64).collect::<Vec<_>>(),
            0.99,
        );
        assert_eq!(s.p99_latency_us, direct);
    }

    #[test]
    fn detail_off_gates_histograms_not_counters() {
        let m = Metrics::new();
        assert!(m.detail(), "detail defaults on");
        m.set_detail(false);
        m.on_response(Duration::from_micros(100));
        let name: Arc<str> = "loms2_up32_dn32_b256".into();
        m.on_artifact_batch(&name, 4, Duration::from_micros(10));
        let s = m.snapshot();
        assert_eq!(s.responses, 1, "counters never gated");
        assert_eq!(s.latency.count, 0, "histogram recording gated");
        assert!(s.artifacts.is_empty());
        m.set_detail(true);
        m.on_response(Duration::from_micros(100));
        m.on_artifact_batch(&name, 4, Duration::from_micros(10));
        let s = m.snapshot();
        assert_eq!(s.latency.count, 1);
        assert_eq!(s.artifacts.len(), 1);
        assert_eq!(s.artifacts[0].name, "loms2_up32_dn32_b256");
        assert_eq!(s.artifacts[0].rows, 4);
    }

    #[test]
    fn artifact_snapshots_sorted_by_name() {
        let m = Metrics::new();
        for n in ["zeta", "alpha", "mid"] {
            let name: Arc<str> = n.into();
            m.on_artifact_batch(&name, 1, Duration::from_micros(5));
        }
        let names: Vec<String> =
            m.snapshot().artifacts.into_iter().map(|a| a.name).collect();
        assert_eq!(names, vec!["alpha", "mid", "zeta"]);
    }

    #[test]
    fn net_counters_accumulate_and_balance() {
        let m = Metrics::new();
        m.on_net_connection();
        // Three frames: a served request, a ping, a malformed body.
        m.on_net_frame_in();
        m.on_net_response();
        m.on_net_frame_in();
        m.on_net_response();
        m.on_net_frame_in();
        m.on_net_decode_error();
        m.on_net_error();
        let s = m.snapshot();
        assert_eq!(s.net_connections, 1);
        assert_eq!(s.net_frames_in, 3);
        assert_eq!(s.net_decode_errors, 1);
        assert_eq!(s.net_responses, 2);
        assert_eq!(s.net_errors, 1);
        assert_eq!(s.net_frames_in, s.net_responses + s.net_errors);
    }

    #[test]
    fn check_accepts_balanced_and_names_violations() {
        let m = Metrics::new();
        m.snapshot().check().unwrap();
        m.on_request();
        m.on_response(Duration::from_micros(10));
        m.on_batch(1, 0);
        m.on_batch_stages(
            Duration::ZERO,
            Duration::ZERO,
            Duration::from_micros(5),
            Duration::ZERO,
        );
        m.on_net_frame_in();
        m.on_net_response();
        m.snapshot().check().unwrap();
        // Unbalance the frames: one unanswered frame in flight is a
        // check() violation (drained state only).
        m.on_net_frame_in();
        let err = m.snapshot().check().unwrap_err();
        assert!(err.contains("net_frames_in"), "{err}");
        m.on_net_error();
        m.snapshot().check().unwrap();
        // A batch without a stage split is a violation too.
        m.on_batch(1, 0);
        let err = m.snapshot().check().unwrap_err();
        assert!(err.contains("stage_batches"), "{err}");
    }

    #[test]
    fn robustness_counters_accumulate() {
        let m = Metrics::new();
        m.on_fault_injected();
        m.on_corrupt_detected();
        m.on_retry();
        m.on_retry();
        m.on_shed();
        let s = m.snapshot();
        assert_eq!(s.faults_injected, 1);
        assert_eq!(s.corrupt_detected, 1);
        assert_eq!(s.retries, 2);
        assert_eq!(s.sheds, 1);
        // Sheds happen before submission, so they never settle work.
        assert_eq!(m.settled(), 0);
        assert_eq!(s.rejected, 0);
    }

    #[test]
    fn extsort_clocks_accumulate() {
        let m = Metrics::new();
        m.on_extsort_clocks(1.5, 0.5, 0.25);
        m.on_extsort_clocks(0.5, 0.5, 0.25);
        let s = m.snapshot();
        assert_eq!(s.extsort_run_form_secs, 2.0);
        assert_eq!(s.extsort_merge_secs, 1.0);
        assert_eq!(s.extsort_io_wait_secs, 0.5);
    }

    #[test]
    fn settled_counts_responses_and_rejections() {
        let m = Metrics::new();
        m.on_response(Duration::from_micros(10));
        m.on_response(Duration::from_micros(10));
        m.on_rejected();
        assert_eq!(m.settled(), 3);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s, Snapshot::default());
    }
}
