//! Request routing: map a merge request's shape to a compiled artifact.
//!
//! Exact-shape matches route directly. Smaller requests route to the
//! tightest artifact that dominates them per list (k must match): lists
//! are padded with `u32::MAX` sentinels — sentinels sort to the tail of
//! the merged output, so the first `Σ real sizes` outputs are exactly
//! the true merge (data-oblivious networks make this safe for any
//! input). Requests no artifact dominates are served by the software
//! backend.

use super::request::MergeRequest;
use crate::runtime::ArtifactMeta;

/// Padding sentinel: sorts after every real key. Real keys must be
/// < u32::MAX (documented service contract).
pub const PAD: u32 = u32::MAX;

/// A routing decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Serve with this artifact (index into the router's table).
    Artifact { idx: usize },
    /// No artifact dominates: execute in software.
    Software,
}

/// Shape router over the loaded artifact set.
#[derive(Debug, Clone)]
pub struct Router {
    artifacts: Vec<ArtifactMeta>,
}

impl Router {
    pub fn new(mut artifacts: Vec<ArtifactMeta>) -> Self {
        // Prefer tighter (smaller total) artifacts at equal k.
        artifacts.sort_by_key(|a| (a.list_sizes.len(), a.total, a.name.clone()));
        Router { artifacts }
    }

    pub fn artifacts(&self) -> &[ArtifactMeta] {
        &self.artifacts
    }

    /// Route a request shape. Exact match wins; otherwise the smallest
    /// dominating artifact with the same list count.
    pub fn route(&self, sizes: &[usize]) -> Route {
        let exact = self
            .artifacts
            .iter()
            .position(|a| a.list_sizes == sizes);
        if let Some(idx) = exact {
            return Route::Artifact { idx };
        }
        let dominating = self.artifacts.iter().position(|a| {
            a.list_sizes.len() == sizes.len()
                && a.list_sizes.iter().zip(sizes).all(|(&cap, &want)| cap >= want)
        });
        match dominating {
            Some(idx) => Route::Artifact { idx },
            None => Route::Software,
        }
    }

    /// Pad a request's lists to the artifact's shape with sentinels.
    pub fn pad_lists(&self, idx: usize, req: &MergeRequest) -> Vec<Vec<u32>> {
        let meta = &self.artifacts[idx];
        req.lists
            .iter()
            .zip(&meta.list_sizes)
            .map(|(list, &cap)| {
                let mut v = list.clone();
                v.resize(cap, PAD);
                v
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(name: &str, sizes: Vec<usize>, batch: usize) -> ArtifactMeta {
        let total = sizes.iter().sum();
        ArtifactMeta {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            list_sizes: sizes,
            batch,
            total,
            block_b: 1,
            plan_steps: 1,
            hw_stages: 1,
            device: name.into(),
        }
    }

    fn router() -> Router {
        Router::new(vec![
            meta("m32", vec![32, 32], 64),
            meta("m64", vec![64, 64], 32),
            meta("m3x7", vec![7, 7, 7], 64),
        ])
    }

    #[test]
    fn exact_match() {
        let r = router();
        let Route::Artifact { idx } = r.route(&[32, 32]) else { panic!() };
        assert_eq!(&*r.artifacts()[idx].name, "m32");
        let Route::Artifact { idx } = r.route(&[7, 7, 7]) else { panic!() };
        assert_eq!(&*r.artifacts()[idx].name, "m3x7");
    }

    #[test]
    fn smaller_requests_route_to_tightest_dominating() {
        let r = router();
        let Route::Artifact { idx } = r.route(&[10, 20]) else { panic!() };
        assert_eq!(&*r.artifacts()[idx].name, "m32");
        let Route::Artifact { idx } = r.route(&[33, 1]) else { panic!() };
        assert_eq!(&*r.artifacts()[idx].name, "m64");
    }

    #[test]
    fn unroutable_goes_software() {
        let r = router();
        assert_eq!(r.route(&[100, 100]), Route::Software);
        assert_eq!(r.route(&[1, 1, 1, 1]), Route::Software);
    }

    #[test]
    fn padding_preserves_merge_semantics() {
        let r = router();
        let req = MergeRequest::new(1, vec![vec![5, 9], vec![1, 7, 8]]);
        let Route::Artifact { idx } = r.route(&req.sizes()) else { panic!() };
        let padded = r.pad_lists(idx, &req);
        assert_eq!(padded[0].len(), 32);
        assert_eq!(padded[1].len(), 32);
        assert_eq!(&padded[0][..2], &[5, 9]);
        assert!(padded[0][2..].iter().all(|&x| x == PAD));
        // Sentinels sort after real keys: merged prefix == true merge.
        let mut all: Vec<u32> = padded.concat();
        all.sort_unstable();
        assert_eq!(&all[..5], &[1, 5, 7, 8, 9]);
    }
}
