//! Hierarchical merge planning: external sort through the merge service.
//!
//! The classic hardware-merge-sorter deployment (§II: merge networks as
//! building blocks of larger sorters): split the keys into chunks, sort
//! each chunk locally, then run a binary merge tree where every level's
//! pairwise merges are *batched through the compiled LOMS ladder*
//! (32+32 → 64, 64+64 → 128, …). Submissions are capped by a sliding
//! window ([`INFLIGHT_WINDOW`]) so queue memory stays bounded whatever
//! the input size. Levels beyond the largest artifact hand the
//! surviving runs to the **streaming merge engine**
//! ([`crate::stream::merge_runs_parallel`]): tile-pumped k-way merge
//! trees in O(k·R) memory, range-partitioned across cores for the final
//! pass, replacing the scalar binary heap that used to finish the sort.
//! The heap ([`kway_merge`]) is kept as the differential reference.

use super::service::MergeService;
use crate::stream;
use anyhow::Result;
use std::collections::{BinaryHeap, VecDeque};

/// Maximum ladder merges in flight at once. Each pending response holds
/// one merged run, so ladder memory is bounded by
/// `INFLIGHT_WINDOW × max_network` keys instead of growing with the
/// input (the old behavior submitted an entire tree level before
/// receiving anything). Two full default artifact batches (2 × 256)
/// keep dynamic batching saturated.
pub const INFLIGHT_WINDOW: usize = 512;

/// External-sort statistics.
#[derive(Debug, Clone, Default)]
pub struct SortStats {
    pub keys: usize,
    pub chunks: usize,
    pub network_levels: usize,
    pub network_merges: usize,
    pub final_kway_runs: usize,
}

/// Phases 1–2 of the external sort: chunk into sorted runs, then merge
/// pairwise through the service's network ladder (windowed) until the
/// runs reach `max_network` keys or one run remains. Shared by
/// [`external_sort`] and the extsort ladder run-former
/// ([`crate::stream::RunFormer::Ladder`]).
pub fn ladder_runs(
    service: &MergeService,
    data: &[u32],
    chunk: usize,
    max_network: usize,
) -> Result<(Vec<Vec<u32>>, SortStats)> {
    let mut stats = SortStats { keys: data.len(), ..Default::default() };
    if data.is_empty() {
        return Ok((Vec::new(), stats));
    }
    // Phase 1: sorted runs.
    let mut runs: Vec<Vec<u32>> = data
        .chunks(chunk)
        .map(|c| {
            let mut v = c.to_vec();
            v.sort_unstable();
            v
        })
        .collect();
    stats.chunks = runs.len();
    // Phase 2: binary merge tree through the service, level by level,
    // never more than INFLIGHT_WINDOW submissions outstanding.
    while runs.len() > 1 && runs[0].len() < max_network {
        let mut next: Vec<Vec<u32>> = Vec::with_capacity(runs.len().div_ceil(2));
        let mut pending = VecDeque::with_capacity(INFLIGHT_WINDOW);
        let mut odd = None;
        let mut iter = runs.into_iter();
        while let Some(a) = iter.next() {
            match iter.next() {
                Some(b) => {
                    // Window full: retire the oldest merge before
                    // submitting another (responses pop in submit
                    // order, so `next` stays level-ordered).
                    if pending.len() >= INFLIGHT_WINDOW {
                        let rx = pending.pop_front().expect("window not empty");
                        let resp = rx.recv().map_err(|_| anyhow::anyhow!("merge rejected"))?;
                        stats.network_merges += 1;
                        next.push(resp.merged);
                    }
                    pending.push_back(service.submit(vec![a, b]));
                }
                None => odd = Some(a),
            }
        }
        for rx in pending {
            let resp = rx.recv().map_err(|_| anyhow::anyhow!("merge rejected"))?;
            stats.network_merges += 1;
            next.push(resp.merged);
        }
        if let Some(a) = odd {
            next.push(a);
        }
        stats.network_levels += 1;
        runs = next;
    }
    Ok((runs, stats))
}

/// Sort `data` by chunking + hierarchical merging through `service`.
/// `chunk` is the initial run length (typically the smallest artifact's
/// list size); `max_network` caps the list size sent through the merge
/// network ladder. The surviving runs stream through the tile-pumped
/// k-way merge tree (phase 3).
pub fn external_sort(
    service: &MergeService,
    data: &[u32],
    chunk: usize,
    max_network: usize,
) -> Result<(Vec<u32>, SortStats)> {
    let (runs, mut stats) = ladder_runs(service, data, chunk, max_network)?;
    stats.final_kway_runs = runs.len();
    // Range-partitioned final merge (0 = one partition per core);
    // byte-identical to the single-tree merge whatever the core count.
    let merged = stream::merge_runs_parallel(&runs, stream::DEFAULT_R, 0)?;
    Ok((merged, stats))
}

/// Heap-based k-way merge of sorted runs — the scalar reference the
/// streaming engine is tested against (and the bench baseline).
pub fn kway_merge(runs: Vec<Vec<u32>>) -> Vec<u32> {
    let total: usize = runs.iter().map(Vec::len).sum();
    let mut out = Vec::with_capacity(total);
    // Min-heap via Reverse of (value, run, idx).
    let mut heap = BinaryHeap::new();
    for (r, run) in runs.iter().enumerate() {
        if !run.is_empty() {
            heap.push(std::cmp::Reverse((run[0], r, 0usize)));
        }
    }
    while let Some(std::cmp::Reverse((v, r, i))) = heap.pop() {
        out.push(v);
        if i + 1 < runs[r].len() {
            heap.push(std::cmp::Reverse((runs[r][i + 1], r, i + 1)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SoftwareBackend;
    use crate::coordinator::service::{MergeService, ServiceConfig};
    use crate::util::Rng;

    #[test]
    fn kway_merge_correct() {
        let runs = vec![vec![1, 5, 9], vec![2, 6], vec![], vec![3, 4, 7, 8]];
        assert_eq!(kway_merge(runs), vec![1, 2, 3, 4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn stream_phase3_matches_heap_reference() {
        // The tile-pumped phase-3 engine must be byte-identical to the
        // scalar heap on the runs the ladder produces.
        let mut rng = Rng::new(0x3A);
        let runs: Vec<Vec<u32>> =
            (0..9).map(|_| rng.sorted_list_ragged(0, 700, 1 << 24)).collect();
        let want = kway_merge(runs.clone());
        let got = crate::stream::merge_runs(&runs, crate::stream::DEFAULT_R).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn external_sort_small() {
        let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(11);
        let data: Vec<u32> = (0..5000).map(|_| rng.next_u32() >> 4).collect();
        let (sorted, stats) = external_sort(&s, &data, 32, 512).unwrap();
        let mut want = data.clone();
        want.sort_unstable();
        assert_eq!(sorted, want);
        assert_eq!(stats.keys, 5000);
        assert_eq!(stats.chunks, 5000usize.div_ceil(32));
        assert!(stats.network_levels >= 3, "ladder used: {stats:?}");
        assert!(stats.network_merges > 50);
    }

    #[test]
    fn external_sort_exceeding_the_inflight_window() {
        // More pairs per level than INFLIGHT_WINDOW: the sliding window
        // must throttle without losing or reordering any merge.
        let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default()).unwrap();
        let n = 32 * (2 * INFLIGHT_WINDOW + 77); // level 0: > window pairs
        let mut rng = Rng::new(0x11D0);
        let data: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 2).collect();
        let (sorted, stats) = external_sort(&s, &data, 32, 256).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(sorted, want);
        assert!(stats.chunks > 2 * INFLIGHT_WINDOW, "{stats:?}");
    }

    #[test]
    fn external_sort_edge_cases() {
        let s = MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default()).unwrap();
        assert_eq!(external_sort(&s, &[], 32, 512).unwrap().0, Vec::<u32>::new());
        assert_eq!(external_sort(&s, &[7], 32, 512).unwrap().0, vec![7]);
        let data = vec![5u32; 100]; // all duplicates
        assert_eq!(external_sort(&s, &data, 32, 512).unwrap().0, data);
    }
}
