//! Execution backends for the merge service.
//!
//! * [`PjrtBackend`] — the production path: AOT-compiled artifacts on the
//!   PJRT CPU client (Python never runs here).
//! * [`SoftwareBackend`] — bit-exact software execution of the *same*
//!   devices (used when artifacts are absent, for unroutable shapes, and
//!   as the differential oracle in tests).

use super::router::PAD;
use crate::runtime::{ArtifactMeta, Runtime};
use crate::sortnet::lanes::{self, LanePlan, LaneScratch};
use crate::sortnet::network::MergeDevice;
use crate::sortnet::plan::CompiledPlan;
use crate::sortnet::{loms, s2ms};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Accounting for one executed batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchRun {
    /// Padding rows the backend actually executed alongside the real
    /// ones (the tile-direct software path executes none; PJRT pads to
    /// the compiled batch shape).
    pub padded_rows: usize,
    /// Which execution path ran the batch: the active SIMD tier label
    /// for the software tile path (`"avx2"`, `"portable"`, …) or
    /// `"pjrt"` — carried back so execute spans and per-artifact stats
    /// name the code path that produced the latency.
    pub tier: &'static str,
}

/// A batch executor over a fixed artifact set.
///
/// Not `Send`: PJRT handles are thread-confined (`Rc` internally), so
/// the service constructs its backend *inside* the executor thread via
/// a factory — see [`super::service::MergeService::start`].
pub trait Backend {
    /// The artifact shapes this backend serves.
    fn artifacts(&self) -> Vec<ArtifactMeta>;
    /// Execute one batch for artifact `name` **straight between request
    /// and response buffers** (the two-copy serving contract). `rows[r]`
    /// is request `r`'s un-padded lists (each sorted ascending, at most
    /// `list_sizes[l]` long, at most `batch` rows); `outs[r]` is the
    /// caller-provided destination for row `r`'s merged prefix
    /// (`outs[r].len()` ≤ `total`, normally the request's real output
    /// width — `PAD` sentinels sort to the tail). Rows beyond
    /// `rows.len()` are implicit padding the backend supplies if its
    /// execution shape demands it.
    fn execute_direct(
        &mut self,
        name: &str,
        rows: &[&[Vec<u32>]],
        outs: &mut [&mut [u32]],
    ) -> Result<BatchRun>;
    /// Whether [`Backend::execute_direct_kv`] is implemented. The
    /// service reads this once at startup and routes key-value jobs to
    /// its software fallback when the backend is key-only (PJRT
    /// artifacts compile bare-key HLO today).
    fn supports_kv(&self) -> bool {
        false
    }
    /// Key-value twin of [`Backend::execute_direct`] — the
    /// rank-then-permute serving contract. `payloads[r]` is request
    /// `r`'s payload column, list-major concatenated to exactly the
    /// row's total key count; `out_keys[r]` / `out_payloads[r]` are the
    /// equal-width destinations for the merged prefix. Keys run through
    /// the comparator stream packed with their origin ranks; each
    /// payload moves **exactly once**, gathered through the emitted
    /// permutation — payload bytes never enter a compare-exchange.
    fn execute_direct_kv(
        &mut self,
        name: &str,
        _rows: &[&[Vec<u32>]],
        _payloads: &[&[u64]],
        _out_keys: &mut [&mut [u32]],
        _out_payloads: &mut [&mut [u64]],
    ) -> Result<BatchRun> {
        Err(anyhow!("{name}: backend {:?} does not execute key-value batches", self.label()))
    }
    /// Backend label for metrics.
    fn label(&self) -> &'static str;
}

/// Pad each ragged request view to the artifact shape and flatten into
/// reusable list-major row-major buffers (`dst[l]` is cleared and
/// refilled, never shrunk — steady-state assembly allocates nothing).
/// The one shared implementation of the pad-and-flatten contract: the
/// PJRT batch path and the assemble-then-execute reference both call
/// it.
fn assemble_padded_lists(
    name: &str,
    sizes: &[usize],
    batch: usize,
    rows: &[&[Vec<u32>]],
    dst: &mut Vec<Vec<u32>>,
) -> Result<()> {
    if dst.len() < sizes.len() {
        dst.resize_with(sizes.len(), Vec::new);
    }
    for (l, &cap) in sizes.iter().enumerate() {
        let flat = &mut dst[l];
        flat.clear();
        flat.reserve(batch * cap);
        for (r, row) in rows.iter().enumerate() {
            anyhow::ensure!(row.len() == sizes.len(), "{name}: row {r} list count");
            anyhow::ensure!(row[l].len() <= cap, "{name}: row {r} list {l} exceeds slot");
            flat.extend_from_slice(&row[l]);
            flat.resize(flat.len() + (cap - row[l].len()), PAD);
        }
        flat.resize(batch * cap, PAD);
    }
    Ok(())
}

/// PJRT-backed execution of `artifacts/*.hlo.txt`.
pub struct PjrtBackend {
    runtime: Runtime,
    /// Reusable padded list-major assembly buffers — the compiled HLO
    /// consumes fixed row-major shapes, so the request view is
    /// assembled per batch, but into the same buffers every time
    /// (§Perf: no per-batch reallocation on the production path).
    assembly: Vec<Vec<u32>>,
}

impl PjrtBackend {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtBackend { runtime: Runtime::load(dir)?, assembly: Vec::new() })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn artifacts(&self) -> Vec<ArtifactMeta> {
        self.runtime.manifest.artifacts.clone()
    }

    fn execute_direct(
        &mut self,
        name: &str,
        rows: &[&[Vec<u32>]],
        outs: &mut [&mut [u32]],
    ) -> Result<BatchRun> {
        let exe = self.runtime.executable_mut(name)?;
        let (batch, total, k) = (exe.meta.batch, exe.meta.total, exe.meta.list_sizes.len());
        anyhow::ensure!(rows.len() == outs.len(), "{name}: rows vs output buffers");
        anyhow::ensure!(rows.len() <= batch, "{name}: {} rows exceed batch {batch}", rows.len());
        assemble_padded_lists(name, &exe.meta.list_sizes, batch, rows, &mut self.assembly)?;
        let out = exe.execute_batch(&self.assembly[..k])?;
        for (r, dst) in outs.iter_mut().enumerate() {
            anyhow::ensure!(dst.len() <= total, "{name}: row {r} output too wide");
            dst.copy_from_slice(&out[r * total..r * total + dst.len()]);
        }
        Ok(BatchRun { padded_rows: batch - rows.len(), tier: self.label() })
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Build the sortnet device matching an artifact's shape (the same
/// construction the Python compile path used). Errors instead of
/// guessing when the device tag is malformed — a silently-wrong column
/// count would build a *different* device than the compiled artifact.
pub fn device_for_meta(meta: &ArtifactMeta) -> Result<MergeDevice> {
    let sizes = &meta.list_sizes;
    if sizes.len() == 2 {
        if meta.device.starts_with("s2ms") {
            Ok(s2ms::s2ms(sizes[0], sizes[1]))
        } else {
            // Column count from the device tag (loms2-<c>col-...).
            let cols = meta
                .device
                .split('-')
                .find_map(|part| part.strip_suffix("col").and_then(|c| c.parse::<usize>().ok()));
            match cols {
                Some(c) if c >= 2 => Ok(loms::loms_2way(sizes[0], sizes[1], c)),
                _ => Err(anyhow!(
                    "artifact {}: no column count in device tag {:?} (expected `loms2-<c>col-...`, c >= 2)",
                    meta.name,
                    meta.device
                )),
            }
        }
    } else {
        Ok(loms::loms_kway(sizes))
    }
}

/// Software twin of the artifact set (same shapes, bit-exact semantics).
/// Devices are lowered twice — to a [`CompiledPlan`] (scalar IR) and a
/// [`LanePlan`] (transposed pure-CAS schedule), both compiled on first
/// use and cached per artifact. Batches arrive as ragged request views
/// and leave through per-row response buffers
/// ([`Backend::execute_direct`]): the lane executor scatters straight
/// from the views into the transposed tile (pad fill inline) and
/// gathers straight into the response buffers — two copies total, no
/// padding rows, sharded across cores when the batch is large enough
/// ([`lanes::auto_threads`]); the scalar plan remains the strict-mode /
/// median / validation engine and runs the sub-tile tail.
pub struct SoftwareBackend {
    metas: Vec<ArtifactMeta>,
    /// `name → metas` index — batch lookup is on the hot path, so it
    /// must not linearly scan the artifact set per call.
    meta_idx: HashMap<Arc<str>, usize>,
    devices: HashMap<Arc<str>, MergeDevice>,
    /// Per-artifact compiled-plan cache (filled lazily on first execute).
    plans: HashMap<Arc<str>, CompiledPlan>,
    /// Lane-expanded twin of each compiled plan (Fast-mode batch path).
    lane_plans: HashMap<Arc<str>, LanePlan>,
    lane_scratch: LaneScratch<u32>,
    /// Packed `(key, origin)` tile scratch for the key-value path.
    kv_scratch: LaneScratch<u64>,
    /// Reusable flat permutation buffer (split per row per KV batch).
    perm_buf: Vec<u32>,
}

impl SoftwareBackend {
    /// Mirror an artifact set in software. Fails if any artifact's
    /// device tag cannot be reconstructed (see [`device_for_meta`]).
    pub fn new(metas: Vec<ArtifactMeta>) -> Result<Self> {
        let mut devices = HashMap::with_capacity(metas.len());
        let mut meta_idx = HashMap::with_capacity(metas.len());
        for (i, m) in metas.iter().enumerate() {
            devices.insert(m.name.clone(), device_for_meta(m)?);
            meta_idx.insert(m.name.clone(), i);
        }
        Ok(SoftwareBackend {
            metas,
            meta_idx,
            devices,
            plans: HashMap::new(),
            lane_plans: HashMap::new(),
            lane_scratch: LaneScratch::new(),
            kv_scratch: LaneScratch::new(),
            perm_buf: Vec::new(),
        })
    }

    /// A default artifact set matching `python/compile/model.py`'s
    /// variants — lets everything run without `make artifacts`.
    pub fn default_set() -> Self {
        let mk = |name: &str, device: &str, sizes: Vec<usize>, batch: usize| ArtifactMeta {
            name: name.into(),
            file: String::new(),
            total: sizes.iter().sum(),
            list_sizes: sizes,
            batch,
            block_b: batch,
            plan_steps: 0,
            hw_stages: 0,
            device: device.into(),
        };
        SoftwareBackend::new(vec![
            mk("loms2_up32_dn32_b256", "loms2-2col-up32-dn32", vec![32, 32], 256),
            mk("loms2_up64_dn64_b128", "loms2-2col-up64-dn64", vec![64, 64], 128),
            mk("loms2_up128_dn128_b16", "loms2-4col-up128-dn128", vec![128, 128], 16),
            mk("loms2_up256_dn256_b32", "loms2-8col-up256-dn256", vec![256, 256], 32),
            mk("loms3_7r_b256", "loms3-7_7_7r", vec![7, 7, 7], 256),
        ])
        .expect("default artifact set is well-formed")
    }

    /// The cached plan for `name`, if already compiled.
    pub fn plan(&self, name: &str) -> Option<&CompiledPlan> {
        self.plans.get(name)
    }

    /// The cached lane plan for `name`, if already expanded.
    pub fn lane_plan(&self, name: &str) -> Option<&LanePlan> {
        self.lane_plans.get(name)
    }

    /// Fill the plan + lane-plan caches for one artifact (idempotent).
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.lane_plans.contains_key(name) {
            return Ok(());
        }
        let d = self
            .devices
            .get(name)
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        if !self.plans.contains_key(name) {
            let plan = CompiledPlan::compile_auto(d).map_err(|e| anyhow!("{name}: {e}"))?;
            self.plans.insert(Arc::from(name), plan);
        }
        let lane = LanePlan::compile(&self.plans[name]);
        self.lane_plans.insert(Arc::from(name), lane);
        Ok(())
    }

    /// Compile every artifact's plan and lane plan up front. Both are
    /// otherwise compiled lazily on first execute, which puts the
    /// (possibly exhaustive-pruning) compile cost on one unlucky first
    /// request — production deployments should warm at startup; tests
    /// that touch one artifact keep the cheap lazy path.
    pub fn warm(&mut self) -> Result<()> {
        let names: Vec<Arc<str>> = self.devices.keys().cloned().collect();
        for name in names {
            self.ensure_compiled(&name)?;
        }
        Ok(())
    }

    /// The pre-tile-direct row-major batch path: `lists[l]` must be a
    /// fully assembled, padded `(batch, list_sizes[l])` buffer and the
    /// whole `(batch, total)` output is returned. Kept as the
    /// assemble-then-execute **reference** for the tile-direct
    /// differential tests and the `service_pipeline` bench baseline —
    /// the serving path itself runs [`Backend::execute_direct`].
    pub fn execute_rowmajor(&mut self, name: &str, lists: &[Vec<u32>]) -> Result<Vec<u32>> {
        let batch = self
            .meta_idx
            .get(name)
            .map(|&i| self.metas[i].batch)
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        self.ensure_compiled(name)?;
        let SoftwareBackend { plans, lane_plans, lane_scratch, .. } = self;
        let plan = &plans[name];
        let lane = &lane_plans[name];
        let mut out = Vec::with_capacity(batch * plan.total_outputs());
        let threads = lanes::auto_threads(batch, plan.n());
        let res = if threads > 1 {
            lanes::run_batch_sharded(lane, plan, lists, batch, threads, &mut out)
        } else {
            lane.run_batch(plan, lists, batch, lane_scratch, &mut out)
        };
        res.map_err(|e| anyhow!("{name}: {e}"))?;
        Ok(out)
    }

    /// Assemble-then-execute convenience over [`Self::execute_rowmajor`]
    /// — the **old serving data path**, end to end: pad each ragged
    /// request to the artifact shape, pad the batch with sentinel rows,
    /// execute row-major, slice each request's real prefix back out.
    /// The single shared reference implementation the tile-direct
    /// differential tests and the `service_pipeline` bench baseline
    /// compare [`Backend::execute_direct`] against.
    pub fn execute_padded_reference(
        &mut self,
        name: &str,
        reqs: &[Vec<Vec<u32>>],
    ) -> Result<Vec<Vec<u32>>> {
        let meta = self
            .meta_idx
            .get(name)
            .map(|&i| self.metas[i].clone())
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        anyhow::ensure!(reqs.len() <= meta.batch, "{name}: {} rows exceed batch", reqs.len());
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        let mut lists = Vec::new();
        assemble_padded_lists(name, &meta.list_sizes, meta.batch, &rows, &mut lists)?;
        let out = self.execute_rowmajor(name, &lists)?;
        Ok(reqs
            .iter()
            .enumerate()
            .map(|(row, r)| {
                let want: usize = r.iter().map(Vec::len).sum();
                out[row * meta.total..row * meta.total + want].to_vec()
            })
            .collect())
    }
}

impl Backend for SoftwareBackend {
    fn artifacts(&self) -> Vec<ArtifactMeta> {
        self.metas.clone()
    }

    fn execute_direct(
        &mut self,
        name: &str,
        rows: &[&[Vec<u32>]],
        outs: &mut [&mut [u32]],
    ) -> Result<BatchRun> {
        let batch = self
            .meta_idx
            .get(name)
            .map(|&i| self.metas[i].batch)
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        anyhow::ensure!(rows.len() == outs.len(), "{name}: rows vs output buffers");
        anyhow::ensure!(rows.len() <= batch, "{name}: {} rows exceed batch {batch}", rows.len());
        self.ensure_compiled(name)?;
        let SoftwareBackend { plans, lane_plans, lane_scratch, .. } = self;
        let plan = &plans[name];
        let lane = &lane_plans[name];
        lanes::run_view_batch_auto(lane, plan, rows, PAD, lane_scratch, outs)
            .map_err(|e| anyhow!("{name}: {e}"))?;
        // Tile-direct executes only the real rows (full tiles + scalar
        // tail) — unlike the row-major path, which padded to `batch`.
        Ok(BatchRun { padded_rows: 0, tier: lanes::active_tier().label() })
    }

    fn supports_kv(&self) -> bool {
        true
    }

    fn execute_direct_kv(
        &mut self,
        name: &str,
        rows: &[&[Vec<u32>]],
        payloads: &[&[u64]],
        out_keys: &mut [&mut [u32]],
        out_payloads: &mut [&mut [u64]],
    ) -> Result<BatchRun> {
        let batch = self
            .meta_idx
            .get(name)
            .map(|&i| self.metas[i].batch)
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        anyhow::ensure!(rows.len() == payloads.len(), "{name}: rows vs payload columns");
        anyhow::ensure!(rows.len() == out_keys.len(), "{name}: rows vs key buffers");
        anyhow::ensure!(rows.len() == out_payloads.len(), "{name}: rows vs payload buffers");
        anyhow::ensure!(rows.len() <= batch, "{name}: {} rows exceed batch {batch}", rows.len());
        for (r, row) in rows.iter().enumerate() {
            let width: usize = row.iter().map(Vec::len).sum();
            anyhow::ensure!(
                payloads[r].len() == width,
                "{name}: row {r} payload column is {} for {width} keys",
                payloads[r].len()
            );
            anyhow::ensure!(
                out_keys[r].len() == out_payloads[r].len(),
                "{name}: row {r} key/payload output widths differ"
            );
        }
        self.ensure_compiled(name)?;
        let SoftwareBackend { plans, lane_plans, kv_scratch, perm_buf, .. } = self;
        let plan = &plans[name];
        let lane = &lane_plans[name];
        // Split one flat reusable buffer into per-row permutation slices.
        let total: usize = out_keys.iter().map(|o| o.len()).sum();
        perm_buf.clear();
        perm_buf.resize(total, 0);
        let mut perm_outs: Vec<&mut [u32]> = Vec::with_capacity(rows.len());
        let mut rest = perm_buf.as_mut_slice();
        for o in out_keys.iter() {
            let (head, tail) = rest.split_at_mut(o.len());
            perm_outs.push(head);
            rest = tail;
        }
        lanes::run_view_batch_perm_auto(lane, plan, rows, kv_scratch, out_keys, &mut perm_outs)
            .map_err(|e| anyhow!("{name}: {e}"))?;
        // The single payload move: gather each row's column through its
        // permutation straight into the response buffer.
        for (r, perm) in perm_outs.iter().enumerate() {
            let src = payloads[r];
            let dst = &mut *out_payloads[r];
            for (t, &p) in perm.iter().enumerate() {
                dst[t] = src[p as usize];
            }
        }
        Ok(BatchRun { padded_rows: 0, tier: lanes::active_tier().label() })
    }

    fn label(&self) -> &'static str {
        "software"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sortnet::exec::ExecMode;
    use crate::sortnet::plan::PlanScratch;
    use crate::util::Rng;

    #[test]
    fn execute_routes_through_lane_plan_and_matches_scalar() {
        let name = "loms2_up32_dn32_b256";
        let mut b = SoftwareBackend::default_set();
        assert!(b.lane_plan(name).is_none());
        let meta = b.artifacts().into_iter().find(|m| &*m.name == name).unwrap();
        let mut rng = Rng::new(17);
        let lists: Vec<Vec<u32>> = meta
            .list_sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::new();
                for _ in 0..meta.batch {
                    flat.extend(rng.sorted_list(s, 100_000));
                }
                flat
            })
            .collect();
        let out = b.execute_rowmajor(name, &lists).unwrap();
        let lane = b.lane_plan(name).expect("lane plan cached after first execute");
        assert_eq!(lane.total_outputs(), meta.total);
        // The Fast-mode lane path must be bit-exact with the scalar plan.
        let mut want = Vec::new();
        b.plan(name)
            .unwrap()
            .run_batch(&lists, meta.batch, ExecMode::Fast, &mut PlanScratch::new(), &mut want)
            .unwrap();
        assert_eq!(out, want);
    }

    #[test]
    fn execute_direct_matches_rowmajor_reference() {
        // Backend-level two-copy differential: ragged requests through
        // execute_direct must equal the padded row-major reference path
        // sliced to each request's real width — including partial
        // batches (scalar tail) and tile-straddling sizes.
        let name = "loms2_up32_dn32_b256";
        let mut b = SoftwareBackend::default_set();
        let meta = b.artifacts().into_iter().find(|m| &*m.name == name).unwrap();
        let mut rng = Rng::new(0x2C0B);
        for real in [1usize, 7, 16, 37, 256] {
            let reqs: Vec<Vec<Vec<u32>>> = (0..real)
                .map(|_| {
                    meta.list_sizes
                        .iter()
                        .map(|&cap| {
                            let len = rng.range(1, cap + 1);
                            rng.sorted_list(len, 1 << 20)
                        })
                        .collect()
                })
                .collect();
            let reference = b.execute_padded_reference(name, &reqs).unwrap();
            let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
            let mut merged: Vec<Vec<u32>> =
                reqs.iter().map(|r| vec![0u32; r.iter().map(Vec::len).sum()]).collect();
            let mut outs: Vec<&mut [u32]> =
                merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            let run = b.execute_direct(name, &rows, &mut outs).unwrap();
            assert_eq!(run.padded_rows, 0, "tile-direct pads no rows");
            assert_eq!(merged, reference, "{name} real={real}");
        }
    }

    #[test]
    fn execute_direct_kv_carries_payloads_stably() {
        // Duplicate-heavy keys with origin-tagged payloads: the merged
        // (key, payload) rows must equal a stable sort of the
        // list-major concatenation — i.e. every duplicate key keeps the
        // payload it arrived with, in arrival order.
        let name = "loms2_up32_dn32_b256";
        let mut b = SoftwareBackend::default_set();
        let meta = b.artifacts().into_iter().find(|m| &*m.name == name).unwrap();
        let mut rng = Rng::new(0xFACE);
        for real in [1usize, 15, 16, 37] {
            let reqs: Vec<Vec<Vec<u32>>> = (0..real)
                .map(|_| {
                    meta.list_sizes
                        .iter()
                        .map(|&cap| {
                            let len = rng.range(1, cap + 1);
                            rng.sorted_list(len, 8) // max 8 => heavy duplication
                        })
                        .collect()
                })
                .collect();
            // Payload = (row << 16) | arrival index: globally unique.
            let pay_cols: Vec<Vec<u64>> = reqs
                .iter()
                .enumerate()
                .map(|(r, req)| {
                    let w: usize = req.iter().map(Vec::len).sum();
                    (0..w).map(|i| ((r as u64) << 16) | i as u64).collect()
                })
                .collect();
            let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
            let pays: Vec<&[u64]> = pay_cols.iter().map(|p| p.as_slice()).collect();
            let widths: Vec<usize> = pay_cols.iter().map(Vec::len).collect();
            let mut keys: Vec<Vec<u32>> = widths.iter().map(|&w| vec![0u32; w]).collect();
            let mut outp: Vec<Vec<u64>> = widths.iter().map(|&w| vec![0u64; w]).collect();
            let mut key_outs: Vec<&mut [u32]> =
                keys.iter_mut().map(|v| v.as_mut_slice()).collect();
            let mut pay_outs: Vec<&mut [u64]> =
                outp.iter_mut().map(|v| v.as_mut_slice()).collect();
            let run = b
                .execute_direct_kv(name, &rows, &pays, &mut key_outs, &mut pay_outs)
                .unwrap();
            assert_eq!(run.padded_rows, 0);
            for (r, req) in reqs.iter().enumerate() {
                let mut want: Vec<(u32, u64)> = req
                    .iter()
                    .flatten()
                    .zip(&pay_cols[r])
                    .map(|(&k, &p)| (k, p))
                    .collect();
                want.sort_by_key(|&(k, _)| k); // stable: arrival order kept
                let got: Vec<(u32, u64)> =
                    keys[r].iter().zip(&outp[r]).map(|(&k, &p)| (k, p)).collect();
                assert_eq!(got, want, "row {r} real={real}");
            }
        }
    }

    #[test]
    fn pjrt_less_backends_reject_kv_by_default() {
        // The trait default refuses; the software backend opts in.
        let b = SoftwareBackend::default_set();
        assert!(b.supports_kv());
    }

    #[test]
    fn software_backend_merges() {
        let mut b = SoftwareBackend::default_set();
        let metas = b.artifacts();
        let meta = metas.iter().find(|m| &*m.name == "loms2_up32_dn32_b256").unwrap();
        let mut rng = Rng::new(9);
        let reqs: Vec<Vec<Vec<u32>>> = (0..meta.batch)
            .map(|_| meta.list_sizes.iter().map(|&s| rng.sorted_list(s, 10_000)).collect())
            .collect();
        let rows: Vec<&[Vec<u32>]> = reqs.iter().map(|r| r.as_slice()).collect();
        let mut merged: Vec<Vec<u32>> = reqs.iter().map(|_| vec![0u32; meta.total]).collect();
        let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
        b.execute_direct("loms2_up32_dn32_b256", &rows, &mut outs).unwrap();
        for (row, got) in merged.iter().enumerate() {
            assert!(got.windows(2).all(|w| w[0] <= w[1]), "row {row}");
        }
    }

    #[test]
    fn device_for_meta_parses_cols() {
        let m = ArtifactMeta {
            name: "x".into(),
            file: String::new(),
            list_sizes: vec![128, 128],
            batch: 1,
            total: 256,
            block_b: 1,
            plan_steps: 0,
            hw_stages: 0,
            device: "loms2-4col-up128-dn128".into(),
        };
        let d = device_for_meta(&m).unwrap();
        assert_eq!(d.grid.unwrap().0, 4);
    }

    #[test]
    fn device_for_meta_rejects_malformed_col_tag() {
        let mut m = ArtifactMeta {
            name: "x".into(),
            file: String::new(),
            list_sizes: vec![128, 128],
            batch: 1,
            total: 256,
            block_b: 1,
            plan_steps: 0,
            hw_stages: 0,
            device: "loms2-Xcol-up128-dn128".into(),
        };
        // Unparsable column counts must error, not silently build 2col.
        let err = device_for_meta(&m).unwrap_err().to_string();
        assert!(err.contains("Xcol"), "{err}");
        m.device = "loms2-up128-dn128".into(); // tag missing entirely
        assert!(device_for_meta(&m).is_err());
        m.device = String::new();
        assert!(device_for_meta(&m).is_err());
        // And the backend constructor surfaces it.
        assert!(SoftwareBackend::new(vec![m]).is_err());
    }

    #[test]
    fn plan_cache_fills_lazily() {
        let name = "loms2_up32_dn32_b256";
        let mut b = SoftwareBackend::default_set();
        assert!(b.plan(name).is_none());
        let meta = b.artifacts().into_iter().find(|m| &*m.name == name).unwrap();
        let mut rng = Rng::new(3);
        let lists: Vec<Vec<u32>> = meta
            .list_sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::new();
                for _ in 0..meta.batch {
                    flat.extend(rng.sorted_list(s, 1000));
                }
                flat
            })
            .collect();
        b.execute_rowmajor(name, &lists).unwrap();
        let plan = b.plan(name).expect("plan cached after first execute");
        // Small untapped shape (33*33 patterns): the auto policy runs
        // the pruning analysis.
        assert!(plan.is_pruned());
        // Second execute reuses the cached plan (same pointer).
        let p0 = plan as *const _;
        b.execute_rowmajor(name, &lists).unwrap();
        assert_eq!(b.plan(name).unwrap() as *const _, p0);
        // warm() fills the remaining artifacts (median-tapped loms3
        // lowers unpruned — its tap stage index must stay valid).
        b.warm().unwrap();
        let loms3 = b.plan("loms3_7r_b256").expect("warmed");
        assert!(!loms3.is_pruned());
    }

    #[test]
    fn unknown_artifact_rejected() {
        let mut b = SoftwareBackend::default_set();
        assert!(b.execute_direct("nope", &[], &mut []).is_err());
        assert!(b.execute_rowmajor("nope", &[]).is_err());
    }
}
