//! Execution backends for the merge service.
//!
//! * [`PjrtBackend`] — the production path: AOT-compiled artifacts on the
//!   PJRT CPU client (Python never runs here).
//! * [`SoftwareBackend`] — bit-exact software execution of the *same*
//!   devices (used when artifacts are absent, for unroutable shapes, and
//!   as the differential oracle in tests).

use crate::runtime::{ArtifactMeta, Runtime};
use crate::sortnet::exec::{ExecMode, ExecScratch};
use crate::sortnet::network::MergeDevice;
use crate::sortnet::{loms, s2ms};
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// A batch executor over a fixed artifact set.
///
/// Not `Send`: PJRT handles are thread-confined (`Rc` internally), so
/// the service constructs its backend *inside* the engine thread via a
/// factory — see [`super::service::MergeService::start`].
pub trait Backend {
    /// The artifact shapes this backend serves.
    fn artifacts(&self) -> Vec<ArtifactMeta>;
    /// Execute one full batch for artifact `name`. `lists[l]` is
    /// row-major `(batch, list_sizes[l])`; returns `(batch, total)`.
    fn execute(&mut self, name: &str, lists: &[Vec<u32>]) -> Result<Vec<u32>>;
    /// Backend label for metrics.
    fn label(&self) -> &'static str;
}

/// PJRT-backed execution of `artifacts/*.hlo.txt`.
pub struct PjrtBackend {
    runtime: Runtime,
}

impl PjrtBackend {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtBackend { runtime: Runtime::load(dir)? })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }
}

impl Backend for PjrtBackend {
    fn artifacts(&self) -> Vec<ArtifactMeta> {
        self.runtime.manifest.artifacts.clone()
    }

    fn execute(&mut self, name: &str, lists: &[Vec<u32>]) -> Result<Vec<u32>> {
        self.runtime.executable_mut(name)?.execute_batch(lists)
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }
}

/// Build the sortnet device matching an artifact's shape (the same
/// construction the Python compile path used).
pub fn device_for_meta(meta: &ArtifactMeta) -> MergeDevice {
    let sizes = &meta.list_sizes;
    if sizes.len() == 2 {
        if meta.device.starts_with("s2ms") {
            s2ms::s2ms(sizes[0], sizes[1])
        } else {
            // Column count from the device name (loms2-<c>col-...), else 2.
            let cols = meta
                .device
                .split('-')
                .find_map(|part| part.strip_suffix("col").and_then(|c| c.parse().ok()))
                .unwrap_or(2);
            loms::loms_2way(sizes[0], sizes[1], cols)
        }
    } else {
        loms::loms_kway(sizes)
    }
}

/// Software twin of the artifact set (same shapes, bit-exact semantics).
pub struct SoftwareBackend {
    metas: Vec<ArtifactMeta>,
    devices: HashMap<String, MergeDevice>,
    scratch: ExecScratch<u32>,
}

impl SoftwareBackend {
    /// Mirror an artifact set in software.
    pub fn new(metas: Vec<ArtifactMeta>) -> Self {
        let devices = metas.iter().map(|m| (m.name.clone(), device_for_meta(m))).collect();
        SoftwareBackend { metas, devices, scratch: ExecScratch::new() }
    }

    /// A default artifact set matching `python/compile/model.py`'s
    /// variants — lets everything run without `make artifacts`.
    pub fn default_set() -> Self {
        let mk = |name: &str, device: &str, sizes: Vec<usize>, batch: usize| ArtifactMeta {
            name: name.into(),
            file: String::new(),
            total: sizes.iter().sum(),
            list_sizes: sizes,
            batch,
            block_b: batch,
            plan_steps: 0,
            hw_stages: 0,
            device: device.into(),
        };
        SoftwareBackend::new(vec![
            mk("loms2_up32_dn32_b256", "loms2-2col-up32-dn32", vec![32, 32], 256),
            mk("loms2_up64_dn64_b128", "loms2-2col-up64-dn64", vec![64, 64], 128),
            mk("loms2_up128_dn128_b16", "loms2-4col-up128-dn128", vec![128, 128], 16),
            mk("loms2_up256_dn256_b32", "loms2-8col-up256-dn256", vec![256, 256], 32),
            mk("loms3_7r_b256", "loms3-7_7_7r", vec![7, 7, 7], 256),
        ])
    }
}

impl Backend for SoftwareBackend {
    fn artifacts(&self) -> Vec<ArtifactMeta> {
        self.metas.clone()
    }

    fn execute(&mut self, name: &str, lists: &[Vec<u32>]) -> Result<Vec<u32>> {
        let meta = self
            .metas
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow!("no software device {name:?}"))?;
        let d = &self.devices[name];
        let mut out = Vec::with_capacity(meta.batch * meta.total);
        let mut v = vec![0u32; d.n];
        for row in 0..meta.batch {
            for (l, &s) in meta.list_sizes.iter().enumerate() {
                let slice = &lists[l][row * s..(row + 1) * s];
                for (i, &x) in slice.iter().enumerate() {
                    v[d.input_map[l][i]] = x;
                }
            }
            self.scratch
                .run(d, &mut v, ExecMode::Fast, None)
                .map_err(|e| anyhow!("{name}: {e}"))?;
            out.extend(d.output_perm.iter().map(|&p| v[p]));
        }
        Ok(out)
    }

    fn label(&self) -> &'static str {
        "software"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn software_backend_merges() {
        let mut b = SoftwareBackend::default_set();
        let metas = b.artifacts();
        let meta = metas.iter().find(|m| m.name == "loms2_up32_dn32_b256").unwrap();
        let mut rng = Rng::new(9);
        let lists: Vec<Vec<u32>> = meta
            .list_sizes
            .iter()
            .map(|&s| {
                let mut flat = Vec::new();
                for _ in 0..meta.batch {
                    flat.extend(rng.sorted_list(s, 10_000));
                }
                flat
            })
            .collect();
        let out = b.execute("loms2_up32_dn32_b256", &lists).unwrap();
        for row in 0..meta.batch {
            let got = &out[row * meta.total..(row + 1) * meta.total];
            assert!(got.windows(2).all(|w| w[0] <= w[1]), "row {row}");
        }
    }

    #[test]
    fn device_for_meta_parses_cols() {
        let m = ArtifactMeta {
            name: "x".into(),
            file: String::new(),
            list_sizes: vec![128, 128],
            batch: 1,
            total: 256,
            block_b: 1,
            plan_steps: 0,
            hw_stages: 0,
            device: "loms2-4col-up128-dn128".into(),
        };
        let d = device_for_meta(&m);
        assert_eq!(d.grid.unwrap().0, 4);
    }

    #[test]
    fn unknown_artifact_rejected() {
        let mut b = SoftwareBackend::default_set();
        assert!(b.execute("nope", &[]).is_err());
    }
}
