//! Merge request/response types of the coordinator (L3).

use std::sync::{mpsc, Arc};
use std::time::Instant;

/// A single k-way merge request: k sorted ascending u32 lists, plus an
/// optional payload column for key-value merges.
#[derive(Debug, Clone)]
pub struct MergeRequest {
    pub id: u64,
    pub lists: Vec<Vec<u32>>,
    /// Key-value mode: one `u64` payload per key, list-major
    /// concatenated (`payloads.len()` equals the total key count).
    /// Payloads ride beside the comparator stream — the backend merges
    /// keys packed with origin ranks and moves each payload exactly
    /// once through the emitted permutation.
    pub payloads: Option<Vec<u64>>,
    /// Submission time (for latency accounting).
    pub submitted: Instant,
    /// Trace id minted at the net edge (0 = untraced). Rides with the
    /// request through batching so span events recorded along the
    /// admit → queue → assemble → execute → respond path carry it.
    pub trace: u64,
}

impl MergeRequest {
    pub fn new(id: u64, lists: Vec<Vec<u32>>) -> Self {
        MergeRequest { id, lists, payloads: None, submitted: Instant::now(), trace: 0 }
    }

    /// A key-value request: `payloads` is the list-major column beside
    /// the keys (validated against the key count at admission).
    pub fn new_kv(id: u64, lists: Vec<Vec<u32>>, payloads: Vec<u64>) -> Self {
        MergeRequest { id, lists, payloads: Some(payloads), submitted: Instant::now(), trace: 0 }
    }

    /// Attach a trace id (builder form used at submission).
    pub fn with_trace(mut self, trace: u64) -> Self {
        self.trace = trace;
        self
    }

    /// Whether this request carries a payload column.
    pub fn is_kv(&self) -> bool {
        self.payloads.is_some()
    }

    /// Shape signature used for routing.
    pub fn sizes(&self) -> Vec<usize> {
        self.lists.iter().map(Vec::len).collect()
    }

    /// Validate the hardware precondition (each list sorted ascending).
    pub fn check_sorted(&self) -> Result<(), String> {
        for (l, list) in self.lists.iter().enumerate() {
            if list.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!("request {}: list {l} is not sorted", self.id));
            }
        }
        Ok(())
    }

    /// Full admission check: lists sorted ascending AND free of the
    /// `u32::MAX` padding sentinel. The router pads requests to artifact
    /// shape with [`super::router::PAD`]`== u32::MAX`, so a request that
    /// legitimately contains that value is indistinguishable from
    /// padding once batched — reject it up front with a clear error
    /// (documented service contract: real keys < `u32::MAX`).
    pub fn check_valid(&self) -> Result<(), String> {
        self.check_sorted()?;
        for (l, list) in self.lists.iter().enumerate() {
            // Lists are sorted, so a sentinel can only sit at the tail.
            if list.last() == Some(&super::router::PAD) {
                return Err(format!(
                    "request {}: list {l} contains u32::MAX, which is reserved as the padding sentinel",
                    self.id
                ));
            }
        }
        if let Some(p) = &self.payloads {
            let width: usize = self.lists.iter().map(Vec::len).sum();
            if p.len() != width {
                return Err(format!(
                    "request {}: payload column holds {} values for {width} keys",
                    self.id,
                    p.len()
                ));
            }
        }
        Ok(())
    }
}

/// The merged result.
#[derive(Debug, Clone)]
pub struct MergeResponse {
    pub id: u64,
    pub merged: Vec<u32>,
    /// Key-value mode only: the merged payload column, `payloads[t]`
    /// riding with `merged[t]` (stable for duplicate keys).
    pub payloads: Option<Vec<u64>>,
    /// End-to-end latency in nanoseconds.
    pub latency_ns: u128,
    /// Which artifact (or "software") served it. Shared with the
    /// artifact metadata (`Arc<str>`), so batch fan-out clones a
    /// refcount instead of allocating a `String` per request.
    pub served_by: Arc<str>,
}

/// Response channel handed back on submission.
pub type ResponseRx = mpsc::Receiver<MergeResponse>;
/// How the service delivers a request's outcome. Kept as an alias so
/// the engine/exec plumbing reads unchanged.
pub type ResponseTx = Responder;

enum ResponderInner {
    Channel(mpsc::Sender<MergeResponse>),
    Callback(Box<dyn FnOnce(Option<MergeResponse>) + Send>),
}

/// One-shot response delivery: either the classic per-request channel
/// (blocking `submit` callers) or a completion callback (the event
/// loop, which must never park a thread per request).
///
/// Dropping a `Responder` without responding signals rejection: the
/// channel variant disconnects the receiver (the old drop-==-reject
/// contract), the callback variant fires with `None`. Every admission
/// failure in the service keeps working by just dropping the handle.
pub struct Responder(Option<ResponderInner>);

impl Responder {
    /// Channel-backed pair: `respond` feeds the returned receiver.
    pub fn channel() -> (Responder, ResponseRx) {
        let (tx, rx) = mpsc::channel();
        (Responder(Some(ResponderInner::Channel(tx))), rx)
    }

    /// Callback-backed responder: `f` runs exactly once, with
    /// `Some(response)` on success or `None` on rejection/drop — on
    /// whichever thread settles the request (engine, exec, or
    /// fallback), so it must be quick and non-blocking.
    pub fn callback(f: impl FnOnce(Option<MergeResponse>) + Send + 'static) -> Responder {
        Responder(Some(ResponderInner::Callback(Box::new(f))))
    }

    /// Deliver the response, consuming the handle.
    pub fn respond(mut self, resp: MergeResponse) {
        match self.0.take() {
            // A vanished receiver is the caller's prerogative (it gave
            // up waiting); nothing to do.
            Some(ResponderInner::Channel(tx)) => {
                let _ = tx.send(resp);
            }
            Some(ResponderInner::Callback(f)) => f(Some(resp)),
            None => unreachable!("respond consumes self"),
        }
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        if let Some(ResponderInner::Callback(f)) = self.0.take() {
            f(None);
        }
    }
}

impl std::fmt::Debug for Responder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            Some(ResponderInner::Channel(_)) => f.write_str("Responder::Channel"),
            Some(ResponderInner::Callback(_)) => f.write_str("Responder::Callback"),
            None => f.write_str("Responder::Spent"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_sorted_check() {
        let r = MergeRequest::new(1, vec![vec![1, 2, 3], vec![4, 5]]);
        assert_eq!(r.sizes(), vec![3, 2]);
        r.check_sorted().unwrap();
        let bad = MergeRequest::new(2, vec![vec![3, 1]]);
        assert!(bad.check_sorted().is_err());
    }

    #[test]
    fn kv_payload_width_checked() {
        let ok = MergeRequest::new_kv(1, vec![vec![1, 2], vec![3]], vec![10, 20, 30]);
        assert!(ok.is_kv());
        ok.check_valid().unwrap();
        let short = MergeRequest::new_kv(2, vec![vec![1, 2], vec![3]], vec![10]);
        assert!(short.check_valid().unwrap_err().contains("payload"));
        // Key-only requests never trip the payload check.
        assert!(!MergeRequest::new(3, vec![vec![1]]).is_kv());
    }

    fn resp(id: u64) -> MergeResponse {
        MergeResponse { id, merged: vec![], payloads: None, latency_ns: 0, served_by: "t".into() }
    }

    #[test]
    fn responder_channel_delivers_and_drop_disconnects() {
        let (tx, rx) = Responder::channel();
        tx.respond(resp(7));
        assert_eq!(rx.recv().unwrap().id, 7);
        let (tx, rx) = Responder::channel();
        drop(tx);
        assert!(rx.recv().is_err(), "drop == reject disconnects the receiver");
    }

    #[test]
    fn responder_callback_fires_once_with_none_on_drop() {
        use std::sync::Mutex;
        let got: Arc<Mutex<Vec<Option<u64>>>> = Arc::new(Mutex::new(vec![]));
        let g = got.clone();
        Responder::callback(move |r| g.lock().unwrap().push(r.map(|r| r.id))).respond(resp(9));
        let g = got.clone();
        drop(Responder::callback(move |r| g.lock().unwrap().push(r.map(|r| r.id))));
        assert_eq!(*got.lock().unwrap(), vec![Some(9), None]);
    }

    #[test]
    fn sentinel_values_rejected() {
        let ok = MergeRequest::new(1, vec![vec![1, 2], vec![3, u32::MAX - 1]]);
        ok.check_valid().unwrap();
        let bad = MergeRequest::new(2, vec![vec![1, 2], vec![3, u32::MAX]]);
        assert!(bad.check_valid().unwrap_err().contains("sentinel"));
        // Sorted check still runs first.
        let unsorted = MergeRequest::new(3, vec![vec![5, 1]]);
        assert!(unsorted.check_valid().is_err());
    }
}
