//! Layer 3: the merge coordinator — a batched merge *service* in the
//! mould of a serving-system router (request queue → shape router →
//! dynamic batcher → pipelined tile-direct executor, with a software
//! fallback pool), plus the hierarchical merge planner that turns the
//! compiled LOMS ladder into an external sorter (windowed submissions,
//! phase 3 on the [`crate::stream`] merge-tree engine). See
//! `rust/DESIGN.md` §"Serving data path" for the two-copy batch
//! contract and §"Streaming merge engine" for the phase-3 engine.

pub mod backend;
pub mod metrics;
pub mod planner;
pub mod request;
pub mod router;
pub mod service;

pub use backend::{Backend, BatchRun, PjrtBackend, SoftwareBackend};
pub use metrics::{ArtifactSnapshot, Metrics, Snapshot};
pub use request::{MergeRequest, MergeResponse};
pub use router::{Route, Router};
pub use service::{ConfigError, MergeService, ServiceConfig};
