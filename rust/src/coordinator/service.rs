//! The merge service: queue → shape router → dynamic batcher → backend.
//!
//! One engine thread owns the backend (PJRT handles are not shared
//! across threads) and drains a channel of submitted requests. Requests
//! routed to the same artifact accumulate in a per-artifact slot queue;
//! a queue flushes when it reaches the artifact's compiled batch size or
//! when its oldest entry exceeds `max_wait` (classic dynamic batching —
//! the same policy a vLLM-style serving router uses). Partially filled
//! batches are padded with sentinel rows; per-request padding to the
//! artifact shape uses `u32::MAX` sentinels (see [`super::router`]).

use super::backend::Backend;
use super::metrics::Metrics;
use super::request::{MergeRequest, MergeResponse, ResponseTx};
use super::router::{Route, Router, PAD};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service tuning.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Maximum time a request may wait for its batch to fill.
    pub max_wait: Duration,
    /// Serve shapes no artifact dominates with the software fallback
    /// (reject them when false).
    pub software_fallback: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { max_wait: Duration::from_millis(2), software_fallback: true }
    }
}

enum Msg {
    Job(Box<MergeRequest>, ResponseTx),
    Shutdown,
}

/// Handle to a running merge service.
pub struct MergeService {
    tx: mpsc::Sender<Msg>,
    engine: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

struct Slot {
    req: MergeRequest,
    tx: ResponseTx,
}

struct Engine<B: Backend> {
    backend: B,
    router: Router,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
    queues: HashMap<usize, Vec<Slot>>,
    oldest: HashMap<usize, Instant>,
    /// Reusable batch-assembly buffers, one set per artifact (§Perf).
    scratch: HashMap<usize, Vec<Vec<u32>>>,
}

impl<B: Backend> Engine<B> {
    fn run(mut self, rx: mpsc::Receiver<Msg>) {
        loop {
            // Wait up to the flush deadline for new work.
            let timeout = self.nearest_deadline().unwrap_or(self.cfg.max_wait);
            match rx.recv_timeout(timeout) {
                Ok(Msg::Job(req, tx)) => self.admit(*req, tx),
                Ok(Msg::Shutdown) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            self.flush_due(false);
        }
        self.flush_due(true);
    }

    fn nearest_deadline(&self) -> Option<Duration> {
        let now = Instant::now();
        self.oldest
            .values()
            .map(|&t| (t + self.cfg.max_wait).saturating_duration_since(now))
            .min()
    }

    fn admit(&mut self, req: MergeRequest, tx: ResponseTx) {
        self.metrics.on_request();
        // Unsorted lists violate the hardware precondition; u32::MAX
        // values collide with the PAD sentinel and would be corrupted by
        // batch padding — both rejected before routing.
        if req.check_valid().is_err() {
            self.metrics.on_rejected();
            drop(tx); // receiver sees a closed channel
            return;
        }
        match self.router.route(&req.sizes()) {
            Route::Artifact { idx } => {
                let q = self.queues.entry(idx).or_default();
                q.push(Slot { req, tx });
                self.oldest.entry(idx).or_insert_with(Instant::now);
                let batch = self.router.artifacts()[idx].batch;
                if self.queues[&idx].len() >= batch {
                    self.flush(idx);
                }
            }
            Route::Software => {
                if !self.cfg.software_fallback {
                    self.metrics.on_rejected();
                    drop(tx);
                    return;
                }
                self.metrics.on_software();
                let mut merged: Vec<u32> = req.lists.concat();
                merged.sort_unstable();
                // Record before sending: a caller may observe the
                // response and read the snapshot before we run again.
                self.metrics.on_response(req.submitted.elapsed());
                let _ = tx.send(MergeResponse {
                    id: req.id,
                    latency_ns: req.submitted.elapsed().as_nanos(),
                    merged,
                    served_by: "software".into(),
                });
            }
        }
    }

    fn flush_due(&mut self, all: bool) {
        let now = Instant::now();
        let due: Vec<usize> = self
            .oldest
            .iter()
            .filter(|(_, &t)| all || now >= t + self.cfg.max_wait)
            .map(|(&i, _)| i)
            .collect();
        for idx in due {
            self.flush(idx);
        }
    }

    fn flush(&mut self, idx: usize) {
        let Some(slots) = self.queues.remove(&idx) else { return };
        self.oldest.remove(&idx);
        if slots.is_empty() {
            return;
        }
        let meta = self.router.artifacts()[idx].clone();
        let real = slots.len();
        let k = meta.list_sizes.len();
        // Assemble the batch directly into reused per-artifact buffers:
        // each request's lists are copied once and padded in place with
        // sentinels; remaining rows are sentinel-filled (§Perf — replaces
        // a padded clone per request per flush).
        let lists = self.scratch.entry(idx).or_insert_with(|| vec![Vec::new(); k]);
        for (l, buf) in lists.iter_mut().enumerate() {
            let cap = meta.list_sizes[l];
            buf.clear();
            buf.reserve(meta.batch * cap);
            for slot in &slots {
                buf.extend_from_slice(&slot.req.lists[l]);
                buf.resize(buf.len() + (cap - slot.req.lists[l].len()), PAD);
            }
            buf.resize(meta.batch * cap, PAD);
        }
        self.metrics.on_batch(real, meta.batch - real);
        let lists = &self.scratch[&idx];
        match self.backend.execute(&meta.name, lists) {
            Ok(out) => {
                for (row, slot) in slots.into_iter().enumerate() {
                    let want: usize = slot.req.sizes().iter().sum();
                    let merged =
                        out[row * meta.total..row * meta.total + want].to_vec();
                    let latency = slot.req.submitted.elapsed();
                    // Record before sending (snapshot-after-recv race).
                    self.metrics.on_response(latency);
                    let _ = slot.tx.send(MergeResponse {
                        id: slot.req.id,
                        merged,
                        latency_ns: latency.as_nanos(),
                        served_by: meta.name.clone(),
                    });
                }
            }
            Err(e) => {
                eprintln!("merge batch {} failed: {e:#}", meta.name);
                for slot in slots {
                    self.metrics.on_rejected();
                    drop(slot.tx);
                }
            }
        }
    }
}

impl MergeService {
    /// Start the service. The backend is constructed by `factory`
    /// *inside* the engine thread — PJRT handles are thread-confined
    /// (`Rc` internally), so they must be born where they run. Fails
    /// fast if the factory errors (e.g. artifacts missing).
    pub fn start<B, F>(factory: F, cfg: ServiceConfig) -> Result<MergeService>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let engine_metrics = Arc::clone(&metrics);
        let handle = std::thread::Builder::new()
            .name("loms-engine".into())
            .spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let router = Router::new(backend.artifacts());
                let engine = Engine {
                    backend,
                    router,
                    cfg,
                    metrics: engine_metrics,
                    queues: HashMap::new(),
                    oldest: HashMap::new(),
                    scratch: HashMap::new(),
                };
                engine.run(rx);
            })
            .expect("spawn engine");
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => anyhow::bail!("engine thread died during startup"),
        }
        Ok(MergeService { tx, engine: Some(handle), metrics, next_id: AtomicU64::new(1) })
    }

    /// Submit a merge; returns the response channel.
    pub fn submit(&self, lists: Vec<Vec<u32>>) -> mpsc::Receiver<MergeResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::Job(Box::new(MergeRequest::new(id, lists)), tx));
        rx
    }

    /// Submit and wait.
    pub fn merge_blocking(&self, lists: Vec<Vec<u32>>) -> Result<MergeResponse> {
        let rx = self.submit(lists);
        rx.recv().map_err(|_| anyhow::anyhow!("request rejected or service stopped"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Stop the engine, flushing pending batches.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MergeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.engine.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SoftwareBackend;
    use crate::util::Rng;

    fn svc() -> MergeService {
        MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default()).unwrap()
    }

    #[test]
    fn single_request_round_trip() {
        let s = svc();
        let resp = s.merge_blocking(vec![vec![1, 3, 9], vec![2, 4]]).unwrap();
        assert_eq!(resp.merged, vec![1, 2, 3, 4, 9]);
        assert_eq!(resp.served_by, "loms2_up32_dn32_b256");
    }

    #[test]
    fn exact_shape_uses_artifact() {
        let s = svc();
        let mut rng = Rng::new(4);
        let a = rng.sorted_list(32, 100_000);
        let b = rng.sorted_list(32, 100_000);
        let resp = s.merge_blocking(vec![a.clone(), b.clone()]).unwrap();
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(resp.merged, want);
    }

    #[test]
    fn many_concurrent_requests_batch() {
        let s = svc();
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..200 {
            let a = rng.sorted_list(32, 10_000);
            let b = rng.sorted_list(32, 10_000);
            let mut want = [a.clone(), b.clone()].concat();
            want.sort_unstable();
            wants.push(want);
            rxs.push(s.submit(vec![a, b]));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            assert_eq!(rx.recv().unwrap().merged, want);
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.responses, 200);
        // 200 requests against a 256-batch artifact: deadline flushes,
        // far fewer batches than requests.
        assert!(snap.batches >= 1, "batched: {}", snap.batches);
        assert!(snap.batches < 20, "must actually batch, got {}", snap.batches);
    }

    #[test]
    fn unsorted_request_rejected() {
        let s = svc();
        let rx = s.submit(vec![vec![5, 1], vec![2, 3]]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
    }

    #[test]
    fn sentinel_request_rejected() {
        // u32::MAX collides with the PAD sentinel: batch padding would
        // make the value indistinguishable from padding, so the service
        // rejects it at admission instead of corrupting the merge.
        let s = svc();
        let rx = s.submit(vec![vec![1, 2, u32::MAX], vec![3, 4]]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
        // The largest *legal* key is still served exactly.
        let resp = s.merge_blocking(vec![vec![1, u32::MAX - 1], vec![2]]).unwrap();
        assert_eq!(resp.merged, vec![1, 2, u32::MAX - 1]);
    }

    #[test]
    fn unroutable_shape_served_by_software() {
        let s = svc();
        let a: Vec<u32> = (0..1000).collect();
        let b: Vec<u32> = (500..1500).collect();
        let resp = s.merge_blocking(vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(resp.served_by, "software");
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(resp.merged, want);
    }

    #[test]
    fn three_way_merge() {
        let s = svc();
        let resp = s
            .merge_blocking(vec![vec![1, 4, 7], vec![2, 5, 8], vec![3, 6, 9]])
            .unwrap();
        assert_eq!(resp.merged, (1..=9).collect::<Vec<u32>>());
    }

    #[test]
    fn shutdown_flushes() {
        let s = svc();
        let rx = s.submit(vec![vec![1, 2], vec![3, 4]]);
        s.shutdown();
        assert_eq!(rx.recv().unwrap().merged, vec![1, 2, 3, 4]);
    }
}
