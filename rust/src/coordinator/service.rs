//! The merge service: queue → shape router → dynamic batcher → backend,
//! **pipelined across three kinds of threads**.
//!
//! * `loms-engine` — admission, shape routing and dynamic batching.
//!   Requests routed to the same artifact accumulate in a per-artifact
//!   slot queue; a queue flushes when it reaches the artifact's compiled
//!   batch size or when its oldest entry exceeds `max_wait` (classic
//!   dynamic batching — the same policy a vLLM-style serving router
//!   uses). A flush is *zero-copy*: the slots (owning the request lists)
//!   are handed to the executor as-is.
//! * `loms-exec` — owns the backend (PJRT handles are thread-confined,
//!   so the backend is constructed *inside* this thread) and drains a
//!   **depth-1 sync channel** of flushed batches: while it executes
//!   batch N, the engine accumulates and flushes batch N+1 — the
//!   two-deep pipeline the tile-direct data path is designed around.
//!   Execution is tile-direct ([`Backend::execute_direct`]): request
//!   lists are scattered straight into the transposed lane tile (pad
//!   fill inline) and each row's output cone is gathered straight into
//!   that response's `merged` vector — the batch payload is copied
//!   exactly twice end to end.
//! * `loms-fallback-*` — a small worker pool serving shapes no artifact
//!   dominates with a software merge, so a single large fallback
//!   `sort_unstable` never stalls dynamic batching for the artifact
//!   queues.
//!
//! Per-request padding to the artifact shape uses `u32::MAX` sentinels
//! (see [`super::router`]), applied inside the tile scatter — partially
//! filled batches execute only their real rows on the software path.

use super::backend::Backend;
use super::metrics::Metrics;
use super::request::{MergeRequest, MergeResponse, Responder, ResponseTx};
use super::router::{Route, Router};
use crate::obs::{self, SpanEvent};
use crate::runtime::ArtifactMeta;
use crate::util::fault::{self, Site};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Service tuning.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Maximum time a request may wait for its batch to fill.
    pub max_wait: Duration,
    /// Serve shapes no artifact dominates with the software fallback
    /// (reject them when false).
    pub software_fallback: bool,
    /// Worker threads for software-fallback merges. Must be ≥ 1 when
    /// `software_fallback` is set — validated at construction
    /// ([`ConfigError::ZeroFallbackThreads`]). Fallback merges run off
    /// the engine thread so a large `sort_unstable` cannot stall
    /// dynamic batching.
    pub fallback_threads: usize,
}

/// Typed construction-time rejections of configurations that would
/// otherwise surface as a runtime stall or panic deep inside the
/// engine/executor threads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    /// `software_fallback` enabled with zero worker threads: every
    /// unroutable shape would queue on a channel nobody drains.
    ZeroFallbackThreads,
    /// An artifact advertises `batch == 0`: its queue could never hold
    /// a request without flushing an empty batch schedule, and the
    /// backend's `rows <= batch` precondition would reject every flush
    /// at execute time.
    ZeroArtifactBatch { name: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroFallbackThreads => {
                write!(f, "software_fallback requires fallback_threads >= 1 (got 0)")
            }
            ConfigError::ZeroArtifactBatch { name } => {
                write!(f, "artifact {name:?} has batch size 0")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_wait: Duration::from_millis(2),
            software_fallback: true,
            fallback_threads: 2,
        }
    }
}

enum Msg {
    Job(Box<MergeRequest>, ResponseTx),
    Shutdown,
}

/// Handle to a running merge service.
pub struct MergeService {
    tx: mpsc::Sender<Msg>,
    /// Stage threads, taken exactly once by whichever caller drains
    /// first — `shutdown(&self)` works through any clone/borrow, and a
    /// second call (or `Drop` after an explicit shutdown) is a no-op.
    joins: Mutex<Option<Joins>>,
    metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

struct Joins {
    engine: JoinHandle<()>,
    exec: JoinHandle<()>,
    fallback: Vec<JoinHandle<()>>,
}

struct Slot {
    req: MergeRequest,
    tx: ResponseTx,
}

/// A flushed batch in flight from the batcher to the executor. Carries
/// the request slots untouched — assembly happens tile-direct inside
/// the executor — plus the artifact name (an `Arc<str>` refcount bump,
/// not a deep `ArtifactMeta` clone: the executor needs nothing else).
struct ExecBatch {
    name: Arc<str>,
    slots: Vec<Slot>,
    /// Key-value batch: every slot carries a payload column and the
    /// executor runs the rank-then-permute path
    /// ([`Backend::execute_direct_kv`]). Key-only and key-value
    /// requests for the same artifact batch separately — their
    /// execution contracts differ.
    kv: bool,
    /// When the oldest slot entered its queue (queue-wait timing).
    queued_at: Instant,
}

type FallbackJob = (Box<MergeRequest>, ResponseTx);

/// The batcher: admission, routing, per-artifact queues, flush policy.
struct Engine {
    router: Router,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
    /// Whether the backend executes key-value batches (read once from
    /// the executor at startup). When false, key-value requests routed
    /// to an artifact are served by the software fallback instead —
    /// PJRT artifacts compile bare-key HLO today.
    backend_kv: bool,
    /// Per-(artifact, kv-mode) slot queues: key-only and key-value
    /// requests never share a batch.
    queues: HashMap<(usize, bool), Vec<Slot>>,
    oldest: HashMap<(usize, bool), Instant>,
    /// Depth-1 pipeline to the executor thread: `send` blocks only when
    /// a batch is already executing *and* another is queued.
    batch_tx: mpsc::SyncSender<ExecBatch>,
    /// Present iff `cfg.software_fallback`.
    fallback_tx: Option<mpsc::Sender<FallbackJob>>,
}

impl Engine {
    fn run(mut self, rx: mpsc::Receiver<Msg>) {
        loop {
            // Wait up to the flush deadline for new work.
            let timeout = self.nearest_deadline().unwrap_or(self.cfg.max_wait);
            match rx.recv_timeout(timeout) {
                Ok(Msg::Job(req, tx)) => self.admit(req, tx),
                Ok(Msg::Shutdown) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            self.flush_due(false);
        }
        self.flush_due(true);
        // Dropping the engine closes `batch_tx` and `fallback_tx`; the
        // executor and fallback workers drain what is in flight and exit.
    }

    fn nearest_deadline(&self) -> Option<Duration> {
        let now = Instant::now();
        self.oldest
            .values()
            .map(|&t| (t + self.cfg.max_wait).saturating_duration_since(now))
            .min()
    }

    fn admit(&mut self, req: Box<MergeRequest>, tx: ResponseTx) {
        self.metrics.on_request();
        if self.metrics.detail() && self.metrics.tracer().sampled(req.trace) {
            let tr = self.metrics.tracer();
            tr.record(SpanEvent {
                trace: req.trace,
                name: "admit",
                start_us: tr.now_us(),
                dur_us: 0,
                artifact: None,
                tier: None,
            });
        }
        // Unsorted lists violate the hardware precondition; u32::MAX
        // values collide with the PAD sentinel and would be corrupted by
        // batch padding — both rejected before routing.
        if req.check_valid().is_err() {
            self.metrics.on_rejected();
            drop(tx); // receiver sees a closed channel
            return;
        }
        let kv = req.is_kv();
        let route = self.router.route(&req.sizes());
        match route {
            // Key-value requests only batch onto an artifact when the
            // backend executes the rank-then-permute contract;
            // otherwise they take the software fallback like any
            // unroutable shape.
            Route::Artifact { idx } if !kv || self.backend_kv => {
                let key = (idx, kv);
                let q = self.queues.entry(key).or_default();
                q.push(Slot { req: *req, tx });
                self.oldest.entry(key).or_insert_with(Instant::now);
                let batch = self.router.artifacts()[idx].batch;
                if self.queues[&key].len() >= batch {
                    self.flush(key);
                }
            }
            Route::Artifact { .. } | Route::Software => {
                let Some(fb) = &self.fallback_tx else {
                    self.metrics.on_rejected();
                    drop(tx);
                    return;
                };
                match fb.send((req, tx)) {
                    Ok(()) => self.metrics.on_software(),
                    Err(mpsc::SendError((_, tx))) => {
                        // Fallback pool died: the caller sees a closed
                        // channel (and the request counts rejected, not
                        // software-served).
                        self.metrics.on_rejected();
                        drop(tx);
                    }
                }
            }
        }
    }

    fn flush_due(&mut self, all: bool) {
        let now = Instant::now();
        let due: Vec<(usize, bool)> = self
            .oldest
            .iter()
            .filter(|(_, &t)| all || now >= t + self.cfg.max_wait)
            .map(|(&k, _)| k)
            .collect();
        for key in due {
            self.flush(key);
        }
    }

    /// Hand a queue to the executor. No assembly happens here: the
    /// slots move as-is, and the send blocks only when the pipeline is
    /// already two batches deep (backpressure instead of queue growth).
    fn flush(&mut self, key: (usize, bool)) {
        let Some(slots) = self.queues.remove(&key) else { return };
        let queued_at = self.oldest.remove(&key).unwrap_or_else(Instant::now);
        if slots.is_empty() {
            return;
        }
        let name = self.router.artifacts()[key.0].name.clone();
        if let Err(mpsc::SendError(batch)) =
            self.batch_tx.send(ExecBatch { name, slots, kv: key.1, queued_at })
        {
            // Executor died: every caller sees a closed channel.
            for slot in batch.slots {
                self.metrics.on_rejected();
                drop(slot.tx);
            }
        }
    }
}

/// The executor stage: owns the backend, drains flushed batches, runs
/// them tile-direct and fans responses out.
fn exec_loop<B: Backend>(mut backend: B, rx: mpsc::Receiver<ExecBatch>, metrics: Arc<Metrics>) {
    while let Ok(ExecBatch { name, slots, kv, queued_at }) = rx.recv() {
        let t0 = Instant::now();
        let queue_wait = t0.saturating_duration_since(queued_at);
        let real = slots.len();
        // Assemble = borrow the batch view and pre-size each response's
        // `merged` vector (its length is the request's real output
        // width). The only data copies happen inside `execute_direct`:
        // request slices → lane tile, output tile slots → these vectors.
        let mut merged: Vec<Vec<u32>> = slots
            .iter()
            .map(|s| vec![0u32; s.req.lists.iter().map(Vec::len).sum()])
            .collect();
        // Key-value batches additionally pre-size one payload column
        // per response; the single payload move happens inside
        // `execute_direct_kv` (gather through the permutation).
        let mut merged_pay: Vec<Vec<u64>> = if kv {
            merged.iter().map(|m| vec![0u64; m.len()]).collect()
        } else {
            Vec::new()
        };
        let (run, t1, t2) = {
            let rows: Vec<&[Vec<u32>]> = slots.iter().map(|s| s.req.lists.as_slice()).collect();
            let mut outs: Vec<&mut [u32]> = merged.iter_mut().map(|v| v.as_mut_slice()).collect();
            let t1 = Instant::now();
            // Transient executor faults (injected via `LOMS_FAULTS`)
            // are absorbed in place: merges are pure and the batch
            // fully overwrites its output buffers, so re-running it is
            // byte-identical and invisible to callers.
            let run = loop {
                let r = if kv {
                    let pays: Vec<&[u64]> = slots
                        .iter()
                        .map(|s| s.req.payloads.as_deref().unwrap_or(&[]))
                        .collect();
                    let mut pay_outs: Vec<&mut [u64]> =
                        merged_pay.iter_mut().map(|v| v.as_mut_slice()).collect();
                    backend.execute_direct_kv(&name, &rows, &pays, &mut outs, &mut pay_outs)
                } else {
                    backend.execute_direct(&name, &rows, &mut outs)
                };
                if r.is_ok() && fault::fires(Site::ExecTransient) {
                    metrics.on_fault_injected();
                    metrics.on_retry();
                    continue;
                }
                break r;
            };
            (run, t1, Instant::now())
        };
        // Traced slots are resolved before the batch is consumed by
        // fan-out; with sampling off this is one atomic load per slot.
        let traced: Vec<u64> = if metrics.detail() && metrics.tracer().sample() != 0 {
            slots
                .iter()
                .map(|s| s.req.trace)
                .filter(|&t| metrics.tracer().sampled(t))
                .collect()
        } else {
            Vec::new()
        };
        let tier = match &run {
            Ok(stats) => stats.tier,
            Err(_) => "",
        };
        let ok = run.is_ok();
        match run {
            Ok(stats) => {
                let pay = kv.then_some(merged_pay);
                metrics.on_artifact_batch(&name, real as u64, t2 - t1);
                respond_batch(&metrics, name.clone(), slots, merged, pay, real, stats.padded_rows);
            }
            Err(e) => {
                eprintln!("merge batch {name} failed: {e:#}");
                for slot in slots {
                    metrics.on_rejected();
                    drop(slot.tx);
                }
            }
        }
        let respond = t2.elapsed();
        metrics.on_batch_stages(queue_wait, t1 - t0, t2 - t1, respond);
        if ok && !traced.is_empty() {
            // Reconstruct the batch's stage timeline on the tracer
            // clock by counting back from "now" — every traced slot in
            // the batch shares the same queue/assemble/execute/respond
            // spans (batching is the point).
            let tr = metrics.tracer();
            let respond_us = obs::us_from_duration(respond);
            let exec_us = obs::us_from_duration(t2 - t1);
            let asm_us = obs::us_from_duration(t1 - t0);
            let qw_us = obs::us_from_duration(queue_wait);
            let t2_us = tr.now_us().saturating_sub(respond_us);
            let t1_us = t2_us.saturating_sub(exec_us);
            let t0_us = t1_us.saturating_sub(asm_us);
            let q_us = t0_us.saturating_sub(qw_us);
            for &trace in &traced {
                tr.record(SpanEvent {
                    trace,
                    name: "queue",
                    start_us: q_us,
                    dur_us: qw_us,
                    artifact: None,
                    tier: None,
                });
                tr.record(SpanEvent {
                    trace,
                    name: "assemble",
                    start_us: t0_us,
                    dur_us: asm_us,
                    artifact: None,
                    tier: None,
                });
                tr.record(SpanEvent {
                    trace,
                    name: "execute",
                    start_us: t1_us,
                    dur_us: exec_us,
                    artifact: Some(name.clone()),
                    tier: Some(tier),
                });
                tr.record(SpanEvent {
                    trace,
                    name: "respond",
                    start_us: t2_us,
                    dur_us: respond_us,
                    artifact: None,
                    tier: None,
                });
            }
        }
    }
}

/// Response fan-out for one executed batch (split out of [`exec_loop`]
/// to keep the borrow regions obvious).
fn respond_batch(
    metrics: &Metrics,
    name: Arc<str>,
    slots: Vec<Slot>,
    merged: Vec<Vec<u32>>,
    mut payloads: Option<Vec<Vec<u64>>>,
    real: usize,
    padded_rows: usize,
) {
    metrics.on_batch(real, padded_rows);
    for (r, (slot, out)) in slots.into_iter().zip(merged).enumerate() {
        let latency = slot.req.submitted.elapsed();
        // Record before responding: a caller may observe the response
        // and read the snapshot before we run again.
        metrics.on_response(latency);
        slot.tx.respond(MergeResponse {
            id: slot.req.id,
            merged: out,
            payloads: payloads.as_mut().map(|p| std::mem::take(&mut p[r])),
            latency_ns: latency.as_nanos(),
            served_by: name.clone(),
        });
    }
}

/// One software-fallback worker: drains the shared job queue and serves
/// each request with a concat + sort merge. Key-only requests use
/// `sort_unstable`; key-value requests zip the payload column beside the
/// keys and sort **stably** by key — the same (key, arrival-order)
/// semantics the rank-then-permute artifact path produces, so a request
/// gets identical bytes whichever path serves it.
fn fallback_loop(rx: Arc<Mutex<mpsc::Receiver<FallbackJob>>>, metrics: Arc<Metrics>) {
    let label: Arc<str> = "software".into();
    loop {
        // Take one job while holding the lock, release it to merge.
        let job = {
            let Ok(guard) = rx.lock() else { return };
            guard.recv()
        };
        let Ok((req, tx)) = job else { return };
        let t_exec = Instant::now();
        let (merged, payloads) = match &req.payloads {
            None => {
                let mut merged: Vec<u32> = req.lists.concat();
                merged.sort_unstable();
                (merged, None)
            }
            Some(pay) => {
                let keys: Vec<u32> = req.lists.concat();
                let mut pairs: Vec<(u32, u64)> =
                    keys.into_iter().zip(pay.iter().copied()).collect();
                pairs.sort_by_key(|&(k, _)| k); // stable: ties keep arrival order
                let merged = pairs.iter().map(|&(k, _)| k).collect();
                let payloads = pairs.iter().map(|&(_, p)| p).collect();
                (merged, Some(payloads))
            }
        };
        let exec_dur = t_exec.elapsed();
        metrics.on_artifact_batch(&label, 1, exec_dur);
        if metrics.detail() && metrics.tracer().sampled(req.trace) {
            let tr = metrics.tracer();
            let exec_us = obs::us_from_duration(exec_dur);
            tr.record(SpanEvent {
                trace: req.trace,
                name: "execute",
                start_us: tr.now_us().saturating_sub(exec_us),
                dur_us: exec_us,
                artifact: Some(label.clone()),
                tier: Some("software"),
            });
        }
        let latency = req.submitted.elapsed();
        metrics.on_response(latency);
        tx.respond(MergeResponse {
            id: req.id,
            merged,
            payloads,
            latency_ns: latency.as_nanos(),
            served_by: label.clone(),
        });
    }
}

impl MergeService {
    /// Start the service. The backend is constructed by `factory`
    /// *inside* the executor thread — PJRT handles are thread-confined
    /// (`Rc` internally), so they must be born where they run. Fails
    /// fast if the factory errors (e.g. artifacts missing) or the
    /// configuration is unusable ([`ConfigError`]).
    pub fn start<B, F>(factory: F, cfg: ServiceConfig) -> Result<MergeService>
    where
        B: Backend + 'static,
        F: FnOnce() -> Result<B> + Send + 'static,
    {
        if cfg.software_fallback && cfg.fallback_threads == 0 {
            return Err(ConfigError::ZeroFallbackThreads.into());
        }
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::channel();
        // Depth-1 pipeline: the engine assembles/queues batch N+1 while
        // the executor runs batch N; a third flush blocks (backpressure).
        let (batch_tx, batch_rx) = mpsc::sync_channel::<ExecBatch>(1);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(Vec<ArtifactMeta>, bool)>>();
        let exec_metrics = Arc::clone(&metrics);
        let exec = std::thread::Builder::new()
            .name("loms-exec".into())
            .spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok((b.artifacts(), b.supports_kv())));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                exec_loop(backend, batch_rx, exec_metrics);
            })
            .context("spawning executor thread")?;
        let (artifacts, backend_kv) = match ready_rx.recv() {
            Ok(Ok(a)) => a,
            Ok(Err(e)) => {
                let _ = exec.join();
                return Err(e);
            }
            Err(_) => anyhow::bail!("executor thread died during startup"),
        };
        if let Some(bad) = artifacts.iter().find(|m| m.batch == 0) {
            let err = ConfigError::ZeroArtifactBatch { name: bad.name.to_string() };
            // Dropping the batch channel ends the executor loop; join
            // it so the thread never outlives the failed constructor.
            drop(batch_tx);
            let _ = exec.join();
            return Err(err.into());
        }
        let mut fallback = Vec::new();
        let fallback_tx = if cfg.software_fallback {
            let (ftx, frx) = mpsc::channel::<FallbackJob>();
            let frx = Arc::new(Mutex::new(frx));
            for i in 0..cfg.fallback_threads {
                let frx = Arc::clone(&frx);
                let m = Arc::clone(&metrics);
                fallback.push(
                    std::thread::Builder::new()
                        .name(format!("loms-fallback-{i}"))
                        .spawn(move || fallback_loop(frx, m))
                        .context("spawning fallback worker")?,
                );
            }
            Some(ftx)
        } else {
            None
        };
        let engine_metrics = Arc::clone(&metrics);
        let engine = std::thread::Builder::new()
            .name("loms-engine".into())
            .spawn(move || {
                let router = Router::new(artifacts);
                let engine = Engine {
                    router,
                    cfg,
                    metrics: engine_metrics,
                    backend_kv,
                    queues: HashMap::new(),
                    oldest: HashMap::new(),
                    batch_tx,
                    fallback_tx,
                };
                engine.run(rx);
            })
            .context("spawning engine thread")?;
        Ok(MergeService {
            tx,
            joins: Mutex::new(Some(Joins { engine, exec, fallback })),
            metrics,
            next_id: AtomicU64::new(1),
        })
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Hand a request to the engine. When the engine is already gone
    /// (a submit raced an explicit [`shutdown`]), the request is
    /// accounted as rejected — keeping the `requests == responses +
    /// rejected` balance and the [`pending`] gauge honest — and the
    /// responder is dropped (drop == reject).
    ///
    /// [`shutdown`]: MergeService::shutdown
    /// [`pending`]: MergeService::pending
    fn enqueue(&self, req: MergeRequest, tx: ResponseTx) {
        if let Err(mpsc::SendError(msg)) = self.tx.send(Msg::Job(Box::new(req), tx)) {
            if let Msg::Job(_, tx) = msg {
                self.metrics.on_request();
                self.metrics.on_rejected();
                drop(tx);
            }
        }
    }

    /// Submit a merge; returns the response channel.
    pub fn submit(&self, lists: Vec<Vec<u32>>) -> mpsc::Receiver<MergeResponse> {
        self.submit_traced(lists, 0)
    }

    /// Submit a merge carrying a trace id (0 = untraced). The net edge
    /// mints ids for frames that arrive without one; in-process callers
    /// may mint via `metrics().tracer().mint()` to follow their own
    /// request through the span ring.
    pub fn submit_traced(&self, lists: Vec<Vec<u32>>, trace: u64) -> mpsc::Receiver<MergeResponse> {
        let (tx, rx) = Responder::channel();
        self.enqueue(MergeRequest::new(self.alloc_id(), lists).with_trace(trace), tx);
        rx
    }

    /// Submit with a completion callback instead of a channel — the
    /// event-driven net server's path, where no thread may park per
    /// request. `on_done` runs exactly once with `Some(response)` on
    /// success or `None` on rejection, on whichever service thread
    /// settles the request — it must be quick and non-blocking.
    pub fn submit_with(
        &self,
        lists: Vec<Vec<u32>>,
        trace: u64,
        on_done: impl FnOnce(Option<MergeResponse>) + Send + 'static,
    ) {
        let req = MergeRequest::new(self.alloc_id(), lists).with_trace(trace);
        self.enqueue(req, Responder::callback(on_done));
    }

    /// Key-value twin of [`submit_with`].
    ///
    /// [`submit_with`]: MergeService::submit_with
    pub fn submit_kv_with(
        &self,
        lists: Vec<Vec<u32>>,
        payloads: Vec<u64>,
        trace: u64,
        on_done: impl FnOnce(Option<MergeResponse>) + Send + 'static,
    ) {
        let req = MergeRequest::new_kv(self.alloc_id(), lists, payloads).with_trace(trace);
        self.enqueue(req, Responder::callback(on_done));
    }

    /// Submit a key-value merge: `payloads` is the list-major column
    /// beside the keys (one `u64` per key). The response carries the
    /// merged keys plus the payload column permuted to match, stable
    /// for duplicate keys.
    pub fn submit_kv(
        &self,
        lists: Vec<Vec<u32>>,
        payloads: Vec<u64>,
    ) -> mpsc::Receiver<MergeResponse> {
        self.submit_kv_traced(lists, payloads, 0)
    }

    /// Key-value submission carrying a trace id (see [`submit_traced`]).
    ///
    /// [`submit_traced`]: MergeService::submit_traced
    pub fn submit_kv_traced(
        &self,
        lists: Vec<Vec<u32>>,
        payloads: Vec<u64>,
        trace: u64,
    ) -> mpsc::Receiver<MergeResponse> {
        let (tx, rx) = Responder::channel();
        self.enqueue(MergeRequest::new_kv(self.alloc_id(), lists, payloads).with_trace(trace), tx);
        rx
    }

    /// Submit and wait.
    pub fn merge_blocking(&self, lists: Vec<Vec<u32>>) -> Result<MergeResponse> {
        let rx = self.submit(lists);
        rx.recv().map_err(|_| anyhow::anyhow!("request rejected or service stopped"))
    }

    /// Submit a key-value merge and wait.
    pub fn merge_blocking_kv(
        &self,
        lists: Vec<Vec<u32>>,
        payloads: Vec<u64>,
    ) -> Result<MergeResponse> {
        let rx = self.submit_kv(lists, payloads);
        rx.recv().map_err(|_| anyhow::anyhow!("request rejected or service stopped"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Requests submitted but not yet answered or rejected — the cheap
    /// pending-work gauge the network server's admission shed reads on
    /// every request frame. Shed requests are refused *before*
    /// `submit`, so they never enter either side of the subtraction.
    pub fn pending(&self) -> u64 {
        let submitted = self.next_id.load(Ordering::Relaxed) - 1;
        submitted.saturating_sub(self.metrics.settled())
    }

    /// Stop the engine, flushing pending batches, and join every stage:
    /// engine first (its drop closes the batch and fallback channels),
    /// then the executor and fallback workers drain what is in flight
    /// and exit.
    ///
    /// Idempotent and clone-proof: the stage handles are taken exactly
    /// once under a lock, so the drain happens regardless of how many
    /// `Arc<MergeService>` clones survive (the old `Arc::try_unwrap`
    /// gate silently skipped it when any clone was held, dropping
    /// in-flight batches). A concurrent second caller blocks until the
    /// drain finishes; a later call (or `Drop`) is a no-op.
    pub fn shutdown(&self) {
        let mut joins = match self.joins.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let Some(j) = joins.take() else { return };
        let _ = self.tx.send(Msg::Shutdown);
        let _ = j.engine.join();
        let _ = j.exec.join();
        for h in j.fallback {
            let _ = h.join();
        }
    }
}

impl Drop for MergeService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SoftwareBackend;
    use crate::util::Rng;

    fn svc() -> MergeService {
        MergeService::start(|| Ok(SoftwareBackend::default_set()), ServiceConfig::default()).unwrap()
    }

    #[test]
    fn single_request_round_trip() {
        let s = svc();
        let resp = s.merge_blocking(vec![vec![1, 3, 9], vec![2, 4]]).unwrap();
        assert_eq!(resp.merged, vec![1, 2, 3, 4, 9]);
        assert_eq!(&*resp.served_by, "loms2_up32_dn32_b256");
    }

    #[test]
    fn exact_shape_uses_artifact() {
        let s = svc();
        let mut rng = Rng::new(4);
        let a = rng.sorted_list(32, 100_000);
        let b = rng.sorted_list(32, 100_000);
        let resp = s.merge_blocking(vec![a.clone(), b.clone()]).unwrap();
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(resp.merged, want);
    }

    /// Stable key-value oracle: sort the zipped pairs by key.
    fn kv_oracle(lists: &[Vec<u32>], payloads: &[u64]) -> (Vec<u32>, Vec<u64>) {
        let keys: Vec<u32> = lists.concat();
        let mut pairs: Vec<(u32, u64)> = keys.into_iter().zip(payloads.iter().copied()).collect();
        pairs.sort_by_key(|&(k, _)| k);
        (pairs.iter().map(|&(k, _)| k).collect(), pairs.iter().map(|&(_, p)| p).collect())
    }

    #[test]
    fn kv_request_round_trip_on_artifact_path() {
        let s = svc();
        let mut rng = Rng::new(0x1234);
        // Artifact-shaped (32+32) with heavy key duplication.
        let lists = vec![rng.sorted_list(32, 50), rng.sorted_list(32, 50)];
        let payloads: Vec<u64> = (0..64).map(|i| 1000 + i).collect();
        let resp = s.merge_blocking_kv(lists.clone(), payloads.clone()).unwrap();
        assert_eq!(&*resp.served_by, "loms2_up32_dn32_b256", "KV batches on the artifact");
        let (want_k, want_p) = kv_oracle(&lists, &payloads);
        assert_eq!(resp.merged, want_k);
        assert_eq!(resp.payloads.as_deref(), Some(want_p.as_slice()));
    }

    #[test]
    fn kv_request_falls_back_for_unroutable_shapes() {
        let s = svc();
        let lists = vec![(0..500).collect::<Vec<u32>>(), (250..750).collect()];
        let payloads: Vec<u64> = (0..1000).map(|i| i * 3).collect();
        let resp = s.merge_blocking_kv(lists.clone(), payloads.clone()).unwrap();
        assert_eq!(&*resp.served_by, "software");
        let (want_k, want_p) = kv_oracle(&lists, &payloads);
        assert_eq!(resp.merged, want_k);
        assert_eq!(resp.payloads.as_deref(), Some(want_p.as_slice()));
    }

    #[test]
    fn kv_payload_width_mismatch_rejected() {
        let s = svc();
        let rx = s.submit_kv(vec![vec![1, 2], vec![3]], vec![10]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
    }

    #[test]
    fn kv_and_key_only_share_the_service() {
        // Interleaved key-only and KV submissions against the same
        // artifact shape: they batch separately but both come back
        // correct.
        let s = svc();
        let mut rng = Rng::new(0xABCD);
        let mut expect = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..60 {
            let lists = vec![rng.sorted_list(32, 200), rng.sorted_list(32, 200)];
            if i % 2 == 0 {
                let payloads: Vec<u64> = (0..64).map(|j| ((i as u64) << 32) | j).collect();
                expect.push(kv_oracle(&lists, &payloads));
                rxs.push((true, s.submit_kv(lists, payloads)));
            } else {
                let (want_k, _) = kv_oracle(&lists, &[0; 64]);
                expect.push((want_k, Vec::new()));
                rxs.push((false, s.submit(lists)));
            }
        }
        for ((kv, rx), (want_k, want_p)) in rxs.into_iter().zip(expect) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.merged, want_k);
            if kv {
                assert_eq!(resp.payloads.as_deref(), Some(want_p.as_slice()));
            } else {
                assert!(resp.payloads.is_none());
            }
        }
    }

    #[test]
    fn many_concurrent_requests_batch() {
        let s = svc();
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for _ in 0..200 {
            let a = rng.sorted_list(32, 10_000);
            let b = rng.sorted_list(32, 10_000);
            let mut want = [a.clone(), b.clone()].concat();
            want.sort_unstable();
            wants.push(want);
            rxs.push(s.submit(vec![a, b]));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            assert_eq!(rx.recv().unwrap().merged, want);
        }
        let snap = s.metrics().snapshot();
        assert_eq!(snap.responses, 200);
        // 200 requests against a 256-batch artifact: deadline flushes,
        // far fewer batches than requests.
        assert!(snap.batches >= 1, "batched: {}", snap.batches);
        assert!(snap.batches < 20, "must actually batch, got {}", snap.batches);
        // Tile-direct: partial batches execute only real rows.
        assert_eq!(snap.rows_padded, 0);
        assert_eq!(snap.rows_real, 200);
    }

    #[test]
    fn stage_timings_recorded_per_batch() {
        let s = svc();
        let mut rng = Rng::new(41);
        for _ in 0..50 {
            let a = rng.sorted_list(32, 10_000);
            let b = rng.sorted_list(32, 10_000);
            s.merge_blocking(vec![a, b]).unwrap();
        }
        let snap = s.metrics().snapshot();
        // Every batch records its stage split; execution of a real
        // batch takes measurable time.
        assert!(snap.execute_us_mean > 0.0, "{snap:?}");
        assert!(snap.queue_wait_us_mean >= 0.0);
        assert!(snap.p99_latency_us >= snap.p50_latency_us);
    }

    #[test]
    fn unsorted_request_rejected() {
        let s = svc();
        let rx = s.submit(vec![vec![5, 1], vec![2, 3]]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
    }

    #[test]
    fn sentinel_request_rejected() {
        // u32::MAX collides with the PAD sentinel: batch padding would
        // make the value indistinguishable from padding, so the service
        // rejects it at admission instead of corrupting the merge.
        let s = svc();
        let rx = s.submit(vec![vec![1, 2, u32::MAX], vec![3, 4]]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
        // The largest *legal* key is still served exactly.
        let resp = s.merge_blocking(vec![vec![1, u32::MAX - 1], vec![2]]).unwrap();
        assert_eq!(resp.merged, vec![1, 2, u32::MAX - 1]);
    }

    #[test]
    fn unroutable_shape_served_by_software() {
        let s = svc();
        let a: Vec<u32> = (0..1000).collect();
        let b: Vec<u32> = (500..1500).collect();
        let resp = s.merge_blocking(vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(&*resp.served_by, "software");
        let mut want = [a, b].concat();
        want.sort_unstable();
        assert_eq!(resp.merged, want);
    }

    #[test]
    fn fallback_pool_runs_off_the_engine_thread() {
        // A large software merge must not stall artifact batching: fire
        // a big fallback request, then a burst of artifact-shaped
        // requests; everything completes and both paths are counted.
        let s = svc();
        let big_a: Vec<u32> = (0..200_000).collect();
        let big_b: Vec<u32> = (100_000..300_000).collect();
        let big_rx = s.submit(vec![big_a, big_b]);
        let mut rng = Rng::new(77);
        let mut rxs = Vec::new();
        for _ in 0..64 {
            rxs.push(s.submit(vec![rng.sorted_list(32, 1000), rng.sorted_list(32, 1000)]));
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().merged.windows(2).all(|w| w[0] <= w[1]));
        }
        let big = big_rx.recv().unwrap();
        assert_eq!(&*big.served_by, "software");
        assert_eq!(big.merged.len(), 400_000);
        let snap = s.metrics().snapshot();
        assert_eq!(snap.software_served, 1);
        assert_eq!(snap.responses, 65);
    }

    #[test]
    fn fallback_disabled_rejects_unroutable() {
        let s = MergeService::start(
            || Ok(SoftwareBackend::default_set()),
            ServiceConfig { software_fallback: false, ..ServiceConfig::default() },
        )
        .unwrap();
        let a: Vec<u32> = (0..1000).collect();
        let b: Vec<u32> = (500..1500).collect();
        let rx = s.submit(vec![a, b]);
        assert!(rx.recv().is_err());
        assert_eq!(s.metrics().snapshot().rejected, 1);
    }

    #[test]
    fn three_way_merge() {
        let s = svc();
        let resp = s
            .merge_blocking(vec![vec![1, 4, 7], vec![2, 5, 8], vec![3, 6, 9]])
            .unwrap();
        assert_eq!(resp.merged, (1..=9).collect::<Vec<u32>>());
    }

    #[test]
    fn zero_fallback_threads_rejected_at_construction() {
        // Regression: fallback_threads = 0 used to be silently clamped
        // to 1; with software_fallback it must be a typed error (a
        // zero-worker pool would strand every unroutable request).
        let err = MergeService::start(
            || Ok(SoftwareBackend::default_set()),
            ServiceConfig { fallback_threads: 0, ..ServiceConfig::default() },
        )
        .unwrap_err();
        assert_eq!(
            err.downcast_ref::<ConfigError>(),
            Some(&ConfigError::ZeroFallbackThreads)
        );
        // Without the fallback path the same setting is legal.
        let s = MergeService::start(
            || Ok(SoftwareBackend::default_set()),
            ServiceConfig {
                software_fallback: false,
                fallback_threads: 0,
                ..ServiceConfig::default()
            },
        )
        .unwrap();
        let resp = s.merge_blocking(vec![vec![1, 3], vec![2, 4]]).unwrap();
        assert_eq!(resp.merged, vec![1, 2, 3, 4]);
    }

    #[test]
    fn zero_batch_artifact_rejected_at_construction() {
        use crate::runtime::ArtifactMeta;
        let meta = ArtifactMeta {
            name: "loms2_up8_dn8_b0".into(),
            file: String::new(),
            list_sizes: vec![8, 8],
            batch: 0,
            total: 16,
            block_b: 0,
            plan_steps: 0,
            hw_stages: 0,
            device: "loms2-2col-up8-dn8".into(),
        };
        let err = MergeService::start(
            move || SoftwareBackend::new(vec![meta]),
            ServiceConfig::default(),
        )
        .unwrap_err();
        match err.downcast_ref::<ConfigError>() {
            Some(ConfigError::ZeroArtifactBatch { name }) => {
                assert_eq!(name, "loms2_up8_dn8_b0")
            }
            other => panic!("expected ZeroArtifactBatch, got {other:?} ({err:#})"),
        }
    }

    #[test]
    fn shutdown_flushes() {
        let s = svc();
        let rx = s.submit(vec![vec![1, 2], vec![3, 4]]);
        s.shutdown();
        assert_eq!(rx.recv().unwrap().merged, vec![1, 2, 3, 4]);
    }

    #[test]
    fn shutdown_drains_with_a_clone_held() {
        // Regression: the old shutdown path gated the drain on
        // `Arc::try_unwrap`, so any surviving clone meant in-flight
        // batches were dropped instead of flushed. The drain must not
        // depend on reference counts.
        let s = Arc::new(svc());
        let clone = Arc::clone(&s);
        let rx = s.submit(vec![vec![1, 2], vec![3, 4]]);
        s.shutdown();
        assert_eq!(
            rx.recv().expect("in-flight request drained despite the held clone").merged,
            vec![1, 2, 3, 4]
        );
        // Idempotent: a second call (through either handle) is a no-op.
        clone.shutdown();
        s.shutdown();
    }

    #[test]
    fn callback_submit_completes_and_post_shutdown_submit_rejects() {
        let s = svc();
        let (tx, rx) = mpsc::channel();
        let tx2 = tx.clone();
        s.submit_with(vec![vec![1, 3], vec![2, 4]], 0, move |r| {
            tx2.send(r.map(|r| r.merged)).unwrap()
        });
        assert_eq!(rx.recv().unwrap(), Some(vec![1, 2, 3, 4]));
        // A rejected request (unsorted) fires the callback with None.
        let tx2 = tx.clone();
        s.submit_with(vec![vec![5, 1]], 0, move |r| tx2.send(r.map(|r| r.merged)).unwrap());
        assert_eq!(rx.recv().unwrap(), None);
        s.shutdown();
        // Post-shutdown submits reject via the callback and stay
        // balanced in the metrics (requests == responses + rejected).
        let tx2 = tx.clone();
        s.submit_with(vec![vec![1, 2]], 0, move |r| tx2.send(r.map(|r| r.merged)).unwrap());
        assert_eq!(rx.recv().unwrap(), None);
        let snap = s.metrics().snapshot();
        snap.check().unwrap();
        assert_eq!(snap.rejected, 2);
        assert_eq!(s.pending(), 0, "post-shutdown submit settles the gauge");
    }

    #[test]
    fn pending_gauge_settles_to_zero() {
        let s = svc();
        assert_eq!(s.pending(), 0);
        s.merge_blocking(vec![vec![1, 3], vec![2, 4]]).unwrap();
        assert_eq!(s.pending(), 0, "answered request settles");
        // A rejected request settles too: on_rejected is recorded
        // before the response channel is dropped.
        let rx = s.submit(vec![vec![5, 1], vec![2]]);
        assert!(rx.recv().is_err());
        assert_eq!(s.pending(), 0, "rejected request settles");
    }
}
