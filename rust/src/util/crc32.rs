//! Table-driven CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`)
//! — the checksum over spill-block payloads ([`crate::stream`]). The
//! offline build vendors no checksum crate, so the tables are computed
//! at compile time by `const fn`s.
//!
//! The bulk path is slicing-by-8 (eight derived tables, eight input
//! bytes per step) — spill blocks sit on the external sort's disk hot
//! path, and a byte-at-a-time CRC would cost more than the disk I/O it
//! protects. Tails shorter than 8 bytes fall back to the byte table.
//!
//! CRC-32 detects every single-bit error (the generator polynomial has
//! more than one term), which is exactly the guarantee the spill
//! integrity layer's proptest pins down bit by bit.

/// Byte-at-a-time lookup table for the reflected polynomial.
const fn byte_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Slicing-by-8 tables: `T[0]` is the byte table; `T[k][i]` advances
/// `T[k-1][i]` by one more zero byte, so eight lookups absorb eight
/// input bytes at once.
const fn slice_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    t[0] = byte_table();
    let mut k = 1;
    while k < 8 {
        let mut i = 0;
        while i < 256 {
            let prev = t[k - 1][i];
            t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            i += 1;
        }
        k += 1;
    }
    t
}

static T: [[u32; 256]; 8] = slice_tables();

/// Initial state for a streaming CRC.
pub const CRC32_INIT: u32 = 0xFFFF_FFFF;

/// Feed `bytes` into a running CRC state. Start from [`CRC32_INIT`];
/// finish with [`crc32_finish`]. Streaming form so the spill writer can
/// checksum across many encode buffers without concatenating them.
pub fn crc32_update(state: u32, bytes: &[u8]) -> u32 {
    let mut c = state;
    let mut chunks = bytes.chunks_exact(8);
    for w in &mut chunks {
        let lo = c ^ u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        let hi = u32::from_le_bytes([w[4], w[5], w[6], w[7]]);
        c = T[7][(lo & 0xFF) as usize]
            ^ T[6][((lo >> 8) & 0xFF) as usize]
            ^ T[5][((lo >> 16) & 0xFF) as usize]
            ^ T[4][(lo >> 24) as usize]
            ^ T[3][(hi & 0xFF) as usize]
            ^ T[2][((hi >> 8) & 0xFF) as usize]
            ^ T[1][((hi >> 16) & 0xFF) as usize]
            ^ T[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        c = T[0][((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c
}

/// Close a streaming CRC state into the final checksum value.
#[inline]
pub fn crc32_finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

/// One-shot CRC-32 of a byte slice.
#[inline]
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_finish(crc32_update(CRC32_INIT, bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The reference byte-at-a-time implementation the sliced path must
    /// match on every input.
    fn crc32_bytewise(bytes: &[u8]) -> u32 {
        let mut c = CRC32_INIT;
        for &b in bytes {
            c = T[0][((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        crc32_finish(c)
    }

    #[test]
    fn known_vectors() {
        // The IEEE check value and a few fixed points.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn sliced_matches_bytewise_at_every_length() {
        let data: Vec<u8> = (0..=255u8).cycle().take(300).collect();
        for len in 0..data.len() {
            assert_eq!(crc32(&data[..len]), crc32_bytewise(&data[..len]), "len {len}");
        }
    }

    #[test]
    fn streaming_equals_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        for split in [0usize, 1, 7, 8, 9, 256, 4_097, 9_999, 10_000] {
            let mut st = CRC32_INIT;
            st = crc32_update(st, &data[..split]);
            st = crc32_update(st, &data[split..]);
            assert_eq!(crc32_finish(st), crc32(&data), "split at {split}");
        }
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let data = b"spill block payload under test".to_vec();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base, "flip at byte {byte} bit {bit} undetected");
            }
        }
    }
}
