//! Deterministic PRNG (SplitMix64 seeding a xoshiro256++ core).
//!
//! The build environment is offline with no `rand` crate available, so the
//! crate carries its own small, well-known generator. Used by the random
//! differential validators, the property-test harness, workload
//! generators and the examples. Not cryptographic.

/// xoshiro256++ with SplitMix64 seeding — deterministic across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator; identical seeds yield identical streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed into the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire-style rejection-free reduction;
    /// negligible bias for the test-workload use cases here).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// A sorted list of `len` u32 values below `max`.
    pub fn sorted_list(&mut self, len: usize, max: u32) -> Vec<u32> {
        let mut v: Vec<u32> = (0..len).map(|_| self.below(max as u64) as u32).collect();
        v.sort_unstable();
        v
    }

    /// A sorted list whose length is itself uniform in `[lo, hi)` — the
    /// common ragged-workload generator. One method because the nested
    /// form `rng.sorted_list(rng.range(lo, hi), max)` is E0499 (two
    /// overlapping `&mut self` borrows).
    pub fn sorted_list_ragged(&mut self, lo: usize, hi: usize, max: u32) -> Vec<u32> {
        let len = self.range(lo, hi);
        self.sorted_list(len, max)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 8);
            assert!((5..8).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval_and_rough_uniformity() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn sorted_list_sorted() {
        let mut r = Rng::new(3);
        let l = r.sorted_list(100, 1000);
        assert_eq!(l.len(), 100);
        assert!(l.windows(2).all(|w| w[0] <= w[1]));
        assert!(l.iter().all(|&x| x < 1000));
    }

    #[test]
    fn sorted_list_ragged_bounds_length() {
        let mut r = Rng::new(8);
        for _ in 0..200 {
            let l = r.sorted_list_ragged(3, 10, 50);
            assert!((3..10).contains(&l.len()));
            assert!(l.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<u32>>());
        assert_ne!(v, (0..50).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
