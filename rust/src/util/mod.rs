//! In-crate substrates for the offline build: PRNG, JSON, CRC-32,
//! deterministic fault injection, timing/report helpers. (The
//! environment vendors only `xla` + `anyhow`.)

pub mod crc32;
pub mod fault;
pub mod json;
pub mod rng;

pub use crc32::crc32;
pub use json::Json;
pub use rng::Rng;
