//! In-crate substrates for the offline build: PRNG, JSON, timing/report
//! helpers. (The environment vendors only `xla` + `anyhow`.)

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
