//! Minimal JSON value model, parser and writer.
//!
//! The offline build has no `serde`/`serde_json`; the repo needs JSON for
//! (a) the artifact manifest written by `python/compile/aot.py`, (b)
//! device export for the Python cross-check, and (c) bench CSV/JSON
//! reports. This is a small, strict-enough RFC 8259 subset: UTF-8 input,
//! `\uXXXX` escapes decoded (surrogate pairs unsupported — not needed for
//! our machine-generated files), numbers as f64 or i64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer-valued numbers (preserved exactly up to i64).
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn int(i: impl Into<i64>) -> Json {
        Json::Int(i.into())
    }

    pub fn usize_arr<I: IntoIterator<Item = usize>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|x| Json::Int(x as i64)).collect())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `usize` array convenience accessor.
    pub fn get_usizes(&self, key: &str) -> Option<Vec<usize>> {
        self.get(key)?.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialise with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        let (nl, pad, padc) = match indent {
            Some(w) => ("\n", " ".repeat(w * (level + 1)), " ".repeat(w * level)),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                out.push_str(nl);
                out.push_str(&padc);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (entire input must be consumed).
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at offset {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected end of input")? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut arr = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    self.ws();
                    arr.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
                    }
                }
            }
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(format!("unexpected byte '{}' at offset {}", c as char, self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or("invalid \\u escape")?);
                        }
                        _ => return Err(format!("bad escape at offset {}", self.i)),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or("truncated UTF-8 sequence")?;
                        s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        if is_float {
            txt.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
        } else {
            txt.parse::<i64>()
                .map(Json::Int)
                .or_else(|_| txt.parse::<f64>().map(Json::Num).map_err(|e| e.to_string()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-17", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::str("loms2-2col")),
            ("sizes", Json::usize_arr([8, 8])),
            ("ok", Json::Bool(true)),
            ("delay_ns", Json::Num(2.24)),
            ("nested", Json::arr([Json::obj(vec![("a", Json::Null)])])),
        ]);
        let s = v.to_string_pretty();
        assert_eq!(Json::parse(&s).unwrap(), v);
        let s2 = v.to_string();
        assert_eq!(Json::parse(&s2).unwrap(), v);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "{\"a\" 1}"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "xs": [1,2,3], "f": 1.5, "s": "x"}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.get_usizes("xs").unwrap(), vec![1, 2, 3]);
        assert_eq!(v.get("f").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x");
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn big_ints_preserved() {
        let v = Json::parse("9007199254740993").unwrap(); // 2^53+1
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }
}
