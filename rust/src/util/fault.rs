//! Deterministic, seed-driven fault injection.
//!
//! Every injection point in the stack is a named [`Site`] guarded by
//! [`fires`]. The sites are compiled in unconditionally — no feature
//! flag, so the exact production binary is what chaos tests exercise —
//! but when no plan is active the check is a single relaxed load of a
//! static, nothing more.
//!
//! Determinism: each site keeps an atomic call counter; the *n*-th
//! evaluation of a site fires iff `mix(seed, site, n)` falls below the
//! site's probability threshold. The set of firing `(site, n)` pairs
//! therefore depends only on the plan, never on thread interleaving —
//! reruns with the same seed inject the same faults even though *which
//! thread* observes each fault may differ.
//!
//! Activation, two ways:
//! * `LOMS_FAULTS` env var, parsed lazily on the first [`fires`] call —
//!   grammar `seed=N,<site>=<prob>[:<max>],...`, e.g.
//!   `seed=7,spill_corrupt_byte=0.01:4,net_conn_reset=0.05`. How CI's
//!   chaos matrix drives whole binaries.
//! * [`install`] for tests: installs a [`FaultPlan`] and returns a
//!   [`FaultGuard`] holding a process-wide lock, so concurrent chaos
//!   tests serialize instead of trampling each other's plans; dropping
//!   the guard reverts to the env-derived state.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Named injection points. Keep [`Site::name`] and [`Site::from_name`]
/// in sync — the env grammar uses the names verbatim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Spill write fails with ENOSPC ([`crate::stream`] writers).
    SpillWriteEnospc,
    /// A spill read comes back short / errored before verification.
    SpillReadShort,
    /// One byte of a read spill block flips before verification.
    SpillCorruptByte,
    /// The server resets a connection mid-serve ([`crate::net`]).
    NetConnReset,
    /// The server writer stalls before a reply write.
    NetWriteStall,
    /// A batch execution fails transiently and is retried in place.
    ExecTransient,
}

pub const SITE_COUNT: usize = 6;

/// Every site, for iteration (counter dumps, plan parsing).
pub const ALL_SITES: [Site; SITE_COUNT] = [
    Site::SpillWriteEnospc,
    Site::SpillReadShort,
    Site::SpillCorruptByte,
    Site::NetConnReset,
    Site::NetWriteStall,
    Site::ExecTransient,
];

impl Site {
    pub fn name(self) -> &'static str {
        match self {
            Site::SpillWriteEnospc => "spill_write_enospc",
            Site::SpillReadShort => "spill_read_short",
            Site::SpillCorruptByte => "spill_corrupt_byte",
            Site::NetConnReset => "net_conn_reset",
            Site::NetWriteStall => "net_write_stall",
            Site::ExecTransient => "exec_transient",
        }
    }

    fn from_name(s: &str) -> Option<Site> {
        ALL_SITES.into_iter().find(|site| site.name() == s)
    }

    #[inline]
    fn idx(self) -> usize {
        self as usize
    }
}

/// Per-site fault rule: firing probability and an optional cap on the
/// total number of fires (`u64::MAX` = unlimited).
#[derive(Clone, Copy, Debug, PartialEq)]
struct Rule {
    prob: f64,
    max: u64,
}

/// A complete injection plan: one seed plus per-site rules.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    rules: [Option<Rule>; SITE_COUNT],
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: [None; SITE_COUNT] }
    }

    /// Fire `site` with probability `prob` (clamped to `[0, 1]`) on
    /// every evaluation, no cap.
    pub fn with(self, site: Site, prob: f64) -> FaultPlan {
        self.with_max(site, prob, u64::MAX)
    }

    /// Fire `site` with probability `prob`, at most `max` times total.
    pub fn with_max(mut self, site: Site, prob: f64, max: u64) -> FaultPlan {
        self.rules[site.idx()] = Some(Rule { prob: prob.clamp(0.0, 1.0), max });
        self
    }

    /// Parse the `LOMS_FAULTS` grammar:
    /// `seed=N,<site>=<prob>[:<max>],...` (whitespace around commas
    /// tolerated; `seed` defaults to 0 when omitted).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::new(0);
        let mut any = false;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) =
                part.split_once('=').ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            let (key, val) = (key.trim(), val.trim());
            if key == "seed" {
                plan.seed = val.parse().map_err(|_| format!("bad seed {val:?}"))?;
                continue;
            }
            let site = Site::from_name(key).ok_or_else(|| format!("unknown fault site {key:?}"))?;
            let (prob_s, max) = match val.split_once(':') {
                Some((p, m)) => {
                    (p, m.parse::<u64>().map_err(|_| format!("bad max count {m:?}"))?)
                }
                None => (val, u64::MAX),
            };
            let prob: f64 =
                prob_s.parse().map_err(|_| format!("bad probability {prob_s:?}"))?;
            if !(0.0..=1.0).contains(&prob) {
                return Err(format!("probability {prob} outside [0, 1]"));
            }
            plan = plan.with_max(site, prob, max);
            any = true;
        }
        if !any {
            return Err("no fault sites in spec".into());
        }
        Ok(plan)
    }
}

/// Tri-state activation flag: 0 = env not yet consulted, 1 = faults
/// off, 2 = a plan is active. The disabled fast path is one relaxed
/// load and one branch.
const STATE_UNKNOWN: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;
static ACTIVE: AtomicU8 = AtomicU8::new(STATE_UNKNOWN);

static SEED: AtomicU64 = AtomicU64::new(0);
/// Per-site firing threshold: `prob` scaled to the full `u64` range
/// (0 = never). Stored as atomics so [`fires`] never takes a lock.
static THRESH: [AtomicU64; SITE_COUNT] = [const { AtomicU64::new(0) }; SITE_COUNT];
static MAX_FIRES: [AtomicU64; SITE_COUNT] = [const { AtomicU64::new(0) }; SITE_COUNT];
static CALLS: [AtomicU64; SITE_COUNT] = [const { AtomicU64::new(0) }; SITE_COUNT];
static FIRED: [AtomicU64; SITE_COUNT] = [const { AtomicU64::new(0) }; SITE_COUNT];

/// Serializes plan installation (and env [re]initialisation) across
/// threads; [`FaultGuard`] holds it for a test's whole lifetime.
static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A chaos test that panicked mid-guard must not poison every later
    // test in the binary.
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// splitmix64-style avalanche over (seed, site, call index): the whole
/// source of injection randomness, so a plan replays exactly.
fn mix(seed: u64, site: u64, n: u64) -> u64 {
    let mut x = seed
        ^ site.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ n.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

fn apply(plan: &FaultPlan) {
    SEED.store(plan.seed, Ordering::SeqCst);
    for (i, rule) in plan.rules.iter().enumerate() {
        let (thresh, max) = match rule {
            Some(r) if r.prob > 0.0 => {
                let t = if r.prob >= 1.0 {
                    u64::MAX
                } else {
                    (r.prob * u64::MAX as f64) as u64
                };
                (t.max(1), r.max)
            }
            _ => (0, 0),
        };
        THRESH[i].store(thresh, Ordering::SeqCst);
        MAX_FIRES[i].store(max, Ordering::SeqCst);
        CALLS[i].store(0, Ordering::SeqCst);
        FIRED[i].store(0, Ordering::SeqCst);
    }
}

/// Parse `LOMS_FAULTS` (if set) under the lock; invalid specs warn once
/// and leave injection off rather than aborting a production binary.
fn init_from_env() {
    let _g = lock();
    if ACTIVE.load(Ordering::SeqCst) != STATE_UNKNOWN {
        return; // raced: someone else initialised while we waited
    }
    match std::env::var("LOMS_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
            Ok(plan) => {
                apply(&plan);
                ACTIVE.store(STATE_ON, Ordering::SeqCst);
            }
            Err(e) => {
                eprintln!("warning: ignoring invalid LOMS_FAULTS ({e})");
                apply(&FaultPlan::default());
                ACTIVE.store(STATE_OFF, Ordering::SeqCst);
            }
        },
        _ => {
            apply(&FaultPlan::default());
            ACTIVE.store(STATE_OFF, Ordering::SeqCst);
        }
    }
}

/// Should this evaluation of `site` fail? The only call sites are the
/// named injection points; disabled cost is one atomic load.
#[inline]
pub fn fires(site: Site) -> bool {
    match ACTIVE.load(Ordering::Relaxed) {
        STATE_OFF => false,
        STATE_UNKNOWN => {
            init_from_env();
            if ACTIVE.load(Ordering::Relaxed) == STATE_OFF {
                return false;
            }
            fires_active(site)
        }
        _ => fires_active(site),
    }
}

fn fires_active(site: Site) -> bool {
    let i = site.idx();
    let thresh = THRESH[i].load(Ordering::Relaxed);
    if thresh == 0 {
        return false;
    }
    let n = CALLS[i].fetch_add(1, Ordering::Relaxed);
    if mix(SEED.load(Ordering::Relaxed), i as u64, n) >= thresh {
        return false;
    }
    // Past the per-site cap, hits stop firing (and stop counting).
    let prev = FIRED[i].fetch_add(1, Ordering::Relaxed);
    if prev < MAX_FIRES[i].load(Ordering::Relaxed) {
        true
    } else {
        FIRED[i].fetch_sub(1, Ordering::Relaxed);
        false
    }
}

/// Faults actually injected at `site` since the active plan was
/// installed.
pub fn injected(site: Site) -> u64 {
    FIRED[site.idx()].load(Ordering::Relaxed)
}

/// Total faults injected across all sites under the active plan.
pub fn injected_total() -> u64 {
    ALL_SITES.iter().map(|s| injected(*s)).sum()
}

/// Is any plan active (env- or test-installed)?
pub fn active() -> bool {
    ACTIVE.load(Ordering::Relaxed) == STATE_ON
}

/// Install `plan` process-wide and hold it active until the returned
/// guard drops (then the env-derived state is restored). Serializes
/// with every other [`install`] caller — chaos tests in one binary run
/// their storms one at a time.
pub fn install(plan: &FaultPlan) -> FaultGuard {
    let guard = lock();
    apply(plan);
    ACTIVE.store(STATE_ON, Ordering::SeqCst);
    FaultGuard { _lock: guard }
}

/// Keeps an installed [`FaultPlan`] active; restores the env-derived
/// state on drop.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        apply(&FaultPlan::default());
        // Back to "unknown": the next `fires` re-reads LOMS_FAULTS, so
        // env-driven chaos runs resume after a programmatic test.
        ACTIVE.store(STATE_UNKNOWN, Ordering::SeqCst);
    }
}

/// The injected disk-full error (`ENOSPC`), built from the raw errno so
/// it round-trips like the real thing.
pub fn enospc() -> std::io::Error {
    std::io::Error::from_raw_os_error(28)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_grammar() {
        let p = FaultPlan::parse("seed=7,spill_corrupt_byte=0.01:4,net_conn_reset=0.05").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(
            p.rules[Site::SpillCorruptByte.idx()],
            Some(Rule { prob: 0.01, max: 4 })
        );
        assert_eq!(
            p.rules[Site::NetConnReset.idx()],
            Some(Rule { prob: 0.05, max: u64::MAX })
        );
        assert!(FaultPlan::parse("bogus_site=0.5").is_err());
        assert!(FaultPlan::parse("spill_read_short=1.5").is_err());
        assert!(FaultPlan::parse("seed=3").is_err(), "a seed alone injects nothing");
        assert!(FaultPlan::parse("spill_read_short").is_err());
    }

    #[test]
    fn deterministic_and_capped() {
        let plan = FaultPlan::new(42).with_max(Site::ExecTransient, 0.5, 10);
        let run = || {
            let _g = install(&plan);
            let fired: Vec<bool> = (0..200).map(|_| fires(Site::ExecTransient)).collect();
            (fired, injected(Site::ExecTransient))
        };
        let (a, fired_a) = run();
        let (b, fired_b) = run();
        assert_eq!(a, b, "same plan must replay the same fault sequence");
        assert!(fired_a > 0, "p=0.5 over 200 calls must fire");
        assert_eq!(fired_a, 10, "cap must bound total fires");
        assert_eq!(fired_a, fired_b);
    }

    #[test]
    fn inactive_sites_never_fire() {
        let plan = FaultPlan::new(1).with(Site::NetWriteStall, 1.0);
        let _g = install(&plan);
        assert!(fires(Site::NetWriteStall));
        for _ in 0..50 {
            assert!(!fires(Site::SpillWriteEnospc), "unconfigured site fired");
        }
        assert_eq!(injected(Site::SpillWriteEnospc), 0);
    }

    #[test]
    fn guard_restores_inactive_state() {
        {
            let plan = FaultPlan::new(9).with(Site::SpillReadShort, 1.0);
            let _g = install(&plan);
            assert!(active());
            assert!(fires(Site::SpillReadShort));
        }
        // No LOMS_FAULTS in the test environment ⇒ off after the guard.
        if std::env::var("LOMS_FAULTS").map_or(true, |s| s.trim().is_empty()) {
            assert!(!fires(Site::SpillReadShort));
            assert!(!active());
        }
    }

    #[test]
    fn enospc_is_storage_full() {
        assert_eq!(enospc().raw_os_error(), Some(28));
    }
}
