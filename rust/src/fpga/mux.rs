//! Output-multiplexer tree shapes.
//!
//! Every single-stage sorter output (S2MS rank outputs, N-sorter outputs)
//! is a wide one-of-C multiplexer built from LUTs. How the tree maps onto
//! the fabric is what separates the devices and methodologies (§VI-A):
//!
//! * **2insLUT**: each leaf LUT takes 2 candidate data bits + 1 select.
//! * **4insLUT**: each leaf LUT takes 4 candidate bits + 2 selects (one
//!   select formed by a *series* function LUT — denser, slower).
//! * **Ultrascale+**: up to 8 leaf LUTs combine inside one slice through
//!   the hard MUXF7/F8/F9 levels (Fig. 7) — no interconnect hops. Wider
//!   trees chain a second series slice through the fabric (the step in
//!   Figs. 11/16 between 16 and 32 outputs).
//! * **Versal Prime**: no MUXF\*; every 2:1 combine is another LUT
//!   reached through the programmable interconnect — one extra series
//!   level per doubling (the constant slope in Figs. 11/12).

use super::device::{Family, FpgaDevice, Methodology, TimingParams};

/// Structural summary of one output's mux tree.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MuxTree {
    /// Candidate inputs (C).
    pub candidates: usize,
    /// Leaf LUT count (first level).
    pub leaf_luts: usize,
    /// Combine LUTs beyond the leaves (0 on Ultrascale+ while the tree
    /// fits the hard MUXF levels of the slices).
    pub combine_luts: usize,
    /// Series slice count on Ultrascale+ (1 slice = LUT + ≤3 MUXF
    /// levels); series LUT levels on Versal.
    pub series_levels: usize,
    /// Data-path delay from the mux slice inputs to the tree output,
    /// selects assumed ready (ns).
    pub delay: f64,
}

fn leaf_width(meth: Methodology) -> usize {
    match meth {
        Methodology::TwoInsLut => 2,
        Methodology::FourInsLut => 4,
    }
}

/// MUXF levels needed to combine `n` leaf LUTs inside one US+ slice
/// (n ≤ 8): 1 leaf → 0 levels, 2 → 1 (F7), 3-4 → 2 (F7+F8), 5-8 → 3.
fn muxf_levels(n: usize) -> usize {
    match n {
        0 | 1 => 0,
        2 => 1,
        3 | 4 => 2,
        _ => 3,
    }
}

/// Build the mux-tree profile for one output with `c` candidates.
pub fn mux_tree(c: usize, meth: Methodology, fpga: &FpgaDevice) -> MuxTree {
    let t: &TimingParams = &fpga.t;
    let lw = leaf_width(meth);
    if c <= 1 {
        return MuxTree { candidates: c, leaf_luts: 0, combine_luts: 0, series_levels: 0, delay: 0.0 };
    }
    let leaves = c.div_ceil(lw);
    match fpga.family {
        Family::UltrascalePlus => {
            // Hierarchy of slices: a slice absorbs up to 8 inputs via its
            // LUTs... at the leaf level each LUT takes `lw` candidates, so
            // one slice covers 8*lw candidates. Deeper levels treat the
            // previous level's slice outputs as candidates again.
            let mut level_inputs = leaves; // units entering the current level (LUT leaves)
            let mut slices = 1usize;
            let mut luts = leaves;
            let mut delay = t.t_lut + t.t_muxf * muxf_levels(level_inputs.min(8)) as f64;
            while level_inputs > 8 {
                // outputs of this level's slices become inputs of the next
                let outs = level_inputs.div_ceil(8);
                let next_leaves = outs.div_ceil(lw);
                luts += next_leaves;
                delay += t.t_net + t.t_lut + t.t_muxf * muxf_levels(next_leaves.min(8)) as f64;
                slices += 1;
                level_inputs = next_leaves;
            }
            MuxTree {
                candidates: c,
                leaf_luts: leaves,
                combine_luts: luts - leaves,
                series_levels: slices,
                delay,
            }
        }
        Family::VersalPrime => {
            // Pure LUT tree: each combine LUT merges up to `lw` child
            // outputs; every level crosses the interconnect.
            let mut luts = leaves;
            let mut level = leaves;
            let mut levels = 1usize;
            let mut delay = t.t_lut;
            while level > 1 {
                level = level.div_ceil(lw);
                luts += level;
                levels += 1;
                delay += t.t_net + t.t_lut;
                if level == 1 {
                    break;
                }
            }
            MuxTree {
                candidates: c,
                leaf_luts: leaves,
                combine_luts: luts - leaves,
                series_levels: levels,
                delay,
            }
        }
    }
}

/// Select-decode LUTs per output (width-independent: select signals are
/// shared by all data bits of an output). 2insLUT selects are raw `ge_*`
/// signals plus one composed signal per leaf pair; 4insLUT additionally
/// spends one series function LUT per leaf (§VI-A).
pub fn select_luts(c: usize, meth: Methodology) -> usize {
    if c <= 2 {
        return 0;
    }
    let lw = leaf_width(meth);
    let leaves = c.div_ceil(lw);
    match meth {
        Methodology::TwoInsLut => leaves / 2,
        Methodology::FourInsLut => leaves / 2 + leaves,
    }
}

/// Extra select-path latency before the tree can switch (ns): the
/// 4insLUT composed select function is produced by a series LUT (§VI-A).
pub fn select_extra_delay(meth: Methodology, fpga: &FpgaDevice) -> f64 {
    match meth {
        Methodology::TwoInsLut => 0.0,
        Methodology::FourInsLut => fpga.t.t_net + fpga.t.t_lut,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::{ULTRASCALE_PLUS, VERSAL_PRIME};

    #[test]
    fn single_candidate_is_wire() {
        let m = mux_tree(1, Methodology::TwoInsLut, &ULTRASCALE_PLUS);
        assert_eq!(m.leaf_luts, 0);
        assert_eq!(m.delay, 0.0);
    }

    #[test]
    fn usplus_one_slice_up_to_16_candidates_2inslut() {
        // §VII-A: only 1 series slice for up to 16 outputs (16 candidates).
        for c in [2usize, 4, 8, 16] {
            let m = mux_tree(c, Methodology::TwoInsLut, &ULTRASCALE_PLUS);
            assert_eq!(m.series_levels, 1, "c={c}");
            assert_eq!(m.leaf_luts, c.div_ceil(2));
            assert_eq!(m.combine_luts, 0, "hard MUXF combining is free");
        }
        // 32 and 64 candidates: 2 series slices (the Fig.-11 step).
        for c in [17usize, 32, 64, 128, 256] {
            let m = mux_tree(c, Methodology::TwoInsLut, &ULTRASCALE_PLUS);
            assert_eq!(m.series_levels, 2, "c={c}");
        }
    }

    #[test]
    fn usplus_delay_steps_with_slices() {
        let d16 = mux_tree(16, Methodology::TwoInsLut, &ULTRASCALE_PLUS).delay;
        let d32 = mux_tree(32, Methodology::TwoInsLut, &ULTRASCALE_PLUS).delay;
        let d64 = mux_tree(64, Methodology::TwoInsLut, &ULTRASCALE_PLUS).delay;
        assert!(d32 > d16);
        // within the same slice count the delay is flat-ish
        assert!((d64 - d32).abs() < 0.08, "d32={d32} d64={d64}");
    }

    #[test]
    fn versal_delay_grows_per_doubling() {
        // No MUXF*: every doubling adds a series LUT level (§VII-A).
        let meth = Methodology::TwoInsLut;
        let mut prev = mux_tree(4, meth, &VERSAL_PRIME);
        for c in [8usize, 16, 32, 64] {
            let m = mux_tree(c, meth, &VERSAL_PRIME);
            assert!(m.series_levels >= prev.series_levels, "c={c}");
            assert!(m.delay > prev.delay, "c={c}");
            prev = m;
        }
    }

    #[test]
    fn versal_pays_combine_luts_usplus_does_not() {
        let u = mux_tree(16, Methodology::TwoInsLut, &ULTRASCALE_PLUS);
        let v = mux_tree(16, Methodology::TwoInsLut, &VERSAL_PRIME);
        assert_eq!(u.combine_luts, 0);
        assert!(v.combine_luts > 0);
        assert!(u.leaf_luts == v.leaf_luts);
    }

    #[test]
    fn fourinslut_denser_but_slower_path() {
        let two = mux_tree(16, Methodology::TwoInsLut, &VERSAL_PRIME);
        let four = mux_tree(16, Methodology::FourInsLut, &VERSAL_PRIME);
        assert!(four.leaf_luts < two.leaf_luts);
        assert!(
            select_extra_delay(Methodology::FourInsLut, &VERSAL_PRIME)
                > select_extra_delay(Methodology::TwoInsLut, &VERSAL_PRIME)
        );
    }
}
