//! Structural FPGA cost model: the stand-in for Vivado synthesis + static
//! timing analysis on the paper's two target products (DESIGN.md §2).
//!
//! * [`device`] — product descriptions + calibrated timing constants.
//! * [`mux`] — output multiplexer tree shapes per methodology/family.
//! * [`cost`] — delay (ns) and LUT usage for any `MergeDevice`; fit check.

pub mod cost;
pub mod device;
pub mod mux;

pub use cost::{CostModel, CostReport};
pub use device::{FpgaDevice, Methodology, ULTRASCALE_PLUS, VERSAL_PRIME};
