//! FPGA device descriptions and calibrated timing constants.
//!
//! The paper reports Vivado-2024.2 synthesis results for two products:
//! the Kintex Ultrascale+ `xcku5p-ffva676-3-e` (slices of 8 LUT6 plus
//! three hard-wired MUXF7/F8/F9 combine levels — Fig. 7) and the Versal
//! Prime `xcvm1102-sfva784-2HP-i-S` (no MUXF\* structures; LUT outputs
//! combine through extra series LUTs over the programmable interconnect).
//!
//! This environment has no Vivado, so speeds and LUT counts come from a
//! *structural cost model* (see [`super::cost`]): the constants below are
//! per-element delays calibrated ONCE against the paper's anchor numbers
//! (§EXPERIMENTS.md "Calibration") and then held fixed for every figure.
//! All curve shapes, crossovers and speedups therefore emerge from the
//! structure of the networks, not from per-figure tuning.

/// Per-device timing constants (nanoseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingParams {
    /// LUT6 logic delay (input pin → output pin).
    pub t_lut: f64,
    /// One general programmable-interconnect hop between slices.
    pub t_net: f64,
    /// One hard MUXF7/F8/F9 level inside a slice (Ultrascale+ only).
    pub t_muxf: f64,
    /// One CARRY8 block on a comparator carry chain.
    pub t_carry8: f64,
    /// Fixed input+output port overhead for a combinatorial path.
    pub t_io: f64,
}

/// FPGA slice/mux topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Kintex Ultrascale+: 8-LUT slices with hard MUXF7/F8/F9.
    UltrascalePlus,
    /// Versal Prime: no MUXF\*; LUT-tree combining via interconnect.
    VersalPrime,
}

/// A target FPGA product.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaDevice {
    pub name: &'static str,
    pub family: Family,
    /// Usable LUT count of the product.
    pub luts_available: usize,
    /// Fraction of LUTs usable before place-and-route congestion makes a
    /// combinatorial design unroutable (drives the Fig.-10 fit marks).
    pub routable_fraction: f64,
    pub t: TimingParams,
}

impl FpgaDevice {
    /// LUT budget a design must stay under to place-and-route.
    pub fn fit_budget(&self) -> usize {
        (self.luts_available as f64 * self.routable_fraction) as usize
    }
}

/// Kintex Ultrascale+ xcku5p-ffva676-3-e (speed grade -3).
///
/// 216,960 LUTs (AMD DS890/KU5P tables). Timing constants calibrated to
/// the paper's 32-bit 2insLUT anchors: Batcher 64-out ≈ 5.9 ns, LOMS
/// 2-col 64-out ≈ 2.24 ns (headline speedup 2.63×), S2MS 64-out fastest.
pub const ULTRASCALE_PLUS: FpgaDevice = FpgaDevice {
    name: "xcku5p",
    family: Family::UltrascalePlus,
    luts_available: 216_960,
    routable_fraction: 0.75,
    t: TimingParams { t_lut: 0.06, t_net: 0.24, t_muxf: 0.04, t_carry8: 0.20, t_io: 0.10 },
};

/// Versal Prime xcvm1102-sfva784-2HP-i-S.
///
/// ≈ 246,240 LUTs (VM1102 tables). Faster base LUT/interconnect than the
/// -3 Ultrascale+ (Fig. 11: Versal Batcher *faster* at 8 bit) but slower
/// wide carry chains (Fig. 12: Versal Batcher slower at 32 bit) and no
/// MUXF\* (Fig. 11: S2MS slope — every mux-tree doubling adds a series
/// slice through the interconnect).
pub const VERSAL_PRIME: FpgaDevice = FpgaDevice {
    name: "xcvm1102",
    family: Family::VersalPrime,
    luts_available: 246_240,
    routable_fraction: 0.75,
    t: TimingParams { t_lut: 0.05, t_net: 0.18, t_muxf: 0.0, t_carry8: 0.28, t_io: 0.08 },
};

/// The two products characterized by the paper.
pub const DEVICES: [FpgaDevice; 2] = [ULTRASCALE_PLUS, VERSAL_PRIME];

/// LUT-packing methodology (§VI-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Methodology {
    /// 2 data inputs + 1 select per LUT: fastest, more LUTs.
    TwoInsLut,
    /// 4 data inputs + 2 selects per LUT (one select formed by a series
    /// function LUT): densest, slower.
    FourInsLut,
}

impl Methodology {
    pub fn label(self) -> &'static str {
        match self {
            Methodology::TwoInsLut => "2insLUT",
            Methodology::FourInsLut => "4insLUT",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_budget_below_total() {
        for d in DEVICES {
            assert!(d.fit_budget() < d.luts_available);
            assert!(d.fit_budget() > d.luts_available / 2);
        }
    }

    #[test]
    fn versal_has_no_hard_mux() {
        assert_eq!(VERSAL_PRIME.t.t_muxf, 0.0);
        assert_eq!(VERSAL_PRIME.family, Family::VersalPrime);
    }

    #[test]
    fn device_relationships_behind_figs_11_12() {
        // Versal: faster base logic, slower carry (drives the 8-bit vs
        // 32-bit Batcher crossover between the two devices).
        assert!(VERSAL_PRIME.t.t_lut < ULTRASCALE_PLUS.t.t_lut);
        assert!(VERSAL_PRIME.t.t_net < ULTRASCALE_PLUS.t.t_net);
        assert!(VERSAL_PRIME.t.t_carry8 > ULTRASCALE_PLUS.t.t_carry8);
    }
}
