//! The structural cost model: combinatorial propagation delay (ns) and
//! LUT usage for any [`MergeDevice`] on a target FPGA under a packing
//! methodology — the substitute for Vivado synthesis + STA (DESIGN.md §2).
//!
//! Per block:
//! * comparator bank — LUT + CARRY8 chains (width-dependent: the 8-bit vs
//!   32-bit separation in Figs. 11/12/18/19),
//! * select / rank decode — LUT levels in front of the output muxes,
//! * output mux trees — [`super::mux`].
//!
//! Per stage: the slowest block; stages are separated by an interconnect
//! hop. A device adds one fixed I/O overhead.

use super::device::{FpgaDevice, Methodology};
use super::mux::{mux_tree, select_extra_delay, select_luts};
use crate::sortnet::network::{Block, MergeDevice};
use crate::sortnet::s2ms::output_candidates;

/// Cost-model context: device × methodology × value width (bits).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub fpga: FpgaDevice,
    pub meth: Methodology,
    pub width: usize,
}

/// Delay + LUT summary for one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostReport {
    pub delay_ns: f64,
    pub luts: usize,
    /// Whether the design fits the device's routable LUT budget
    /// (the Fig.-10 diagonal marks).
    pub fits: bool,
    pub stages: usize,
}

impl CostModel {
    pub fn new(fpga: FpgaDevice, meth: Methodology, width: usize) -> Self {
        CostModel { fpga, meth, width }
    }

    /// W-bit unsigned comparator (`ge`) on a CARRY8 chain: 2 bits per
    /// LUT, 8 LUTs per CARRY8 block.
    pub fn comparator_delay(&self) -> f64 {
        let t = &self.fpga.t;
        let lut_stages = self.width.div_ceil(2);
        let chains = lut_stages.div_ceil(8);
        t.t_lut + chains as f64 * t.t_carry8
    }

    pub fn comparator_luts(&self) -> usize {
        self.width.div_ceil(2)
    }

    /// Rank-decode LUT levels for a single-stage N-sorter: each output's
    /// one-hot select is a function of the N-1 comparison bits of a
    /// candidate — one LUT6 level while N-1 ≤ 6, two beyond.
    fn decode_levels(&self, n: usize) -> usize {
        if n <= 1 {
            0
        } else if n - 1 <= 6 {
            1
        } else {
            2
        }
    }

    /// Delay of one block (input ports of the block's first LUTs → block
    /// output), selects included.
    pub fn block_delay(&self, b: &Block) -> f64 {
        let t = &self.fpga.t;
        match b {
            Block::Cas { .. } => {
                // comparator -> ge routes to the W output mux LUTs.
                self.comparator_delay() + t.t_net + t.t_lut
            }
            Block::MergeS2 { up, dn, .. } => {
                let (m, n) = (up.len(), dn.len());
                if m == 0 || n == 0 {
                    return 0.0; // wire-through (already sorted run)
                }
                let cmax = (0..m + n).map(|t_| output_candidates(m, n, t_)).max().unwrap_or(1);
                self.comparator_delay()
                    + select_extra_delay(self.meth, &self.fpga)
                    + t.t_net
                    + mux_tree(cmax, self.meth, &self.fpga).delay
            }
            Block::SortN { pos } | Block::FilterN { pos, .. } => {
                let n = pos.len();
                if n <= 1 {
                    return 0.0;
                }
                if n == 2 {
                    return self.comparator_delay() + t.t_net + t.t_lut;
                }
                let decode = self.decode_levels(n) as f64 * (t.t_lut + t.t_net);
                self.comparator_delay()
                    + t.t_net
                    + decode
                    + select_extra_delay(self.meth, &self.fpga)
                    + mux_tree(n, self.meth, &self.fpga).delay
            }
        }
    }

    /// LUTs of one block.
    pub fn block_luts(&self, b: &Block) -> usize {
        let w = self.width;
        match b {
            Block::Cas { .. } => self.comparator_luts() + w,
            Block::MergeS2 { up, dn, .. } => {
                let (m, n) = (up.len(), dn.len());
                if m == 0 || n == 0 {
                    return 0;
                }
                let cmp = m * n * self.comparator_luts();
                let mut mux = 0usize;
                let mut sel = 0usize;
                for t_ in 0..m + n {
                    let c = output_candidates(m, n, t_);
                    let tree = mux_tree(c, self.meth, &self.fpga);
                    mux += (tree.leaf_luts + tree.combine_luts) * w;
                    sel += select_luts(c, self.meth);
                }
                cmp + mux + sel
            }
            Block::SortN { pos } => self.nsorter_luts(pos.len(), pos.len()),
            Block::FilterN { pos, taps } => self.nsorter_luts(pos.len(), taps.len()),
        }
    }

    /// N-sorter with `built` physical outputs (N for a sorter, fewer for
    /// an N-filter).
    fn nsorter_luts(&self, n: usize, built: usize) -> usize {
        let w = self.width;
        if n <= 1 {
            return 0;
        }
        if n == 2 {
            return self.comparator_luts() + w;
        }
        let cmp = n * (n - 1) / 2 * self.comparator_luts();
        let tree = mux_tree(n, self.meth, &self.fpga);
        let mux = built * (tree.leaf_luts + tree.combine_luts) * w;
        // one-hot decode: one LUT per (candidate, built output) per level.
        let decode = built * n * self.decode_levels(n);
        cmp + mux + decode + built * select_luts(n, self.meth)
    }

    /// Full-device propagation delay: I/O overhead + per-stage critical
    /// paths + inter-stage routing.
    pub fn delay_ns(&self, d: &MergeDevice) -> f64 {
        let t = &self.fpga.t;
        let mut total = t.t_io;
        let mut real_stages = 0usize;
        for s in &d.stages {
            let worst = s.blocks.iter().map(|b| self.block_delay(b)).fold(0.0f64, f64::max);
            if worst > 0.0 {
                if real_stages > 0 {
                    total += t.t_net;
                }
                total += worst;
                real_stages += 1;
            }
        }
        total
    }

    /// Full-device LUT usage.
    pub fn luts(&self, d: &MergeDevice) -> usize {
        d.stages.iter().flat_map(|s| &s.blocks).map(|b| self.block_luts(b)).sum()
    }

    /// Delay of the device's median path (stages up to the tap).
    pub fn median_delay_ns(&self, d: &MergeDevice) -> Option<f64> {
        let (stop, _) = d.median_tap?;
        let t = &self.fpga.t;
        let mut total = t.t_io;
        let mut real_stages = 0usize;
        for s in d.stages.iter().take(stop) {
            let worst = s.blocks.iter().map(|b| self.block_delay(b)).fold(0.0f64, f64::max);
            if worst > 0.0 {
                if real_stages > 0 {
                    total += t.t_net;
                }
                total += worst;
                real_stages += 1;
            }
        }
        Some(total)
    }

    /// Full cost report.
    pub fn report(&self, d: &MergeDevice) -> CostReport {
        let luts = self.luts(d);
        CostReport {
            delay_ns: self.delay_ns(d),
            luts,
            fits: luts <= self.fpga.fit_budget(),
            stages: d.depth(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::device::{ULTRASCALE_PLUS, VERSAL_PRIME};
    use crate::sortnet::{batcher, loms, s2ms};

    fn us2(width: usize) -> CostModel {
        CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, width)
    }

    #[test]
    fn comparator_width_scaling() {
        let c8 = us2(8).comparator_delay();
        let c32 = us2(32).comparator_delay();
        assert!(c32 > c8, "wider compare is slower");
        assert_eq!(us2(8).comparator_luts(), 4);
        assert_eq!(us2(32).comparator_luts(), 16);
    }

    #[test]
    fn batcher_delay_scales_with_stages() {
        let m = us2(32);
        let d16 = m.delay_ns(&batcher::odd_even_merge(8)); // 4 stages
        let d64 = m.delay_ns(&batcher::odd_even_merge(32)); // 6 stages
        assert!(d64 > d16);
        let per_stage = (d64 - d16) / 2.0;
        assert!(per_stage > 0.5 && per_stage < 1.5, "per stage {per_stage}");
    }

    #[test]
    fn s2ms_faster_than_batcher_same_size() {
        // The S2MS headline: single stage beats the log-depth cascade.
        for outs in [8usize, 16, 32, 64] {
            let m = us2(32);
            let s = m.delay_ns(&s2ms::s2ms(outs / 2, outs / 2));
            let b = m.delay_ns(&batcher::odd_even_merge(outs / 2));
            assert!(s < b, "{outs} outputs: s2ms {s} vs batcher {b}");
        }
    }

    #[test]
    fn loms_between_s2ms_and_batcher() {
        let m = us2(32);
        for outs in [32usize, 64] {
            let s = m.delay_ns(&s2ms::s2ms(outs / 2, outs / 2));
            let l = m.delay_ns(&loms::loms_2way(outs / 2, outs / 2, 2));
            let b = m.delay_ns(&batcher::odd_even_merge(outs / 2));
            assert!(s < l && l < b, "{outs}: s2ms {s} loms {l} batcher {b}");
        }
    }

    #[test]
    fn s2ms_uses_most_luts_batcher_fewest() {
        let m = us2(32);
        for outs in [16usize, 32, 64] {
            let s = m.luts(&s2ms::s2ms(outs / 2, outs / 2));
            let l = m.luts(&loms::loms_2way(outs / 2, outs / 2, 2));
            let b = m.luts(&batcher::odd_even_merge(outs / 2));
            assert!(b < l && l < s, "{outs}: batcher {b} loms {l} s2ms {s}");
        }
    }

    #[test]
    fn oem_and_bitonic_same_delay_different_luts() {
        // §VII-A: identical propagation delay per FPGA; OEMS uses fewer
        // comparators hence fewer LUTs.
        let m = us2(32);
        let oem = batcher::odd_even_merge(16);
        let bit = batcher::bitonic_merge(16);
        assert!((m.delay_ns(&oem) - m.delay_ns(&bit)).abs() < 1e-9);
        assert!(m.luts(&oem) < m.luts(&bit));
    }

    #[test]
    fn versal_32bit_slower_than_usplus_for_batcher() {
        // Fig. 12 (32-bit): Versal Batcher slower; Fig. 11 (8-bit): faster.
        let d = batcher::odd_even_merge(16);
        let us8 = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 8).delay_ns(&d);
        let v8 = CostModel::new(VERSAL_PRIME, Methodology::TwoInsLut, 8).delay_ns(&d);
        let us32 = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32).delay_ns(&d);
        let v32 = CostModel::new(VERSAL_PRIME, Methodology::TwoInsLut, 32).delay_ns(&d);
        assert!(v8 < us8, "8-bit: versal {v8} vs us+ {us8}");
        assert!(v32 > us32, "32-bit: versal {v32} vs us+ {us32}");
    }

    #[test]
    fn fourinslut_denser_slower() {
        // Denser on both devices. The speed penalty the paper emphasises
        // (§VI-A) is on Ultrascale+, where the hard MUXF levels make the
        // 2insLUT tree combine essentially free; on Versal the wider
        // branching of 4insLUT can actually shorten the LUT tree, so no
        // cross-methodology delay ordering is asserted there.
        for fpga in [ULTRASCALE_PLUS, VERSAL_PRIME] {
            let two = CostModel::new(fpga, Methodology::TwoInsLut, 32);
            let four = CostModel::new(fpga, Methodology::FourInsLut, 32);
            let d = s2ms::s2ms(8, 8);
            assert!(four.luts(&d) < two.luts(&d), "{}", fpga.name);
        }
        let two = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
        let four = CostModel::new(ULTRASCALE_PLUS, Methodology::FourInsLut, 32);
        let d = s2ms::s2ms(8, 8);
        assert!(four.delay_ns(&d) > two.delay_ns(&d));
    }

    #[test]
    fn fit_boundary_matches_fig10() {
        // §VII-C: the 64-output S2MS was the largest that fit the xcku5p;
        // 128-output does not fit, but the 128-output 2-col LOMS does.
        let m = us2(32);
        assert!(m.report(&s2ms::s2ms(32, 32)).fits, "64-out S2MS must fit");
        assert!(!m.report(&s2ms::s2ms(64, 64)).fits, "128-out S2MS must not fit");
        assert!(m.report(&loms::loms_2way(64, 64, 2)).fits, "128-out LOMS 2col must fit");
        assert!(m.report(&loms::loms_2way(128, 128, 8)).fits, "256-out LOMS 8col must fit");
    }

    #[test]
    fn paper_anchor_numbers() {
        // Headline anchors (abstract + §VII): with the frozen calibration
        // the model must stay near the paper's numbers. Tolerances are
        // deliberately loose — the constants are calibrated once, and the
        // claim is curve *shape*, not ps-exact STA.
        let m = us2(32);
        let batcher = m.delay_ns(&batcher::odd_even_merge(32));
        let loms = m.delay_ns(&loms::loms_2way(32, 32, 2));
        let speedup = batcher / loms;
        assert!((loms - 2.24).abs() / 2.24 < 0.10, "LOMS 64-out {loms} vs paper 2.24");
        assert!((speedup - 2.63).abs() / 2.63 < 0.15, "speedup {speedup} vs paper 2.63");
        // 3-way full merge: paper 3.4 ns.
        let l3 = m.delay_ns(&loms::loms_kway(&[7, 7, 7]));
        assert!((l3 - 3.4).abs() / 3.4 < 0.15, "3c_7r {l3} vs paper 3.4");
    }

    #[test]
    fn versal_s2ms_slower_than_usplus_s2ms() {
        // §VII-A: the hard MUXF* path makes Ultrascale+ S2MS both faster
        // and smaller than Versal S2MS.
        for w in [8usize, 32] {
            for outs in [8usize, 16, 32, 64] {
                let d = s2ms::s2ms(outs / 2, outs / 2);
                let us = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, w);
                let v = CostModel::new(VERSAL_PRIME, Methodology::TwoInsLut, w);
                assert!(v.delay_ns(&d) > us.delay_ns(&d), "w={w} outs={outs}");
                assert!(v.luts(&d) > us.luts(&d), "w={w} outs={outs}");
            }
        }
    }

    #[test]
    fn batcher_luts_equal_across_devices() {
        // Fig. 13: Batcher LUT usage identical on both FPGAs (no mux trees).
        let d = batcher::odd_even_merge(16);
        let us = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
        let v = CostModel::new(VERSAL_PRIME, Methodology::TwoInsLut, 32);
        assert_eq!(us.luts(&d), v.luts(&d));
    }

    #[test]
    fn median_path_shorter_than_full() {
        let m = us2(32);
        let d = loms::loms_kway(&[7, 7, 7]);
        let med = m.median_delay_ns(&d).unwrap();
        assert!(med < m.delay_ns(&d));
    }
}
// (appended by the coverage pass)
#[cfg(test)]
mod more_tests {
    use super::*;
    use crate::fpga::device::ULTRASCALE_PLUS;
    use crate::sortnet::{loms, mwms, prune};

    #[test]
    fn median_devices_use_fewer_luts_than_full() {
        // §VII-D: "the median sorters use fewer LUTs" (no figure shown).
        let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
        assert!(m.luts(&loms::loms_3way_median(7)) < m.luts(&loms::loms_kway(&[7, 7, 7])));
        assert!(
            m.luts(&mwms::mwms_3way_median_cost_proxy(7)) < m.luts(&mwms::mwms_3way_cost_proxy(7))
        );
    }

    #[test]
    fn wider_values_cost_more_in_both_axes() {
        let d = loms::loms_2way(16, 16, 2);
        for fpga in crate::fpga::device::DEVICES {
            let m8 = CostModel::new(fpga, Methodology::TwoInsLut, 8);
            let m32 = CostModel::new(fpga, Methodology::TwoInsLut, 32);
            assert!(m32.delay_ns(&d) > m8.delay_ns(&d), "{}", fpga.name);
            assert!(m32.luts(&d) > m8.luts(&d), "{}", fpga.name);
        }
    }

    #[test]
    fn pruning_reduces_luts_never_delay_structure() {
        let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
        let d = mwms::mwms_3way(7);
        let (p, _) = prune::prune(&d).unwrap();
        assert!(m.luts(&p) < m.luts(&d));
        // Pruned stages never get slower (filters share the sorter path).
        assert!(m.delay_ns(&p) <= m.delay_ns(&d) + 1e-9);
    }

    #[test]
    fn loms_multi_column_trade_matches_paper() {
        // §IV: more columns → smaller column sorters (faster stage 1)
        // but wider row sorters (slower stage 2); at 256 outputs the
        // 8-col device is the only one that fits, and delay grows mildly
        // with column count at fixed size.
        let m = CostModel::new(ULTRASCALE_PLUS, Methodology::TwoInsLut, 32);
        let d2 = loms::loms_2way(32, 32, 2);
        let d8 = loms::loms_2way(32, 32, 8);
        assert!(m.luts(&d8) < m.luts(&d2), "8col {} vs 2col {}", m.luts(&d8), m.luts(&d2));
        assert!(m.delay_ns(&d8) > m.delay_ns(&d2));
    }
}
