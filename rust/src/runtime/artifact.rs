//! Artifact manifest: the contract between `python/compile/aot.py`
//! (which writes `artifacts/manifest.json` + one HLO text file per
//! compiled merge variant) and the Rust runtime (which loads them).

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Metadata of one AOT-compiled merge executable.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Shared artifact name: every `MergeResponse` carries it, so it is
    /// an `Arc<str>` the service clones by refcount instead of
    /// allocating a `String` per request at batch fan-out.
    pub name: Arc<str>,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Sorted input list sizes (k lists).
    pub list_sizes: Vec<usize>,
    /// Compiled batch size (rows per execution).
    pub batch: usize,
    /// Total output width per row.
    pub total: usize,
    /// Pallas batch block size (documentation/perf metadata).
    pub block_b: usize,
    /// Vector-op depth of the compiled plan (TPU stage-count analogue).
    pub plan_steps: usize,
    /// Hardware stage count of the underlying device.
    pub hw_stages: usize,
    /// Source device name (netgen).
    pub device: String,
}

impl ArtifactMeta {
    /// `Some(r)` when this artifact is a square 2-way merger (`r + r`
    /// lists) — the shape the streaming engine's block kernel mirrors.
    /// `loms sort` uses it to pick a block size R that matches a
    /// compiled artifact instead of hard-coding one.
    pub fn square_2way(&self) -> Option<usize> {
        match self.list_sizes[..] {
            [a, b] if a == b => Some(a),
            _ => None,
        }
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                a.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("artifact missing {k}"))
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?.into(),
                file: get_str("file")?,
                list_sizes: a
                    .get_usizes("list_sizes")
                    .ok_or_else(|| anyhow!("artifact missing list_sizes"))?,
                batch: get_usize("batch")?,
                total: get_usize("total")?,
                block_b: get_usize("block_b").unwrap_or(1),
                plan_steps: get_usize("plan_steps").unwrap_or(0),
                hw_stages: get_usize("hw_stages").unwrap_or(0),
                device: get_str("device").unwrap_or_default(),
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| &*a.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("loms_manifest_test");
        write_manifest(
            &dir,
            r#"{"artifacts": [{"name": "m1", "file": "m1.hlo.txt",
                "list_sizes": [32, 32], "batch": 64, "total": 64,
                "block_b": 32, "plan_steps": 2, "hw_stages": 2,
                "device": "loms2", "dtype": "u32"}]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("m1").unwrap();
        assert_eq!(a.list_sizes, vec![32, 32]);
        assert_eq!(a.batch, 64);
        assert!(m.hlo_path(a).ends_with("m1.hlo.txt"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn square_2way_detection() {
        let mut a = ArtifactMeta {
            name: "x".into(),
            file: String::new(),
            list_sizes: vec![32, 32],
            batch: 1,
            total: 64,
            block_b: 1,
            plan_steps: 0,
            hw_stages: 0,
            device: String::new(),
        };
        assert_eq!(a.square_2way(), Some(32));
        a.list_sizes = vec![32, 16];
        assert_eq!(a.square_2way(), None);
        a.list_sizes = vec![7, 7, 7];
        assert_eq!(a.square_2way(), None);
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load("/nonexistent/loms").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration: the repo's own artifacts (skipped when not built).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{}", a.name);
            assert_eq!(a.total, a.list_sizes.iter().sum::<usize>());
        }
    }
}
