//! PJRT execution of AOT-compiled merge artifacts.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per artifact;
//! Python is never on this path.

use super::artifact::{ArtifactMeta, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Per-executable execution statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub rows_merged: u64,
    pub total_exec_ns: u128,
}

/// A compiled merge executable plus its metadata.
pub struct MergeExecutable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    stats: ExecStats,
}

impl MergeExecutable {
    /// Execute one full batch. `lists[l]` is row-major `(batch,
    /// list_sizes[l])` flattened; returns row-major `(batch, total)`.
    pub fn execute_batch(&mut self, lists: &[Vec<u32>]) -> Result<Vec<u32>> {
        let meta = &self.meta;
        anyhow::ensure!(lists.len() == meta.list_sizes.len(), "{}: wrong list count", meta.name);
        let mut literals = Vec::with_capacity(lists.len());
        for (l, flat) in lists.iter().enumerate() {
            let rows = meta.batch;
            let cols = meta.list_sizes[l];
            anyhow::ensure!(
                flat.len() == rows * cols,
                "{}: list {l} has {} values, want {rows}x{cols}",
                meta.name,
                flat.len()
            );
            literals.push(
                xla::Literal::vec1(flat)
                    .reshape(&[rows as i64, cols as i64])
                    .with_context(|| format!("{}: reshaping input {l}", meta.name))?,
            );
        }
        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("{}: execute", meta.name))?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let values = out.to_vec::<u32>()?;
        self.stats.executions += 1;
        self.stats.rows_merged += meta.batch as u64;
        self.stats.total_exec_ns += t0.elapsed().as_nanos();
        anyhow::ensure!(
            values.len() == meta.batch * meta.total,
            "{}: output size {} want {}",
            meta.name,
            values.len(),
            meta.batch * meta.total
        );
        Ok(values)
    }

    pub fn stats(&self) -> ExecStats {
        self.stats
    }
}

/// The runtime: a PJRT CPU client with every manifest artifact compiled.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<Arc<str>, MergeExecutable>,
}

impl Runtime {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        let mut executables = HashMap::new();
        for meta in &manifest.artifacts {
            let path = manifest.hlo_path(meta);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("{}: parsing HLO text: {e}", meta.name))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("{}: compile: {e}", meta.name))?;
            executables
                .insert(meta.name.clone(), MergeExecutable { meta: meta.clone(), exe, stats: ExecStats::default() });
        }
        Ok(Runtime { manifest, client, executables })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn executable_mut(&mut self, name: &str) -> Result<&mut MergeExecutable> {
        self.executables
            .get_mut(name)
            .ok_or_else(|| anyhow!("no executable named {name:?}"))
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.executables.keys().map(|k| k.to_string()).collect();
        v.sort();
        v
    }

    pub fn stats(&self) -> Vec<(String, ExecStats)> {
        let mut v: Vec<(String, ExecStats)> =
            self.executables.iter().map(|(k, e)| (k.to_string(), e.stats)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}
