//! Runtime layer: load AOT artifacts (HLO text emitted by
//! `python/compile/aot.py`) and execute them on the PJRT CPU client.
//! The `loms` binary is self-contained once `make artifacts` has run —
//! Python never executes on the request path.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactMeta, Manifest};
pub use client::{ExecStats, MergeExecutable, Runtime};
