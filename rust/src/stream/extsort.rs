//! External sorting in bounded memory: run formation + spill + a
//! streaming k-way merge through the LOMS tile kernels.
//!
//! Phase 1 chunks the input into `run_len`-key runs and sorts each —
//! either directly ([`RunFormer::Std`]) or through the merge-network
//! ladder of a running [`MergeService`] ([`RunFormer::Ladder`], the
//! planner's batch sorters). Runs live in memory or spill to a file of
//! little-endian `u32` keys. Phase 2 repeatedly merges groups of at
//! most `max_fanin` runs through [`MergeTree`] — each pass streams run
//! to run, never holding more than O(`max_fanin`·R) keys — until at
//! most `max_fanin` runs remain. Phase 3 streams the final k-way merge
//! to the caller (a `Vec` or an output file).
//!
//! With spilling enabled the resident set is O(`run_len` +
//! `max_fanin`·R) keys however large the input — the bounded-memory
//! story the fixed-width merge devices themselves cannot provide.

use super::merge2::BlockKernel;
use super::source::{boxed, FileRunStream, SliceStream, SortedStream};
use super::tree::{MergeTree, DEFAULT_R};
use crate::coordinator::{planner, MergeService};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Keys pulled from the merge tree per drain step.
const DRAIN: usize = 4096;

/// External-sort tuning.
#[derive(Debug, Clone)]
pub struct ExtSortConfig {
    /// Phase-1 run length in keys.
    pub run_len: usize,
    /// Merge-tree block size R (the `loms2` R+R kernel shape).
    pub r: usize,
    /// Maximum runs merged per tree (≥ 2); more runs ⇒ extra passes.
    pub max_fanin: usize,
    /// Spill runs to files under this directory; `None` keeps runs in
    /// memory (merge passes still stream block by block).
    pub spill_dir: Option<PathBuf>,
}

impl Default for ExtSortConfig {
    fn default() -> Self {
        ExtSortConfig { run_len: 1 << 16, r: DEFAULT_R, max_fanin: 64, spill_dir: None }
    }
}

impl ExtSortConfig {
    /// Shape checks plus the one kernel compile every tree of this sort
    /// will share (`r` is validated by the compile itself).
    fn validate(&self) -> Result<BlockKernel> {
        anyhow::ensure!(self.run_len >= 1, "run_len must be >= 1");
        anyhow::ensure!(self.max_fanin >= 2, "max_fanin must be >= 2");
        BlockKernel::new(self.r)
    }
}

/// External-sort accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExtSortStats {
    pub keys: usize,
    /// Phase-1 runs formed.
    pub runs: usize,
    /// Intermediate merge passes (0 when `runs ≤ max_fanin`).
    pub merge_passes: usize,
    /// Runs written to spill files (phase 1 + intermediate passes).
    pub spilled_runs: usize,
    /// Bytes written to spill files.
    pub spill_bytes: u64,
}

/// How phase 1 sorts each run.
pub enum RunFormer<'a> {
    /// `sort_unstable` per run — handles the full `u32` domain.
    Std,
    /// The merge-network ladder through a running service (the
    /// planner's batch sorters: chunk, merge level by level, stream the
    /// survivors). Inherits the service's key-domain contract (real
    /// keys < `u32::MAX`).
    Ladder { service: &'a MergeService, chunk: usize, max_network: usize },
}

fn sort_run(former: &RunFormer<'_>, keys: &[u32]) -> Result<Vec<u32>> {
    match former {
        RunFormer::Std => {
            let mut v = keys.to_vec();
            v.sort_unstable();
            Ok(v)
        }
        RunFormer::Ladder { service, chunk, max_network } => {
            Ok(planner::external_sort(service, keys, *chunk, *max_network)?.0)
        }
    }
}

/// LE-encode `keys` into the reusable `bytes` buffer.
fn encode_keys(keys: &[u32], bytes: &mut Vec<u8>) {
    bytes.clear();
    bytes.reserve(keys.len() * 4);
    for &k in keys {
        bytes.extend_from_slice(&k.to_le_bytes());
    }
}

/// Monotonic spill-file id — unique across concurrent sorts in one
/// process; the pid keeps parallel processes apart.
fn next_spill_path(dir: &Path) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("loms-spill-{}-{id}.u32", std::process::id()))
}

/// Append-only writer for a spill file of back-to-back sorted runs.
struct SpillWriter {
    w: BufWriter<File>,
    path: PathBuf,
    runs: Vec<(u64, u64)>,
    /// Keys written so far.
    pos: u64,
    /// Start of the open run, if any.
    cur: Option<u64>,
    /// Reusable LE-encoding buffer — one `write_all` per chunk, not per
    /// key (this sits on the disk hot path of every pass).
    bytes: Vec<u8>,
}

impl SpillWriter {
    fn create(path: PathBuf) -> Result<SpillWriter> {
        let f = File::create(&path)
            .with_context(|| format!("creating spill file {}", path.display()))?;
        Ok(SpillWriter {
            w: BufWriter::new(f),
            path,
            runs: Vec::new(),
            pos: 0,
            cur: None,
            bytes: Vec::new(),
        })
    }

    fn begin_run(&mut self) {
        debug_assert!(self.cur.is_none());
        self.cur = Some(self.pos);
    }

    fn write_keys(&mut self, keys: &[u32]) -> Result<()> {
        encode_keys(keys, &mut self.bytes);
        self.w.write_all(&self.bytes)?;
        self.pos += keys.len() as u64;
        Ok(())
    }

    fn end_run(&mut self) {
        let start = self.cur.take().expect("end_run without begin_run");
        self.runs.push((start, self.pos - start));
    }

    fn push_run(&mut self, keys: &[u32]) -> Result<()> {
        self.begin_run();
        self.write_keys(keys)?;
        self.end_run();
        Ok(())
    }

    fn finish(mut self) -> Result<(PathBuf, Vec<(u64, u64)>)> {
        self.w.flush()?;
        Ok((self.path, self.runs))
    }
}

/// Where the current generation of runs lives.
enum RunStore {
    Mem(Vec<Vec<u32>>),
    File { path: PathBuf, runs: Vec<(u64, u64)> },
}

impl RunStore {
    fn count(&self) -> usize {
        match self {
            RunStore::Mem(runs) => runs.len(),
            RunStore::File { runs, .. } => runs.len(),
        }
    }

    /// Open streams over runs `[lo, hi)`.
    fn open(&self, lo: usize, hi: usize) -> Result<Vec<Box<dyn SortedStream + '_>>> {
        match self {
            RunStore::Mem(runs) => {
                Ok(runs[lo..hi].iter().map(|r| boxed(SliceStream::new(r))).collect())
            }
            RunStore::File { path, runs } => runs[lo..hi]
                .iter()
                .map(|&(start, len)| Ok(boxed(FileRunStream::open(path, start, len)?)))
                .collect(),
        }
    }

    fn cleanup(self) {
        if let RunStore::File { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Drain a tree into `out`, handing the shared kernel back for the
/// next tree.
fn drain_to_vec(mut tree: MergeTree<'_>, out: &mut Vec<u32>) -> Result<BlockKernel> {
    while tree.next_chunk(DRAIN, out)? > 0 {}
    Ok(tree.into_kernel())
}

/// One intermediate pass: merge groups of `max_fanin` runs into the
/// next generation (memory→memory or spill→spill), then drop the old
/// generation. The kernel threads through every tree of the pass.
fn merge_pass(
    store: RunStore,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    mut kernel: BlockKernel,
) -> Result<(RunStore, BlockKernel)> {
    let count = store.count();
    let next = match &store {
        RunStore::Mem(_) => {
            let mut runs = Vec::with_capacity(count.div_ceil(cfg.max_fanin));
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut run = Vec::new();
                let tree = MergeTree::with_kernel(store.open(lo, hi)?, kernel);
                kernel = drain_to_vec(tree, &mut run)?;
                runs.push(run);
                lo = hi;
            }
            RunStore::Mem(runs)
        }
        RunStore::File { path, .. } => {
            let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
            let mut w = SpillWriter::create(next_spill_path(&dir))?;
            let mut chunk = Vec::with_capacity(DRAIN);
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut tree = MergeTree::with_kernel(store.open(lo, hi)?, kernel);
                w.begin_run();
                loop {
                    chunk.clear();
                    if tree.next_chunk(DRAIN, &mut chunk)? == 0 {
                        break;
                    }
                    w.write_keys(&chunk)?;
                }
                w.end_run();
                kernel = tree.into_kernel();
                lo = hi;
            }
            let (path, runs) = w.finish()?;
            stats.spilled_runs += runs.len();
            stats.spill_bytes += runs.iter().map(|&(_, len)| len * 4).sum::<u64>();
            RunStore::File { path, runs }
        }
    };
    store.cleanup();
    Ok((next, kernel))
}

/// Sort `data` with default run formation (`sort_unstable` per run).
pub fn extsort(data: &[u32], cfg: &ExtSortConfig) -> Result<(Vec<u32>, ExtSortStats)> {
    extsort_with(data, cfg, &RunFormer::Std)
}

/// Sort `data`: form runs with `former`, optionally spill them, merge
/// pass by pass, stream the final k-way merge into a `Vec`.
pub fn extsort_with(
    data: &[u32],
    cfg: &ExtSortConfig,
    former: &RunFormer<'_>,
) -> Result<(Vec<u32>, ExtSortStats)> {
    let mut kernel = cfg.validate()?;
    let mut stats = ExtSortStats { keys: data.len(), ..Default::default() };
    if data.is_empty() {
        return Ok((Vec::new(), stats));
    }
    let mut store = match &cfg.spill_dir {
        None => {
            let runs: Vec<Vec<u32>> = data
                .chunks(cfg.run_len)
                .map(|c| sort_run(former, c))
                .collect::<Result<_>>()?;
            RunStore::Mem(runs)
        }
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spill dir {}", dir.display()))?;
            let mut w = SpillWriter::create(next_spill_path(dir))?;
            for c in data.chunks(cfg.run_len) {
                w.push_run(&sort_run(former, c)?)?;
            }
            let (path, runs) = w.finish()?;
            stats.spilled_runs += runs.len();
            stats.spill_bytes += 4 * data.len() as u64;
            RunStore::File { path, runs }
        }
    };
    stats.runs = store.count();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass(store, cfg, &mut stats, kernel)?;
        stats.merge_passes += 1;
    }
    let mut out = Vec::with_capacity(data.len());
    drain_to_vec(MergeTree::with_kernel(store.open(0, store.count())?, kernel), &mut out)?;
    store.cleanup();
    Ok((out, stats))
}

/// Sort a file of little-endian `u32` keys into `output`, never holding
/// more than O(`run_len` + `max_fanin`·R) keys in memory. Runs spill
/// under `cfg.spill_dir` (defaulting to `output`'s directory). Backs
/// the `loms sort --input/--output` CLI path.
pub fn extsort_file(input: &Path, output: &Path, cfg: &ExtSortConfig) -> Result<ExtSortStats> {
    let mut kernel = cfg.validate()?;
    let bytes = std::fs::metadata(input)
        .with_context(|| format!("stat {}", input.display()))?
        .len();
    anyhow::ensure!(bytes % 4 == 0, "{}: not a whole number of u32 keys", input.display());
    let total = bytes / 4;
    let mut stats = ExtSortStats { keys: total as usize, ..Default::default() };
    let dir = cfg
        .spill_dir
        .clone()
        .or_else(|| output.parent().map(Path::to_path_buf).filter(|p| !p.as_os_str().is_empty()))
        .unwrap_or_else(|| PathBuf::from("."));
    std::fs::create_dir_all(&dir).with_context(|| format!("creating spill dir {}", dir.display()))?;
    // Phase 1: read run_len-key windows, sort, spill.
    let mut store = {
        let mut rd = BufReader::new(
            File::open(input).with_context(|| format!("opening {}", input.display()))?,
        );
        let mut w = SpillWriter::create(next_spill_path(&dir))?;
        let mut buf = vec![0u8; cfg.run_len * 4];
        let mut remaining = total;
        while remaining > 0 {
            let n = (cfg.run_len as u64).min(remaining) as usize;
            rd.read_exact(&mut buf[..n * 4]).context("reading input keys")?;
            let mut run: Vec<u32> = buf[..n * 4]
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            run.sort_unstable();
            w.push_run(&run)?;
            remaining -= n as u64;
        }
        let (path, runs) = w.finish()?;
        stats.spilled_runs += runs.len();
        stats.spill_bytes += bytes;
        RunStore::File { path, runs }
    };
    stats.runs = store.count();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass(store, cfg, &mut stats, kernel)?;
        stats.merge_passes += 1;
    }
    // Phase 3: stream the final merge straight into the output file.
    {
        let mut w = BufWriter::new(
            File::create(output).with_context(|| format!("creating {}", output.display()))?,
        );
        let mut tree = MergeTree::with_kernel(store.open(0, store.count())?, kernel);
        let mut chunk = Vec::with_capacity(DRAIN);
        let mut out_bytes = Vec::new();
        loop {
            chunk.clear();
            if tree.next_chunk(DRAIN, &mut chunk)? == 0 {
                break;
            }
            encode_keys(&chunk, &mut out_bytes);
            w.write_all(&out_bytes)?;
        }
        w.flush()?;
    }
    store.cleanup();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("loms_extsort_{tag}_{}", std::process::id()))
    }

    #[test]
    fn in_memory_sort_matches_std() {
        let mut rng = Rng::new(0xE5);
        let data: Vec<u32> = (0..10_000).map(|_| rng.next_u32()).collect();
        let cfg = ExtSortConfig { run_len: 700, r: 8, ..Default::default() };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(stats.runs, 10_000usize.div_ceil(700));
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.spilled_runs, 0);
    }

    #[test]
    fn multi_pass_spill_sort_matches_std() {
        let dir = tmp_dir("multipass");
        let mut rng = Rng::new(0x5111);
        // Full-domain keys, u32::MAX included (Std former).
        let mut data: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
        data.extend([u32::MAX, u32::MAX - 1, u32::MAX]);
        let cfg = ExtSortConfig {
            run_len: 512,
            r: 8,
            max_fanin: 3,
            spill_dir: Some(dir.clone()),
        };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(stats.merge_passes >= 2, "fanin 3 over {} runs: {stats:?}", stats.runs);
        assert!(stats.spilled_runs > stats.runs, "intermediate runs spilled too");
        assert!(stats.spill_bytes > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_to_file_round_trip() {
        let dir = tmp_dir("file");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("input.u32");
        let output = dir.join("sorted.u32");
        let mut rng = Rng::new(0xF17E);
        let data: Vec<u32> = (0..5_000).map(|_| rng.next_u32()).collect();
        let mut f = File::create(&input).unwrap();
        for &k in &data {
            f.write_all(&k.to_le_bytes()).unwrap();
        }
        drop(f);
        let cfg = ExtSortConfig {
            run_len: 333,
            r: 8,
            max_fanin: 4,
            spill_dir: Some(dir.clone()),
        };
        let stats = extsort_file(&input, &output, &cfg).unwrap();
        assert_eq!(stats.keys, data.len());
        assert!(stats.merge_passes >= 1);
        let got: Vec<u32> = std::fs::read(&output)
            .unwrap()
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn degenerate_inputs() {
        let cfg = ExtSortConfig { r: 4, ..Default::default() };
        assert_eq!(extsort(&[], &cfg).unwrap().0, Vec::<u32>::new());
        assert_eq!(extsort(&[9], &cfg).unwrap().0, vec![9]);
        let dup = vec![7u32; 500];
        assert_eq!(extsort(&dup, &cfg).unwrap().0, dup);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(ExtSortConfig { run_len: 0, ..Default::default() }.validate().is_err());
        assert!(ExtSortConfig { max_fanin: 1, ..Default::default() }.validate().is_err());
        assert!(ExtSortConfig { r: 0, ..Default::default() }.validate().is_err());
    }
}
