//! External sorting in bounded memory: pipelined run formation + spill
//! + a streaming k-way merge through the LOMS tile kernels, with a
//! range-partitioned final pass.
//!
//! Phase 1 chunks the input into `run_len`-key runs and sorts each —
//! either directly ([`RunFormer::Std`]) or through the merge-network
//! ladder of a running [`MergeService`] ([`RunFormer::Ladder`], the
//! planner's batch sorters). With `sort_threads > 1` (the default
//! resolves to one per core) the Std path shards run sorting across a
//! worker pool behind a bounded chunk queue, with spill writes on a
//! dedicated sink thread ([`super::io::pipeline`]) — the serial spill
//! layout is reproduced exactly. Runs live in memory or spill to
//! **segmented** files of little-endian `u32` keys, one segment per
//! future merge group, so each pass can unlink consumed segments as it
//! goes instead of holding a full second copy of the data (the rolling
//! ~1·input disk footprint, vs ~2× for a monolithic spill).
//!
//! Phase 2 repeatedly merges groups of at most `max_fanin` runs through
//! [`MergeTree`]; spill reads go through per-run prefetch threads
//! (double buffering, [`super::source::PrefetchRunStream`]) and spill
//! writes through a write-behind thread, so the merge tree never blocks
//! on disk. Phase 3 range-partitions the final merge across
//! `partitions` independent trees ([`super::part`]) writing disjoint
//! regions of the output — byte-identical to the single-tree merge,
//! but scaling with cores.
//!
//! With spilling enabled the resident set is O(`sort_threads`·`run_len`
//! + `partitions`·`max_fanin`·(R + `prefetch_buf`)) keys however large
//! the input — the bounded-memory story the fixed-width merge devices
//! themselves cannot provide.

use super::io::{
    self, encode_keys_into, sidecar_path, spill_io, IoPhase, IoWait, SpillChecksum, SpillGuard,
    WriteBehind,
};
use super::merge2::BlockKernel;
use super::part;
use super::source::{
    boxed, FileRunStream, PrefetchRunStream, SliceStream, SortedStream, SpillRunStream,
};
use super::tree::{MergeTree, TreeStats, DEFAULT_R};
use crate::coordinator::{planner, MergeService};
use crate::obs::HistStats;
use crate::util::fault::{self, Site};
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Keys pulled from the merge tree per drain step.
const DRAIN: usize = 4096;

/// External-sort tuning.
#[derive(Debug, Clone)]
pub struct ExtSortConfig {
    /// Phase-1 run length in keys.
    pub run_len: usize,
    /// Merge-tree block size R (the `loms2` R+R kernel shape).
    pub r: usize,
    /// Maximum runs merged per tree (≥ 2); more runs ⇒ extra passes.
    /// Also the spill-segment size: each segment holds the input of one
    /// future merge group, so passes can unlink segments as they go.
    pub max_fanin: usize,
    /// Spill runs to files under this directory; `None` keeps runs in
    /// memory (merge passes still stream block by block).
    pub spill_dir: Option<PathBuf>,
    /// Phase-1 sort worker threads; `0` = one per core. Applies to
    /// [`RunFormer::Std`] (the ladder former stays serial — it owns the
    /// batching service).
    pub sort_threads: usize,
    /// Final-pass range partitions; `0` = auto (per core, sized by
    /// input), `1` = single merge tree. Output bytes are identical
    /// whatever the value.
    pub partitions: usize,
    /// Keys per prefetch buffer for spill reads; `0` disables the
    /// read-ahead threads (synchronous reads).
    pub prefetch_buf: usize,
    /// Checksum spill segments (per-block CRC-32 sidecars, verified on
    /// read with one bounded re-read on failure). On by default; off
    /// trades integrity for the last few percent of throughput.
    pub verify_spill: bool,
}

impl Default for ExtSortConfig {
    fn default() -> Self {
        ExtSortConfig {
            run_len: 1 << 16,
            r: DEFAULT_R,
            max_fanin: 64,
            spill_dir: None,
            sort_threads: 0,
            partitions: 0,
            prefetch_buf: 1 << 15,
            verify_spill: true,
        }
    }
}

impl ExtSortConfig {
    /// Shape checks plus the one kernel compile every tree of this sort
    /// will share (`r` is validated by the compile itself).
    fn validate(&self) -> Result<BlockKernel> {
        anyhow::ensure!(self.run_len >= 1, "run_len must be >= 1");
        anyhow::ensure!(self.max_fanin >= 2, "max_fanin must be >= 2");
        BlockKernel::new(self.r)
    }
}

/// External-sort accounting.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExtSortStats {
    pub keys: usize,
    /// Phase-1 runs formed.
    pub runs: usize,
    /// Intermediate merge passes (0 when `runs ≤ max_fanin`).
    pub merge_passes: usize,
    /// Runs written to spill files (phase 1 + intermediate passes).
    pub spilled_runs: usize,
    /// Bytes written to spill files.
    pub spill_bytes: u64,
    /// Phase-1 (run formation) wall seconds.
    pub run_form_secs: f64,
    /// Merge wall seconds (intermediate passes + final pass).
    pub merge_secs: f64,
    /// Seconds compute threads spent blocked on disk — synchronous
    /// reads/writes plus stalls waiting on prefetch / write-behind
    /// threads — summed across threads (may exceed wall time).
    pub io_wait_secs: f64,
    /// Range partitions the final pass ran (1 = single merge tree).
    pub partitions: usize,
    /// Spill blocks that failed their checksum (including ones the
    /// bounded re-read then recovered).
    pub corrupt_detected: u64,
    /// Bounded re-reads of spill blocks (recovered or not).
    pub read_retries: u64,
    /// Per-chunk sort latency (phase 1 CPU; not part of
    /// `io_wait_secs`). Behind `loms sort --stats true`.
    pub chunk_sort: HistStats,
    /// Per-buffer spill/output write-stall latency.
    pub spill_write: HistStats,
    /// Per-buffer prefetch-wait latency (compute blocked on read-ahead).
    pub prefetch_wait: HistStats,
    /// Merge-tree scheduling counters pooled across passes/partitions.
    pub tree: TreeStats,
}

impl ExtSortStats {
    /// Drain the shared I/O accounting into the stats block — the
    /// common epilogue of every extsort entry point (key-only and KV,
    /// slice and file).
    pub(crate) fn absorb_wait(&mut self, wait: &IoWait) {
        self.io_wait_secs = wait.secs();
        self.corrupt_detected = wait.corrupt_detected();
        self.read_retries = wait.read_retries();
        self.chunk_sort = wait.phase_stats(IoPhase::ChunkSort);
        self.spill_write = wait.phase_stats(IoPhase::SpillWrite);
        self.prefetch_wait = wait.phase_stats(IoPhase::PrefetchWait);
    }
}

/// How phase 1 sorts each run.
pub enum RunFormer<'a> {
    /// `sort_unstable` per run — handles the full `u32` domain.
    Std,
    /// The merge-network ladder through a running service (the
    /// planner's batch sorters: chunk, merge level by level, stream the
    /// survivors). Inherits the service's key-domain contract (real
    /// keys < `u32::MAX`).
    Ladder { service: &'a MergeService, chunk: usize, max_network: usize },
}

fn sort_run(former: &RunFormer<'_>, keys: &[u32]) -> Result<Vec<u32>> {
    match former {
        RunFormer::Std => {
            let mut v = keys.to_vec();
            v.sort_unstable();
            Ok(v)
        }
        RunFormer::Ladder { service, chunk, max_network } => {
            Ok(planner::external_sort(service, keys, *chunk, *max_network)?.0)
        }
    }
}

/// Monotonic spill-file id — unique across concurrent sorts in one
/// process; the pid keeps parallel processes apart.
fn next_spill_path(dir: &Path) -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let id = NEXT.fetch_add(1, Ordering::Relaxed);
    dir.join(format!("loms-spill-{}-{id}.u32", std::process::id()))
}

/// One spill segment: a file of back-to-back sorted runs, sized to one
/// merge group so the consuming pass can unlink it the moment its last
/// run drains. `runs` are `(start, len)` in records of the segment.
pub(crate) struct SpillSeg {
    pub(crate) path: PathBuf,
    pub(crate) runs: Vec<(u64, u64)>,
}

/// Where encoded spill bytes go: buffered synchronous writes (phase 1's
/// dedicated sink thread is already off the compute path) or a
/// write-behind thread (merge passes, whose writer IS the compute
/// thread).
enum SegSink {
    Buf(BufWriter<File>),
    Behind(WriteBehind),
}

/// Append-only writer for segmented spill files of sorted runs.
/// Rotates to a fresh file every `cap` runs and registers every file
/// (and checksum sidecar) with the [`SpillGuard`] so error paths leave
/// no stragglers. Every failure on this path is a typed
/// [`io::ExtSortError::Spill`] — never a panic: an injected or real
/// ENOSPC propagates out of the sort while the guard unlinks partials.
struct SpillWriter {
    dir: PathBuf,
    guard: SpillGuard,
    wait: IoWait,
    behind: bool,
    /// Checksum segments into `.crc` sidecars as they are written.
    verify: bool,
    /// Runs per segment before rotating (`usize::MAX` = one segment).
    cap: usize,
    sink: Option<(SegSink, PathBuf)>,
    /// Rolling per-block CRC of the open segment (when verifying).
    sum: Option<SpillChecksum>,
    /// Runs of the open segment.
    runs: Vec<(u64, u64)>,
    segs: Vec<SpillSeg>,
    /// Keys written into the open segment.
    pos: u64,
    /// Start of the open run, if any.
    cur: Option<u64>,
    /// Reusable LE-encoding buffer for the synchronous sink.
    bytes: Vec<u8>,
}

impl SpillWriter {
    fn new(
        dir: PathBuf,
        cap: usize,
        behind: bool,
        verify: bool,
        guard: SpillGuard,
        wait: IoWait,
    ) -> SpillWriter {
        SpillWriter {
            dir,
            guard,
            wait,
            behind,
            verify,
            cap: cap.max(1),
            sink: None,
            sum: None,
            runs: Vec::new(),
            segs: Vec::new(),
            pos: 0,
            cur: None,
            bytes: Vec::new(),
        }
    }

    fn open_seg(&mut self) -> Result<()> {
        let path = next_spill_path(&self.dir);
        let f = File::create(&path).map_err(|e| spill_io(e, "creating spill file", &path))?;
        self.guard.register(&path);
        let sink = if self.behind {
            SegSink::Behind(
                WriteBehind::spawn(f, self.wait.clone())
                    .map_err(|e| spill_io(e, "starting write-behind for", &path))?,
            )
        } else {
            SegSink::Buf(BufWriter::new(f))
        };
        self.sum = self.verify.then(|| SpillChecksum::new(4));
        self.sink = Some((sink, path));
        Ok(())
    }

    fn begin_run(&mut self) -> Result<()> {
        debug_assert!(self.cur.is_none());
        if self.sink.is_none() {
            self.open_seg()?;
        }
        self.cur = Some(self.pos);
        Ok(())
    }

    fn write_keys(&mut self, keys: &[u32]) -> Result<()> {
        let SpillWriter { sink, bytes, wait, pos, sum, .. } = self;
        let Some((sink, path)) = sink.as_mut() else {
            anyhow::bail!("spill write outside an open segment");
        };
        if fault::fires(Site::SpillWriteEnospc) {
            return Err(spill_io(fault::enospc(), "writing spill run to", path));
        }
        match sink {
            SegSink::Buf(w) => {
                encode_keys_into(keys, bytes);
                if let Some(sum) = sum.as_mut() {
                    sum.update(bytes);
                }
                wait.timed_phase(IoPhase::SpillWrite, || w.write_all(bytes))
                    .map_err(|e| spill_io(e, "writing spill run to", path))?;
            }
            SegSink::Behind(wb) => {
                let mut b = wb.buffer();
                encode_keys_into(keys, &mut b);
                if let Some(sum) = sum.as_mut() {
                    sum.update(&b);
                }
                wb.submit(b).map_err(|e| spill_io(e, "writing spill run to", path))?;
            }
        }
        *pos += keys.len() as u64;
        Ok(())
    }

    fn end_run(&mut self) -> Result<()> {
        let Some(start) = self.cur.take() else {
            anyhow::bail!("spill run closed without begin_run");
        };
        self.runs.push((start, self.pos - start));
        if self.runs.len() >= self.cap {
            self.close_seg()?;
        }
        Ok(())
    }

    fn push_run(&mut self, keys: &[u32]) -> Result<()> {
        self.begin_run()?;
        self.write_keys(keys)?;
        self.end_run()
    }

    fn close_seg(&mut self) -> Result<()> {
        let Some((sink, path)) = self.sink.take() else { return Ok(()) };
        match sink {
            SegSink::Buf(mut w) => self
                .wait
                .timed(|| w.flush())
                .map_err(|e| spill_io(e, "flushing spill segment", &path))?,
            SegSink::Behind(wb) => {
                wb.finish().map_err(|e| spill_io(e, "flushing spill segment", &path))?
            }
        }
        if let Some(sum) = self.sum.take() {
            let side = sidecar_path(&path);
            self.guard.register(&side);
            let entries = sum.finish();
            self.wait
                .timed(|| std::fs::write(&side, &entries))
                .map_err(|e| spill_io(e, "writing spill sidecar", &side))?;
        }
        self.segs.push(SpillSeg { path, runs: std::mem::take(&mut self.runs) });
        self.pos = 0;
        Ok(())
    }

    fn finish(mut self) -> Result<Vec<SpillSeg>> {
        self.close_seg()?;
        Ok(std::mem::take(&mut self.segs))
    }
}

/// Where the current generation of runs lives.
enum RunStore {
    Mem(Vec<Vec<u32>>),
    Files(Vec<SpillSeg>),
}

/// Open one spill run as a stream. With `verify` the read goes through
/// the checksum-verifying [`SpillRunStream`] (block-aligned, bounded
/// re-read recovery); otherwise raw reads — prefetched (double-buffered
/// reader thread) when a buffer is configured and the run outgrows it,
/// synchronous otherwise.
fn open_key_run(
    path: &Path,
    start: u64,
    len: u64,
    prefetch: usize,
    verify: bool,
    wait: &IoWait,
) -> Result<Box<dyn SortedStream + 'static>> {
    if verify {
        let pf = if len <= prefetch as u64 { 0 } else { prefetch };
        Ok(boxed(SpillRunStream::open(path, start, len, pf, wait.clone())?))
    } else if prefetch == 0 || len <= prefetch as u64 {
        Ok(boxed(FileRunStream::open(path, start, len)?))
    } else {
        Ok(boxed(PrefetchRunStream::open(path, start, len, prefetch, wait.clone())?))
    }
}

impl RunStore {
    fn count(&self) -> usize {
        match self {
            RunStore::Mem(runs) => runs.len(),
            RunStore::Files(segs) => segs.iter().map(|s| s.runs.len()).sum(),
        }
    }

    /// Flatten the segmented layout into `(path, start, len)` per run,
    /// in global run order.
    fn flat_runs(&self) -> Vec<(&Path, u64, u64)> {
        match self {
            RunStore::Mem(_) => Vec::new(),
            RunStore::Files(segs) => segs
                .iter()
                .flat_map(|s| s.runs.iter().map(|&(start, len)| (s.path.as_path(), start, len)))
                .collect(),
        }
    }

    /// Open streams over runs `[lo, hi)`.
    fn open(
        &self,
        lo: usize,
        hi: usize,
        prefetch: usize,
        verify: bool,
        wait: &IoWait,
    ) -> Result<Vec<Box<dyn SortedStream + '_>>> {
        match self {
            RunStore::Mem(runs) => {
                Ok(runs[lo..hi].iter().map(|r| boxed(SliceStream::new(r))).collect())
            }
            RunStore::Files(_) => self.flat_runs()[lo..hi]
                .iter()
                .map(|&(path, start, len)| open_key_run(path, start, len, prefetch, verify, wait))
                .collect(),
        }
    }

    /// Unlink any remaining spill segments and sidecars (the
    /// clean-finish path; the guard also covers them on early exits).
    fn cleanup(self, guard: &SpillGuard) {
        if let RunStore::Files(segs) = self {
            for seg in segs {
                io::remove_seg(guard, &seg.path);
            }
        }
    }
}

/// Drain a tree into `out`, pooling its scheduling counters and handing
/// the shared kernel back for the next tree.
fn drain_to_vec(
    mut tree: MergeTree<'_>,
    out: &mut Vec<u32>,
    tstats: &mut TreeStats,
) -> Result<BlockKernel> {
    while tree.next_chunk(DRAIN, out)? > 0 {}
    tstats.absorb(tree.stats());
    Ok(tree.into_kernel())
}

/// One intermediate pass: merge groups of `max_fanin` runs into the
/// next generation (memory→memory or spill→spill), unlinking each
/// consumed spill segment as soon as its last run drains — the rolling
/// pass that keeps the disk footprint near one copy of the data. The
/// kernel threads through every tree of the pass.
fn merge_pass(
    store: RunStore,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    mut kernel: BlockKernel,
    guard: &SpillGuard,
    wait: &IoWait,
) -> Result<(RunStore, BlockKernel)> {
    let count = store.count();
    match store {
        RunStore::Mem(_) => {
            let mut runs = Vec::with_capacity(count.div_ceil(cfg.max_fanin));
            let mut lo = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut run = Vec::new();
                let tree = MergeTree::with_kernel(
                    store.open(lo, hi, cfg.prefetch_buf, cfg.verify_spill, wait)?,
                    kernel,
                );
                kernel = drain_to_vec(tree, &mut run, &mut stats.tree)?;
                runs.push(run);
                lo = hi;
            }
            Ok((RunStore::Mem(runs), kernel))
        }
        RunStore::Files(ref segs) => {
            let dir = segs
                .first()
                .and_then(|s| s.path.parent())
                .map(Path::to_path_buf)
                .unwrap_or_else(|| PathBuf::from("."));
            // Per-segment global end index, for unlink-as-consumed.
            let seg_ends: Vec<usize> = segs
                .iter()
                .scan(0usize, |acc, s| {
                    *acc += s.runs.len();
                    Some(*acc)
                })
                .collect();
            let mut w = SpillWriter::new(
                dir,
                cfg.max_fanin,
                true,
                cfg.verify_spill,
                guard.clone(),
                wait.clone(),
            );
            let mut chunk = Vec::with_capacity(DRAIN);
            let mut lo = 0;
            let mut consumed_segs = 0;
            while lo < count {
                let hi = (lo + cfg.max_fanin).min(count);
                let mut tree = MergeTree::with_kernel(
                    store.open(lo, hi, cfg.prefetch_buf, cfg.verify_spill, wait)?,
                    kernel,
                );
                w.begin_run()?;
                loop {
                    chunk.clear();
                    if tree.next_chunk(DRAIN, &mut chunk)? == 0 {
                        break;
                    }
                    w.write_keys(&chunk)?;
                }
                w.end_run()?;
                stats.tree.absorb(tree.stats());
                kernel = tree.into_kernel();
                // Roll the footprint: every segment whose runs are all
                // merged is dead weight — unlink it now, not pass-end.
                if let RunStore::Files(segs) = &store {
                    while consumed_segs < segs.len() && seg_ends[consumed_segs] <= hi {
                        io::remove_seg(guard, &segs[consumed_segs].path);
                        consumed_segs += 1;
                    }
                }
                lo = hi;
            }
            let segs_out = w.finish()?;
            stats.spilled_runs += segs_out.iter().map(|s| s.runs.len()).sum::<usize>();
            stats.spill_bytes += segs_out
                .iter()
                .flat_map(|s| s.runs.iter())
                .map(|&(_, len)| len * 4)
                .sum::<u64>();
            Ok((RunStore::Files(segs_out), kernel))
        }
    }
}

/// Sort `data` with default run formation (`sort_unstable` per run).
pub fn extsort(data: &[u32], cfg: &ExtSortConfig) -> Result<(Vec<u32>, ExtSortStats)> {
    extsort_with(data, cfg, &RunFormer::Std)
}

/// Phase-1 run formation over an in-memory slice, sharded across
/// `threads` scoped workers on contiguous chunk groups (order
/// preserved by construction).
fn form_runs_mem(
    data: &[u32],
    run_len: usize,
    threads: usize,
    wait: &IoWait,
) -> Result<Vec<Vec<u32>>> {
    let chunks: Vec<&[u32]> = data.chunks(run_len).collect();
    let sort_one = |c: &&[u32]| {
        wait.timed_phase(IoPhase::ChunkSort, || {
            let mut v = c.to_vec();
            v.sort_unstable();
            v
        })
    };
    if threads <= 1 || chunks.len() <= 1 {
        return Ok(chunks.iter().map(sort_one).collect());
    }
    let per = chunks.len().div_ceil(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = chunks
            .chunks(per)
            .map(|group| s.spawn(move || group.iter().map(sort_one).collect::<Vec<_>>()))
            .collect();
        let mut runs = Vec::with_capacity(chunks.len());
        for h in handles {
            runs.extend(h.join().map_err(|_| anyhow::anyhow!("run-sort worker panicked"))?);
        }
        Ok(runs)
    })
}

/// Sort `data`: form runs with `former`, optionally spill them, merge
/// pass by pass, stream the final k-way merge into a `Vec` (the final
/// pass range-partitions across cores when the runs are in memory).
pub fn extsort_with(
    data: &[u32],
    cfg: &ExtSortConfig,
    former: &RunFormer<'_>,
) -> Result<(Vec<u32>, ExtSortStats)> {
    let mut kernel = cfg.validate()?;
    let mut stats = ExtSortStats { keys: data.len(), ..Default::default() };
    if data.is_empty() {
        stats.partitions = 1;
        return Ok((Vec::new(), stats));
    }
    let guard = SpillGuard::new();
    let wait = IoWait::new();
    let threads = part::resolve_threads(cfg.sort_threads);
    let parallel_std = threads > 1 && matches!(former, RunFormer::Std);
    let t0 = Instant::now();
    let mut store = match &cfg.spill_dir {
        None => RunStore::Mem(match former {
            RunFormer::Std => form_runs_mem(data, cfg.run_len, threads, &wait)?,
            RunFormer::Ladder { .. } => data
                .chunks(cfg.run_len)
                .map(|c| wait.timed_phase(IoPhase::ChunkSort, || sort_run(former, c)))
                .collect::<Result<_>>()?,
        }),
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spill dir {}", dir.display()))?;
            let w = SpillWriter::new(
                dir.clone(),
                cfg.max_fanin,
                false,
                cfg.verify_spill,
                guard.clone(),
                wait.clone(),
            );
            let segs = if parallel_std {
                let mut chunks = data.chunks(cfg.run_len);
                let wait = &wait;
                io::pipeline(
                    threads,
                    || Ok(chunks.next()),
                    |c: &[u32]| {
                        wait.timed_phase(IoPhase::ChunkSort, || {
                            let mut v = c.to_vec();
                            v.sort_unstable();
                            v
                        })
                    },
                    w,
                    |w, run| w.push_run(&run),
                )?
                .finish()?
            } else {
                let mut w = w;
                for c in data.chunks(cfg.run_len) {
                    let run = wait.timed_phase(IoPhase::ChunkSort, || sort_run(former, c))?;
                    w.push_run(&run)?;
                }
                w.finish()?
            };
            stats.spilled_runs += segs.iter().map(|s| s.runs.len()).sum::<usize>();
            stats.spill_bytes += 4 * data.len() as u64;
            RunStore::Files(segs)
        }
    };
    stats.runs = store.count();
    stats.run_form_secs = t0.elapsed().as_secs_f64();
    let tm = Instant::now();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass(store, cfg, &mut stats, kernel, &guard, &wait)?;
        stats.merge_passes += 1;
    }
    let out = match &store {
        RunStore::Mem(runs)
            if runs.len() > 1 && part::resolve_partitions(cfg.partitions, data.len()) > 1 =>
        {
            let (out, nparts, tstats) =
                part::merge_runs_parallel_stats(runs, cfg.r, cfg.partitions)?;
            stats.partitions = nparts;
            stats.tree.absorb(tstats);
            out
        }
        _ => {
            let mut out = Vec::with_capacity(data.len());
            let streams = store.open(0, store.count(), cfg.prefetch_buf, cfg.verify_spill, &wait)?;
            let _ = drain_to_vec(MergeTree::with_kernel(streams, kernel), &mut out, &mut stats.tree)?;
            stats.partitions = 1;
            out
        }
    };
    store.cleanup(&guard);
    stats.merge_secs = tm.elapsed().as_secs_f64();
    stats.absorb_wait(&wait);
    Ok((out, stats))
}

/// Phase 3 of a file sort: merge the surviving runs straight into
/// `output`. With more than one partition, sample the runs, cut every
/// run at the pivot boundaries (exact — runs are sorted), pre-size the
/// output, and merge each key range on its own thread into its own
/// disjoint region of the file; otherwise one tree + write-behind.
fn final_merge_file(
    store: &RunStore,
    output: &Path,
    total: u64,
    cfg: &ExtSortConfig,
    stats: &mut ExtSortStats,
    wait: &IoWait,
    kernel: BlockKernel,
) -> Result<()> {
    let runs = store.flat_runs();
    let parts = part::resolve_partitions(cfg.partitions, total as usize);
    if parts <= 1 || runs.len() <= 1 || total == 0 {
        let f = File::create(output)
            .with_context(|| format!("creating {}", output.display()))?;
        let mut wb = WriteBehind::spawn(f, wait.clone()).context("starting output writer")?;
        let mut tree = MergeTree::with_kernel(
            store.open(0, store.count(), cfg.prefetch_buf, cfg.verify_spill, wait)?,
            kernel,
        );
        let mut chunk = Vec::with_capacity(DRAIN);
        loop {
            chunk.clear();
            if tree.next_chunk(DRAIN, &mut chunk)? == 0 {
                break;
            }
            let mut b = wb.buffer();
            encode_keys_into(&chunk, &mut b);
            wb.submit(b).context("writing sorted output")?;
        }
        stats.tree.absorb(tree.stats());
        wb.finish().context("writing sorted output")?;
        stats.partitions = 1;
        return Ok(());
    }
    // Sample every run, pick pivots at the pooled quantiles, cut.
    let mut samples = Vec::new();
    for &(path, start, len) in &runs {
        part::FileCutter::open(path, start, len, 4)?.sample_into(&mut samples)?;
    }
    let pivots = part::pivots_from_samples(samples, parts);
    let cuts: Vec<Vec<u64>> = runs
        .iter()
        .map(|&(path, start, len)| part::FileCutter::open(path, start, len, 4)?.cuts(&pivots))
        .collect::<Result<_>>()?;
    // Cut rows must be monotone — binary search over *unsorted* (i.e.
    // corrupted-on-disk) run data can violate that, and the sizes below
    // would underflow. Verified reads still catch the corruption; this
    // guard just fails first with a diagnosis instead of wrapping.
    for (c, &(path, _, len)) in cuts.iter().zip(&runs) {
        anyhow::ensure!(
            c.windows(2).all(|w| w[0] <= w[1]) && c.last().is_none_or(|&e| e <= len),
            "non-monotone partition cuts for {} (corrupt spill data?)",
            path.display()
        );
    }
    let nparts = pivots.len() + 1;
    let sizes: Vec<u64> =
        (0..nparts).map(|p| cuts.iter().map(|c| c[p + 1] - c[p]).sum()).collect();
    let mut offs = Vec::with_capacity(nparts);
    let mut acc = 0u64;
    for &sz in &sizes {
        offs.push(acc);
        acc += sz;
    }
    anyhow::ensure!(acc == total, "partition cuts lost keys ({acc} of {total})");
    // Pre-size the output so P writers can target disjoint regions.
    File::create(output)
        .and_then(|f| f.set_len(total * 4))
        .with_context(|| format!("creating {}", output.display()))?;
    let (runs, cuts, sizes, offs) = (&runs, &cuts, &sizes, &offs);
    let part_stats = std::thread::scope(|s| {
        let handles: Vec<_> = (0..nparts)
            .filter(|&p| sizes[p] > 0)
            .map(|p| {
                s.spawn(move || -> Result<TreeStats> {
                    let mut f = File::options()
                        .write(true)
                        .open(output)
                        .with_context(|| format!("opening {} region", output.display()))?;
                    f.seek(SeekFrom::Start(offs[p] * 4))?;
                    let mut wb =
                        WriteBehind::spawn(f, wait.clone()).context("starting output writer")?;
                    let streams: Vec<Box<dyn SortedStream + '_>> = runs
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| cuts[*i][p + 1] > cuts[*i][p])
                        .map(|(i, &(path, start, _))| {
                            open_key_run(
                                path,
                                start + cuts[i][p],
                                cuts[i][p + 1] - cuts[i][p],
                                cfg.prefetch_buf,
                                cfg.verify_spill,
                                wait,
                            )
                        })
                        .collect::<Result<_>>()?;
                    let mut tree = MergeTree::new(streams, cfg.r)?;
                    let mut chunk = Vec::with_capacity(DRAIN);
                    let mut written = 0u64;
                    loop {
                        chunk.clear();
                        let n = tree.next_chunk(DRAIN, &mut chunk)?;
                        if n == 0 {
                            break;
                        }
                        let mut b = wb.buffer();
                        encode_keys_into(&chunk, &mut b);
                        wb.submit(b).context("writing sorted output")?;
                        written += n as u64;
                    }
                    anyhow::ensure!(
                        written == sizes[p],
                        "partition {p} wrote {written} of {} keys",
                        sizes[p]
                    );
                    wb.finish().context("writing sorted output")?;
                    Ok(tree.stats())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("partition merge panicked"))?)
            .collect::<Result<Vec<TreeStats>>>()
    })?;
    for st in part_stats {
        stats.tree.absorb(st);
    }
    stats.partitions = nparts;
    Ok(())
}

/// Sort a file of little-endian `u32` keys into `output`, never holding
/// more than O(`sort_threads`·`run_len` + `partitions`·`max_fanin`·R)
/// keys in memory. Runs spill under `cfg.spill_dir` (defaulting to
/// `output`'s directory); spill files are unlinked even when the sort
/// fails partway. Backs the `loms sort --input/--output` CLI path.
pub fn extsort_file(input: &Path, output: &Path, cfg: &ExtSortConfig) -> Result<ExtSortStats> {
    let mut kernel = cfg.validate()?;
    let bytes = std::fs::metadata(input)
        .with_context(|| format!("stat {}", input.display()))?
        .len();
    anyhow::ensure!(bytes % 4 == 0, "{}: not a whole number of u32 keys", input.display());
    let total = bytes / 4;
    let mut stats = ExtSortStats { keys: total as usize, ..Default::default() };
    let dir = cfg
        .spill_dir
        .clone()
        .or_else(|| output.parent().map(Path::to_path_buf).filter(|p| !p.as_os_str().is_empty()))
        .unwrap_or_else(|| PathBuf::from("."));
    std::fs::create_dir_all(&dir).with_context(|| format!("creating spill dir {}", dir.display()))?;
    let guard = SpillGuard::new();
    let wait = IoWait::new();
    let threads = part::resolve_threads(cfg.sort_threads);
    let t0 = Instant::now();
    // Phase 1: read run_len-key windows in order, sort across the
    // worker pool, spill in order from the sink thread.
    let mut store = {
        let mut rd = BufReader::with_capacity(
            1 << 20,
            File::open(input).with_context(|| format!("opening {}", input.display()))?,
        );
        let mut remaining = total;
        let produce = || -> Result<Option<Vec<u32>>> {
            if remaining == 0 {
                return Ok(None);
            }
            let n = (cfg.run_len as u64).min(remaining) as usize;
            let mut buf = vec![0u8; n * 4];
            wait.timed(|| rd.read_exact(&mut buf)).context("reading input keys")?;
            let mut keys = Vec::with_capacity(n);
            io::decode_keys_into(&buf, &mut keys);
            remaining -= n as u64;
            Ok(Some(keys))
        };
        let w = SpillWriter::new(
            dir.clone(),
            cfg.max_fanin,
            false,
            cfg.verify_spill,
            guard.clone(),
            wait.clone(),
        );
        let segs = if threads > 1 {
            let wait = &wait;
            io::pipeline(
                threads,
                produce,
                |mut keys: Vec<u32>| {
                    wait.timed_phase(IoPhase::ChunkSort, || keys.sort_unstable());
                    keys
                },
                w,
                |w, run| w.push_run(&run),
            )?
            .finish()?
        } else {
            let mut w = w;
            let mut produce = produce;
            while let Some(mut keys) = produce()? {
                wait.timed_phase(IoPhase::ChunkSort, || keys.sort_unstable());
                w.push_run(&keys)?;
            }
            w.finish()?
        };
        stats.spilled_runs += segs.iter().map(|s| s.runs.len()).sum::<usize>();
        stats.spill_bytes += bytes;
        RunStore::Files(segs)
    };
    stats.runs = store.count();
    stats.run_form_secs = t0.elapsed().as_secs_f64();
    let tm = Instant::now();
    while store.count() > cfg.max_fanin {
        (store, kernel) = merge_pass(store, cfg, &mut stats, kernel, &guard, &wait)?;
        stats.merge_passes += 1;
    }
    // Phase 3: partition-parallel merge straight into the output file.
    final_merge_file(&store, output, total, cfg, &mut stats, &wait, kernel)?;
    store.cleanup(&guard);
    stats.merge_secs = tm.elapsed().as_secs_f64();
    stats.absorb_wait(&wait);
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("loms_extsort_{tag}_{}", std::process::id()))
    }

    #[test]
    fn in_memory_sort_matches_std() {
        let mut rng = Rng::new(0xE5);
        let data: Vec<u32> = (0..10_000).map(|_| rng.next_u32()).collect();
        let cfg = ExtSortConfig { run_len: 700, r: 8, ..Default::default() };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(stats.runs, 10_000usize.div_ceil(700));
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.spilled_runs, 0);
    }

    #[test]
    fn multi_pass_spill_sort_matches_std() {
        let dir = tmp_dir("multipass");
        let mut rng = Rng::new(0x5111);
        // Full-domain keys, u32::MAX included (Std former).
        let mut data: Vec<u32> = (0..20_000).map(|_| rng.next_u32()).collect();
        data.extend([u32::MAX, u32::MAX - 1, u32::MAX]);
        let cfg = ExtSortConfig {
            run_len: 512,
            r: 8,
            max_fanin: 3,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        assert!(stats.merge_passes >= 2, "fanin 3 over {} runs: {stats:?}", stats.runs);
        assert!(stats.spilled_runs > stats.runs, "intermediate runs spilled too");
        assert!(stats.spill_bytes > 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn file_to_file_round_trip() {
        let dir = tmp_dir("file");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("input.u32");
        let output = dir.join("sorted.u32");
        let mut rng = Rng::new(0xF17E);
        let data: Vec<u32> = (0..5_000).map(|_| rng.next_u32()).collect();
        let mut f = File::create(&input).unwrap();
        for &k in &data {
            f.write_all(&k.to_le_bytes()).unwrap();
        }
        drop(f);
        let cfg = ExtSortConfig {
            run_len: 333,
            r: 8,
            max_fanin: 4,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        };
        let stats = extsort_file(&input, &output, &cfg).unwrap();
        assert_eq!(stats.keys, data.len());
        assert!(stats.merge_passes >= 1);
        assert!(stats.partitions >= 1);
        let got: Vec<u32> = std::fs::read(&output)
            .unwrap()
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut want = data;
        want.sort_unstable();
        assert_eq!(got, want);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn degenerate_inputs() {
        let cfg = ExtSortConfig { r: 4, ..Default::default() };
        assert_eq!(extsort(&[], &cfg).unwrap().0, Vec::<u32>::new());
        assert_eq!(extsort(&[9], &cfg).unwrap().0, vec![9]);
        let dup = vec![7u32; 500];
        assert_eq!(extsort(&dup, &cfg).unwrap().0, dup);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        assert!(ExtSortConfig { run_len: 0, ..Default::default() }.validate().is_err());
        assert!(ExtSortConfig { max_fanin: 1, ..Default::default() }.validate().is_err());
        assert!(ExtSortConfig { r: 0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn phase_timings_are_populated() {
        let dir = tmp_dir("timings");
        let mut rng = Rng::new(0x7131);
        let data: Vec<u32> = (0..30_000).map(|_| rng.next_u32()).collect();
        let cfg = ExtSortConfig {
            run_len: 1024,
            r: 8,
            max_fanin: 4,
            spill_dir: Some(dir.clone()),
            sort_threads: 2,
            ..Default::default()
        };
        let (got, stats) = extsort(&data, &cfg).unwrap();
        assert_eq!(got.len(), data.len());
        assert!(stats.run_form_secs > 0.0);
        assert!(stats.merge_secs > 0.0);
        assert!(stats.io_wait_secs >= 0.0);
        assert!(stats.partitions >= 1);
        assert!(stats.tree.kernel_rows > 0, "{:?}", stats.tree);
        // Per-phase histograms: every chunk sort and spill write is
        // recorded (one histogram sample per chunk / buffer).
        assert_eq!(stats.chunk_sort.count as usize, 30_000usize.div_ceil(1024));
        assert!(stats.spill_write.count > 0, "{:?}", stats.spill_write);
        assert!(stats.chunk_sort.max_us >= stats.chunk_sort.p50_us);
        let _ = std::fs::remove_dir_all(dir);
    }
}
